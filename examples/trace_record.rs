//! Record a Chrome trace from a live TCP server → `TRACE_sched.json`
//! (the flight-recorder end-to-end path CI exercises in the scheduler
//! matrix).
//!
//! Spawns the bench sweep's mock-backend coordinator (no model
//! artifacts needed), enables trace sampling, serves it over TCP,
//! drives concurrent generation clients (each a persistent
//! [`server::Client`] connection), snapshots the recorder with the
//! `trace` control line, validates the Chrome shape (one `recv` and one
//! `retire` event per request), and writes the JSON for Perfetto.
//!
//!     cargo run --release --example trace_record [out.json]
//!
//! Topology follows the scheduler-matrix env knobs (`PPD_TEST_WORKERS`,
//! `PPD_TEST_FUSE`, `PPD_TEST_SHARED`, `PPD_TEST_PIPELINED`), so every
//! matrix cell records its own topology's trace.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use ppd::bench::{spawn_sweep_coordinator, SweepConfig, SweepMode};
use ppd::coordinator::server;

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

fn main() -> Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "TRACE_sched.json".into());
    let workers: usize =
        std::env::var("PPD_TEST_WORKERS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let mode = if env_flag("PPD_TEST_PIPELINED") {
        SweepMode::Pipelined
    } else if env_flag("PPD_TEST_SHARED") {
        SweepMode::Shared
    } else if env_flag("PPD_TEST_FUSE") {
        SweepMode::Fused
    } else {
        SweepMode::Serial
    };
    let cfg = SweepConfig {
        mode,
        workers,
        max_inflight: 4,
        requests: 16,
        max_new: 8,
        device_latency: Duration::from_micros(200),
    };
    let (requests, max_new) = (cfg.requests, cfg.max_new);
    let coord = spawn_sweep_coordinator(&cfg)?;
    coord.tracer().set_enabled(true);

    let addr = "127.0.0.1:17951";
    // one connection per generation plus the trace scrape, then serve
    // returns and the join below surfaces any server-side error
    let srv = std::thread::spawn(move || server::serve(coord, addr, Some(requests as u64 + 1)));
    std::thread::sleep(Duration::from_millis(200));

    let clients = 4usize;
    std::thread::scope(|s| -> Result<()> {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || -> Result<()> {
                    // one persistent connection per client thread; every
                    // request on it reuses the same socket
                    let mut client = server::Client::connect(addr)?;
                    for i in 0..requests / clients {
                        let resp =
                            client.request(&format!("trace record {c}/{i}"), max_new)?;
                        if let Some(e) = resp.json().get("error") {
                            bail!("request {c}/{i} failed: {e}");
                        }
                    }
                    Ok(())
                })
            })
            .collect();
        for h in handles {
            match h.join() {
                Ok(r) => r?,
                Err(_) => bail!("client thread panicked"),
            }
        }
        Ok(())
    })?;

    let trace = server::client_trace(addr).context("trace scrape")?;
    match srv.join() {
        Ok(r) => r.context("server exit")?,
        Err(_) => bail!("server thread panicked"),
    }

    let events = trace.req("traceEvents")?.as_arr()?;
    let (mut recv, mut retire) = (0usize, 0usize);
    for e in events {
        match e.get("name").and_then(|n| n.as_str().ok()) {
            Some("recv") => recv += 1,
            Some("retire") => retire += 1,
            _ => {}
        }
    }
    if recv != requests || retire != requests {
        bail!("expected {requests} recv + retire events, got recv={recv} retire={retire}");
    }
    let dropped = trace.req("otherData")?.req("dropped_events")?.as_f64()?;
    println!(
        "{} workers={workers} : {} trace events ({recv} requests), {dropped} dropped",
        mode.name(),
        events.len(),
    );
    std::fs::write(&out, format!("{trace}\n")).with_context(|| format!("writing {out}"))?;
    println!("wrote {out} — load it at https://ui.perfetto.dev");
    Ok(())
}
