//! Quickstart: load a trained model from `artifacts/`, generate with
//! vanilla decoding and with PPD, and show the speed accounting.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::{build_engine, EngineKind};
use ppd::decoding::vanilla::VanillaEngine;
use ppd::decoding::DecodeEngine;
use ppd::runtime::Runtime;
use ppd::workload::{decode, encode};

fn main() -> Result<()> {
    let root = std::path::PathBuf::from("artifacts");
    let model = std::env::args().nth(1).unwrap_or_else(|| "ppd-m".into());
    let paths = ArtifactPaths::new(root, &model);

    println!("loading {model} (HLO buckets + weights via PJRT)...");
    let rt = Runtime::load(&paths)?;
    println!(
        "  {} params, {} prompt-token params ({:.5}% trainable — the paper's P_tr)",
        rt.cfg.param_count,
        rt.cfg.prompt_param_count,
        100.0 * rt.cfg.trainable_fraction()
    );

    let prompt = encode("user: what is your favorite color?\nassistant:");
    let max_new = 48;

    let mut vanilla = VanillaEngine::new(&rt, 0.0, 0);
    let a = vanilla.generate(&prompt, max_new)?;
    println!("\n[vanilla] {:.1} tok/s, {} steps", a.throughput(), a.steps);
    println!("{}", decode(&a.tokens));

    let cfg = ServeConfig::default();
    let mut engine = build_engine(EngineKind::Ppd, &rt, None, &paths, &cfg, 0)?;
    let b = engine.generate(&prompt, max_new)?;
    println!(
        "\n[ppd] {:.1} tok/s, {} steps, tau={:.2} (tokens per forward pass)",
        b.throughput(),
        b.steps,
        b.tau()
    );
    println!("{}", decode(&b.tokens));

    assert_eq!(a.tokens, b.tokens, "greedy PPD must match vanilla exactly");
    println!(
        "\noutputs identical ✓ — PPD used {} forward passes instead of {} ({:.2}x fewer)",
        b.steps,
        a.steps,
        a.steps as f64 / b.steps as f64
    );
    Ok(())
}
