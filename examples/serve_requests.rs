//! End-to-end serving driver (the repo's E2E validation example):
//! spawns the multi-worker coordinator, loads the trained model,
//! replays the chat/math/code serving traces as concurrent request
//! batches through the full stack (queue -> worker engines -> PJRT ->
//! verification -> KV compaction, caches pooled), and reports
//! latency/throughput like a serving benchmark.
//!
//!     cargo run --release --example serve_requests [model] [engine] [workers] [fuse|shared]
//!
//! Pass `fuse` as the 4th argument to batch every in-flight tree step
//! into one device call per tick, or `shared` to additionally route
//! every worker's tick through ONE device dispatcher (one runtime, one
//! device queue — `--shared-runtime`); the final device line reports
//! forwards-per-token either way, which is where the batching win
//! shows up.

use std::time::Duration;
use std::time::Instant;

use anyhow::Result;

use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::{Coordinator, EngineKind, Request, SchedPolicy};
use ppd::metrics::ServeReport;
use ppd::util::bench::Table;
use ppd::workload::load_trace;

fn main() -> Result<()> {
    let root = std::path::PathBuf::from("artifacts");
    let model = std::env::args().nth(1).unwrap_or_else(|| "ppd-m".into());
    let engine = std::env::args().nth(2).unwrap_or_else(|| "ppd".into());
    let workers: usize = std::env::args()
        .nth(3)
        .map(|w| w.parse().expect("workers must be a number"))
        .unwrap_or(2);
    let kind = EngineKind::parse(&engine)?;
    let mode = std::env::args().nth(4).unwrap_or_default();
    let fuse_steps = mode == "fuse";
    let shared_runtime = mode == "shared";
    let max_new = 48;

    let cfg = ServeConfig { n_candidates: 6, n_prompt_budget: 10, ..Default::default() };
    println!(
        "spawning coordinator: model={model} engine={engine} workers={workers} \
         fuse={fuse_steps} shared={shared_runtime}"
    );
    let draft = matches!(kind, EngineKind::Spec | EngineKind::SpecPpd).then(|| "ppd-d".to_string());
    let coord = Coordinator::spawn_with_policy(
        root.clone(),
        model.clone(),
        draft,
        kind,
        cfg,
        workers,
        SchedPolicy { fuse_steps, shared_runtime, ..Default::default() },
    )?;

    let mut table = Table::new(&["task", "reqs", "tok", "tok/s", "mean tau", "p50 lat (ms)", "p95 lat (ms)"]);
    let paths = ArtifactPaths::new(root, &model);
    let mut grand = ServeReport::new();
    let t_all = Instant::now();
    for task in ["chat", "math", "code"] {
        let trace = load_trace(&paths.trace(task))?;
        let mut report = ServeReport::new();
        let t0 = Instant::now();
        // submit the whole batch up front: workers drain it concurrently
        // and run_batch reassembles the out-of-order completions by id
        let reqs: Vec<Request> = trace
            .iter()
            .take(16)
            .enumerate()
            .map(|(id, item)| {
                Request::builder(item.prompt.clone()).id(id as u64).max_new(max_new).build()
            })
            .collect();
        let resps = coord.run_batch(reqs)?;
        for resp in &resps {
            assert!(resp.is_ok(), "{:?}", resp.error_msg());
            let t = resp.timing;
            let latency = Duration::from_secs_f64(t.queue_s + t.prefill_s + t.decode_s);
            report.record_request(resp.tokens().len(), resp.steps(), latency);
            grand.record_request(resp.tokens().len(), resp.steps(), latency);
        }
        report.wall_s = t0.elapsed().as_secs_f64();
        let h = report.request_latency.as_ref().unwrap();
        table.row(&[
            task.to_string(),
            format!("{}", report.requests),
            format!("{}", report.generated_tokens),
            format!("{:.1}", report.throughput_tok_s()),
            format!("{:.2}", report.mean_tau()),
            format!("{:.0}", h.quantile_s(0.5) * 1e3),
            format!("{:.0}", h.quantile_s(0.95) * 1e3),
        ]);
    }
    grand.wall_s = t_all.elapsed().as_secs_f64();
    grand.absorb_queue_stats(coord.queue_stats());
    table.print();
    println!("\noverall: {}", grand.to_json());
    println!(
        "queue: {}  caches created: {} (workers: {})",
        coord.queue_stats().to_json(),
        coord.caches_created(),
        coord.workers()
    );
    // device-call accounting: workers flush their RuntimeStats on
    // drain, so shut the pool down first, then report forwards per
    // token — the number --fuse-steps exists to shrink
    let dispatch = coord.dispatch_stats();
    if shared_runtime {
        println!(
            "dispatcher: {} cross-worker batches (mean width {:.2}, {} spanning >1 worker), \
             {} solo forwards, peak queue depth {}",
            dispatch.batches_total(),
            dispatch.mean_width(),
            dispatch.multi_worker_batches_total(),
            dispatch.solo_forwards_total(),
            dispatch.max_queue_depth()
        );
    }
    let agg = coord.runtime_agg();
    drop(coord);
    let rt_stats = agg.snapshot();
    let tokens = grand.generated_tokens.max(1);
    println!(
        "device: {} forwards ({} fused batches, mean width {:.2}) -> {:.3} forwards/token",
        rt_stats.forwards,
        rt_stats.forward_batches,
        rt_stats.mean_batch_rows(),
        rt_stats.forwards as f64 / tokens as f64
    );
    Ok(())
}
