//! End-to-end serving driver (the repo's E2E validation example):
//! spawns the coordinator worker, loads the trained model, replays the
//! chat/math/code serving traces as a request stream through the full
//! stack (queue -> engine -> PJRT -> verification -> KV compaction),
//! and reports latency/throughput like a serving benchmark.
//!
//!     cargo run --release --example serve_requests [model] [engine]

use std::time::Instant;

use anyhow::Result;

use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::{Coordinator, EngineKind, Request};
use ppd::metrics::ServeReport;
use ppd::util::bench::Table;
use ppd::workload::load_trace;

fn main() -> Result<()> {
    let root = std::path::PathBuf::from("artifacts");
    let model = std::env::args().nth(1).unwrap_or_else(|| "ppd-m".into());
    let engine = std::env::args().nth(2).unwrap_or_else(|| "ppd".into());
    let kind = EngineKind::parse(&engine)?;
    let max_new = 48;

    let cfg = ServeConfig { n_candidates: 6, n_prompt_budget: 10, ..Default::default() };
    println!("spawning coordinator: model={model} engine={engine}");
    let draft = matches!(kind, EngineKind::Spec | EngineKind::SpecPpd).then(|| "ppd-d".to_string());
    let coord = Coordinator::spawn(root.clone(), model.clone(), draft, kind, cfg)?;

    let mut table = Table::new(&["task", "reqs", "tok", "tok/s", "mean tau", "p50 lat (ms)", "p95 lat (ms)"]);
    let paths = ArtifactPaths::new(root, &model);
    let mut grand = ServeReport::new();
    let t_all = Instant::now();
    for task in ["chat", "math", "code"] {
        let trace = load_trace(&paths.trace(task))?;
        let mut report = ServeReport::new();
        let t0 = Instant::now();
        for (id, item) in trace.iter().take(16).enumerate() {
            let t_req = Instant::now();
            coord.submit(Request { id: id as u64, prompt: item.prompt.clone(), max_new })?;
            let resp = coord.recv()?;
            assert!(resp.error.is_none(), "{:?}", resp.error);
            report.record_request(resp.tokens.len(), resp.steps, t_req.elapsed());
            grand.record_request(resp.tokens.len(), resp.steps, t_req.elapsed());
        }
        report.wall_s = t0.elapsed().as_secs_f64();
        let h = report.request_latency.as_ref().unwrap();
        table.row(&[
            task.to_string(),
            format!("{}", report.requests),
            format!("{}", report.generated_tokens),
            format!("{:.1}", report.throughput_tok_s()),
            format!("{:.2}", report.mean_tau()),
            format!("{:.0}", h.quantile_s(0.5) * 1e3),
            format!("{:.0}", h.quantile_s(0.95) * 1e3),
        ]);
    }
    grand.wall_s = t_all.elapsed().as_secs_f64();
    table.print();
    println!("\noverall: {}", grand.to_json());
    Ok(())
}
