// perf probe: breakdown of upload/exec/download per bucket
use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::{build_engine, EngineKind};
use ppd::runtime::Runtime;
use ppd::workload::encode;

fn main() -> anyhow::Result<()> {
    let root = std::path::PathBuf::from("artifacts");
    let model = std::env::args().nth(1).unwrap_or("ppd-m".into());
    let paths = ArtifactPaths::new(root, &model);
    let rt = Runtime::load(&paths)?;
    let cfg = ServeConfig { n_candidates: 6, n_prompt_budget: 10, ..Default::default() };
    let prompt = encode("user: what is your favorite color?\nassistant:");
    for kind in [EngineKind::Vanilla, EngineKind::Ppd] {
        let _ = rt.take_stats();
        let mut e = build_engine(kind, &rt, None, &paths, &cfg, 0)?;
        use ppd::decoding::DecodeEngine;
        let r = e.generate(&prompt, 64)?;
        let st = rt.take_stats();
        println!("{:?}: steps={} decode={:.3}s | forwards={} exec={:.3}s upload={:.3}s download={:.3}s per-bucket={:?}",
            kind, r.steps, r.decode_s, st.forwards, st.forward_s, st.upload_s, st.download_s, st.per_bucket);
    }
    Ok(())
}
