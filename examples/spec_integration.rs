//! §5.3 reproduction as an example: PPD is orthogonal to speculative
//! decoding — applying it to the *draft* model cuts the number of draft
//! forward passes per speculation round.  Compares plain spec decoding
//! vs spec+PPD drafting on the chat trace and reports the draft-pass
//! saving plus wallclock on this host and under a latency envelope where
//! draft forwards dominate (the paper's GPU setting).
//!
//!     cargo run --release --example spec_integration

use anyhow::Result;

use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::{build_engine, EngineKind};
use ppd::decoding::DecodeEngine;
use ppd::runtime::{Device, Runtime};
use ppd::util::bench::Table;
use ppd::workload::load_trace;

fn main() -> Result<()> {
    let root = std::path::PathBuf::from("artifacts");
    let target_name = std::env::args().nth(1).unwrap_or_else(|| "ppd-m".into());
    let paths = ArtifactPaths::new(root.clone(), &target_name);
    let target = Runtime::load(&paths)?;
    let draft = Runtime::load(&ArtifactPaths::new(root, "ppd-d"))?;
    let cfg = ServeConfig { n_candidates: 6, n_prompt_budget: 10, ..Default::default() };
    let max_new = 48;

    let trace = load_trace(&paths.trace("chat"))?;
    let items: Vec<_> = trace.iter().take(10).collect();

    let mut table = Table::new(&["engine", "tok", "target fwd", "draft fwd", "tok/s", "tau"]);
    let mut rows = Vec::new();
    let mut cache =
        ppd::kvcache::HostKvCache::new(target.cfg.n_layers, target.cfg.max_ctx, target.cfg.d_model);
    for kind in [EngineKind::Spec, EngineKind::SpecPpd] {
        let mut engine = build_engine(kind, &target, Some(&draft as &dyn Device), &paths, &cfg, 0)?;
        let (mut tok, mut steps, mut dsteps, mut time) = (0usize, 0usize, 0usize, 0.0f64);
        let mut outputs = Vec::new();
        for it in &items {
            let r = engine.generate_with_cache(&it.prompt, max_new, &mut cache)?;
            tok += r.tokens.len();
            steps += r.steps;
            dsteps += r.draft_steps;
            time += r.decode_s;
            outputs.push(r.tokens);
        }
        table.row(&[
            engine.name().into(),
            format!("{tok}"),
            format!("{steps}"),
            format!("{dsteps}"),
            format!("{:.0}", tok as f64 / time),
            format!("{:.2}", tok as f64 / steps as f64),
        ]);
        rows.push((kind, tok, steps, dsteps, outputs));
    }
    table.print();

    let (_, tok_a, steps_a, draft_a, out_a) = &rows[0];
    let (_, _tok_b, steps_b, draft_b, out_b) = &rows[1];
    assert_eq!(out_a, out_b, "both speculative variants must match (greedy)");
    println!("\noutputs identical across variants ✓");
    println!(
        "draft forward passes: {draft_a} -> {draft_b} ({:.2}x fewer with PPD drafting)",
        *draft_a as f64 / *draft_b as f64
    );
    // Envelope projection: on the paper's GPUs the draft model's forward
    // latency dominates the drafting phase and tree width is cheap.
    // Model: round cost = draft_fwd * L_d + target_fwd * L_t with
    // L_t = 4 L_d (7B vs 68M is >10x, we stay conservative).
    let l_d = 1.0;
    let l_t = 4.0;
    let cost_a = *draft_a as f64 * l_d + *steps_a as f64 * l_t;
    let cost_b = *draft_b as f64 * l_d + *steps_b as f64 * l_t;
    println!(
        "latency-envelope projection (L_target = 4 L_draft, tree width free): spec+ppd is {:.2}x faster — paper §5.3 reports up to 1.22x",
        cost_a / cost_b * (*tok_a as f64 / *tok_a as f64)
    );
    Ok(())
}
