//! Per-task engine comparison (the Fig 5 workload axis as a runnable
//! example): for chat/math/code traces, run every engine greedily and
//! report throughput, τ, and output-exactness vs vanilla.
//!
//!     cargo run --release --example task_speedups [model]

use anyhow::Result;

use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::{build_engine, EngineKind};
use ppd::decoding::vanilla::VanillaEngine;
use ppd::decoding::DecodeEngine;
use ppd::runtime::{Device, Runtime};
use ppd::util::bench::Table;
use ppd::workload::load_trace;

fn main() -> Result<()> {
    let root = std::path::PathBuf::from("artifacts");
    let model = std::env::args().nth(1).unwrap_or_else(|| "ppd-s".into());
    let paths = ArtifactPaths::new(root.clone(), &model);
    let rt = Runtime::load(&paths)?;
    let draft = Runtime::load(&ArtifactPaths::new(root, "ppd-d"))?;
    let cfg = ServeConfig { n_candidates: 6, n_prompt_budget: 10, ..Default::default() };
    let max_new = 48;

    let mut table = Table::new(&["task", "engine", "tok/s", "tau", "exact"]);
    for task in ["chat", "math", "code"] {
        let trace = load_trace(&paths.trace(task))?;
        let items: Vec<_> = trace.iter().take(8).collect();

        // one cache reused across every engine run in this example
        let mut cache = ppd::kvcache::HostKvCache::new(rt.cfg.n_layers, rt.cfg.max_ctx, rt.cfg.d_model);

        // vanilla reference outputs
        let mut vanilla = VanillaEngine::new(&rt, 0.0, 0);
        let mut refs = Vec::new();
        let mut v_tok = 0usize;
        let mut v_time = 0.0;
        for it in &items {
            let r = vanilla.generate_with_cache(&it.prompt, max_new, &mut cache)?;
            v_tok += r.tokens.len();
            v_time += r.decode_s;
            refs.push(r.tokens);
        }
        table.row(&[task.into(), "vanilla".into(), format!("{:.0}", v_tok as f64 / v_time), "1.00".into(), "-".into()]);

        for kind in [EngineKind::Ppd, EngineKind::Medusa, EngineKind::Pld, EngineKind::Spec] {
            let mut engine = build_engine(kind, &rt, Some(&draft as &dyn Device), &paths, &cfg, 0)?;
            let mut tok = 0usize;
            let mut time = 0.0;
            let mut steps = 0usize;
            let mut exact = true;
            for (it, want) in items.iter().zip(&refs) {
                let r = engine.generate_with_cache(&it.prompt, max_new, &mut cache)?;
                exact &= &r.tokens == want;
                tok += r.tokens.len();
                steps += r.steps;
                time += r.decode_s;
            }
            table.row(&[
                task.into(),
                engine.name().into(),
                format!("{:.0}", tok as f64 / time),
                format!("{:.2}", tok as f64 / steps as f64),
                if exact { "yes".into() } else { "NO".into() },
            ]);
        }
    }
    table.print();
    Ok(())
}
