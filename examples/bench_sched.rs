//! Scheduler throughput sweep → `BENCH_sched.json` (the CI bench
//! trajectory).
//!
//! Runs the deterministic mock-backend coordinator (no model artifacts
//! needed) across the scheduling topologies — serial vs fused vs
//! shared-runtime dispatch vs pipelined shared dispatch vs the paged
//! prefix-reuse point (`--kv-blocks`) vs the SLO-scheduled workload mix
//! (`--sched-policy slo` over the chat/summarize/code trace blend), at
//! 1 and 4 workers — and writes
//! one JSON report with tokens/s, device calls per token, mean fused
//! width, exact p50/p95/p99 TTFT + inter-token latency, and paged-KV
//! memory accounting (resident bytes, prefix hits) per point.  The report
//! is validated before it is written, so a malformed artifact fails the
//! producing process, not a downstream consumer.
//!
//!     cargo run --release --example bench_sched [out.json]

use std::time::Duration;

use anyhow::{Context, Result};

use ppd::bench::{run_sweep, validate_report, SweepConfig, SweepMode};
use ppd::util::json::Json;

fn main() -> Result<()> {
    let out = std::env::args().nth(1).unwrap_or_else(|| "BENCH_sched.json".into());
    let mut runs = Vec::new();
    for mode in SweepMode::all() {
        for workers in [1usize, 4] {
            let cfg = SweepConfig {
                mode,
                workers,
                max_inflight: 4,
                requests: 32,
                max_new: 16,
                device_latency: Duration::from_micros(200),
            };
            let j = run_sweep(&cfg)
                .with_context(|| format!("sweep {mode:?} workers={workers}"))?;
            println!(
                "{:>6} workers={} : {:>9.0} tok/s, {:.3} device calls/token, \
                 mean width {:.2}, ttft p95 {:.0}us, itl p95 {:.0}us",
                mode.name(),
                workers,
                j.req("tokens_per_s")?.as_f64()?,
                j.req("device_calls_per_token")?.as_f64()?,
                j.req("mean_fused_width")?.as_f64()?,
                j.req("ttft_p95_us")?.as_f64()?,
                j.req("itl_p95_us")?.as_f64()?,
            );
            runs.push(j);
        }
    }
    let report = Json::obj(vec![
        ("bench", Json::Str("sched".into())),
        ("schema", Json::Num(1.0)),
        ("runs", Json::Arr(runs)),
    ]);
    // refuse to write a malformed trajectory point
    validate_report(&report).context("bench report failed validation")?;
    std::fs::write(&out, format!("{report}\n"))
        .with_context(|| format!("writing {out}"))?;
    println!("wrote {out}");
    Ok(())
}
