#!/usr/bin/env python3
"""Bench-trajectory regression gate for the scheduler sweep.

Compares a fresh ``BENCH_sched.json`` (written by
``cargo run --release --example bench_sched``) against the committed
``BENCH_baseline.json`` and fails when device calls per token regress:

* every sweep point's value must stay at or under its committed
  ``ceiling`` (a hard structural bound: the fusion ladder with margin);
* points that carry a numeric ``reference`` must additionally stay
  within ``growth_pct`` (default 10%) of it.

``serial`` points are a pure function of the scheduler (one device call
per generated token), so their references are exact.  ``fused`` and
``shared`` points go through live threads and coalescing windows, so
their baseline starts ceiling-only; seed tight references from a
trusted machine with::

    python3 tools/bench_gate.py BENCH_sched.json BENCH_baseline.json --seed

which fills each ``reference`` from the fresh run (and is a no-op on
the ceilings).  CI runs the plain compare form.
"""

import argparse
import json
import sys


def load_points(report):
    if report.get("bench") != "sched" or "runs" not in report:
        raise SystemExit("bench_gate: fresh artifact is not a sched sweep report")
    points = {}
    for run in report["runs"]:
        key = f"{run['mode']}/{int(run['workers'])}"
        points[key] = float(run["device_calls_per_token"])
    return points


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="BENCH_sched.json from this run")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument(
        "--seed",
        action="store_true",
        help="rewrite the baseline's references from the fresh run",
    )
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = load_points(json.load(f))
    with open(args.baseline) as f:
        baseline = json.load(f)

    gate = baseline.get("gate", {})
    growth = 1.0 + float(gate.get("growth_pct", 10)) / 100.0
    expected = baseline.get("points", {})

    missing = sorted(set(expected) - set(fresh))
    if missing:
        raise SystemExit(f"bench_gate: fresh run is missing sweep points: {missing}")

    if args.seed:
        for key, spec in expected.items():
            spec["reference"] = round(fresh[key], 4)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"bench_gate: seeded {len(expected)} references into {args.baseline}")
        return

    failures = []
    print("bench_gate: device calls per token (fresh vs committed)")
    for key in sorted(expected):
        spec = expected[key]
        value = fresh[key]
        ceiling = float(spec["ceiling"])
        reference = spec.get("reference")
        limit = ceiling
        detail = f"ceiling {ceiling:.3f}"
        if reference is not None:
            limit = min(limit, float(reference) * growth)
            detail += f", reference {float(reference):.3f} (+{gate.get('growth_pct', 10)}%)"
        verdict = "ok" if value <= limit else "FAIL"
        print(f"  {key:>9}: {value:.4f}  [{detail}] {verdict}")
        if value > limit:
            failures.append(f"{key}: {value:.4f} > {limit:.4f} ({detail})")

    if failures:
        print("bench_gate: device-call trajectory regressed:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        raise SystemExit(1)
    print("bench_gate: trajectory holds")


if __name__ == "__main__":
    main()
