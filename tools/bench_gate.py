#!/usr/bin/env python3
"""Bench-trajectory regression gate for the scheduler sweep.

Compares a fresh ``BENCH_sched.json`` (written by
``cargo run --release --example bench_sched``) against the committed
``BENCH_baseline.json`` and fails when the trajectory regresses:

* every sweep point's ``device_calls_per_token`` must stay at or under
  its committed ``ceiling`` (a hard structural bound: the fusion ladder
  with margin);
* points that carry a numeric ``reference`` must additionally stay
  within ``growth_pct`` (default 10%) of it;
* points that carry a numeric ``tps_reference`` must keep
  ``tokens_per_s`` above ``tps_reference × (1 - tps_drop_pct/100)``
  (default 30% — wallclock throughput varies across machines far more
  than the structural call counts do, so the drop allowance is
  deliberately generous and only catches collapses).

Latency quantiles (``ttft_p50_us`` .. ``itl_p99_us``) are **carried,
not gated**: the compare form prints them for trend reading and
``--seed`` records them in each point's ``latency`` block, but no
latency value can fail the gate — scheduling latency on shared CI
runners is too noisy for a hard threshold.

The ``mix`` sweep points (the SLO-scheduled chat/summarize/code
workload blend, ``--sched-policy slo``) are likewise **carried, not
gated**: they ride the fresh artifact and the informational sections
below print their quantiles, but the baseline declares no ceiling for
them — the trace-driven arrival/length blend makes their device-call
trajectory workload-shaped rather than a structural property of the
scheduler, so a hard bound would gate on the trace, not the code.

``serial`` points are a pure function of the scheduler (one device call
per generated token), so their references are exact.  ``fused``,
``shared``, and ``pipelined`` points go through live threads and
coalescing windows, so their baseline starts ceiling-only; seed tight
references (device-call and tokens/s both) from a trusted machine
with::

    python3 tools/bench_gate.py BENCH_sched.json BENCH_baseline.json --seed

which fills each ``reference``/``tps_reference`` from the fresh run
(and is a no-op on the ceilings).  CI runs the plain compare form.
"""

import argparse
import json
import sys


# informational fields: carried through --seed and printed by the
# compare form, never part of any pass/fail decision
LATENCY_KEYS = (
    "ttft_p50_us",
    "ttft_p95_us",
    "ttft_p99_us",
    "itl_p50_us",
    "itl_p95_us",
    "itl_p99_us",
)

# paged-KV memory accounting: carried (seeded into each point's
# ``memory`` block) for trend reading, never gated — resident bytes
# depend on pool high-water timing, which is scheduler-race noisy
MEMORY_KEYS = (
    "resident_kv_bytes",
    "prefix_hits",
)


def load_points(report):
    if report.get("bench") != "sched" or "runs" not in report:
        raise SystemExit("bench_gate: fresh artifact is not a sched sweep report")
    points = {}
    for run in report["runs"]:
        key = f"{run['mode']}/{int(run['workers'])}"
        point = {
            "device_calls_per_token": float(run["device_calls_per_token"]),
            "tokens_per_s": float(run["tokens_per_s"]),
        }
        # tolerate older artifacts that predate the latency/memory fields
        for lk in LATENCY_KEYS + MEMORY_KEYS:
            if lk in run:
                point[lk] = float(run[lk])
        points[key] = point
    return points


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="BENCH_sched.json from this run")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument(
        "--seed",
        action="store_true",
        help="rewrite the baseline's references (device-call and tokens/s) "
        "from the fresh run",
    )
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = load_points(json.load(f))
    with open(args.baseline) as f:
        baseline = json.load(f)

    gate = baseline.get("gate", {})
    growth = 1.0 + float(gate.get("growth_pct", 10)) / 100.0
    tps_drop_pct = float(gate.get("tps_drop_pct", 30))
    tps_keep = 1.0 - tps_drop_pct / 100.0
    expected = baseline.get("points", {})

    missing = sorted(set(expected) - set(fresh))
    if missing:
        raise SystemExit(f"bench_gate: fresh run is missing sweep points: {missing}")

    if args.seed:
        for key, spec in expected.items():
            spec["reference"] = round(fresh[key]["device_calls_per_token"], 4)
            spec["tps_reference"] = round(fresh[key]["tokens_per_s"], 1)
            latency = {
                lk: round(fresh[key][lk], 1)
                for lk in LATENCY_KEYS
                if lk in fresh[key]
            }
            if latency:
                # carried for trend reading; the compare form never
                # gates on these
                spec["latency"] = latency
            memory = {
                mk: round(fresh[key][mk], 1)
                for mk in MEMORY_KEYS
                if mk in fresh[key]
            }
            if memory:
                spec["memory"] = memory
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"bench_gate: seeded {len(expected)} references into {args.baseline}")
        return

    failures = []
    print("bench_gate: device calls per token (fresh vs committed)")
    for key in sorted(expected):
        spec = expected[key]
        value = fresh[key]["device_calls_per_token"]
        ceiling = float(spec["ceiling"])
        reference = spec.get("reference")
        limit = ceiling
        detail = f"ceiling {ceiling:.3f}"
        if reference is not None:
            limit = min(limit, float(reference) * growth)
            detail += f", reference {float(reference):.3f} (+{gate.get('growth_pct', 10)}%)"
        verdict = "ok" if value <= limit else "FAIL"
        print(f"  {key:>11}: {value:.4f}  [{detail}] {verdict}")
        if value > limit:
            failures.append(f"{key}: {value:.4f} > {limit:.4f} ({detail})")

    print("bench_gate: tokens/s (fresh vs committed floor)")
    for key in sorted(expected):
        spec = expected[key]
        tps_ref = spec.get("tps_reference")
        tps = fresh[key]["tokens_per_s"]
        if tps_ref is None:
            print(f"  {key:>11}: {tps:10.0f}  [no reference seeded]")
            continue
        floor = float(tps_ref) * tps_keep
        verdict = "ok" if tps >= floor else "FAIL"
        print(
            f"  {key:>11}: {tps:10.0f}  [reference {float(tps_ref):.0f}, "
            f"floor -{tps_drop_pct:.0f}%] {verdict}"
        )
        if tps < floor:
            failures.append(f"{key}: {tps:.0f} tok/s < floor {floor:.0f}")

    # informational sections walk the FRESH points, so sweep modes the
    # baseline does not gate (e.g. mix/*) still show their trend here
    if any(lk in fresh[key] for key in sorted(fresh) for lk in LATENCY_KEYS):
        print("bench_gate: latency quantiles (informational, never gated)")
        for key in sorted(fresh):
            point = fresh[key]
            if not any(lk in point for lk in LATENCY_KEYS):
                continue
            ttft = "/".join(
                f"{point.get(lk, float('nan')):.0f}"
                for lk in ("ttft_p50_us", "ttft_p95_us", "ttft_p99_us")
            )
            itl = "/".join(
                f"{point.get(lk, float('nan')):.0f}"
                for lk in ("itl_p50_us", "itl_p95_us", "itl_p99_us")
            )
            print(f"  {key:>11}: ttft p50/p95/p99 {ttft} us, itl {itl} us")

    if any(mk in fresh[key] for key in sorted(fresh) for mk in MEMORY_KEYS):
        print("bench_gate: paged-KV memory (informational, never gated)")
        for key in sorted(fresh):
            point = fresh[key]
            if not any(mk in point for mk in MEMORY_KEYS):
                continue
            kb = point.get("resident_kv_bytes", 0.0) / 1024.0
            hits = int(point.get("prefix_hits", 0))
            print(f"  {key:>11}: resident KV {kb:.1f} KiB, prefix hits {hits}")

    if failures:
        print("bench_gate: bench trajectory regressed:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        raise SystemExit(1)
    print("bench_gate: trajectory holds")


if __name__ == "__main__":
    main()
