//! Env-mutation lint: no `std::env::set_var` / `remove_var` anywhere in
//! the Rust tree.
//!
//! PR 5 shipped (and had to hand-fix) a test that flipped an env var
//! while worker threads were live — `setenv` racing `getenv` is
//! undefined behaviour in glibc, and with `set_var` becoming `unsafe`
//! in edition 2024 the language agrees.  Configuration wants to flow
//! through programmatic overrides (e.g.
//! `runtime::set_kv_buckets_disabled`) that are scoped and
//! thread-safe, so the lint bans the identifiers outright — tests
//! included, because tests are exactly where the race shipped from.

use std::path::Path;

use crate::checks::{rel, Violation};
use crate::scan;

const BANNED: &[&str] = &["set_var", "remove_var"];

pub fn check(root: &Path) -> Vec<Violation> {
    let files = scan::rust_files(
        &[root.join("rust"), root.join("examples")],
        &[root.join("rust/xtask")],
    );
    let mut out = Vec::new();
    for file in files {
        out.extend(check_file(&file, root));
    }
    out
}

pub fn check_file(path: &Path, root: &Path) -> Vec<Violation> {
    let src = match std::fs::read_to_string(path) {
        Ok(s) => s,
        Err(_) => return Vec::new(),
    };
    let sc = scan::scan_rust(&src);
    let file = rel(path, root);
    let mut out = Vec::new();
    for name in BANNED {
        for off in scan::ident_occurrences(&sc.code, name) {
            out.push(Violation::new(
                file.clone(),
                scan::line_of(&sc.code, off),
                format!(
                    "forbidden env mutation `{name}`: mutating the process environment \
                     while threads run is UB (glibc setenv/getenv race) — use a \
                     programmatic override such as `runtime::set_kv_buckets_disabled` \
                     instead"
                ),
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn fixture(name: &str) -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name)
    }

    #[test]
    fn seeded_violations_are_caught_and_comments_are_not() {
        let path = fixture("env_mutation/bad_env.rs");
        let root = fixture("env_mutation");
        let v = check_file(&path, &root);
        // the fixture seeds exactly one set_var and one remove_var call;
        // its comment and string mentions must NOT fire
        assert_eq!(v.len(), 2, "{:?}", v.iter().map(Violation::render).collect::<Vec<_>>());
        assert!(v[0].msg.contains("set_var"));
        assert!(v[1].msg.contains("remove_var"));
        assert!(v.iter().all(|x| x.line > 0));
    }

    #[test]
    fn the_repo_is_clean() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
        let v = check(&root);
        assert!(
            v.is_empty(),
            "{:?}",
            v.iter().map(Violation::render).collect::<Vec<_>>()
        );
    }
}
