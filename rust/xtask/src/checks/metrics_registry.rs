//! Metrics-registry check: every `ppd_*` string literal in the crate
//! must agree with `rust/src/metrics/registry.rs`.
//!
//! Enforced, in both directions:
//! * an undeclared `ppd_*` literal anywhere in src/tests/benches/
//!   examples fails (drift: someone emitted or asserted a metric the
//!   registry doesn't know);
//! * label keys written next to a declared name (`name{key="..."}`)
//!   must match the declared label set exactly;
//! * duplicate or ill-formed registry names fail;
//! * a declared metric that no non-test src file emits fails (dead
//!   registry entries rot the docs);
//! * a declared metric missing from README.md fails (the README metrics
//!   table is the operator-facing contract).
//!
//! Emission is recognised either as a literal containing the full name
//! or — for the `push(suffix)` builder pattern in the exporters — as a
//! declared prefix literal plus the exact suffix literal in the same
//! file.

use std::path::{Path, PathBuf};

use crate::checks::{rel, Violation};
use crate::scan::{self, Scan, StrLit};

pub struct Registry {
    /// (name, label keys)
    pub metrics: Vec<(String, Vec<String>)>,
    pub prefixes: Vec<String>,
    pub allow: Vec<String>,
}

pub fn check(root: &Path) -> Vec<Violation> {
    check_paths(
        &root.join("rust/src/metrics/registry.rs"),
        &[
            root.join("rust/src"),
            root.join("rust/tests"),
            root.join("rust/benches"),
            root.join("examples"),
        ],
        &root.join("rust/src"),
        &root.join("README.md"),
        root,
    )
}

pub fn check_paths(
    registry_path: &Path,
    scan_roots: &[PathBuf],
    emission_root: &Path,
    readme_path: &Path,
    root: &Path,
) -> Vec<Violation> {
    let mut out = Vec::new();
    let reg_src = match std::fs::read_to_string(registry_path) {
        Ok(s) => s,
        Err(e) => {
            out.push(Violation::new(rel(registry_path, root), 0, format!("unreadable: {e}")));
            return out;
        }
    };
    let registry = match parse_registry(&reg_src) {
        Ok(r) => r,
        Err(msg) => {
            out.push(Violation::new(rel(registry_path, root), 0, msg));
            return out;
        }
    };
    let reg_file = rel(registry_path, root);

    // registry self-consistency
    for (i, (name, _)) in registry.metrics.iter().enumerate() {
        if !name.starts_with("ppd_")
            || !name.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        {
            out.push(Violation::new(
                reg_file.clone(),
                0,
                format!("ill-formed metric name `{name}` (want ppd_[a-z0-9_]+)"),
            ));
        }
        if registry.metrics[..i].iter().any(|(n, _)| n == name) {
            out.push(Violation::new(
                reg_file.clone(),
                0,
                format!("duplicate metric declaration `{name}`"),
            ));
        }
    }

    // literal scan + per-file emission inventory
    let files = scan::rust_files(scan_roots, &[]);
    let mut emissions: Vec<Vec<String>> = Vec::new();
    for file in &files {
        if file == registry_path {
            continue;
        }
        let src = match std::fs::read_to_string(file) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let sc = scan::scan_rust(&src);
        let regions = scan::test_regions(&sc.code);
        let name = rel(file, root);
        let mut nontest = Vec::new();
        for lit in &sc.strings {
            let s = lit.content.replace("{{", "{").replace("}}", "}");
            scan_literal(&s, lit, &name, &registry, &mut out);
            if !scan::in_test_region(&regions, lit.offset) {
                nontest.push(s);
            }
        }
        if file.starts_with(emission_root) {
            emissions.push(nontest);
        }
    }

    // every declared metric must be emitted somewhere in non-test src
    for (name, _) in &registry.metrics {
        let emitted = emissions.iter().any(|lits| {
            if lits.iter().any(|s| s.contains(name.as_str())) {
                return true;
            }
            registry.prefixes.iter().any(|p| {
                name.starts_with(p.as_str())
                    && lits.iter().any(|s| s == p)
                    && lits.iter().any(|s| s == &name[p.len()..])
            })
        });
        if !emitted {
            out.push(Violation::new(
                reg_file.clone(),
                0,
                format!("metric `{name}` is declared but never emitted by non-test src"),
            ));
        }
    }

    // README coverage
    match std::fs::read_to_string(readme_path) {
        Ok(readme) => {
            for (name, _) in &registry.metrics {
                if !readme.contains(name.as_str()) {
                    out.push(Violation::new(
                        rel(readme_path, root),
                        0,
                        format!("metric `{name}` is not documented in the README metrics table"),
                    ));
                }
            }
        }
        Err(e) => out.push(Violation::new(rel(readme_path, root), 0, format!("unreadable: {e}"))),
    }
    out
}

/// Classify every `ppd_*` token in one (brace-normalised) literal.
fn scan_literal(s: &str, lit: &StrLit, file: &str, registry: &Registry, out: &mut Vec<Violation>) {
    let bytes = s.as_bytes();
    let mut i = 0usize;
    while let Some(p) = scan::find_sub(bytes, i, b"ppd_") {
        i = p + 1;
        // token boundary on the left; a token right after `{` is a
        // format-placeholder interpolation (`{ppd_tau:.2}`), not a name
        if p > 0 {
            let prev = bytes[p - 1];
            if prev.is_ascii_lowercase() || prev.is_ascii_digit() || prev == b'_' || prev == b'{' {
                continue;
            }
        }
        let mut end = p;
        while end < bytes.len()
            && (bytes[end].is_ascii_lowercase() || bytes[end].is_ascii_digit() || bytes[end] == b'_')
        {
            end += 1;
        }
        let tok = &s[p..end];
        if let Some((_, labels)) = registry.metrics.iter().find(|(n, _)| n == tok) {
            if end < bytes.len() && bytes[end] == b'{' {
                match parse_labels(bytes, end) {
                    Some(mut keys) => {
                        let mut want = labels.clone();
                        keys.sort();
                        want.sort();
                        if keys != want {
                            out.push(Violation::new(
                                file.to_string(),
                                lit.line,
                                format!(
                                    "metric `{tok}` written with labels {keys:?}, registry \
                                     declares {want:?}"
                                ),
                            ));
                        }
                    }
                    None => out.push(Violation::new(
                        file.to_string(),
                        lit.line,
                        format!("malformed label block after metric `{tok}`"),
                    )),
                }
            }
            continue;
        }
        if registry.prefixes.iter().any(|pfx| pfx == tok) {
            continue;
        }
        if registry.allow.iter().any(|a| tok.starts_with(a.as_str())) {
            continue;
        }
        out.push(Violation::new(
            file.to_string(),
            lit.line,
            format!(
                "undeclared `ppd_*` literal `{tok}` — declare it in \
                 rust/src/metrics/registry.rs (METRICS) or allowlist it (NON_METRIC_ALLOW)"
            ),
        ));
    }
}

/// Parse `{key="...",key="..."}` starting at the `{`; values may carry
/// `{placeholder}` interpolations.  Returns the keys, or None on a
/// malformed block.
fn parse_labels(bytes: &[u8], open: usize) -> Option<Vec<String>> {
    let mut keys = Vec::new();
    let mut i = open + 1;
    loop {
        let start = i;
        while i < bytes.len() && (bytes[i].is_ascii_lowercase() || bytes[i] == b'_') {
            i += 1;
        }
        if i == start || i >= bytes.len() || bytes[i] != b'=' {
            return None;
        }
        keys.push(String::from_utf8_lossy(&bytes[start..i]).into_owned());
        i += 1;
        if i >= bytes.len() || bytes[i] != b'"' {
            return None;
        }
        i += 1;
        while i < bytes.len() && bytes[i] != b'"' {
            i += 1;
        }
        if i >= bytes.len() {
            return None;
        }
        i += 1; // closing quote
        match bytes.get(i) {
            Some(b',') => i += 1,
            Some(b'}') => return Some(keys),
            _ => return None,
        }
    }
}

/// Parse the three declaration tables out of registry.rs source.
pub fn parse_registry(src: &str) -> Result<Registry, String> {
    let sc = scan::scan_rust(src);
    let (ma, mb) = const_value_range(&sc, "METRICS")
        .ok_or("cannot locate `const METRICS` table in registry.rs")?;
    let mut metrics = Vec::new();
    for (ga, gb) in paren_groups(&sc.code, ma, mb) {
        let lits: Vec<&StrLit> =
            sc.strings.iter().filter(|l| l.offset >= ga && l.offset < gb).collect();
        if lits.len() < 2 {
            return Err(format!(
                "metric entry at byte {ga} has {} string literals, want name + help",
                lits.len()
            ));
        }
        let name = lits[0].content.clone();
        let labels = lits[1..lits.len() - 1].iter().map(|l| l.content.clone()).collect();
        metrics.push((name, labels));
    }
    if metrics.is_empty() {
        return Err("METRICS table is empty".into());
    }
    let read_list = |ident: &str| -> Result<Vec<String>, String> {
        let (a, b) = const_value_range(&sc, ident)
            .ok_or_else(|| format!("cannot locate `const {ident}` in registry.rs"))?;
        Ok(sc
            .strings
            .iter()
            .filter(|l| l.offset >= a && l.offset < b)
            .map(|l| l.content.clone())
            .collect())
    };
    Ok(Registry {
        metrics,
        prefixes: read_list("METRIC_PREFIXES")?,
        allow: read_list("NON_METRIC_ALLOW")?,
    })
}

/// Byte range of the `[...]` value of `const <ident>: ... = &[...]` —
/// the occurrence preceded by `const`, value brackets after the `=`.
fn const_value_range(sc: &Scan, ident: &str) -> Option<(usize, usize)> {
    let bytes = sc.code.as_bytes();
    for occ in scan::ident_occurrences(&sc.code, ident) {
        let before = sc.code[..occ].trim_end();
        if !before.ends_with("const") {
            continue;
        }
        let eq = scan::find_sub(bytes, occ, b"=")?;
        let open = scan::find_sub(bytes, eq, b"[")?;
        let mut depth = 0i64;
        let mut k = open;
        while k < bytes.len() {
            match bytes[k] {
                b'[' => depth += 1,
                b']' => {
                    depth -= 1;
                    if depth == 0 {
                        return Some((open, k + 1));
                    }
                }
                _ => {}
            }
            k += 1;
        }
        return None;
    }
    None
}

/// Top-level `(...)` group ranges within `[a, b)`.
fn paren_groups(code: &str, a: usize, b: usize) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut depth = 0i64;
    let mut start = 0usize;
    for k in a..b.min(bytes.len()) {
        match bytes[k] {
            b'(' => {
                if depth == 0 {
                    start = k;
                }
                depth += 1;
            }
            b')' => {
                depth -= 1;
                if depth == 0 {
                    out.push((start, k + 1));
                }
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn fixture_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures/metrics_registry")
    }

    #[test]
    fn seeded_fixture_violations_are_caught() {
        let dir = fixture_dir();
        let v = check_paths(
            &dir.join("registry.rs"),
            &[dir.join("src")],
            &dir.join("src"),
            &dir.join("README.md"),
            &dir,
        );
        let msgs: Vec<String> = v.iter().map(Violation::render).collect();
        assert!(msgs.iter().any(|m| m.contains("duplicate metric declaration `ppd_fx_dup_total`")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("undeclared `ppd_*` literal `ppd_fx_unknown_total`")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("metric `ppd_fx_labeled_total` written with labels")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("`ppd_fx_never_emitted_total` is declared but never emitted")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("`ppd_fx_undocumented_total` is not documented")), "{msgs:?}");
        assert_eq!(v.len(), 5, "{msgs:?}");
    }

    #[test]
    fn the_repo_is_clean() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
        let v = check(&root);
        assert!(
            v.is_empty(),
            "{:?}",
            v.iter().map(Violation::render).collect::<Vec<_>>()
        );
    }

    #[test]
    fn registry_parses_the_real_declarations() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
        let src = std::fs::read_to_string(root.join("rust/src/metrics/registry.rs"))
            .expect("registry source");
        let reg = parse_registry(&src).expect("parse");
        assert!(reg.metrics.len() >= 30);
        assert!(reg.prefixes.iter().any(|p| p == "ppd_queue_"));
        let (_, labels) = reg
            .metrics
            .iter()
            .find(|(n, _)| n == "ppd_runtime_bucket_forwards_total")
            .expect("declared");
        assert_eq!(labels, &["n", "kv"]);
    }
}
