//! Device-escape lint: decode engines reach the device only through
//! the `runtime::Device` trait.
//!
//! The one-call-per-tick invariant (PR 3/4) holds because every engine
//! in `rust/src/decoding/` is generic over `dyn Device` — a `Runtime`
//! borrowed directly would let an engine issue device calls that bypass
//! the fused tick plan and the shared-runtime dispatcher.  The lint
//! bans the `Runtime` identifier from the decoding tree outright: no
//! imports, no fields, no inherent-method calls.  (`SharedRuntime` — a
//! `Device` impl that routes through the dispatcher — is a different
//! identifier and stays legal, as do doc-comment mentions.)

use std::path::Path;

use crate::checks::{rel, Violation};
use crate::scan;

pub fn check(root: &Path) -> Vec<Violation> {
    check_dir(&root.join("rust/src/decoding"), root)
}

pub fn check_dir(dir: &Path, root: &Path) -> Vec<Violation> {
    let mut out = Vec::new();
    for file in scan::rust_files(&[dir.to_path_buf()], &[]) {
        let src = match std::fs::read_to_string(&file) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let sc = scan::scan_rust(&src);
        let name = rel(&file, root);
        for off in scan::ident_occurrences(&sc.code, "Runtime") {
            out.push(Violation::new(
                name.clone(),
                scan::line_of(&sc.code, off),
                "decode engines must reach the device through the `runtime::Device` \
                 trait; a direct `Runtime` reference bypasses the fused tick plan and \
                 the shared-runtime dispatcher (one-call-per-tick invariant)",
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn seeded_runtime_reference_is_caught() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures/device_escape");
        let v = check_dir(&dir, &dir);
        // the fixture engine imports Runtime and holds a &Runtime field:
        // two hits; its SharedRuntime use and doc-comment mention are legal
        assert_eq!(v.len(), 2, "{:?}", v.iter().map(Violation::render).collect::<Vec<_>>());
        assert!(v.iter().all(|x| x.file.ends_with("bad_engine.rs")));
    }

    #[test]
    fn the_repo_is_clean() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
        let v = check(&root);
        assert!(
            v.is_empty(),
            "{:?}",
            v.iter().map(Violation::render).collect::<Vec<_>>()
        );
    }
}
