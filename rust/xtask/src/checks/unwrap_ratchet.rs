//! Unwrap ratchet: per-module `.unwrap()` / `.expect(` counts in
//! non-test code must not grow past the committed baseline.
//!
//! `rust/xtask/unwrap-baseline.txt` holds `module: count` lines, one
//! per directory (or top-level file) under `rust/src`.  Growth in the
//! enforced hot-path modules (`batch`, `coordinator`, `runtime`) is a
//! violation — convert the new site to a typed error, or, when it
//! really is an invariant, an `.expect("why this cannot fail")` plus a
//! deliberate baseline bump (`cargo xtask analyze --update-baselines`).
//! Growth elsewhere only warns; shrinkage anywhere prints a reminder to
//! ratchet the baseline down.  Test-region code (`#[cfg(test)]`) is not
//! counted: tests may unwrap freely.

use std::collections::BTreeMap;
use std::path::Path;

use crate::checks::Violation;
use crate::scan;

pub const ENFORCED: &[&str] = &["batch", "coordinator", "runtime"];

pub fn baseline_path(root: &Path) -> std::path::PathBuf {
    root.join("rust/xtask/unwrap-baseline.txt")
}

pub fn check(root: &Path, update: bool) -> Vec<Violation> {
    let counts = count_modules(&root.join("rust/src"));
    let path = baseline_path(root);
    if update {
        let mut text = String::from(
            "# Non-test .unwrap()/.expect( sites per module under rust/src.\n\
             # Maintained by `cargo xtask analyze --update-baselines`; growth in\n\
             # batch/coordinator/runtime fails `cargo xtask analyze`.\n",
        );
        for (module, n) in &counts {
            text.push_str(&format!("{module}: {n}\n"));
        }
        if let Err(e) = std::fs::write(&path, text) {
            return vec![Violation::new(path.display().to_string(), 0, format!("write failed: {e}"))];
        }
        return Vec::new();
    }
    let baseline_src = match std::fs::read_to_string(&path) {
        Ok(s) => s,
        Err(e) => {
            return vec![Violation::new(
                "rust/xtask/unwrap-baseline.txt",
                0,
                format!("unreadable ({e}) — run `cargo xtask analyze --update-baselines`"),
            )]
        }
    };
    compare(&counts, &parse_baseline(&baseline_src))
}

pub fn compare(
    counts: &BTreeMap<String, usize>,
    baseline: &BTreeMap<String, usize>,
) -> Vec<Violation> {
    let mut out = Vec::new();
    for (module, &n) in counts {
        let base = baseline.get(module).copied().unwrap_or(0);
        if n > base {
            if ENFORCED.contains(&module.as_str()) {
                out.push(Violation::new(
                    "rust/xtask/unwrap-baseline.txt",
                    0,
                    format!(
                        "unwrap/expect count in `{module}` grew {base} -> {n}: convert the \
                         new site to a typed error, or justify it and run \
                         `cargo xtask analyze --update-baselines`"
                    ),
                ));
            } else {
                eprintln!(
                    "warning: unwrap/expect count in `{module}` grew {base} -> {n} \
                     (unenforced module; consider updating the baseline)"
                );
            }
        } else if n < base {
            eprintln!(
                "note: unwrap/expect count in `{module}` shrank {base} -> {n} — run \
                 `cargo xtask analyze --update-baselines` to ratchet down"
            );
        }
    }
    out
}

pub fn parse_baseline(src: &str) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for line in src.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some((module, n)) = line.split_once(':') {
            if let Ok(n) = n.trim().parse() {
                out.insert(module.trim().to_string(), n);
            }
        }
    }
    out
}

/// Non-test unwrap/expect counts keyed by first path component under
/// `src_root` (top-level files count under their file stem).
pub fn count_modules(src_root: &Path) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for file in scan::rust_files(&[src_root.to_path_buf()], &[]) {
        let module = match file.strip_prefix(src_root).ok().and_then(|r| {
            let mut comps = r.components();
            let first = comps.next()?.as_os_str().to_string_lossy().into_owned();
            Some(if comps.next().is_some() {
                first
            } else {
                first.trim_end_matches(".rs").to_string()
            })
        }) {
            Some(m) => m,
            None => continue,
        };
        let src = match std::fs::read_to_string(&file) {
            Ok(s) => s,
            Err(_) => continue,
        };
        *out.entry(module).or_insert(0) += count_file(&src);
    }
    out
}

pub fn count_file(src: &str) -> usize {
    let sc = scan::scan_rust(src);
    let regions = scan::test_regions(&sc.code);
    let bytes = sc.code.as_bytes();
    let mut n = 0usize;
    for (name, want_empty_parens) in [("unwrap", true), ("expect", false)] {
        for occ in scan::ident_occurrences(&sc.code, name) {
            // method position: a `.` before the ident (whitespace
            // between allowed — chained calls wrap across lines)
            let mut d = occ;
            while d > 0 && bytes[d - 1].is_ascii_whitespace() {
                d -= 1;
            }
            if d == 0 || bytes[d - 1] != b'.' || scan::in_test_region(&regions, occ) {
                continue;
            }
            let mut i = occ + name.len();
            while i < bytes.len() && bytes[i].is_ascii_whitespace() {
                i += 1;
            }
            if i >= bytes.len() || bytes[i] != b'(' {
                continue;
            }
            if want_empty_parens {
                let mut j = i + 1;
                while j < bytes.len() && bytes[j].is_ascii_whitespace() {
                    j += 1;
                }
                if j >= bytes.len() || bytes[j] != b')' {
                    continue;
                }
            }
            n += 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn fixture_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures/unwrap_ratchet")
    }

    #[test]
    fn counts_skip_tests_and_non_call_idents() {
        let counts = count_modules(&fixture_dir().join("src"));
        // overflow/mod.rs seeds 2 unwraps + 1 expect in live code, plus
        // test-region unwraps and an `unwrap_or` that must not count
        assert_eq!(counts.get("overflow"), Some(&3), "{counts:?}");
        assert_eq!(counts.get("ok"), Some(&0), "{counts:?}");
    }

    #[test]
    fn growth_over_baseline_fails_enforced_modules_only() {
        let mut counts = BTreeMap::new();
        counts.insert("batch".to_string(), 5);
        counts.insert("metrics".to_string(), 9);
        let baseline = parse_baseline("# comment\nbatch: 4\nmetrics: 2\n");
        let v = compare(&counts, &baseline);
        assert_eq!(v.len(), 1, "{:?}", v.iter().map(Violation::render).collect::<Vec<_>>());
        assert!(v[0].msg.contains("`batch` grew 4 -> 5"));
    }

    #[test]
    fn the_repo_matches_its_baseline() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
        let v = check(&root, false);
        assert!(
            v.is_empty(),
            "{:?}",
            v.iter().map(Violation::render).collect::<Vec<_>>()
        );
    }
}
