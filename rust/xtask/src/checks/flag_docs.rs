//! Flag-documentation check: the CLI surface and the README agree.
//!
//! Enforced, in both directions:
//! * every flag the serve binary parses (an `args.get("name")` /
//!   `self.get("name")` literal in `rust/src/main.rs`) must appear as
//!   `--name` in README.md — an operator must never need the source to
//!   discover a knob;
//! * every `pub` field of `SchedPolicy` must be reachable from some
//!   parsed flag (`--kebab-case` of the field name, allowing a longer
//!   unit suffix such as `--max-queue-age-ms` for `max_queue_age`) —
//!   a policy knob without a CLI path is dead configuration;
//! * every `--flag` token the README mentions must be parsed by a
//!   binary in this repo (`main.rs`, or the extra sources — the xtask
//!   CLI) or belong to a known external tool (cargo, pytest, the
//!   bench-gate script) — the README must not document ghosts.

use std::path::{Path, PathBuf};

use crate::checks::{rel, Violation};
use crate::scan::{self, Scan};

/// Flags owned by external tools the README legitimately invokes
/// (cargo, pytest's repo-local `--fast`, `tools/bench_gate.py --seed`).
const EXTERNAL_FLAGS: &[&str] = &[
    "--release",
    "--features",
    "--all-features",
    "--all-targets",
    "--manifest-path",
    "--workspace",
    "--locked",
    "--offline",
    "--test",
    "--lib",
    "--example",
    "--examples",
    "--doc",
    "--no-deps",
    "--quiet",
    "--jobs",
    "--fast",
    "--seed",
];

pub fn check(root: &Path) -> Vec<Violation> {
    check_paths(
        &root.join("rust/src/main.rs"),
        &root.join("rust/src/coordinator/scheduler.rs"),
        &[root.join("rust/xtask/src/main.rs")],
        &root.join("README.md"),
        root,
    )
}

pub fn check_paths(
    main_path: &Path,
    policy_path: &Path,
    extra_sources: &[PathBuf],
    readme_path: &Path,
    root: &Path,
) -> Vec<Violation> {
    let mut out = Vec::new();
    let main_src = match std::fs::read_to_string(main_path) {
        Ok(s) => s,
        Err(e) => {
            out.push(Violation::new(rel(main_path, root), 0, format!("unreadable: {e}")));
            return out;
        }
    };
    let main_file = rel(main_path, root);
    let sc = scan::scan_rust(&main_src);
    let defined = defined_flags(&sc);
    if defined.is_empty() {
        out.push(Violation::new(
            main_file.clone(),
            0,
            "no `get(\"flag\")` reads found — flag extraction is broken".to_string(),
        ));
        return out;
    }

    // everything a repo binary mentions or parses counts as known
    let mut known: Vec<String> = defined.iter().map(|(f, _)| f.clone()).collect();
    for lit in &sc.strings {
        for (tok, _) in flag_tokens(&lit.content) {
            known.push(tok);
        }
    }
    for path in extra_sources {
        if let Ok(src) = std::fs::read_to_string(path) {
            for lit in scan::scan_rust(&src).strings {
                for (tok, _) in flag_tokens(&lit.content) {
                    known.push(tok);
                }
            }
        }
    }
    known.extend(EXTERNAL_FLAGS.iter().map(|s| s.to_string()));
    known.sort();
    known.dedup();

    // README coverage of the parsed surface
    let readme = match std::fs::read_to_string(readme_path) {
        Ok(s) => s,
        Err(e) => {
            out.push(Violation::new(rel(readme_path, root), 0, format!("unreadable: {e}")));
            return out;
        }
    };
    let readme_file = rel(readme_path, root);
    let readme_tokens = flag_tokens(&readme);
    for (flag, line) in &defined {
        if !readme_tokens.iter().any(|(t, _)| t == flag) {
            out.push(Violation::new(
                main_file.clone(),
                *line,
                format!("flag `{flag}` is parsed here but not documented in README.md"),
            ));
        }
    }

    // README must not document flags nothing parses
    for (tok, line) in &readme_tokens {
        if !known.contains(tok) {
            out.push(Violation::new(
                readme_file.clone(),
                *line,
                format!("README documents `{tok}`, which no binary in this repo parses"),
            ));
        }
    }

    // every SchedPolicy knob must be reachable from the CLI
    match std::fs::read_to_string(policy_path) {
        Ok(src) => match policy_fields(&src) {
            Some(fields) => {
                for field in fields {
                    let kebab = format!("--{}", field.replace('_', "-"));
                    let covered = defined.iter().any(|(f, _)| {
                        f == &kebab || f.starts_with(&format!("{kebab}-"))
                    });
                    if !covered {
                        out.push(Violation::new(
                            rel(policy_path, root),
                            0,
                            format!(
                                "SchedPolicy field `{field}` has no `{kebab}` flag in \
                                 {main_file} — policy knobs must be CLI-reachable"
                            ),
                        ));
                    }
                }
            }
            None => out.push(Violation::new(
                rel(policy_path, root),
                0,
                "cannot locate `struct SchedPolicy`".to_string(),
            )),
        },
        Err(e) => out.push(Violation::new(rel(policy_path, root), 0, format!("unreadable: {e}"))),
    }
    out
}

/// Flags the binary parses: string literals that are the direct
/// argument of a `get(` call and look like a flag name.
fn defined_flags(sc: &Scan) -> Vec<(String, usize)> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for lit in &sc.strings {
        let before = sc.code[..lit.offset.saturating_sub(1)].trim_end();
        if !before.ends_with("get(") {
            continue;
        }
        let name = &lit.content;
        let ok = !name.is_empty()
            && name.as_bytes()[0].is_ascii_lowercase()
            && name.bytes().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == b'-');
        if ok {
            let flag = format!("--{name}");
            if !out.iter().any(|(f, _)| f == &flag) {
                out.push((flag, lit.line));
            }
        }
    }
    out
}

/// `--flag` tokens in free text, with their 1-based line numbers.
fn flag_tokens(text: &str) -> Vec<(String, usize)> {
    let bytes = text.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while let Some(p) = scan::find_sub(bytes, i, b"--") {
        i = p + 2;
        if p > 0 {
            let prev = bytes[p - 1];
            if prev.is_ascii_alphanumeric() || prev == b'-' || prev == b'_' {
                continue;
            }
        }
        let start = p + 2;
        if start >= bytes.len() || !bytes[start].is_ascii_lowercase() {
            continue;
        }
        let mut end = start;
        while end < bytes.len()
            && (bytes[end].is_ascii_lowercase()
                || bytes[end].is_ascii_digit()
                || bytes[end] == b'-')
        {
            end += 1;
        }
        while end > start && bytes[end - 1] == b'-' {
            end -= 1;
        }
        out.push((text[p..end].to_string(), scan::line_of(text, p)));
        i = end;
    }
    out
}

/// The `pub` field names of `struct SchedPolicy` in `src`.
fn policy_fields(src: &str) -> Option<Vec<String>> {
    let sc = scan::scan_rust(src);
    let bytes = sc.code.as_bytes();
    let at = scan::find_sub(bytes, 0, b"struct SchedPolicy")?;
    let open = scan::find_sub(bytes, at, b"{")?;
    let mut depth = 0i64;
    let mut close = open;
    for k in open..bytes.len() {
        match bytes[k] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    close = k;
                    break;
                }
            }
            _ => {}
        }
    }
    let body = &sc.code[open + 1..close];
    let b = body.as_bytes();
    let mut fields = Vec::new();
    for occ in scan::ident_occurrences(body, "pub") {
        let mut i = occ + 3;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        let start = i;
        while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
            i += 1;
        }
        let mut j = i;
        while j < b.len() && b[j].is_ascii_whitespace() {
            j += 1;
        }
        if i > start && j < b.len() && b[j] == b':' {
            fields.push(body[start..i].to_string());
        }
    }
    Some(fields)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn fixture_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures/flag_docs")
    }

    #[test]
    fn seeded_fixture_violations_are_caught() {
        let dir = fixture_dir();
        let v = check_paths(
            &dir.join("main.rs"),
            &dir.join("scheduler.rs"),
            &[],
            &dir.join("README.md"),
            &dir,
        );
        let msgs: Vec<String> = v.iter().map(Violation::render).collect();
        assert!(
            msgs.iter().any(|m| m.contains("`--hidden-knob` is parsed here but not documented")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("`--bogus-flag`, which no binary in this repo parses")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("SchedPolicy field `unmapped_field` has no `--unmapped-field` flag")),
            "{msgs:?}"
        );
        assert_eq!(v.len(), 3, "{msgs:?}");
    }

    #[test]
    fn flag_tokens_respect_word_boundaries() {
        let toks: Vec<String> =
            flag_tokens("run with `--kv-blocks=256` or --workers 4 -- not --- nor a--b")
                .into_iter()
                .map(|(t, _)| t)
                .collect();
        assert_eq!(toks, vec!["--kv-blocks".to_string(), "--workers".to_string()]);
    }

    #[test]
    fn the_repo_is_clean() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
        let v = check(&root);
        assert!(v.is_empty(), "{:?}", v.iter().map(Violation::render).collect::<Vec<_>>());
    }
}
