//! Artifact-contract check: the graph-name/config contract that is
//! duplicated between `python/compile/aot.py` (the producer) and the
//! Rust `config`/`runtime` parsers (the consumer) must agree.
//!
//! Cross-checked:
//! * the `fwd_*`/`medusa*` HLO file-name templates (placeholders
//!   normalised to `{}`) must match set-for-set in both directions;
//! * every `config.json` key the Rust loader `req(...)`s or
//!   `get(...)`s must be written by aot.py's config dict;
//! * the manifest key `main.rs` reads (`models`) must be written by
//!   aot.py's manifest dict;
//! * the Rust `kv_buckets` fallback (`None => vec![...]`) must be a
//!   subset of aot.py's `KV_VARIANTS` — a fallback the exporter never
//!   produces would 404 at graph-load time;
//! * concrete `fwd_b{B}_n{N}_s{kv}.hlo.txt` names asserted in ci.yml
//!   must be combinations the exporter actually emits (bucket
//!   membership and the `*_MAX_N` caps).

use std::collections::BTreeSet;
use std::path::Path;

use crate::checks::{rel, Violation};
use crate::scan::{self, Scan};

pub fn check(root: &Path) -> Vec<Violation> {
    check_paths(
        &root.join("python/compile/aot.py"),
        &root.join("rust/src/config/mod.rs"),
        Some(&root.join("rust/src/main.rs")),
        Some(&root.join(".github/workflows/ci.yml")),
        root,
    )
}

struct AotFacts {
    buckets: Vec<u64>,
    kv_variants: Vec<u64>,
    batch_buckets: Vec<u64>,
    kv_variant_max_n: Option<u64>,
    batch_max_n: Option<u64>,
    templates: BTreeSet<String>,
    config_keys: BTreeSet<String>,
    manifest_keys: BTreeSet<String>,
}

pub fn check_paths(
    aot_path: &Path,
    config_rs_path: &Path,
    main_rs_path: Option<&Path>,
    ci_path: Option<&Path>,
    root: &Path,
) -> Vec<Violation> {
    let mut out = Vec::new();
    let aot_file = rel(aot_path, root);
    let cfg_file = rel(config_rs_path, root);
    let aot_src = match std::fs::read_to_string(aot_path) {
        Ok(s) => s,
        Err(e) => return vec![Violation::new(aot_file, 0, format!("unreadable: {e}"))],
    };
    let aot = parse_aot(&aot_src, &aot_file, &mut out);

    let cfg_src = match std::fs::read_to_string(config_rs_path) {
        Ok(s) => s,
        Err(e) => return vec![Violation::new(cfg_file, 0, format!("unreadable: {e}"))],
    };
    let sc = scan::scan_rust(&cfg_src);
    let regions = scan::test_regions(&sc.code);

    // rust-side templates + config keys (non-test code only)
    let mut rs_templates = BTreeSet::new();
    let mut req_keys = BTreeSet::new();
    let mut get_keys = BTreeSet::new();
    for lit in &sc.strings {
        if scan::in_test_region(&regions, lit.offset) {
            continue;
        }
        let base = lit.content.rsplit('/').next().unwrap_or(&lit.content);
        if base.ends_with(".hlo.txt") && (base.starts_with("fwd_") || base.starts_with("medusa")) {
            rs_templates.insert(norm_template(base));
        }
        match call_before(&sc.code, lit.offset) {
            Some("req") => {
                req_keys.insert(lit.content.clone());
            }
            Some("get") => {
                get_keys.insert(lit.content.clone());
            }
            _ => {}
        }
    }

    for t in aot.templates.difference(&rs_templates) {
        out.push(Violation::new(
            aot_file.clone(),
            0,
            format!("template `{t}` produced by aot.py but not consumed by the rust config"),
        ));
    }
    for t in rs_templates.difference(&aot.templates) {
        out.push(Violation::new(
            cfg_file.clone(),
            0,
            format!("template `{t}` expected by the rust config but not produced by aot.py"),
        ));
    }
    for k in req_keys.iter().chain(get_keys.iter()) {
        if !aot.config_keys.contains(k) {
            out.push(Violation::new(
                aot_file.clone(),
                0,
                format!("rust config loader reads key `{k}` but aot.py never writes it"),
            ));
        }
    }

    // kv_buckets fallback ∈ KV_VARIANTS
    for (line, vals) in none_vec_fallbacks(&sc.code, &regions) {
        for v in vals {
            if !aot.kv_variants.is_empty() && !aot.kv_variants.contains(&v) {
                out.push(Violation::new(
                    cfg_file.clone(),
                    line,
                    format!(
                        "kv fallback `{v}` is not in aot.py KV_VARIANTS {:?} — the \
                         exporter never produces that graph",
                        aot.kv_variants
                    ),
                ));
            }
        }
    }

    // manifest contract: main.rs reads manifest["models"]
    if let Some(main_path) = main_rs_path {
        if let Ok(main_src) = std::fs::read_to_string(main_path) {
            let msc = scan::scan_rust(&main_src);
            let reads_models = !scan::ident_occurrences(&msc.code, "load_manifest").is_empty()
                && msc
                    .strings
                    .iter()
                    .any(|l| l.content == "models" && call_before(&msc.code, l.offset) == Some("req"));
            if reads_models && !aot.manifest_keys.contains("models") {
                out.push(Violation::new(
                    aot_file.clone(),
                    0,
                    "manifest key `models` is read by rust/src/main.rs but aot.py never writes it",
                ));
            }
        }
    }

    // ci.yml asserted artifact names
    if let Some(ci) = ci_path {
        if let Ok(ci_src) = std::fs::read_to_string(ci) {
            check_ci_names(&ci_src, &rel(ci, root), &aot, &mut out);
        }
    }
    out
}

/// The callee identifier immediately before a string literal's opening
/// quote, if the literal is that call's first argument (`req("k")`).
fn call_before(code: &str, content_offset: usize) -> Option<&'static str> {
    // content_offset points at the content start; the (blanked) opening
    // quote sits one byte before it
    if content_offset < 5 {
        return None;
    }
    let before = &code[content_offset - 5..content_offset - 1];
    if before == "req(" {
        Some("req")
    } else if before == "get(" {
        Some("get")
    } else {
        None
    }
}

/// `None => vec![ ... ]` fallback arms in non-test code: (line, values).
fn none_vec_fallbacks(code: &str, regions: &[(usize, usize)]) -> Vec<(usize, Vec<u64>)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    for occ in scan::ident_occurrences(code, "None") {
        if scan::in_test_region(regions, occ) {
            continue;
        }
        let mut i = occ + 4;
        let skip_ws = |i: &mut usize| {
            while *i < bytes.len() && bytes[*i].is_ascii_whitespace() {
                *i += 1;
            }
        };
        skip_ws(&mut i);
        if !code[i..].starts_with("=>") {
            continue;
        }
        i += 2;
        skip_ws(&mut i);
        if !code[i..].starts_with("vec!") {
            continue;
        }
        i += 4;
        skip_ws(&mut i);
        if i >= bytes.len() || bytes[i] != b'[' {
            continue;
        }
        let close = match scan::find_sub(bytes, i, b"]") {
            Some(c) => c,
            None => continue,
        };
        out.push((scan::line_of(code, occ), parse_ints(&code[i..close])));
    }
    out
}

fn check_ci_names(ci: &str, ci_file: &str, aot: &AotFacts, out: &mut Vec<Violation>) {
    let bytes = ci.as_bytes();
    let mut i = 0usize;
    while let Some(p) = scan::find_sub(bytes, i, b"fwd_b") {
        i = p + 1;
        let mut j = p + 5;
        let b = match take_int(bytes, &mut j) {
            Some(v) => v,
            None => continue,
        };
        if !ci[j..].starts_with("_n") {
            continue;
        }
        j += 2;
        let n = match take_int(bytes, &mut j) {
            Some(v) => v,
            None => continue,
        };
        let mut kv = None;
        if ci[j..].starts_with("_s") {
            j += 2;
            kv = take_int(bytes, &mut j);
        }
        if !ci[j..].starts_with(".hlo.txt") {
            continue;
        }
        let line = scan::line_of(ci, p);
        if !aot.batch_buckets.is_empty() && !aot.batch_buckets.contains(&b) {
            out.push(Violation::new(
                ci_file.to_string(),
                line,
                format!("ci asserts batch bucket {b}, aot.py BATCH_BUCKETS is {:?}", aot.batch_buckets),
            ));
        }
        if !aot.buckets.is_empty() && !aot.buckets.contains(&n) {
            out.push(Violation::new(
                ci_file.to_string(),
                line,
                format!("ci asserts token bucket {n}, aot.py BUCKETS is {:?}", aot.buckets),
            ));
        }
        if let Some(max) = aot.batch_max_n {
            if n > max {
                out.push(Violation::new(
                    ci_file.to_string(),
                    line,
                    format!("ci asserts n={n} above aot.py BATCH_MAX_N={max}"),
                ));
            }
        }
        if let Some(kv) = kv {
            if !aot.kv_variants.is_empty() && !aot.kv_variants.contains(&kv) {
                out.push(Violation::new(
                    ci_file.to_string(),
                    line,
                    format!("ci asserts kv variant {kv}, aot.py KV_VARIANTS is {:?}", aot.kv_variants),
                ));
            }
            if let Some(max) = aot.kv_variant_max_n {
                if n > max {
                    out.push(Violation::new(
                        ci_file.to_string(),
                        line,
                        format!("ci asserts n={n} above aot.py KV_VARIANT_MAX_N={max}"),
                    ));
                }
            }
        }
    }
}

fn take_int(bytes: &[u8], i: &mut usize) -> Option<u64> {
    let start = *i;
    while *i < bytes.len() && bytes[*i].is_ascii_digit() {
        *i += 1;
    }
    if *i == start {
        return None;
    }
    std::str::from_utf8(&bytes[start..*i]).ok()?.parse().ok()
}

fn parse_ints(s: &str) -> Vec<u64> {
    let mut out = Vec::new();
    let bytes = s.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i].is_ascii_digit() {
            let mut j = i;
            if let Some(v) = take_int(bytes, &mut j) {
                out.push(v);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// Normalise `{anything}` placeholder spans to `{}`.
fn norm_template(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '{' {
            for d in chars.by_ref() {
                if d == '}' {
                    break;
                }
            }
            out.push_str("{}");
        } else {
            out.push(c);
        }
    }
    out
}

fn parse_aot(src: &str, aot_file: &str, out: &mut Vec<Violation>) -> AotFacts {
    let sc = scan::scan_python(src);
    let mut facts = AotFacts {
        buckets: py_int_list(&sc, "BUCKETS").unwrap_or_default(),
        kv_variants: py_int_list(&sc, "KV_VARIANTS").unwrap_or_default(),
        batch_buckets: py_int_list(&sc, "BATCH_BUCKETS").unwrap_or_default(),
        kv_variant_max_n: py_int(&sc, "KV_VARIANT_MAX_N"),
        batch_max_n: py_int(&sc, "BATCH_MAX_N"),
        templates: BTreeSet::new(),
        config_keys: dict_keys(&sc, "config"),
        manifest_keys: dict_keys(&sc, "manifest"),
    };
    for (name, ok) in [
        ("BUCKETS", !facts.buckets.is_empty()),
        ("KV_VARIANTS", !facts.kv_variants.is_empty()),
        ("BATCH_BUCKETS", !facts.batch_buckets.is_empty()),
        ("KV_VARIANT_MAX_N", facts.kv_variant_max_n.is_some()),
        ("BATCH_MAX_N", facts.batch_max_n.is_some()),
    ] {
        if !ok {
            out.push(Violation::new(
                aot_file.to_string(),
                0,
                format!("cannot parse `{name}` from aot.py — the contract check is blind"),
            ));
        }
    }
    for lit in &sc.strings {
        let s = lit.content.as_str();
        if s.ends_with(".hlo.txt") && (s.starts_with("fwd_") || s.starts_with("medusa")) {
            facts.templates.insert(norm_template(s));
        }
    }
    facts
}

/// `NAME = [ints...]` at statement level in blanked python code.
fn py_int_list(sc: &Scan, name: &str) -> Option<Vec<u64>> {
    let bytes = sc.code.as_bytes();
    for occ in scan::ident_occurrences(&sc.code, name) {
        let mut i = occ + name.len();
        while i < bytes.len() && bytes[i] == b' ' {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'=' || sc.code[i..].starts_with("==") {
            continue;
        }
        i += 1;
        while i < bytes.len() && bytes[i] == b' ' {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'[' {
            continue;
        }
        let close = scan::find_sub(bytes, i, b"]")?;
        let vals = parse_ints(&sc.code[i..close]);
        if !vals.is_empty() {
            return Some(vals);
        }
    }
    None
}

/// `NAME = <int>` at statement level in blanked python code.
fn py_int(sc: &Scan, name: &str) -> Option<u64> {
    let bytes = sc.code.as_bytes();
    for occ in scan::ident_occurrences(&sc.code, name) {
        let mut i = occ + name.len();
        while i < bytes.len() && bytes[i] == b' ' {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'=' || sc.code[i..].starts_with("==") {
            continue;
        }
        i += 1;
        while i < bytes.len() && bytes[i] == b' ' {
            i += 1;
        }
        let mut j = i;
        if let Some(v) = take_int(bytes, &mut j) {
            return Some(v);
        }
    }
    None
}

/// Keys of the first `NAME = { ... }` dict assignment: string literals
/// inside the braces whose closing quote is followed by `:`.
fn dict_keys(sc: &Scan, name: &str) -> BTreeSet<String> {
    let bytes = sc.code.as_bytes();
    let mut out = BTreeSet::new();
    for occ in scan::ident_occurrences(&sc.code, name) {
        let mut i = occ + name.len();
        while i < bytes.len() && bytes[i] == b' ' {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'=' || sc.code[i..].starts_with("==") {
            continue;
        }
        i += 1;
        while i < bytes.len() && (bytes[i] == b' ' || bytes[i] == b'\n') {
            i += 1;
        }
        if i >= bytes.len() || bytes[i] != b'{' {
            continue;
        }
        let mut depth = 0i64;
        let mut k = i;
        let mut end = bytes.len();
        while k < bytes.len() {
            match bytes[k] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = k;
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        for lit in &sc.strings {
            if lit.offset <= i || lit.offset >= end {
                continue;
            }
            // closing quote sits right after the raw content
            let mut after = lit.offset + lit.content.len() + 1;
            while after < bytes.len() && bytes[after] == b' ' {
                after += 1;
            }
            if after < bytes.len() && bytes[after] == b':' {
                out.insert(lit.content.clone());
            }
        }
        return out;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn seeded_contract_drift_is_caught() {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures/artifact_contract");
        let v = check_paths(
            &dir.join("aot.py"),
            &dir.join("config.rs"),
            None,
            Some(&dir.join("ci.yml")),
            &dir,
        );
        let msgs: Vec<String> = v.iter().map(Violation::render).collect();
        assert!(
            msgs.iter().any(|m| m.contains("template `fwd_x{}_n{}.hlo.txt` expected by the rust config")),
            "{msgs:?}"
        );
        assert!(msgs.iter().any(|m| m.contains("reads key `missing_key`")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("kv fallback `512`")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("batch bucket 3")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("kv variant 999")), "{msgs:?}");
        assert_eq!(v.len(), 5, "{msgs:?}");
    }

    #[test]
    fn the_repo_is_clean() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
        let v = check(&root);
        assert!(
            v.is_empty(),
            "{:?}",
            v.iter().map(Violation::render).collect::<Vec<_>>()
        );
    }
}
