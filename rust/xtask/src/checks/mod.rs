//! The six project-invariant checks `cargo xtask analyze` runs.

pub mod artifact_contract;
pub mod device_escape;
pub mod env_mutation;
pub mod flag_docs;
pub mod metrics_registry;
pub mod unwrap_ratchet;

use std::path::Path;

/// One finding: `file` is repo-relative, `line` is 1-based (0 for
/// file-level findings).
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl Violation {
    pub fn new(file: impl Into<String>, line: usize, msg: impl Into<String>) -> Self {
        Violation { file: file.into(), line, msg: msg.into() }
    }

    pub fn render(&self) -> String {
        if self.line == 0 {
            format!("{}: {}", self.file, self.msg)
        } else {
            format!("{}:{}: {}", self.file, self.line, self.msg)
        }
    }
}

/// Repo-relative display path (falls back to the full path when the
/// file is outside `root`, e.g. fixture scans in the self-tests).
pub fn rel(path: &Path, root: &Path) -> String {
    path.strip_prefix(root).unwrap_or(path).display().to_string()
}
