//! Token-level Rust/Python source scanning shared by every check.
//!
//! A deliberate non-goal is full parsing: `syn` would drag a dependency
//! tree into the no-network container, and each check here needs only
//! token-level facts — where comments and string literals are, where
//! `#[cfg(test)]` regions span, where an identifier occurs.  The
//! scanner blanks comment text and literal *contents* to spaces
//! (newlines preserved), so byte offsets and line numbers in the
//! blanked code match the original source exactly.

/// One string literal: `offset` is the byte offset of the content start
/// in the original source, `line` its 1-based line, `content` the
/// unescaped text.
pub struct StrLit {
    pub offset: usize,
    pub line: usize,
    pub content: String,
}

pub struct Scan {
    /// source with comments and literal contents blanked to spaces
    pub code: String,
    pub strings: Vec<StrLit>,
}

fn blank(out: &mut [u8], a: usize, b: usize) {
    let b = b.min(out.len());
    for byte in &mut out[a..b] {
        if *byte != b'\n' {
            *byte = b' ';
        }
    }
}

fn count_nl(bytes: &[u8], a: usize, b: usize) -> usize {
    bytes[a..b.min(bytes.len())].iter().filter(|&&c| c == b'\n').count()
}

fn prev_is_ident(bytes: &[u8], i: usize) -> bool {
    i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_')
}

fn unescape(raw: &str) -> String {
    let mut out = String::with_capacity(raw.len());
    let mut it = raw.chars();
    while let Some(c) = it.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match it.next() {
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('0') => out.push('\0'),
            Some('\\') => out.push('\\'),
            Some('"') => out.push('"'),
            Some('\'') => out.push('\''),
            Some(other) => {
                out.push('\\');
                out.push(other);
            }
            None => out.push('\\'),
        }
    }
    out
}

/// Scan Rust source: blank comments/literals, collect string literals.
pub fn scan_rust(src: &str) -> Scan {
    let bytes = src.as_bytes();
    let n = bytes.len();
    let mut out = bytes.to_vec();
    let mut strings = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;

    while i < n {
        let c = bytes[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && bytes[i + 1] == b'/' {
            let mut j = i;
            while j < n && bytes[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if bytes[j] == b'/' && j + 1 < n && bytes[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if bytes[j] == b'*' && j + 1 < n && bytes[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if bytes[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        // raw (byte) strings: r"..", r#".."#, br#".."# — guard against
        // plain identifiers starting with r/b
        if (c == b'r' || (c == b'b' && i + 1 < n && bytes[i + 1] == b'r')) && !prev_is_ident(bytes, i)
        {
            let mut j = i + if c == b'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && bytes[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && bytes[j] == b'"' {
                let start = j + 1;
                let mut k = start;
                let end = loop {
                    if k >= n {
                        break n;
                    }
                    if bytes[k] == b'"'
                        && k + 1 + hashes <= n
                        && bytes[k + 1..k + 1 + hashes].iter().all(|&h| h == b'#')
                    {
                        break k;
                    }
                    k += 1;
                };
                strings.push(StrLit { offset: start, line, content: src[start..end].to_string() });
                let stop = (end + 1 + hashes).min(n);
                line += count_nl(bytes, i, stop);
                blank(&mut out, i, stop);
                i = stop;
                continue;
            }
            // not a raw string after all (e.g. `r#type` raw ident, or a
            // plain ident) — consume one byte and keep going
            i += 1;
            continue;
        }
        // plain / byte string
        if c == b'"' || (c == b'b' && i + 1 < n && bytes[i + 1] == b'"' && !prev_is_ident(bytes, i))
        {
            let start = i + if c == b'b' { 2 } else { 1 };
            let mut j = start;
            while j < n {
                match bytes[j] {
                    b'\\' => j += 2,
                    b'"' => break,
                    _ => j += 1,
                }
            }
            let end = j.min(n);
            strings.push(StrLit { offset: start, line, content: unescape(&src[start..end]) });
            let stop = (end + 1).min(n);
            line += count_nl(bytes, i, stop);
            blank(&mut out, i, stop);
            i = stop;
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            if i + 1 < n && bytes[i + 1] == b'\\' {
                let mut j = i + 2;
                while j < n && bytes[j] != b'\'' {
                    j += 1;
                }
                blank(&mut out, i, (j + 1).min(n));
                i = (j + 1).min(n);
                continue;
            }
            if i + 2 < n && bytes[i + 2] == b'\'' {
                blank(&mut out, i, i + 3);
                i += 3;
                continue;
            }
            i += 1; // lifetime
            continue;
        }
        // skip over plain identifiers wholesale so ident-leading `b`/`r`
        // never re-enter the literal branches mid-word
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i + 1;
            while j < n && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_') {
                j += 1;
            }
            i = j;
            continue;
        }
        i += 1;
    }
    // only ASCII bytes were overwritten (with ASCII spaces), so the
    // result is valid UTF-8
    Scan { code: String::from_utf8(out).expect("blanking preserves UTF-8"), strings }
}

/// Scan Python source: blank `#` comments, triple-quoted strings
/// entirely, and single-quoted literal contents; collect the
/// single-quoted literals (raw, no unescaping — the aot.py contract
/// strings contain no escapes).
pub fn scan_python(src: &str) -> Scan {
    let bytes = src.as_bytes();
    let n = bytes.len();
    let mut out = bytes.to_vec();
    let mut strings = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;

    while i < n {
        let c = bytes[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b'#' {
            let mut j = i;
            while j < n && bytes[j] != b'\n' {
                j += 1;
            }
            blank(&mut out, i, j);
            i = j;
            continue;
        }
        if c == b'"' || c == b'\'' {
            // triple-quoted: blank whole literal, keep nothing
            if i + 2 < n && bytes[i + 1] == c && bytes[i + 2] == c {
                let mut j = i + 3;
                while j + 2 < n && !(bytes[j] == c && bytes[j + 1] == c && bytes[j + 2] == c) {
                    j += 1;
                }
                let stop = (j + 3).min(n);
                line += count_nl(bytes, i, stop);
                blank(&mut out, i, stop);
                i = stop;
                continue;
            }
            let start = i + 1;
            let mut j = start;
            while j < n && bytes[j] != c {
                if bytes[j] == b'\\' {
                    j += 1;
                }
                j += 1;
            }
            let end = j.min(n);
            strings.push(StrLit { offset: start, line, content: src[start..end].to_string() });
            let stop = (end + 1).min(n);
            line += count_nl(bytes, i, stop);
            blank(&mut out, i, stop);
            i = stop;
            continue;
        }
        i += 1;
    }
    Scan { code: String::from_utf8(out).expect("blanking preserves UTF-8"), strings }
}

/// 1-based line of a byte offset in `text`.
pub fn line_of(text: &str, offset: usize) -> usize {
    text.as_bytes()[..offset.min(text.len())].iter().filter(|&&c| c == b'\n').count() + 1
}

/// Byte ranges of `#[cfg(test)]`-gated items (attribute through the
/// matching close brace of the item's block).
pub fn test_regions(code: &str) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let pat = b"#[cfg(test)]";
    let mut regions = Vec::new();
    let mut i = 0usize;
    while let Some(p) = find_sub(bytes, i, pat) {
        let mut j = p + pat.len();
        while j < bytes.len() && bytes[j] != b'{' {
            j += 1;
        }
        let mut depth = 0i64;
        let mut k = j;
        let mut end = bytes.len();
        while k < bytes.len() {
            match bytes[k] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        end = k + 1;
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        regions.push((p, end));
        i = end.max(p + 1);
    }
    regions
}

pub fn in_test_region(regions: &[(usize, usize)], offset: usize) -> bool {
    regions.iter().any(|&(a, b)| a <= offset && offset < b)
}

/// Byte offsets of exact-identifier occurrences of `ident` in blanked
/// code (so `Runtime` never matches `SharedRuntime` or `RuntimeStats`).
pub fn ident_occurrences(code: &str, ident: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    let pat = ident.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while let Some(p) = find_sub(bytes, i, pat) {
        let before_ok = !prev_is_ident(bytes, p);
        let after = p + pat.len();
        let after_ok = after >= bytes.len()
            || !(bytes[after].is_ascii_alphanumeric() || bytes[after] == b'_');
        if before_ok && after_ok {
            out.push(p);
        }
        i = p + 1;
    }
    out
}

/// Naive substring search from `from`.
pub fn find_sub(haystack: &[u8], from: usize, needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || haystack.len() < needle.len() {
        return None;
    }
    let last = haystack.len() - needle.len();
    let mut i = from;
    while i <= last {
        if &haystack[i..i + needle.len()] == needle {
            return Some(i);
        }
        i += 1;
    }
    None
}

/// All `.rs` files under `roots` (recursive, sorted), skipping `target`,
/// `.git`, and anything under `exclude`.
pub fn rust_files(
    roots: &[std::path::PathBuf],
    exclude: &[std::path::PathBuf],
) -> Vec<std::path::PathBuf> {
    let mut out = Vec::new();
    for root in roots {
        walk(root, exclude, &mut out);
    }
    out.sort();
    out
}

fn walk(dir: &std::path::Path, exclude: &[std::path::PathBuf], out: &mut Vec<std::path::PathBuf>) {
    if exclude.iter().any(|e| dir.starts_with(e)) {
        return;
    }
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            let name = entry.file_name();
            if name == "target" || name == ".git" || name == "fixtures" {
                continue;
            }
            walk(&path, exclude, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked_but_offsets_hold() {
        let src = "let a = \"x{y}\"; // set_var in a comment\nlet b = 'c';\n";
        let sc = scan_rust(src);
        assert_eq!(sc.strings.len(), 1);
        assert_eq!(sc.strings[0].content, "x{y}");
        assert_eq!(sc.strings[0].line, 1);
        assert!(!sc.code.contains("set_var"));
        assert!(!sc.code.contains("x{y}"));
        assert_eq!(sc.code.len(), src.len());
        assert_eq!(line_of(&sc.code, sc.code.find("let b").expect("b")), 2);
    }

    #[test]
    fn raw_strings_lifetimes_and_nested_comments() {
        let src = "fn f<'a>(x: &'a str) { let r = r#\"a \"quote\" b\"#; /* outer /* inner */ still */ let c = '\\n'; }";
        let sc = scan_rust(src);
        assert_eq!(sc.strings.len(), 1);
        assert_eq!(sc.strings[0].content, "a \"quote\" b");
        assert!(!sc.code.contains("inner"));
        assert!(sc.code.contains("fn f<'a>"));
    }

    #[test]
    fn escapes_unescape_and_unicode_survives() {
        let src = "let s = \"a\\\"b\\n\"; // ──▶ arrows\nlet t = \"ok\";";
        let sc = scan_rust(src);
        assert_eq!(sc.strings[0].content, "a\"b\n");
        assert_eq!(sc.strings[1].content, "ok");
        assert_eq!(sc.strings[1].line, 2);
    }

    #[test]
    fn test_region_spans_the_mod_block() {
        let code = "fn live() {}\n#[cfg(test)]\nmod tests {\n fn inner() { x.unwrap() }\n}\nfn after() {}\n";
        let sc = scan_rust(code);
        let regions = test_regions(&sc.code);
        assert_eq!(regions.len(), 1);
        assert!(in_test_region(&regions, sc.code.find("unwrap").expect("u")));
        assert!(!in_test_region(&regions, sc.code.find("live").expect("l")));
        assert!(!in_test_region(&regions, sc.code.find("after").expect("a")));
    }

    #[test]
    fn ident_occurrences_respect_boundaries() {
        let sc = scan_rust("use a::Runtime; let x: SharedRuntime = y; RuntimeStats::new();");
        assert_eq!(ident_occurrences(&sc.code, "Runtime").len(), 1);
    }

    #[test]
    fn python_docstrings_are_dropped_and_fstrings_kept() {
        let src = "\"\"\"doc fwd_n<k>.hlo.txt\"\"\"\nX = [1, 2]\nname = f\"fwd_n{n}.hlo.txt\"  # comment \"quoted\"\n";
        let sc = scan_python(src);
        assert_eq!(sc.strings.len(), 1);
        assert_eq!(sc.strings[0].content, "fwd_n{n}.hlo.txt");
        assert!(sc.code.contains("X = [1, 2]"));
        assert!(!sc.code.contains("comment"));
    }
}
