//! `cargo xtask analyze` — the project static-analysis suite.
//!
//! Six checks over the whole repo (see ISSUE 6 / README "Static
//! analysis & sanitizers"):
//!
//! * `env-mutation`      — no `std::env::set_var`/`remove_var` in rust/
//! * `device-escape`     — decoding engines use `Device`, never `Runtime`
//! * `metrics-registry`  — `ppd_*` literals agree with metrics/registry.rs
//! * `artifact-contract` — aot.py and the rust config parsers agree
//! * `unwrap-ratchet`    — per-module unwrap counts never grow
//! * `flag-docs`         — CLI flags and the README agree, both ways
//!
//! Exit code 1 when any check finds a violation.  Flags:
//!
//!     cargo xtask analyze [--check NAME] [--root PATH] [--update-baselines]

mod checks;
mod scan;

use std::path::PathBuf;
use std::process::ExitCode;

use checks::Violation;

fn usage() -> ! {
    eprintln!(
        "usage: cargo xtask analyze [--check NAME] [--root PATH] [--update-baselines]\n\
         checks: env-mutation device-escape metrics-registry artifact-contract unwrap-ratchet flag-docs"
    );
    std::process::exit(2);
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("analyze") => {}
        _ => usage(),
    }
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let mut only: Option<String> = None;
    let mut update = false;
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => root = PathBuf::from(args.next().unwrap_or_else(|| usage())),
            "--check" => only = Some(args.next().unwrap_or_else(|| usage())),
            "--update-baselines" => update = true,
            _ => usage(),
        }
    }
    let root = root.canonicalize().unwrap_or(root);

    type Check = fn(&std::path::Path) -> Vec<Violation>;
    let table: &[(&str, Check)] = &[
        ("env-mutation", checks::env_mutation::check),
        ("device-escape", checks::device_escape::check),
        ("metrics-registry", checks::metrics_registry::check),
        ("artifact-contract", checks::artifact_contract::check),
        ("flag-docs", checks::flag_docs::check),
    ];

    let mut total = 0usize;
    let wanted = |name: &str| only.as_deref().map_or(true, |o| o == name);
    for (name, run) in table {
        if !wanted(name) {
            continue;
        }
        total += report(name, run(&root));
    }
    if wanted("unwrap-ratchet") {
        total += report("unwrap-ratchet", checks::unwrap_ratchet::check(&root, update));
        if update {
            println!("unwrap-ratchet    : baseline rewritten");
        }
    }

    if total == 0 {
        println!("analyze: all checks clean");
        ExitCode::SUCCESS
    } else {
        println!("analyze: {total} violation(s)");
        ExitCode::FAILURE
    }
}

fn report(name: &str, violations: Vec<Violation>) -> usize {
    if violations.is_empty() {
        println!("{name:<18}: ok");
    } else {
        println!("{name:<18}: {} violation(s)", violations.len());
        for v in &violations {
            println!("  {}", v.render());
        }
    }
    violations.len()
}
