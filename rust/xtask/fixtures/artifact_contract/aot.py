"""Seeded fixture exporter for the artifact-contract check.

Docstring template mentions like fwd_n<k>.hlo.txt are ignored by the
scan (triple-quoted strings are dropped).
"""

BUCKETS = [8]
KV_VARIANTS = [256]
KV_VARIANT_MAX_N = 64
BATCH_BUCKETS = [2]
BATCH_MAX_N = 64


def export(model, n, models):
    names = [f"fwd_n{n}.hlo.txt", "medusa.hlo.txt"]
    config = {
        "name": model,
        "kv_buckets": KV_VARIANTS,
    }
    manifest = {
        "models": models,
    }
    return names, config, manifest
