// Seeded fixture consumer for the artifact-contract check: one template
// the exporter never produces, one config key it never writes, one kv
// fallback it never exports.  Scanned, never compiled.

pub fn graph_names(bucket: usize, batch: usize) -> Vec<String> {
    vec![
        format!("fwd_n{bucket}.hlo.txt"),
        format!("fwd_x{batch}_n{bucket}.hlo.txt"), // seeded: exporter never writes fwd_x*
        "medusa.hlo.txt".to_string(),
    ]
}

pub fn load(j: &Json) -> (String, String, Vec<u64>) {
    let name = j.req("name");
    let missing = j.req("missing_key"); // seeded: aot.py never writes this
    let kv = match j.get("kv_buckets") {
        Some(v) => v,
        None => vec![512], // seeded: 512 is not a KV_VARIANT
    };
    (name, missing, kv)
}
