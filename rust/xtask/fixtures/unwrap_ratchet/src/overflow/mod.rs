// Seeded fixture: 2 unwraps + 1 expect in live code; the test-region
// unwrap and the unwrap_or must not count.

pub fn live(x: Option<u32>, y: Option<u32>) -> u32 {
    let a = x.unwrap();
    let b = y.unwrap();
    let c = x.expect("seeded expect");
    let d = x.unwrap_or(0);
    a + b + c + d
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_in_tests_are_free() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
        v.expect("also free");
    }
}
