// Seeded fixture: a module with zero unwrap/expect sites.

pub fn fine() -> usize {
    0
}
