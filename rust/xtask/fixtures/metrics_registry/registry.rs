// Seeded fixture registry: one duplicate declaration, one metric no
// fixture src emits, one metric the fixture README omits.

pub type MetricDecl = (&'static str, &'static [&'static str], &'static str);

pub const METRICS: &[MetricDecl] = &[
    ("ppd_fx_good_total", &[], "a good counter"),
    ("ppd_fx_labeled_total", &["kv"], "a labeled counter"),
    ("ppd_fx_dup_total", &[], "declared twice"),
    ("ppd_fx_dup_total", &[], "declared twice again"),
    ("ppd_fx_undocumented_total", &[], "missing from the fixture README"),
    ("ppd_fx_never_emitted_total", &[], "declared but never emitted"),
];

pub const METRIC_PREFIXES: &[&str] = &[];

pub const NON_METRIC_ALLOW: &[&str] = &["ppd_fx_tmp"];
