// Seeded fixture emitter: one label mismatch, one undeclared literal,
// one allowlisted temp-dir name.

pub fn emit() -> String {
    let mut out = String::new();
    out.push_str("ppd_fx_good_total 1\n");
    out.push_str("ppd_fx_dup_total 1\n");
    out.push_str("ppd_fx_undocumented_total 1\n");
    out.push_str("ppd_fx_labeled_total{wrong=\"x\"} 2\n"); // label mismatch
    out.push_str("ppd_fx_unknown_total 3\n"); // undeclared
    out.push_str("ppd_fx_tmp_dir"); // allowlisted
    out
}
