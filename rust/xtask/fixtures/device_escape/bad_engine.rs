// Seeded violation fixture for the device-escape lint: a decode engine
// reaching for Runtime directly.  (Mentioning Runtime in this comment
// is legal — comments are stripped before the scan.)

use crate::runtime::Runtime; // seeded violation 1

pub struct BadEngine<'a> {
    rt: &'a Runtime, // seeded violation 2
    shared: SharedRuntime, // legal: SharedRuntime routes through the dispatcher
}

impl BadEngine<'_> {
    fn step(&self) {
        let _ = self.rt;
        let _ = &self.shared;
    }
}
