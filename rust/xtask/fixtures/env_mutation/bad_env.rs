// Seeded violation fixture for the env-mutation lint.  Scanned by the
// xtask self-tests, never compiled.
// Mentioning set_var in a comment must NOT fire.

fn poke_env() {
    std::env::set_var("PPD_KV_BUCKETS", "0"); // seeded violation 1
    let msg = "remove_var inside a string literal is also fine";
    std::env::remove_var("PPD_KV_BUCKETS"); // seeded violation 2
    let _ = msg;
}
