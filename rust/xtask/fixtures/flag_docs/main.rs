// flag-docs fixture: parses three flags; `--hidden-knob` is missing
// from the fixture README on purpose.
use std::collections::HashMap;

fn main() {
    let args: HashMap<String, String> = HashMap::new();
    let _workers = args.get("workers");
    let _inflight = args.get("max-inflight");
    let _hidden = args.get("hidden-knob");
    println!("usage: fx serve [--workers N] [--max-inflight M] [--hidden-knob X]");
}
