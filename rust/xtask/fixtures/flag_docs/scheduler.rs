// flag-docs fixture: `max_inflight` maps to the parsed --max-inflight;
// `unmapped_field` has no CLI path and must be flagged.
pub struct SchedPolicy {
    pub max_inflight: usize,
    pub unmapped_field: bool,
}
