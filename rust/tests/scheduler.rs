//! Deterministic step-scheduler test harness (no model artifacts, no
//! threads for the core tests): a mock engine implements BOTH decode
//! paths —
//!
//!  * `generate_with_cache` is **overridden** with a monolithic
//!    run-to-completion loop (the PR 1 worker behavior), and
//!  * `begin_seq`/`step` implement the same token function
//!    incrementally, drawing one RNG value per step from the
//!    *sequence's own* RNG — and the `plan_step`/`apply_step`/
//!    `forward_batch` triple implements it a third time for the fused
//!    scheduler,
//!
//! so driving [`StepScheduler`] by hand and comparing token streams
//! proves the continuous-batching machinery is output-transparent:
//! admission order, interleaving depth, retirement order, and step
//! fusion must not perturb any sequence.  The mock additionally
//! verifies on every step that it was handed back *its own* KV cache
//! (committed length grows by exactly one per step), and the fused
//! path echoes each plan's row through `forward_batch` so a collation
//! or routing mixup fails loudly in `apply_step`.
//!
//! Scripted orderings covered:
//!  * token-exact equivalence: step-scheduled (max_inflight ∈ {1,2,4})
//!    vs the run-to-completion reference, fused and unfused;
//!  * a sequence admitted mid-flight never perturbs a running one
//!    (fused and unfused);
//!  * out-of-order retirement routes every reply to its own channel;
//!  * queue-aging drops stale jobs with an error response;
//!  * cancellation before admission and mid-flight, freeing the cache
//!    back to the pool (fused and unfused);
//!  * fused stepping issues ≥2× fewer device calls than per-sequence
//!    stepping for the same workload at depth 4, with ≥1 tick where
//!    one `forward_batch` served >1 sequence;
//!  * shared-runtime dispatch ([`SharedHarness`]: many schedulers, one
//!    scripted `DeviceDispatcher`): token-exact vs serial AND
//!    per-worker-fused at workers 1/2/4 × max_inflight 1/2/4, exactly
//!    ONE device call per wall tick with 4 busy workers (vs 4
//!    per-worker-fused), mid-flight admission, cancellation, and
//!    dead-dispatcher recovery (errors + pool reconciliation);
//!  * KV-length bucketing for batched graphs ([`KvExec`] rides the real
//!    collate/truncate/split pipeline): short-KV-bucketed vs full-ctx
//!    execution token-exact at workers 1/2/4 × max_inflight 1/2/4,
//!    with the smallest covering bucket demonstrably selected (via the
//!    dispatcher's kv histogram) when every rider is short;
//!  * pipelined split ticks ([`SharedHarness`] in pipelined mode:
//!    submit → admit inside the overlap window → pump → complete, every
//!    round flushed through the dispatcher's prepare/pre-collate path):
//!    token-exact vs the unpipelined shared path at workers 1/2/4 ×
//!    max_inflight 1/2/4 — including mid-flight admission landing while
//!    a round is at the dispatcher, cancellation, a dispatcher dying
//!    mid-overlap, and scheduler teardown with a tick still in flight
//!    (caches reconciled with the pool, reply channels answered);
//!  * the full coordinator (threads + queue + scheduler) end to end,
//!    with the worker count taken from `PPD_TEST_WORKERS`, fusion from
//!    `PPD_TEST_FUSE`, shared-runtime dispatch from `PPD_TEST_SHARED`,
//!    and the pipelined split-tick loop from `PPD_TEST_PIPELINED`
//!    (CI matrix).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Result};

use ppd::batch::dispatch::{
    DeviceDispatcher, DeviceExecutor, DispatchStats, TickRow, DEFAULT_WINDOW,
};
use ppd::batch::collator::{collate, split, CollatedBatch};
use ppd::batch::{
    select_kv_bucket, union_max_slot, BatchInventory, BatchItem, BatchMeta, BatchStepEngine,
    PlanInputs, StepPlan, StepResult,
};
use ppd::coordinator::queue::Job;
use ppd::coordinator::scheduler::SchedObserver;
use ppd::coordinator::{
    serve_jobs, Coordinator, DeviceHost, Priority, QueueDiscipline, Request, Response,
    ResponseEvent, SchedPolicy, StepScheduler, WorkerBackend, WorkerCtx,
};
use ppd::decoding::{DecodeEngine, FinishReason, GenerationResult, SeqState, StepOutcome};
use ppd::kvcache::{HostKvCache, SharedCachePool};
use ppd::metrics::{us_bucket_quantile, QueueStats, RequestLatency, REQUEST_US_BOUNDS};
use ppd::runtime::{RuntimeStats, StepOutput};
use ppd::trace::{Phase, ScriptedClock, TraceEvent, Tracer, NO_REQ};
use ppd::util::rng::Rng;
use ppd::workload;

const SHAPE: (usize, usize, usize) = (2, 64, 4);

/// Deterministic mock: token i of a request is
/// `(sum(prompt) + i + rng_i) % 127` where `rng_i` is the i-th draw of
/// `Rng::new(seed)`.  The step path draws lazily from `SeqState::rng`;
/// the run-to-completion override draws from its own local RNG — if
/// interleaving ever leaks RNG draws (or caches) across sequences, the
/// two paths diverge.  `forwards` counts device calls: one per unfused
/// `step`, one per `forward_batch` however many sequences rode along —
/// the batching win the fused acceptance test asserts on.
struct MockEngine {
    seed: u64,
    /// artificial per-step latency (threaded tests need steps to take
    /// long enough that cancellation can land mid-flight)
    step_delay: Duration,
    /// device calls issued (the fused path's whole point is fewer)
    forwards: usize,
    /// `forward_batch` invocations
    batch_calls: usize,
    /// sequences served through `forward_batch`
    batch_rows: usize,
    /// largest single fused batch observed
    max_batch: usize,
}

struct MockSeq {
    base: u64,
    /// committed length this sequence expects to find in *its* cache
    expect_committed: usize,
}

/// The row tag a sequence's next plan carries; `forward_batch` echoes
/// it back, and `apply_step` cross-checks — a row routed to the wrong
/// sequence fails there.
fn mock_tag(base: u64, emitted: usize) -> u32 {
    ((base + emitted as u64) % 1009) as u32
}

impl MockEngine {
    fn new() -> Self {
        Self::with_delay(Duration::ZERO)
    }

    fn with_delay(step_delay: Duration) -> Self {
        MockEngine {
            seed: 0,
            step_delay,
            forwards: 0,
            batch_calls: 0,
            batch_rows: 0,
            max_batch: 0,
        }
    }

    /// The shared post-forward half of a step: cache identity check,
    /// commit, RNG draw, token emit, accounting.  Used by both the
    /// unfused `step` and the fused `apply_step`, which is exactly the
    /// production plan/apply structure.
    fn advance(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome> {
        let (base, expect) = {
            let st = seq.inner.downcast_ref::<MockSeq>().expect("mock seq state");
            (st.base, st.expect_committed)
        };
        // the scheduler must hand each sequence its own cache back:
        // committed length is this sequence's step counter
        if cache.committed() != expect {
            bail!("cache mixup: committed {} != expected {}", cache.committed(), expect);
        }
        if cache.remaining() > 0 {
            // write this step's tag into the committed row so the cache
            // carries real data — the kv-bucketing executor compares
            // truncated uploads byte-for-byte against these rows
            let slot = cache.committed() as u32;
            let (l, _s, d) = cache.shape();
            let row = vec![mock_tag(base, seq.res.tokens.len()) as f32; 2 * l * d];
            cache.scatter(&row, &[slot])?;
            cache.commit_contiguous(1)?;
        }
        let i = seq.res.tokens.len() as u64;
        let r = seq.rng.below(97) as u64;
        seq.res.tokens.push(((base + i + r) % 127) as u32);
        seq.res.steps += 1;
        seq.res.accepted_per_step.push(1);
        seq.res.input_lens.push(1);
        seq.inner.downcast_mut::<MockSeq>().expect("mock seq state").expect_committed =
            cache.committed();
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(seq.finish(FinishReason::Budget));
        }
        Ok(StepOutcome::Running)
    }
}

impl DecodeEngine for MockEngine {
    fn name(&self) -> &'static str {
        "sched-mock"
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        SHAPE
    }

    fn begin_request(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn request_seed(&self) -> u64 {
        self.seed
    }

    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        cache: &mut HostKvCache,
    ) -> Result<SeqState> {
        if prompt.first() == Some(&0) {
            panic!("mock engine panic");
        }
        cache.reset();
        let committed = prompt.len().min(cache.capacity());
        // prefix-aware "prefill": a prefix-seeded paged cache already
        // holds its first committed() rows, commit only the remainder
        cache.commit_contiguous(committed.saturating_sub(cache.committed()))?;
        let base: u64 = prompt.iter().map(|&t| t as u64).sum();
        Ok(SeqState::new(
            max_new,
            Rng::new(seed),
            Box::new(MockSeq { base, expect_committed: committed }),
        ))
    }

    fn step(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome> {
        if let Some(r) = seq.finished {
            return Ok(StepOutcome::Finished(r));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(seq.finish(FinishReason::Budget));
        }
        self.forwards += 1; // one device call per unfused step
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        self.advance(seq, cache)
    }

    /// The PR 1 run-to-completion path, kept monolithic on purpose: the
    /// reference the step-scheduled outputs must match token-exactly.
    fn generate_with_cache(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        cache: &mut HostKvCache,
    ) -> Result<GenerationResult> {
        if prompt.first() == Some(&0) {
            panic!("mock engine panic");
        }
        cache.reset();
        cache.commit_contiguous(prompt.len().min(cache.capacity()))?;
        let mut rng = Rng::new(self.seed);
        let base: u64 = prompt.iter().map(|&t| t as u64).sum();
        let mut res = GenerationResult::default();
        for i in 0..max_new as u64 {
            let r = rng.below(97) as u64;
            res.tokens.push(((base + i + r) % 127) as u32);
        }
        res.steps = max_new.max(1);
        res.accepted_per_step = vec![1; res.steps];
        res.decode_s = 1e-3;
        Ok(res)
    }
}

impl BatchStepEngine for MockEngine {
    fn plan_step(&mut self, seq: &mut SeqState, cache: &HostKvCache) -> Result<StepPlan> {
        if let Some(r) = seq.finished {
            return Ok(StepPlan::Finished(StepOutcome::Finished(r)));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Budget)));
        }
        let st = seq.inner.downcast_ref::<MockSeq>().expect("mock seq state");
        if cache.committed() != st.expect_committed {
            bail!(
                "cache mixup at plan: committed {} != expected {}",
                cache.committed(),
                st.expect_committed
            );
        }
        let tag = mock_tag(st.base, seq.res.tokens.len());
        Ok(StepPlan::Forward(PlanInputs {
            tokens: vec![tag],
            pos: vec![cache.committed() as u32],
            slots: vec![cache.committed() as u32],
            bias: vec![0.0; SHAPE.1],
            max_ctx: SHAPE.1,
        }))
    }

    fn apply_step(
        &mut self,
        seq: &mut SeqState,
        res: &StepResult<'_>,
        cache: &mut HostKvCache,
    ) -> Result<StepOutcome> {
        // the batched output row must be THIS sequence's echo: a
        // collation/routing mixup across sequences surfaces here
        let want = {
            let st = seq.inner.downcast_ref::<MockSeq>().expect("mock seq state");
            mock_tag(st.base, seq.res.tokens.len()) as f32
        };
        if res.out.logits != [want] {
            bail!("row routed to the wrong sequence: got {:?} want {want}", res.out.logits);
        }
        self.advance(seq, cache)
    }

    fn forward_batch(&mut self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.forwards += 1; // ONE device call for the whole batch
        self.batch_calls += 1;
        self.batch_rows += items.len();
        self.max_batch = self.max_batch.max(items.len());
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        Ok(items
            .iter()
            .map(|it| StepOutput {
                n: 1,
                logits: vec![it.plan.tokens[0] as f32],
                hidden: vec![],
                new_kv: vec![],
            })
            .collect())
    }
}

/// Run-to-completion reference output for (prompt, max_new, seed).
fn reference_tokens(prompt: &[u32], max_new: usize, seed: u64) -> Vec<u32> {
    let mut e = MockEngine::new();
    e.begin_request(seed);
    e.generate(prompt, max_new).unwrap().tokens
}

fn mk_req(id: u64, text: &str, max_new: usize) -> Request {
    Request::builder(workload::encode(text)).id(id).max_new(max_new).build()
}

/// Harness state for hand-scripted schedules.
struct Harness {
    engine: MockEngine,
    pool: SharedCachePool,
    stats: QueueStats,
    sched: StepScheduler,
    rx: mpsc::Receiver<Response>,
    tx: mpsc::Sender<Response>,
}

impl Harness {
    fn new(max_inflight: usize, max_queue_age: Option<Duration>) -> Self {
        Self::with_policy(SchedPolicy { max_inflight, max_queue_age, ..Default::default() })
    }

    /// A harness whose scheduler fuses every tick's steps into one
    /// `forward_batch`.
    fn fused(max_inflight: usize) -> Self {
        Self::with_policy(SchedPolicy {
            max_inflight,
            fuse_steps: true,
            ..Default::default()
        })
    }

    fn with_policy(policy: SchedPolicy) -> Self {
        let (tx, rx) = mpsc::channel();
        Harness {
            engine: MockEngine::new(),
            pool: SharedCachePool::new(policy.max_inflight),
            stats: QueueStats::new(),
            sched: StepScheduler::new(0, policy),
            rx,
            tx,
        }
    }

    fn admit(&mut self, req: Request) -> (bool, ppd::coordinator::CancelFlag) {
        let job = Job::new(req, self.tx.clone());
        let cancel = job.cancel.clone();
        let admitted = self.sched.admit(&mut self.engine, &self.pool, &self.stats, job);
        (admitted, cancel)
    }

    fn tick(&mut self) -> usize {
        self.sched.tick(&mut self.engine, &self.pool, &self.stats)
    }

    fn drain(&mut self) -> Vec<Response> {
        while !self.sched.is_empty() {
            self.tick();
        }
        let mut out = Vec::new();
        while let Ok(r) = self.rx.try_recv() {
            out.push(r);
        }
        out
    }

    /// Script: admit whenever a slot is free, tick otherwise, until
    /// every request retired; responses sorted by id.
    fn run_workload(&mut self, reqs: Vec<Request>) -> Vec<Response> {
        let n = reqs.len();
        let mut pending = reqs.into_iter();
        let mut next = pending.next();
        while next.is_some() || !self.sched.is_empty() {
            while self.sched.has_capacity() {
                match next.take() {
                    Some(r) => {
                        let (ok, _) = self.admit(r);
                        assert!(ok, "admission refused with free capacity");
                        next = pending.next();
                    }
                    None => break,
                }
            }
            self.tick();
        }
        let mut resps = self.drain();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), n);
        resps
    }
}

#[test]
fn step_path_matches_run_to_completion_directly() {
    // sanity before any scheduling: begin_seq + step loop == monolith
    let mut via_steps = MockEngine::new();
    let mut cache = HostKvCache::new(SHAPE.0, SHAPE.1, SHAPE.2);
    let prompt = workload::encode("step equivalence");
    let mut seq = via_steps.begin_seq(&prompt, 10, 7, &mut cache).unwrap();
    while !seq.is_finished() {
        via_steps.step(&mut seq, &mut cache).unwrap();
    }
    assert_eq!(seq.into_result().tokens, reference_tokens(&prompt, 10, 7));
}

fn workload_reqs(n: u64) -> (Vec<Request>, Vec<Vec<u32>>) {
    let reqs: Vec<Request> = (0..n)
        .map(|i| mk_req(i, &format!("request number {i}"), 6 + i as usize))
        .collect();
    let expect = reqs
        .iter()
        .map(|r| reference_tokens(&r.prompt, r.max_new, r.seed))
        .collect();
    (reqs, expect)
}

#[test]
fn scheduler_outputs_are_token_exact_for_every_inflight_depth() {
    let (_, expect) = workload_reqs(6);
    for max_inflight in [1usize, 2, 4] {
        let mut h = Harness::new(max_inflight, None);
        let (reqs, _) = workload_reqs(6);
        let resps = h.run_workload(reqs);
        for (r, want) in resps.iter().zip(&expect) {
            assert!(r.is_ok(), "max_inflight={max_inflight}: {:?}", r.error_msg());
            assert_eq!(
                r.tokens(), *want,
                "max_inflight={max_inflight} perturbed request {}",
                r.id
            );
        }
        // the pool never allocated beyond the admission budget
        assert!(h.pool.created() <= max_inflight);
        assert_eq!(h.pool.outstanding(), 0);
        assert_eq!(h.stats.admitted_total(), 6);
        assert!(h.stats.max_inflight_seqs() as usize <= max_inflight);
        // unfused: one device call per scheduled (non-retiring) step
        assert_eq!(h.stats.fused_batches_total(), 0);
    }
}

#[test]
fn fused_scheduler_outputs_are_token_exact_for_every_inflight_depth() {
    // the tentpole acceptance invariant: fusing every tick's steps into
    // one forward_batch is output-transparent at any interleaving depth
    let (_, expect) = workload_reqs(6);
    for max_inflight in [1usize, 2, 4] {
        let mut h = Harness::fused(max_inflight);
        let (reqs, _) = workload_reqs(6);
        let resps = h.run_workload(reqs);
        for (r, want) in resps.iter().zip(&expect) {
            assert!(r.is_ok(), "max_inflight={max_inflight}: {:?}", r.error_msg());
            assert_eq!(
                r.tokens(), *want,
                "fused max_inflight={max_inflight} perturbed request {}",
                r.id
            );
        }
        assert_eq!(h.pool.outstanding(), 0);
        assert!(h.stats.fused_batches_total() > 0, "fusion never engaged");
        assert_eq!(h.engine.batch_calls as u64, h.stats.fused_batches_total());
        if max_inflight >= 2 {
            // ≥1 tick where one device call served >1 sequence
            assert!(
                h.engine.max_batch >= 2,
                "max_inflight={max_inflight}: no tick ever fused >1 sequence"
            );
            assert_eq!(h.engine.max_batch as u64, h.stats.max_fused_batch());
            // fewer device calls than scheduled steps == amortization
            assert!(
                (h.engine.forwards as u64) < h.stats.sched_steps_total(),
                "fusion bought no device-call reduction"
            );
        }
    }
}

#[test]
fn fused_stepping_halves_device_calls_at_depth_4() {
    // same workload, same scripted schedule, fused vs unfused: with 4
    // in-flight sequences the fused path must issue ≥2× fewer device
    // calls (acceptance criterion), token-exactly
    let (reqs_a, expect) = workload_reqs(8);
    let (reqs_b, _) = workload_reqs(8);

    let mut unfused = Harness::new(4, None);
    let a = unfused.run_workload(reqs_a);
    let mut fused = Harness::fused(4);
    let b = fused.run_workload(reqs_b);

    for ((x, y), want) in a.iter().zip(&b).zip(&expect) {
        assert_eq!(x.tokens(), *want);
        assert_eq!(x.tokens(), y.tokens(), "fusion changed request {} output", x.id);
    }
    assert!(
        fused.engine.forwards * 2 <= unfused.engine.forwards,
        "fused {} vs unfused {} device calls: < 2x reduction",
        fused.engine.forwards,
        unfused.engine.forwards
    );
    assert!(fused.engine.max_batch >= 2, "no tick fused more than one sequence");
    // every scheduled step still happened — only the dispatch fused:
    // each step planned a forward, so fused rows == scheduled steps
    assert_eq!(fused.stats.sched_steps_total(), unfused.stats.sched_steps_total());
    assert_eq!(fused.engine.batch_rows as u64, fused.stats.sched_steps_total());
}

#[test]
fn mid_flight_admission_never_perturbs_a_running_sequence() {
    for fuse in [false, true] {
        let a = mk_req(0, "long running sequence a", 12);
        let b = mk_req(1, "late arrival b", 5);
        let want_a = reference_tokens(&a.prompt, a.max_new, a.seed);
        let want_b = reference_tokens(&b.prompt, b.max_new, b.seed);

        let mut h = if fuse { Harness::fused(2) } else { Harness::new(2, None) };
        let (ok, _) = h.admit(a);
        assert!(ok);
        // A runs alone for three steps...
        for _ in 0..3 {
            assert_eq!(h.tick(), 1, "fuse={fuse}");
        }
        // ...then B is admitted mid-flight and they interleave
        let (ok, _) = h.admit(b);
        assert!(ok);
        assert_eq!(h.sched.len(), 2);
        let mut resps = h.drain();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].tokens(), want_a, "fuse={fuse}: mid-flight admission perturbed A");
        assert_eq!(resps[1].tokens(), want_b, "fuse={fuse}: interleaving perturbed B");
        // B (5 tokens) retired before A (12 tokens) despite admission order
        assert_eq!(h.stats.max_inflight_seqs(), 2);
        if fuse {
            assert!(h.engine.max_batch >= 2, "interleaved steps never fused");
        }
    }
}

#[test]
fn out_of_order_retirement_routes_replies_to_their_own_channels() {
    // two reply channels, different lengths: the short one's response
    // must arrive on its own channel while the long one is in flight
    let mut engine = MockEngine::new();
    let pool = SharedCachePool::new(2);
    let stats = QueueStats::new();
    let mut sched = StepScheduler::new(
        0,
        SchedPolicy { max_inflight: 2, ..Default::default() },
    );

    let (tx_long, rx_long) = mpsc::channel();
    let (tx_short, rx_short) = mpsc::channel();
    let long = mk_req(10, "the long request", 9);
    let short = mk_req(11, "short", 2);
    let want_long = reference_tokens(&long.prompt, long.max_new, long.seed);
    let want_short = reference_tokens(&short.prompt, short.max_new, short.seed);

    sched.admit(&mut engine, &pool, &stats, Job::new(long, tx_long));
    sched.admit(&mut engine, &pool, &stats, Job::new(short, tx_short));
    sched.tick(&mut engine, &pool, &stats);
    sched.tick(&mut engine, &pool, &stats);
    // short (2 tokens) is done; long is still running
    let r_short = rx_short.try_recv().expect("short retired first");
    assert_eq!(r_short.id, 11);
    assert_eq!(r_short.tokens(), want_short);
    assert!(rx_long.try_recv().is_err(), "long must still be in flight");
    assert_eq!(sched.len(), 1);
    while !sched.is_empty() {
        sched.tick(&mut engine, &pool, &stats);
    }
    let r_long = rx_long.try_recv().expect("long retired");
    assert_eq!(r_long.id, 10);
    assert_eq!(r_long.tokens(), want_long);
}

#[test]
fn stale_job_is_dropped_with_an_error_response() {
    let mut h = Harness::new(2, Some(Duration::from_millis(30)));
    let job_req = mk_req(0, "will expire", 4);
    let fresh_req = mk_req(1, "still fresh", 4);
    let want_fresh = reference_tokens(&fresh_req.prompt, 4, 1);

    // build the stale job first, let it age past the deadline
    let stale = Job::new(job_req, h.tx.clone());
    std::thread::sleep(Duration::from_millis(60));
    let admitted = h.sched.admit(&mut h.engine, &h.pool, &h.stats, stale);
    assert!(!admitted, "stale job must not be admitted");
    assert_eq!(h.stats.expired_total(), 1);
    let resp = h.rx.try_recv().expect("expired job still gets a response");
    assert_eq!(resp.id, 0);
    let msg = resp.error_msg().unwrap_or_default();
    assert!(msg.contains("max queue age"), "unexpected error: {msg}");
    // no cache was consumed by the drop
    assert_eq!(h.pool.outstanding(), 0);

    // a fresh job on the same scheduler still runs normally
    let (ok, _) = h.admit(fresh_req);
    assert!(ok);
    let resps = h.drain();
    assert_eq!(resps.len(), 1);
    assert_eq!(resps[0].tokens(), want_fresh);
}

#[test]
fn cancelled_job_is_refused_at_admission() {
    let mut h = Harness::new(2, None);
    let job = Job::new(mk_req(0, "cancel me early", 8), h.tx.clone());
    job.cancel.cancel();
    let admitted = h.sched.admit(&mut h.engine, &h.pool, &h.stats, job);
    assert!(!admitted);
    assert_eq!(h.stats.cancelled_total(), 1);
    let resp = h.rx.try_recv().expect("cancelled job gets an error response");
    assert!(resp.error_msg().unwrap_or_default().contains("cancelled"));
    assert_eq!(h.pool.outstanding(), 0);
}

#[test]
fn cancelled_inflight_sequence_frees_its_cache() {
    for fuse in [false, true] {
        let mut h = if fuse { Harness::fused(2) } else { Harness::new(2, None) };
        let (ok, cancel) = h.admit(mk_req(0, "cancel me mid flight", 50));
        assert!(ok);
        h.tick();
        h.tick();
        assert_eq!(h.pool.outstanding(), 1, "running sequence holds its cache");
        cancel.cancel();
        let still_running = h.tick();
        assert_eq!(still_running, 0, "fuse={fuse}: cancelled sequence must retire on the next tick");
        assert_eq!(h.pool.outstanding(), 0, "fuse={fuse}: cancel must return the cache to the pool");
        assert_eq!(h.stats.cancelled_total(), 1);
        let resp = h.rx.try_recv().expect("cancelled sequence answers its channel");
        assert!(resp.error_msg().unwrap_or_default().contains("cancelled"));
        // the freed cache is immediately reusable
        let (ok, _) = h.admit(mk_req(1, "next request reuses the slot", 3));
        assert!(ok);
        assert_eq!(h.pool.created(), 1, "cancelled sequence's cache was reused, not reallocated");
    }
}

#[test]
fn paged_pool_is_token_exact_for_serial_and_fused_scheduling() {
    // paged acceptance, host half: swapping the slab pool for a
    // block-budgeted paged pool is output-transparent on the unfused
    // and fused step paths at every inflight depth — and the shared
    // "request " prompt chunk prefills once, so every later admission
    // hits the prefix store
    let (_, expect) = workload_reqs(6);
    for fused in [false, true] {
        for max_inflight in [1usize, 2, 4] {
            let mut h =
                if fused { Harness::fused(max_inflight) } else { Harness::new(max_inflight, None) };
            h.pool = SharedCachePool::with_block_budget(max_inflight, 256);
            let (reqs, _) = workload_reqs(6);
            let resps = h.run_workload(reqs);
            for (r, want) in resps.iter().zip(&expect) {
                assert!(r.is_ok(), "fused={fused} inflight={max_inflight}: {:?}", r.error_msg());
                assert_eq!(
                    r.tokens(), *want,
                    "paged pool perturbed request {} (fused={fused}, inflight={max_inflight})",
                    r.id
                );
            }
            assert_eq!(h.pool.outstanding(), 0);
            // every retired sequence returned its pages on checkin;
            // only the store-pinned shared prompt chunk stays resident
            assert_eq!(h.pool.blocks_used(), 1, "fused={fused} inflight={max_inflight}");
            assert!(h.pool.peak_blocks_used() > 1, "paged pool never engaged");
            // request 0 publishes the "request " chunk, requests 1-5 hit it
            assert_eq!(h.pool.prefix_hits(), 5, "fused={fused} inflight={max_inflight}");
            assert!(h.pool.prefix_blocks_shared() >= 5);
        }
    }
}

#[test]
fn cancelled_paged_sequence_returns_its_pages() {
    // refcount hygiene through cancel: the cancelled sequence's private
    // pages go back to the pool; its published prompt chunks stay in
    // the store and serve the next admission of the same prompt
    for fuse in [false, true] {
        let mut h = if fuse { Harness::fused(2) } else { Harness::new(2, None) };
        h.pool = SharedCachePool::with_block_budget(2, 64);
        let (ok, cancel) = h.admit(mk_req(0, "cancel me mid flight", 50));
        assert!(ok);
        h.tick();
        h.tick();
        // 20 prompt rows + 2 generated rows = 3 pages at 8 slots/page
        assert!(h.pool.blocks_used() >= 3, "running sequence holds its pages");
        cancel.cancel();
        h.tick();
        assert_eq!(h.pool.outstanding(), 0, "fuse={fuse}");
        // the prompt covers 2 whole 8-slot chunks, both published at
        // admission — exactly those survive the cancel, nothing else
        assert_eq!(
            h.pool.blocks_used(),
            2,
            "fuse={fuse}: cancel must free every page the store does not pin"
        );
        let (ok, _) = h.admit(mk_req(1, "cancel me mid flight", 3));
        assert!(ok);
        assert_eq!(
            h.pool.prefix_hits(),
            1,
            "fuse={fuse}: readmission must reuse the cancelled sequence's prompt chunks"
        );
        h.drain();
        assert_eq!(h.pool.outstanding(), 0);
        assert_eq!(h.pool.blocks_used(), 2, "fuse={fuse}");
    }
}

#[test]
fn panicking_begin_seq_refuses_job_and_keeps_scheduler_alive() {
    let mut h = Harness::new(2, None);
    // prompt token 0 is unreachable from workload::encode on real text;
    // the mock uses it to simulate an engine panic
    let job = Job::new(Request::builder(vec![0]).max_new(4).build(), h.tx.clone());
    let admitted = h.sched.admit(&mut h.engine, &h.pool, &h.stats, job);
    assert!(!admitted);
    let resp = h.rx.try_recv().expect("panic surfaces as error response");
    assert!(resp.error_msg().unwrap_or_default().contains("panic"));
    assert_eq!(h.pool.outstanding(), 0, "panicked admission must not leak its cache");
    // scheduler still serves
    let (ok, _) = h.admit(mk_req(1, "after the panic", 3));
    assert!(ok);
    assert_eq!(h.drain().len(), 1);
}

// ---- scripted shared-runtime harness (many schedulers, no threads) ----

/// The dispatcher-side executor for shared-runtime tests: echoes every
/// plan's tag row (the same contract as `MockEngine::forward_batch`, so
/// `apply_step`'s routing check still bites) and counts device calls.
struct MockExec {
    forwards: AtomicUsize,
    /// union width of every fused device call, in order
    widths: Mutex<Vec<usize>>,
    /// artificial device latency (threaded cancellation tests need wall
    /// ticks slow enough for a cancel to land mid-flight)
    delay: Duration,
}

impl MockExec {
    fn new() -> Self {
        Self::with_delay(Duration::ZERO)
    }

    fn with_delay(delay: Duration) -> Self {
        MockExec { forwards: AtomicUsize::new(0), widths: Mutex::new(Vec::new()), delay }
    }

    fn forwards(&self) -> usize {
        self.forwards.load(Ordering::SeqCst)
    }
}

impl DeviceExecutor for MockExec {
    fn exec_forward(
        &self,
        tokens: &[u32],
        _pos: &[u32],
        _slots: &[u32],
        _bias: &[f32],
        _cache: &[f32],
    ) -> Result<StepOutput> {
        self.forwards.fetch_add(1, Ordering::SeqCst);
        Ok(StepOutput { n: 1, logits: vec![tokens[0] as f32], hidden: vec![], new_kv: vec![] })
    }

    fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.forwards.fetch_add(1, Ordering::SeqCst); // ONE call, any width
        self.widths.lock().unwrap().push(items.len());
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(items
            .iter()
            .map(|it| StepOutput {
                n: 1,
                logits: vec![it.plan.tokens[0] as f32],
                hidden: vec![],
                new_kv: vec![],
            })
            .collect())
    }
}

/// KV-bucketing executor: runs the REAL union-max-slot → covering-
/// bucket → collate(truncate) → split pipeline `Runtime::forward_batch`
/// uses, over the mock engine's echo contract.  Every fused call
/// verifies the truncated cache-union upload still carries each row's
/// committed bytes exactly (the mock engine scatters a per-step tag
/// into its cache, so corruption is detectable), then echoes each
/// row's tag back through `split` — a selection, truncation, or
/// routing bug either errors the batch here or trips `apply_step`'s
/// wrong-tag check.  Reports the selected kv through the meta channel
/// so the dispatcher's `ppd_dispatch_kv_bucket` histogram fills in.
struct KvExec {
    kv_buckets: Vec<usize>,
    /// models `PPD_DISABLE_KV_BUCKETS` without touching process env
    /// (the runtime reads the env var; selection itself is this flag)
    disabled: bool,
    forwards: AtomicUsize,
}

impl KvExec {
    fn new(kv_buckets: Vec<usize>, disabled: bool) -> Self {
        KvExec { kv_buckets, disabled, forwards: AtomicUsize::new(0) }
    }

    fn run(&self, items: &[BatchItem<'_>]) -> Result<(Vec<StepOutput>, usize)> {
        self.forwards.fetch_add(1, Ordering::SeqCst);
        let full = SHAPE.1;
        let (planes, d) = (2 * SHAPE.0, SHAPE.2);
        let max_slot = union_max_slot(items);
        let kv = select_kv_bucket(&self.kv_buckets, full, max_slot, self.disabled, |_| true);
        let k = items.len();
        let n = items.iter().map(|it| it.plan.len()).max().unwrap_or(1);
        let c = collate(items, k, n, planes, full, d, kv)?;
        // the truncated union must still carry every row's cache bytes
        for (i, it) in items.iter().enumerate() {
            let full_cache = it.cache.as_slice();
            for p in 0..planes {
                let dst = (i * planes + p) * kv * d;
                let src = p * full * d;
                if c.cache[dst..dst + kv * d] != full_cache[src..src + kv * d] {
                    bail!("kv truncation corrupted row {i} plane {p}");
                }
            }
        }
        // echo each row's tag token through the padded device layout
        let vocab = 1;
        let mut logits = vec![0.0f32; k * n * vocab];
        for i in 0..k {
            logits[i * n] = c.tokens[i * n] as f32;
        }
        let hidden = vec![0.0f32; k * n * d];
        let new_kv = vec![0.0f32; k * planes * n * d];
        Ok((split(&c, &logits, &hidden, &new_kv, vocab)?, kv))
    }
}

impl DeviceExecutor for KvExec {
    fn exec_forward(
        &self,
        tokens: &[u32],
        _pos: &[u32],
        _slots: &[u32],
        _bias: &[f32],
        _cache: &[f32],
    ) -> Result<StepOutput> {
        self.forwards.fetch_add(1, Ordering::SeqCst);
        Ok(StepOutput { n: 1, logits: vec![tokens[0] as f32], hidden: vec![], new_kv: vec![] })
    }

    fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.run(items).map(|(outs, _)| outs)
    }

    fn exec_forward_batch_meta(
        &self,
        items: &[BatchItem<'_>],
    ) -> Result<(Vec<StepOutput>, BatchMeta)> {
        self.run(items).map(|(outs, kv)| (outs, BatchMeta { kv: Some(kv) }))
    }

    /// Advertise a batched-graph inventory so the pipelined dispatcher
    /// pre-collates rounds on its collector stage — the path
    /// `Runtime::batch_inventory` feeds in production.  Single-token
    /// plans, `fwd_b{2,4,8}` ladder, every kv variant present.
    fn batch_inventory(&self) -> Option<BatchInventory> {
        let (planes, d) = (2 * SHAPE.0, SHAPE.2);
        let tree_buckets = vec![1];
        let batch_buckets = vec![2, 4, 8];
        let mut available = std::collections::BTreeSet::new();
        for &b in &batch_buckets {
            for &n in &tree_buckets {
                available.insert((b, n, SHAPE.1));
                for &kv in &self.kv_buckets {
                    available.insert((b, n, kv));
                }
            }
        }
        Some(BatchInventory {
            tree_buckets,
            batch_buckets,
            kv_buckets: self.kv_buckets.clone(),
            available,
            planes,
            max_ctx: SHAPE.1,
            d,
            kv_disabled: self.disabled,
        })
    }

    /// Execute a round the collector stage already collated: echo each
    /// real row's tag through the padded `[batch, n]` device layout and
    /// split — the same contract as [`KvExec::run`], so a divergence
    /// between the pre-collated and executor-collated paths trips
    /// `apply_step`'s wrong-tag check.
    fn exec_collated(&self, c: &CollatedBatch) -> Result<(Vec<StepOutput>, BatchMeta)> {
        self.forwards.fetch_add(1, Ordering::SeqCst);
        let (b, n, d, planes) = (c.batch, c.n, c.d, c.planes);
        let vocab = 1;
        let mut logits = vec![0.0f32; b * n * vocab];
        for i in 0..c.rows {
            logits[i * n] = c.tokens[i * n] as f32;
        }
        let hidden = vec![0.0f32; b * n * d];
        let new_kv = vec![0.0f32; b * planes * n * d];
        Ok((split(c, &logits, &hidden, &new_kv, vocab)?, BatchMeta { kv: Some(c.kv) }))
    }
}

/// N hand-driven schedulers sharing ONE dispatcher/executor — the
/// deterministic model of the `--shared-runtime` topology.  A wall tick
/// is: every scheduler plans + submits, the dispatcher flushes once,
/// every scheduler applies.  Generic over the executor so the
/// kv-bucketing tests can swap in [`KvExec`]; defaults to [`MockExec`].
struct SharedHarness<E: DeviceExecutor = MockExec> {
    scheds: Vec<StepScheduler>,
    engines: Vec<MockEngine>,
    pool: Arc<SharedCachePool>,
    stats: Arc<QueueStats>,
    dispatcher: DeviceDispatcher,
    dstats: Arc<DispatchStats>,
    exec: E,
    /// flush rounds through the dispatcher's pipelined prepare/
    /// pre-collate path (`pump_pipelined`) instead of the plain pump
    pipelined: bool,
    tx: mpsc::Sender<Response>,
    rx: mpsc::Receiver<Response>,
}

impl SharedHarness<MockExec> {
    fn new(workers: usize, max_inflight: usize) -> Self {
        Self::with_exec(workers, max_inflight, MockExec::new())
    }

    fn pipelined(workers: usize, max_inflight: usize) -> Self {
        Self::build(workers, max_inflight, MockExec::new(), true)
    }
}

impl<E: DeviceExecutor> SharedHarness<E> {
    fn with_exec(workers: usize, max_inflight: usize, exec: E) -> Self {
        Self::build(workers, max_inflight, exec, false)
    }

    fn build(workers: usize, max_inflight: usize, exec: E, pipelined: bool) -> Self {
        let pool = Arc::new(SharedCachePool::new(workers * max_inflight));
        Self::build_with_pool(workers, max_inflight, exec, pipelined, pool)
    }

    /// `build` with a caller-supplied pool (the paged-KV grids swap in
    /// a `SharedCachePool::with_block_budget`).
    fn build_with_pool(
        workers: usize,
        max_inflight: usize,
        exec: E,
        pipelined: bool,
        pool: Arc<SharedCachePool>,
    ) -> Self {
        let dstats = Arc::new(DispatchStats::default());
        let (handle, dispatcher) =
            DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::clone(&dstats));
        let policy = SchedPolicy {
            max_inflight,
            shared_runtime: true,
            pipelined,
            ..Default::default()
        };
        let stats = Arc::new(QueueStats::new());
        let scheds = (0..workers)
            .map(|w| {
                StepScheduler::with_dispatcher(
                    w,
                    policy,
                    handle.clone(),
                    Arc::clone(&pool),
                    Arc::clone(&stats),
                )
            })
            .collect();
        let engines = (0..workers).map(|_| MockEngine::new()).collect();
        let (tx, rx) = mpsc::channel();
        SharedHarness {
            scheds,
            engines,
            pool,
            stats,
            dispatcher,
            dstats,
            exec,
            pipelined,
            tx,
            rx,
        }
    }

    fn admit(&mut self, w: usize, req: Request) -> (bool, ppd::coordinator::CancelFlag) {
        let job = Job::new(req, self.tx.clone());
        let cancel = job.cancel.clone();
        let ok = self.scheds[w].admit(&mut self.engines[w], &self.pool, &self.stats, job);
        (ok, cancel)
    }

    fn busy(&self) -> bool {
        self.scheds.iter().any(|s| !s.is_empty())
    }

    /// Phase A of a wall tick: every scheduler plans and submits its
    /// fused rows to the dispatcher.
    fn submit_all(&mut self) {
        for (s, e) in self.scheds.iter_mut().zip(self.engines.iter_mut()) {
            s.tick_shared_submit(e, &self.pool, &self.stats);
        }
    }

    /// The dispatcher flush; pipelined harnesses route through
    /// [`DeviceDispatcher::pump_pipelined`] so every round takes the
    /// prepare/pre-collate path the collector stage runs in production.
    fn pump_round(&mut self) -> usize {
        if self.pipelined {
            self.dispatcher.pump_pipelined(&self.exec)
        } else {
            self.dispatcher.pump(&self.exec)
        }
    }

    /// Phase B: every scheduler joins its reply and applies the round.
    fn complete_all(&mut self) {
        for (s, e) in self.scheds.iter_mut().zip(self.engines.iter_mut()) {
            s.tick_shared_complete(e, &self.pool, &self.stats);
        }
    }

    /// One wall tick across every scheduler; returns the device calls
    /// it cost (the tentpole claim: ≤ 1, however many workers ran).
    fn wall_tick(&mut self) -> usize {
        self.submit_all();
        let calls = self.pump_round();
        self.complete_all();
        calls
    }

    fn drain_responses(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        while let Ok(r) = self.rx.try_recv() {
            out.push(r);
        }
        out
    }
}

#[test]
fn shared_runtime_is_token_exact_at_every_worker_and_inflight_depth() {
    // the tentpole acceptance invariant: routing every worker's tick
    // through one shared dispatcher is output-transparent at workers
    // 1/2/4 × max_inflight 1/2/4 — and no wall tick ever costs more
    // than one device call
    let (_, expect) = workload_reqs(8);
    for workers in [1usize, 2, 4] {
        for max_inflight in [1usize, 2, 4] {
            let mut h = SharedHarness::new(workers, max_inflight);
            let (reqs, _) = workload_reqs(8);
            let mut pending: std::collections::VecDeque<Request> =
                reqs.into_iter().collect();
            while !pending.is_empty() || h.busy() {
                // opportunistic admission on every scheduler with a free
                // slot — sequences join mid-flight constantly
                for w in 0..workers {
                    if h.scheds[w].has_capacity() {
                        if let Some(r) = pending.pop_front() {
                            let (ok, _) = h.admit(w, r);
                            assert!(ok, "admission refused with free capacity");
                        }
                    }
                }
                let calls = h.wall_tick();
                assert!(
                    calls <= 1,
                    "workers={workers} inflight={max_inflight}: wall tick cost {calls} device calls"
                );
            }
            let mut resps = h.drain_responses();
            resps.sort_by_key(|r| r.id);
            assert_eq!(resps.len(), 8);
            for (r, want) in resps.iter().zip(&expect) {
                assert!(r.is_ok(), "{:?}", r.error_msg());
                assert_eq!(
                    r.tokens(), *want,
                    "shared runtime perturbed request {} (workers={workers}, inflight={max_inflight})",
                    r.id
                );
            }
            assert_eq!(h.pool.outstanding(), 0);
            assert_eq!(h.dstats.queue_depth(), 0, "submissions leaked in the window");
            // every scheduled step's row went through the dispatcher
            assert_eq!(h.dstats.rows_total(), h.stats.sched_steps_total());
            assert_eq!(h.exec.forwards(), h.dstats.batches_total() as usize);
        }
    }
}

#[test]
fn paged_pool_is_token_exact_for_shared_and_pipelined_dispatch() {
    // paged acceptance, dispatcher half: the block-budgeted pool under
    // the shared-runtime and pipelined tick paths is output-transparent
    // at workers 1/2/4 × max_inflight 1/2/4, with cross-worker prefix
    // sharing through the one pool
    let (_, expect) = workload_reqs(8);
    for pipelined in [false, true] {
        for workers in [1usize, 2, 4] {
            for max_inflight in [1usize, 2, 4] {
                let pool = Arc::new(SharedCachePool::with_block_budget(
                    workers * max_inflight,
                    256,
                ));
                let mut h = SharedHarness::build_with_pool(
                    workers,
                    max_inflight,
                    MockExec::new(),
                    pipelined,
                    pool,
                );
                let (reqs, _) = workload_reqs(8);
                let mut pending: std::collections::VecDeque<Request> =
                    reqs.into_iter().collect();
                while !pending.is_empty() || h.busy() {
                    for w in 0..workers {
                        if h.scheds[w].has_capacity() {
                            if let Some(r) = pending.pop_front() {
                                assert!(h.admit(w, r).0, "admission refused with free capacity");
                            }
                        }
                    }
                    h.wall_tick();
                }
                let mut resps = h.drain_responses();
                resps.sort_by_key(|r| r.id);
                assert_eq!(resps.len(), 8);
                for (r, want) in resps.iter().zip(&expect) {
                    assert!(r.is_ok(), "pipelined={pipelined}: {:?}", r.error_msg());
                    assert_eq!(
                        r.tokens(), *want,
                        "paged pool perturbed request {} (pipelined={pipelined}, \
                         workers={workers}, inflight={max_inflight})",
                        r.id
                    );
                }
                assert_eq!(h.pool.outstanding(), 0);
                // retired pages all came back; only the store-pinned
                // shared prompt chunk is still resident
                assert_eq!(
                    h.pool.blocks_used(),
                    1,
                    "pipelined={pipelined} workers={workers} inflight={max_inflight}"
                );
                // the first admission publishes "request ", all seven
                // later admissions — across every worker — hit it
                assert_eq!(
                    h.pool.prefix_hits(),
                    7,
                    "pipelined={pipelined} workers={workers} inflight={max_inflight}"
                );
            }
        }
    }
}

#[test]
fn kv_bucketed_shared_dispatch_is_token_exact_at_every_depth() {
    // acceptance (KV-length bucketing for batched graphs): executing
    // the cross-worker union at the smallest covering kv bucket —
    // through the REAL collate/truncate/split pipeline — is
    // token-exact with full-context execution at workers 1/2/4 ×
    // max_inflight 1/2/4, and the dispatcher's kv histogram shows the
    // short buckets actually engaging
    let (_, expect) = workload_reqs(8);
    for workers in [1usize, 2, 4] {
        for max_inflight in [1usize, 2, 4] {
            let mut per_mode: Vec<Vec<Response>> = Vec::new();
            for disabled in [false, true] {
                let mut h = SharedHarness::with_exec(
                    workers,
                    max_inflight,
                    KvExec::new(vec![16, 32, 48], disabled),
                );
                let (reqs, _) = workload_reqs(8);
                let mut pending: std::collections::VecDeque<Request> =
                    reqs.into_iter().collect();
                while !pending.is_empty() || h.busy() {
                    for w in 0..workers {
                        if h.scheds[w].has_capacity() {
                            if let Some(r) = pending.pop_front() {
                                assert!(h.admit(w, r).0, "admission refused");
                            }
                        }
                    }
                    let calls = h.wall_tick();
                    assert!(
                        calls <= 1,
                        "workers={workers} inflight={max_inflight}: {calls} calls per tick"
                    );
                }
                let mut resps = h.drain_responses();
                resps.sort_by_key(|r| r.id);
                assert_eq!(resps.len(), 8);
                for (r, want) in resps.iter().zip(&expect) {
                    assert!(r.is_ok(), "disabled={disabled}: {:?}", r.error_msg());
                    assert_eq!(
                        r.tokens(), *want,
                        "kv bucketing (disabled={disabled}) perturbed request {} \
                         (workers={workers}, inflight={max_inflight})",
                        r.id
                    );
                }
                assert_eq!(h.pool.outstanding(), 0);
                let hist = h.dstats.kv_hist();
                assert!(!hist.is_empty(), "no fused batch reported its kv context");
                if disabled {
                    // PPD_DISABLE_KV_BUCKETS semantics: full ctx only
                    assert!(
                        hist.keys().all(|&kv| kv == SHAPE.1),
                        "disabled run left full context: {hist:?}"
                    );
                } else {
                    // these prompts keep every slot below 47, so some
                    // short bucket must have been selected
                    assert!(
                        hist.keys().any(|&kv| kv < SHAPE.1),
                        "short kv buckets never engaged: {hist:?}"
                    );
                }
                per_mode.push(resps);
            }
            // bucketed == full-context, byte for byte
            for (a, b) in per_mode[0].iter().zip(&per_mode[1]) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.tokens(), b.tokens(),
                    "short-kv vs full-ctx diverged on request {} \
                     (workers={workers}, inflight={max_inflight})",
                    a.id
                );
            }
        }
    }
}

#[test]
fn all_short_riders_select_the_smallest_kv_bucket() {
    // acceptance: when every rider is short, the union max slot stays
    // below the smallest bucket and ONLY that bucket executes —
    // observable through the new kv-bucket stats
    let workers = 2;
    let mut h =
        SharedHarness::with_exec(workers, 2, KvExec::new(vec![16, 32, 48], false));
    let reqs: Vec<Request> =
        (0..4).map(|i| Request::builder(workload::encode("ab")).id(i).max_new(4).build()).collect();
    let expect: Vec<Vec<u32>> = reqs
        .iter()
        .map(|r| reference_tokens(&r.prompt, r.max_new, r.seed))
        .collect();
    for (i, r) in reqs.into_iter().enumerate() {
        assert!(h.admit(i % workers, r).0);
    }
    let mut ticks = 0;
    while h.busy() {
        assert!(h.wall_tick() <= 1);
        ticks += 1;
        assert!(ticks < 50, "workload failed to drain");
    }
    let mut resps = h.drain_responses();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), 4);
    for (r, want) in resps.iter().zip(&expect) {
        assert!(r.is_ok(), "{:?}", r.error_msg());
        assert_eq!(r.tokens(), *want);
    }
    // prompt "ab" commits 2 rows and 4 steps keep every slot ≤ 6: the
    // 16-slot bucket covers every tick, so nothing larger may appear
    let hist = h.dstats.kv_hist();
    assert_eq!(hist.keys().copied().collect::<Vec<_>>(), vec![16], "{hist:?}");
    assert!(h.dstats.max_union_slot() < 15, "{}", h.dstats.max_union_slot());
    assert_eq!(h.pool.outstanding(), 0);
}

#[test]
fn shared_dispatch_is_one_device_call_per_wall_tick_with_four_workers() {
    // acceptance criterion, exactly: 4 busy workers under the shared
    // runtime cost 1 device call per wall tick where the per-worker-
    // fused topology costs 4 — token-exactly, including a mid-flight
    // admission and a cancellation
    let workers = 4;
    let mut h = SharedHarness::new(workers, 2);
    let mut fused: Vec<Harness> = (0..workers).map(|_| Harness::fused(2)).collect();

    // the same 4 requests (one per worker) on both topologies
    let (reqs_a, expect) = workload_reqs(4);
    let (reqs_b, _) = workload_reqs(4);
    for (w, r) in reqs_a.into_iter().enumerate() {
        assert!(h.admit(w, r).0);
    }
    for (w, r) in reqs_b.into_iter().enumerate() {
        assert!(fused[w].admit(r).0);
    }
    // a doomed second sequence on worker 0, cancelled at tick 1
    let (ok, cancel) = h.admit(0, mk_req(91, "cancelled mid flight", 40));
    assert!(ok);
    let (ok, cancel_twin) = fused[0].admit(mk_req(91, "cancelled mid flight", 40));
    assert!(ok);

    // a late arrival admitted mid-flight on worker 1, at tick 2
    let mut late =
        Some((mk_req(90, "late arrival", 5), mk_req(90, "late arrival", 5)));
    let want_late = {
        let r = &late.as_ref().unwrap().0;
        reference_tokens(&r.prompt, r.max_new, r.seed)
    };

    let mut tick = 0usize;
    while h.busy() || fused.iter().any(|f| !f.sched.is_empty()) {
        if tick == 1 {
            cancel.cancel();
            cancel_twin.cancel();
        }
        if tick == 2 {
            let (a, b) = late.take().expect("late admitted exactly once");
            assert!(h.admit(1, a).0, "mid-flight admission refused");
            assert!(fused[1].admit(b).0);
        }
        let all_busy = h.scheds.iter().all(|s| !s.is_empty());
        let calls = h.wall_tick();
        assert!(calls <= 1, "wall tick {tick} cost {calls} device calls");
        let fused_calls: usize = fused
            .iter_mut()
            .map(|f| {
                let before = f.engine.forwards;
                if !f.sched.is_empty() {
                    f.tick();
                }
                f.engine.forwards - before
            })
            .sum();
        if all_busy {
            assert_eq!(
                calls, 1,
                "tick {tick}: 4 busy workers must cost exactly ONE shared device call"
            );
            assert_eq!(
                fused_calls, workers,
                "tick {tick}: per-worker fusion costs one call per busy worker"
            );
        }
        tick += 1;
        assert!(tick < 200, "workload failed to drain");
    }
    assert!(late.is_none(), "the mid-flight admission case never ran");

    // token-exactness: shared responses == per-worker-fused responses
    // == the run-to-completion reference
    let mut a = h.drain_responses();
    a.sort_by_key(|r| r.id);
    let mut b: Vec<Response> = fused.iter_mut().flat_map(|f| f.drain()).collect();
    b.sort_by_key(|r| r.id);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens(), y.tokens(), "shared diverged from per-worker-fused on {}", x.id);
        assert_eq!(x.is_ok(), y.is_ok());
    }
    for (r, want) in a.iter().take(4).zip(&expect) {
        assert!(r.is_ok(), "{:?}", r.error_msg());
        assert_eq!(r.tokens(), *want, "shared runtime perturbed request {}", r.id);
    }
    let late_resp = a.iter().find(|r| r.id == 90).expect("late request completed");
    assert_eq!(late_resp.tokens(), want_late, "mid-flight admission perturbed the late request");
    let doomed_resp = a.iter().find(|r| r.id == 91).expect("cancelled request answered");
    assert!(doomed_resp.error_msg().unwrap_or_default().contains("cancelled"));
    // cross-worker fusion demonstrably engaged
    assert!(h.dstats.multi_worker_batches_total() > 0, "no batch ever spanned workers");
    assert!(h.dstats.max_width() >= 2);
    assert_eq!(h.pool.outstanding(), 0);
}

#[test]
fn shared_scheduler_cancellation_frees_cache_and_costs_no_device_call() {
    let mut h = SharedHarness::new(2, 2);
    let (ok, cancel) = h.admit(0, mk_req(0, "cancel me in shared mode", 50));
    assert!(ok);
    h.wall_tick();
    h.wall_tick();
    assert_eq!(h.pool.outstanding(), 1);
    cancel.cancel();
    let calls = h.wall_tick();
    assert_eq!(calls, 0, "a tick that only cancels must not touch the device");
    assert!(!h.busy());
    assert_eq!(h.pool.outstanding(), 0, "cancel must return the cache to the pool");
    assert_eq!(h.stats.cancelled_total(), 1);
    let resp = h.rx.try_recv().expect("cancelled sequence answers its channel");
    assert!(resp.error_msg().unwrap_or_default().contains("cancelled"));
}

#[test]
fn dead_dispatcher_fails_sequences_and_reconciles_the_pool() {
    // submit-side loss: the dispatcher dies before the next tick — the
    // rows come straight back, sequences retire with errors, caches
    // return to the pool
    let mut h = SharedHarness::new(2, 1);
    let (ok, _) = h.admit(0, mk_req(0, "submit side loss", 9));
    assert!(ok);
    let (ok, _) = h.admit(1, mk_req(1, "submit side loss b", 9));
    assert!(ok);
    h.wall_tick();
    let (_, dummy) =
        DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::new(DispatchStats::default()));
    drop(std::mem::replace(&mut h.dispatcher, dummy));
    h.wall_tick();
    assert!(!h.busy());
    assert_eq!(h.pool.outstanding(), 0, "returned rows must check their caches in");
    let resps = h.drain_responses();
    assert_eq!(resps.len(), 2);
    for r in resps {
        assert!(
            r.error_msg().unwrap_or_default().contains("dispatcher"),
            "{:?}",
            r.error_msg()
        );
    }

    // reply-side loss: submissions are in flight when the dispatcher
    // dies — the caches are gone with it, and the pool's outstanding
    // count must be reconciled (not leaked against the cap)
    let mut h = SharedHarness::new(2, 1);
    let (ok, _) = h.admit(0, mk_req(0, "reply side loss", 9));
    assert!(ok);
    let (ok, _) = h.admit(1, mk_req(1, "reply side loss b", 9));
    assert!(ok);
    for (s, e) in h.scheds.iter_mut().zip(h.engines.iter_mut()) {
        s.tick_shared_submit(e, &h.pool, &h.stats);
    }
    assert_eq!(h.pool.outstanding(), 2);
    let (_, dummy) =
        DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::new(DispatchStats::default()));
    drop(std::mem::replace(&mut h.dispatcher, dummy));
    for (s, e) in h.scheds.iter_mut().zip(h.engines.iter_mut()) {
        s.tick_shared_complete(e, &h.pool, &h.stats);
    }
    assert!(!h.busy());
    assert_eq!(h.pool.outstanding(), 0, "lost caches must be forgotten, not leaked");
    let resps = h.drain_responses();
    assert_eq!(resps.len(), 2);
    for r in resps {
        assert!(!r.is_ok());
    }
    // the freed budget is usable again: a fresh admission succeeds
    let (ok, _) = h.admit(0, mk_req(7, "after the loss", 2));
    assert!(ok);
}

#[test]
fn pipelined_shared_dispatch_is_token_exact_at_every_depth() {
    // tentpole acceptance: the pipelined split-tick path — submission
    // first, admission landing INSIDE the overlap window while the
    // round is at the dispatcher, rounds flushed through the
    // prepare/pre-collate path — is output-transparent vs the
    // unpipelined shared path and the reference at workers 1/2/4 ×
    // max_inflight 1/2/4
    let (_, expect) = workload_reqs(8);
    for workers in [1usize, 2, 4] {
        for max_inflight in [1usize, 2, 4] {
            let mut per_mode: Vec<Vec<Response>> = Vec::new();
            for pipelined in [false, true] {
                let mut h = if pipelined {
                    SharedHarness::pipelined(workers, max_inflight)
                } else {
                    SharedHarness::new(workers, max_inflight)
                };
                let (reqs, _) = workload_reqs(8);
                let mut pending: std::collections::VecDeque<Request> =
                    reqs.into_iter().collect();
                while !pending.is_empty() || h.busy() {
                    h.submit_all();
                    // mid-flight admission in the overlap window: the
                    // submitted rows are away at the dispatcher, yet
                    // `len()` must still count them — capacity is never
                    // exceeded by overlap-window admissions
                    for w in 0..workers {
                        assert!(h.scheds[w].len() <= max_inflight, "overlap over-admitted");
                        if h.scheds[w].has_capacity() {
                            if let Some(r) = pending.pop_front() {
                                assert!(h.admit(w, r).0, "admission refused");
                            }
                        }
                    }
                    let calls = h.pump_round();
                    assert!(
                        calls <= 1,
                        "workers={workers} inflight={max_inflight} pipelined={pipelined}: \
                         wall tick cost {calls} device calls"
                    );
                    h.complete_all();
                }
                let mut resps = h.drain_responses();
                resps.sort_by_key(|r| r.id);
                assert_eq!(resps.len(), 8);
                for (r, want) in resps.iter().zip(&expect) {
                    assert!(r.is_ok(), "pipelined={pipelined}: {:?}", r.error_msg());
                    assert_eq!(
                        r.tokens(), *want,
                        "pipelined={pipelined} perturbed request {} \
                         (workers={workers}, inflight={max_inflight})",
                        r.id
                    );
                }
                assert_eq!(h.pool.outstanding(), 0);
                assert!(
                    h.stats.max_inflight_seqs() as usize <= max_inflight,
                    "overlap-window admission exceeded max_inflight"
                );
                assert_eq!(h.dstats.queue_depth(), 0);
                per_mode.push(resps);
            }
            for (a, b) in per_mode[0].iter().zip(&per_mode[1]) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.tokens(), b.tokens(),
                    "pipelined diverged from unpipelined on request {} \
                     (workers={workers}, inflight={max_inflight})",
                    a.id
                );
            }
        }
    }
}

#[test]
fn pipelined_precollated_rounds_are_token_exact_at_every_depth() {
    // the collector-stage collation path: [`KvExec`] advertises a
    // batched-graph inventory, so the pipelined pump pre-collates every
    // multi-rider round (bucket selection + truncation on the host
    // stage) and executes it via `exec_collated` — which must be
    // token-exact with the executor-collated unpipelined path at
    // workers 1/2/4 × max_inflight 1/2/4
    let (_, expect) = workload_reqs(8);
    for workers in [1usize, 2, 4] {
        for max_inflight in [1usize, 2, 4] {
            let mut per_mode: Vec<Vec<Response>> = Vec::new();
            for pipelined in [false, true] {
                let mut h = SharedHarness::build(
                    workers,
                    max_inflight,
                    KvExec::new(vec![16, 32, 48], false),
                    pipelined,
                );
                let (reqs, _) = workload_reqs(8);
                let mut pending: std::collections::VecDeque<Request> =
                    reqs.into_iter().collect();
                while !pending.is_empty() || h.busy() {
                    h.submit_all();
                    for w in 0..workers {
                        if h.scheds[w].has_capacity() {
                            if let Some(r) = pending.pop_front() {
                                assert!(h.admit(w, r).0, "admission refused");
                            }
                        }
                    }
                    assert!(h.pump_round() <= 1);
                    h.complete_all();
                }
                let mut resps = h.drain_responses();
                resps.sort_by_key(|r| r.id);
                assert_eq!(resps.len(), 8);
                for (r, want) in resps.iter().zip(&expect) {
                    assert!(r.is_ok(), "pipelined={pipelined}: {:?}", r.error_msg());
                    assert_eq!(
                        r.tokens(), *want,
                        "pre-collated round perturbed request {} \
                         (workers={workers}, inflight={max_inflight}, pipelined={pipelined})",
                        r.id
                    );
                }
                assert_eq!(h.pool.outstanding(), 0);
                if pipelined && workers * max_inflight >= 2 {
                    // multi-rider rounds exist at this depth, and every
                    // one of them fits a fwd_b{2,4,8} bucket: the
                    // collector stage must have collated them
                    assert!(
                        h.dstats.overlap_precollated_batches_total() > 0,
                        "inventory present but no round was pre-collated \
                         (workers={workers}, inflight={max_inflight})"
                    );
                    // kv-bucket selection survives the move to the
                    // collector stage: these prompts stay short
                    assert!(
                        h.dstats.kv_hist().keys().any(|&kv| kv < SHAPE.1),
                        "short kv buckets never engaged on the pre-collated path"
                    );
                }
                per_mode.push(resps);
            }
            for (a, b) in per_mode[0].iter().zip(&per_mode[1]) {
                assert_eq!(a.id, b.id);
                assert_eq!(
                    a.tokens(), b.tokens(),
                    "pre-collated diverged from executor-collated on request {} \
                     (workers={workers}, inflight={max_inflight})",
                    a.id
                );
            }
        }
    }
}

#[test]
fn pipelined_cancellation_frees_cache_and_costs_no_device_call() {
    let mut h = SharedHarness::pipelined(2, 2);
    let (ok, cancel) = h.admit(0, mk_req(0, "cancel me in pipelined mode", 50));
    assert!(ok);
    h.wall_tick();
    h.wall_tick();
    assert_eq!(h.pool.outstanding(), 1);
    cancel.cancel();
    let calls = h.wall_tick();
    assert_eq!(calls, 0, "a tick that only cancels must not touch the device");
    assert!(!h.busy());
    assert_eq!(h.pool.outstanding(), 0, "cancel must return the cache to the pool");
    assert_eq!(h.stats.cancelled_total(), 1);
    let resp = h.rx.try_recv().expect("cancelled sequence answers its channel");
    assert!(resp.error_msg().unwrap_or_default().contains("cancelled"));
}

#[test]
fn pipelined_dead_dispatcher_mid_overlap_fails_rows_and_reconciles() {
    // the overlap window's worst case: the dispatcher dies while a
    // submitted round is in flight AND a new admission just landed in
    // the window — the round's caches are lost (forgotten, not
    // leaked), the newcomer survives to fail cleanly on its own submit
    let mut h = SharedHarness::pipelined(2, 2);
    assert!(h.admit(0, mk_req(0, "overlap loss a", 9)).0);
    assert!(h.admit(1, mk_req(1, "overlap loss b", 9)).0);
    h.submit_all();
    assert!(h.admit(0, mk_req(2, "joined mid overlap", 3)).0);
    assert_eq!(h.pool.outstanding(), 3);
    let (_, dummy) =
        DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::new(DispatchStats::default()));
    drop(std::mem::replace(&mut h.dispatcher, dummy));
    h.complete_all();
    assert_eq!(h.pool.outstanding(), 1, "lost caches forgotten, the newcomer's kept");
    let resps = h.drain_responses();
    assert_eq!(resps.len(), 2);
    for r in &resps {
        assert!(
            r.error_msg().unwrap_or_default().contains("dispatcher"),
            "{:?}",
            r.error_msg()
        );
    }
    // the mid-overlap admission retires on its next submit: the dead
    // dispatcher hands its rows straight back
    h.submit_all();
    h.complete_all();
    assert!(!h.busy());
    assert_eq!(h.pool.outstanding(), 0);
    let resps = h.drain_responses();
    assert_eq!(resps.len(), 1);
    assert_eq!(resps[0].id, 2);
    assert!(resps[0].error_msg().unwrap_or_default().contains("dispatcher"));
}

#[test]
fn dropping_scheduler_with_inflight_tick_reconciles_caches_and_answers() {
    // regression: `StepScheduler::Drop` used to silently drop a pending
    // shared tick — leaking the rows' caches against the pool cap and
    // leaving their reply channels unanswered forever.  Teardown must
    // reconcile all three reply scenarios.

    // (a) the round was flushed and the reply is waiting: the caches
    // come back and must be checked IN (reusable), the jobs answered
    let mut h = SharedHarness::new(1, 2);
    assert!(h.admit(0, mk_req(0, "torn down a", 9)).0);
    assert!(h.admit(0, mk_req(1, "torn down b", 9)).0);
    h.submit_all();
    h.pump_round();
    assert!(h.scheds[0].has_pending());
    assert_eq!(h.pool.outstanding(), 2);
    h.scheds.clear(); // Drop with the reply queued
    assert_eq!(h.pool.outstanding(), 0, "returned caches must check back in");
    let resps = h.drain_responses();
    assert_eq!(resps.len(), 2);
    for r in &resps {
        assert!(
            r.error_msg().unwrap_or_default().contains("shut down"),
            "{:?}",
            r.error_msg()
        );
    }
    let c = h.pool.checkout(SHAPE.0, SHAPE.1, SHAPE.2).expect("freed capacity reusable");
    assert_eq!(h.pool.created(), 2, "reconciled caches are reused, not reallocated");
    h.pool.checkin(c);

    // (b) the dispatcher died holding the round: the reply channel is
    // disconnected — teardown must forget the lost caches immediately,
    // not wait out the drain timeout
    let mut h = SharedHarness::new(1, 2);
    assert!(h.admit(0, mk_req(0, "torn down c", 9)).0);
    h.submit_all();
    let (_, dummy) =
        DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::new(DispatchStats::default()));
    drop(std::mem::replace(&mut h.dispatcher, dummy));
    let t0 = std::time::Instant::now();
    h.scheds.clear();
    assert!(
        t0.elapsed() < Duration::from_millis(200),
        "a disconnected reply must not cost the full drain timeout"
    );
    assert_eq!(h.pool.outstanding(), 0, "lost caches must be forgotten, not leaked");
    let resps = h.drain_responses();
    assert_eq!(resps.len(), 1);
    assert!(!resps[0].is_ok());

    // (c) the dispatcher is alive but wedged (never flushes): teardown
    // waits out the bounded drain timeout, then forgets
    let mut h = SharedHarness::new(1, 2);
    assert!(h.admit(0, mk_req(0, "torn down d", 9)).0);
    h.submit_all();
    let t0 = std::time::Instant::now();
    h.scheds.clear();
    assert!(
        t0.elapsed() >= Duration::from_millis(200),
        "a wedged dispatcher should cost the bounded drain timeout"
    );
    assert_eq!(h.pool.outstanding(), 0, "wedged-dispatcher caches reconciled by forget");
    let resps = h.drain_responses();
    assert_eq!(resps.len(), 1);
    assert!(
        resps[0].error_msg().unwrap_or_default().contains("shut down"),
        "{:?}",
        resps[0].error_msg()
    );
}

// ---- full coordinator (threads + queue + scheduler) ----

struct MockBackend {
    step_delay: Duration,
}

impl WorkerBackend for MockBackend {
    fn run(&self, worker: usize, ctx: WorkerCtx) {
        let mut engine = MockEngine::with_delay(self.step_delay);
        ctx.ready();
        serve_jobs(worker, &mut engine, &ctx);
        // flush device-call counters exactly like ModelBackend does
        let mut rows_by_worker = std::collections::BTreeMap::new();
        if engine.batch_rows > 0 {
            rows_by_worker.insert(worker, engine.batch_rows);
        }
        ctx.absorb_runtime_stats(&RuntimeStats {
            forwards: engine.forwards,
            forward_batches: engine.batch_calls,
            batch_rows: engine.batch_rows,
            rows_by_worker,
            ..Default::default()
        });
    }

    fn run_device(&self, host: DeviceHost) {
        // shared-runtime device host with the mock executor — the same
        // wiring ModelBackend::run_device uses around a real Runtime
        let exec = MockExec::with_delay(self.step_delay);
        let agg = host.runtime_agg();
        host.serve(&exec);
        let widths = exec.widths.lock().unwrap();
        agg.absorb(&RuntimeStats {
            forwards: exec.forwards(),
            forward_batches: widths.len(),
            batch_rows: widths.iter().sum(),
            ..Default::default()
        });
    }
}

fn test_workers() -> usize {
    std::env::var("PPD_TEST_WORKERS")
        .ok()
        .and_then(|w| w.parse().ok())
        .unwrap_or(2)
}

/// CI matrix knob: `PPD_TEST_FUSE=1` runs the coordinator e2e tests
/// with fused stepping so equivalence is enforced both ways.
fn test_fuse() -> bool {
    std::env::var("PPD_TEST_FUSE").as_deref() == Ok("1")
}

/// CI matrix knob: `PPD_TEST_SHARED=1` runs the coordinator e2e tests
/// under the shared-runtime dispatcher.
fn test_shared() -> bool {
    std::env::var("PPD_TEST_SHARED").as_deref() == Ok("1")
}

/// CI matrix knob: `PPD_TEST_PIPELINED=1` runs the coordinator e2e
/// tests through the pipelined split-tick worker loop and the
/// double-buffered dispatcher (the matrix only sets it together with
/// `PPD_TEST_SHARED=1`, since `--pipelined` rides the shared
/// dispatcher).
fn test_pipelined() -> bool {
    std::env::var("PPD_TEST_PIPELINED").as_deref() == Ok("1")
}

/// CI matrix knob: `PPD_TEST_STREAM=1` routes the coordinator e2e
/// workload through the streaming submit path, so every topology cell
/// proves the per-step event stream reassembles to the exact terminal
/// tokens.
fn test_stream() -> bool {
    std::env::var("PPD_TEST_STREAM").as_deref() == Ok("1")
}

/// `run_batch` through the streaming submit path: every request gets
/// its own event channel, and the concatenation of its `Tokens` frames
/// must equal the terminal response's token sequence.  The scheduler
/// never emits terminal frames (the server synthesizes those), so only
/// `Started`/`Tokens` may appear here.
fn run_batch_streamed(coord: &Coordinator, reqs: Vec<Request>) -> Vec<Response> {
    let mut chans = Vec::new();
    for r in reqs {
        let id = r.id;
        let (tx, rx) = mpsc::channel();
        let (etx, erx) = mpsc::channel();
        coord
            .submit_streaming(r, tx, etx, ppd::coordinator::CancelFlag::new())
            .expect("streamed submit");
        chans.push((id, rx, erx));
    }
    let mut resps = Vec::new();
    for (id, rx, erx) in chans {
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("terminal response");
        assert_eq!(resp.id, id);
        let mut streamed = Vec::new();
        let mut started = 0usize;
        while let Ok(ev) = erx.try_recv() {
            assert_eq!(ev.id(), id, "event routed to the wrong request");
            match ev {
                ResponseEvent::Started { .. } => started += 1,
                ResponseEvent::Tokens { accepted, .. } => streamed.extend(accepted),
                other => panic!("scheduler emitted a terminal frame: {other:?}"),
            }
        }
        if resp.is_ok() {
            assert_eq!(started, 1, "request {id}: exactly one Started frame");
            assert_eq!(
                streamed,
                resp.tokens(),
                "request {id}: streamed frames diverged from the terminal response"
            );
        }
        resps.push(resp);
    }
    resps.sort_by_key(|r| r.id);
    resps
}

/// Read one gauge/counter line out of `Coordinator::metrics_text`.
fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(&format!("{name} ")))
        .unwrap_or_else(|| panic!("{name} missing from metrics_text"))
        .trim()
        .parse()
        .unwrap_or_else(|e| panic!("{name}: unparsable value ({e})"))
}

#[test]
fn coordinator_continuous_batching_is_token_exact_end_to_end() {
    let workers = test_workers();
    let fuse = test_fuse();
    let shared = test_shared();
    let pipelined = test_pipelined();
    let reqs = |n: u64| -> Vec<Request> {
        (0..n).map(|i| mk_req(i, &format!("e2e request {i}"), 4 + (i as usize % 7))).collect()
    };
    let expect: Vec<Vec<u32>> = reqs(24)
        .iter()
        .map(|r| reference_tokens(&r.prompt, r.max_new, r.seed))
        .collect();

    let batching = Coordinator::spawn_with_backend_policy(
        std::sync::Arc::new(MockBackend { step_delay: Duration::ZERO }),
        workers,
        SchedPolicy {
            max_inflight: 4,
            fuse_steps: fuse,
            shared_runtime: shared,
            pipelined,
            ..Default::default()
        },
    )
    .expect("spawn batching");
    let serial = Coordinator::spawn_with_backend_policy(
        std::sync::Arc::new(MockBackend { step_delay: Duration::ZERO }),
        workers,
        SchedPolicy {
            max_inflight: 1,
            fuse_steps: fuse,
            shared_runtime: shared,
            pipelined,
            ..Default::default()
        },
    )
    .expect("spawn serial");

    let stream = test_stream();
    let a = if stream {
        run_batch_streamed(&batching, reqs(24))
    } else {
        batching.run_batch(reqs(24)).expect("batching batch")
    };
    let b = serial.run_batch(reqs(24)).expect("serial batch");
    for (i, ((x, y), want)) in a.iter().zip(&b).zip(&expect).enumerate() {
        assert!(x.is_ok(), "{:?}", x.error_msg());
        assert_eq!(x.id, i as u64);
        assert_eq!(x.tokens(), *want, "continuous batching perturbed request {i}");
        assert_eq!(x.tokens(), y.tokens(), "max_inflight=4 diverged from max_inflight=1");
    }
    // pool stays within the admission budget; all caches returned
    assert!(batching.caches_created() <= workers * 4);
    assert_eq!(batching.caches_outstanding(), 0);
    let stats = batching.queue_stats();
    assert_eq!(stats.completed_total(), 24);
    assert_eq!(stats.admitted_total(), 24);
    assert!(stats.sched_steps_total() > 0);
    assert!(stats.max_inflight_seqs() <= 4);
    if fuse || shared {
        assert!(stats.fused_batches_total() > 0, "fusion never engaged end to end");
    } else {
        assert_eq!(stats.fused_batches_total(), 0);
    }
    if shared {
        assert!(
            batching.dispatch_stats().batches_total() > 0,
            "shared runtime never dispatched a fused batch"
        );
        assert_eq!(batching.dispatch_stats().queue_depth(), 0);
    } else {
        assert_eq!(batching.dispatch_stats().batches_total(), 0);
    }
    if stream {
        // 24 Started frames plus at least one Tokens frame per request
        assert!(
            stats.stream_events_total() >= 48,
            "only {} stream frames for 24 streamed requests",
            stats.stream_events_total()
        );
    } else {
        assert_eq!(stats.stream_events_total(), 0);
    }
}

#[test]
fn shared_coordinator_fuses_across_workers_end_to_end() {
    // the threaded version of the tentpole claim: with 4 workers the
    // shared-runtime coordinator's device sees strictly fewer calls
    // than the per-worker-fused topology for the same workload, with
    // batches that demonstrably span workers — token-exactly
    let workers = 4;
    let reqs = |n: u64| -> Vec<Request> {
        (0..n).map(|i| mk_req(i, &format!("cross worker {i}"), 10)).collect()
    };
    let expect: Vec<Vec<u32>> = reqs(16)
        .iter()
        .map(|r| reference_tokens(&r.prompt, r.max_new, r.seed))
        .collect();
    let run = |shared: bool| -> (RuntimeStats, u64, u64, f64) {
        let coord = Coordinator::spawn_with_backend_policy(
            std::sync::Arc::new(MockBackend { step_delay: Duration::from_millis(1) }),
            workers,
            SchedPolicy {
                max_inflight: 2,
                fuse_steps: !shared,
                shared_runtime: shared,
                ..Default::default()
            },
        )
        .expect("spawn");
        let resps = coord.run_batch(reqs(16)).expect("batch");
        for (i, r) in resps.iter().enumerate() {
            assert!(r.is_ok(), "{:?}", r.error_msg());
            assert_eq!(r.tokens(), expect[i], "shared={shared} perturbed request {i}");
        }
        assert_eq!(coord.caches_outstanding(), 0);
        let d = coord.dispatch_stats();
        let (batches, multi, width) =
            (d.batches_total(), d.multi_worker_batches_total(), d.mean_width());
        let agg = coord.runtime_agg();
        drop(coord); // joins workers + device host, which flush counters
        (agg.snapshot(), batches, multi, width)
    };
    let (fused_agg, fused_batches, _, _) = run(false);
    let (shared_agg, batches, multi, width) = run(true);
    assert_eq!(fused_batches, 0, "per-worker mode must not touch the dispatcher");
    assert!(batches > 0, "shared mode never dispatched");
    assert!(multi > 0, "no device call ever carried rows from >1 worker");
    assert!(width > 1.0, "mean cross-worker width {width} never exceeded one row");
    assert!(
        shared_agg.forwards < fused_agg.forwards,
        "shared runtime issued {} device calls vs {} per-worker-fused — cross-worker \
         fusion bought nothing",
        shared_agg.forwards,
        fused_agg.forwards
    );
    // rows are attributed to the schedulers that planned them
    let by_worker = &shared_agg.rows_by_worker;
    assert!(by_worker.len() >= 2, "rows_by_worker {by_worker:?} names <2 workers");
    assert_eq!(
        by_worker.values().sum::<usize>(),
        shared_agg.batch_rows,
        "per-worker row attribution must cover every fused row"
    );
}

#[test]
fn pipelined_coordinator_is_token_exact_end_to_end() {
    // the threaded version of the pipelined claim: the split-tick
    // worker loop + double-buffered dispatcher (collector thread,
    // adaptive window, staged rounds) serve exactly the tokens the
    // unpipelined shared topology serves, and the pipelined stats
    // channel fills in (adaptive window reported, device busy time
    // accumulated)
    let workers = 4;
    let reqs = |n: u64| -> Vec<Request> {
        (0..n).map(|i| mk_req(i, &format!("pipelined e2e {i}"), 10)).collect()
    };
    let expect: Vec<Vec<u32>> = reqs(16)
        .iter()
        .map(|r| reference_tokens(&r.prompt, r.max_new, r.seed))
        .collect();
    for pipelined in [false, true] {
        let coord = Coordinator::spawn_with_backend_policy(
            std::sync::Arc::new(MockBackend { step_delay: Duration::from_millis(1) }),
            workers,
            SchedPolicy {
                max_inflight: 2,
                shared_runtime: true,
                pipelined,
                ..Default::default()
            },
        )
        .expect("spawn");
        let resps = coord.run_batch(reqs(16)).expect("batch");
        for (i, r) in resps.iter().enumerate() {
            assert!(r.is_ok(), "pipelined={pipelined}: {:?}", r.error_msg());
            assert_eq!(r.tokens(), expect[i], "pipelined={pipelined} perturbed request {i}");
        }
        assert_eq!(coord.caches_outstanding(), 0);
        let d = coord.dispatch_stats();
        assert!(d.batches_total() > 0, "pipelined={pipelined}: never dispatched");
        assert_eq!(d.queue_depth(), 0);
        if pipelined {
            assert!(d.window_us() > 0, "adaptive window never reported");
            assert!(d.device_busy_us_total() > 0, "device busy time never accumulated");
        }
    }
}

#[test]
fn fused_coordinator_cuts_device_calls_end_to_end() {
    // one worker so the schedule is load-deterministic enough to
    // compare: the fused coordinator must issue ≥2× fewer device calls
    // for the same 16-request workload (acceptance criterion, asserted
    // via RuntimeStats — the same counters ModelBackend flushes)
    let reqs = |n: u64| -> Vec<Request> {
        (0..n).map(|i| mk_req(i, &format!("fused e2e {i}"), 8)).collect()
    };
    let run = |fuse: bool| -> (RuntimeStats, u64) {
        let coord = Coordinator::spawn_with_backend_policy(
            std::sync::Arc::new(MockBackend { step_delay: Duration::ZERO }),
            1,
            SchedPolicy { max_inflight: 4, fuse_steps: fuse, ..Default::default() },
        )
        .expect("spawn");
        let resps = coord.run_batch(reqs(16)).expect("batch");
        assert!(resps.iter().all(|r| r.is_ok()));
        let max_fused = coord.queue_stats().max_fused_batch();
        let agg = coord.runtime_agg();
        drop(coord); // joins workers, which flush their counters
        (agg.snapshot(), max_fused)
    };
    let (unfused, _) = run(false);
    let (fused, max_fused) = run(true);
    assert!(unfused.forward_batches == 0 && unfused.forwards > 0);
    assert!(fused.forward_batches > 0);
    assert!(
        fused.forwards * 2 <= unfused.forwards,
        "fused {} vs unfused {} device calls: < 2x reduction",
        fused.forwards,
        unfused.forwards
    );
    assert!(max_fused >= 2, "no tick ever served >1 sequence in one forward_batch");
}

#[test]
fn coordinator_cancel_flag_aborts_inflight_request() {
    let coord = Coordinator::spawn_with_backend_policy(
        std::sync::Arc::new(MockBackend { step_delay: Duration::from_millis(2) }),
        1,
        SchedPolicy {
            max_inflight: 2,
            fuse_steps: test_fuse(),
            shared_runtime: test_shared(),
            pipelined: test_pipelined(),
            ..Default::default()
        },
    )
    .expect("spawn");
    let (tx, rx) = mpsc::channel();
    let cancel = ppd::coordinator::CancelFlag::new();
    // ~20s of work without cancellation: the 50ms cancel must cut it
    coord
        .submit_cancellable(mk_req(0, "very long", 10_000), tx, cancel.clone())
        .expect("submit");
    std::thread::sleep(Duration::from_millis(50));
    cancel.cancel();
    let resp = rx.recv_timeout(Duration::from_secs(5)).expect("cancel response");
    assert!(
        resp.error_msg().unwrap_or_default().contains("cancelled"),
        "{:?}",
        resp.error_msg()
    );
    assert_eq!(coord.caches_outstanding(), 0);
}

#[test]
fn streamed_events_are_token_exact_across_topologies() {
    // tentpole acceptance: the per-step event stream reassembles to
    // exactly the non-streamed tokens at workers 1/2/4 × inflight
    // 1/2/4 across all four topologies (run_batch_streamed asserts the
    // frame-vs-terminal equality per request; this grid pins the
    // streamed output to the run-to-completion reference)
    let topologies: [(&str, bool, bool, bool); 4] = [
        ("serial", false, false, false),
        ("fused", true, false, false),
        ("shared", false, true, false),
        ("pipelined", false, true, true),
    ];
    let reqs = |n: u64| -> Vec<Request> {
        (0..n).map(|i| mk_req(i, &format!("stream grid {i}"), 3 + (i as usize % 4))).collect()
    };
    let expect: Vec<Vec<u32>> = reqs(6)
        .iter()
        .map(|r| reference_tokens(&r.prompt, r.max_new, r.seed))
        .collect();
    for (name, fuse, shared, pipelined) in topologies {
        for workers in [1usize, 2, 4] {
            for max_inflight in [1usize, 2, 4] {
                let coord = Coordinator::spawn_with_backend_policy(
                    std::sync::Arc::new(MockBackend { step_delay: Duration::ZERO }),
                    workers,
                    SchedPolicy {
                        max_inflight,
                        fuse_steps: fuse,
                        shared_runtime: shared,
                        pipelined,
                        ..Default::default()
                    },
                )
                .expect("spawn");
                let resps = run_batch_streamed(&coord, reqs(6));
                assert_eq!(resps.len(), 6);
                for (r, want) in resps.iter().zip(&expect) {
                    assert!(
                        r.is_ok(),
                        "{name} workers={workers} inflight={max_inflight}: {:?}",
                        r.error_msg()
                    );
                    assert_eq!(
                        r.tokens(),
                        *want,
                        "{name} workers={workers} inflight={max_inflight}: \
                         streaming perturbed request {}",
                        r.id
                    );
                }
                assert_eq!(coord.caches_outstanding(), 0);
                assert!(coord.queue_stats().stream_events_total() >= 12);
            }
        }
    }
}

#[test]
fn session_resume_reuses_prefix_pages_and_counts_metrics() {
    // acceptance: a resumed session turn must record ≥1 prefix-store
    // hit — turn 1 publishes its prompt (and, at retire, its generated
    // tokens) into the paged prefix store under the session's custody,
    // and turn 2's checkout finds them
    let coord = Coordinator::spawn_with_backend_policy(
        std::sync::Arc::new(MockBackend { step_delay: Duration::ZERO }),
        1,
        SchedPolicy { max_inflight: 2, kv_blocks: Some(64), ..Default::default() },
    )
    .expect("spawn");
    let turn = |i: u64| {
        Request::builder(workload::encode("session resume prompt"))
            .id(i)
            .max_new(6)
            .seed(7)
            .session("conv-1")
            .build()
    };
    let (tx, rx) = mpsc::channel();
    coord.submit_routed(turn(0), tx.clone()).expect("submit turn 0");
    let r0 = rx.recv_timeout(Duration::from_secs(10)).expect("turn 0");
    assert!(r0.is_ok(), "{:?}", r0.error_msg());
    coord.submit_routed(turn(1), tx).expect("submit turn 1");
    let r1 = rx.recv_timeout(Duration::from_secs(10)).expect("turn 1");
    assert!(r1.is_ok(), "{:?}", r1.error_msg());
    // same session + same prompt → identical seeds → identical tokens
    assert_eq!(r0.tokens(), r1.tokens());

    let text = coord.metrics_text();
    assert_eq!(metric_value(&text, "ppd_session_resumes_total"), 1.0);
    assert!(
        metric_value(&text, "ppd_session_prefix_turn_hits_total") >= 1.0,
        "resumed turn never found its session's pages in the prefix store"
    );
    assert!(
        metric_value(&text, "ppd_prefix_hits_total") >= 1.0,
        "prefix store recorded no hit for the resumed turn"
    );
}

#[test]
fn slo_discipline_prevents_priority_inversion_end_to_end() {
    // regression: under fifo a queued high-priority job waits out every
    // earlier arrival; under --sched-policy slo it is picked the moment
    // a slot frees, and the out-of-order pickup is counted as a
    // preemption
    let coord = Coordinator::spawn_with_backend_policy(
        std::sync::Arc::new(MockBackend { step_delay: Duration::from_millis(2) }),
        1,
        SchedPolicy {
            max_inflight: 1,
            sched_policy: QueueDiscipline::Slo,
            ..Default::default()
        },
    )
    .expect("spawn");
    let (tx, rx) = mpsc::channel();
    // a long blocker occupies the only slot...
    coord
        .submit_routed(
            Request::builder(workload::encode("blocker")).id(0).max_new(40).build(),
            tx.clone(),
        )
        .expect("submit blocker");
    std::thread::sleep(Duration::from_millis(30)); // let the worker admit it
    // ...then bulk work queues ahead of a late interactive request
    for i in 1..=2u64 {
        coord
            .submit_routed(
                Request::builder(workload::encode("bulk job"))
                    .id(i)
                    .max_new(4)
                    .priority(Priority::Low)
                    .tenant("batch")
                    .build(),
                tx.clone(),
            )
            .expect("submit bulk");
    }
    coord
        .submit_routed(
            Request::builder(workload::encode("interactive"))
                .id(3)
                .max_new(4)
                .priority(Priority::High)
                .tenant("chat")
                .build(),
            tx.clone(),
        )
        .expect("submit high");
    drop(tx);
    let mut order = Vec::new();
    for _ in 0..4 {
        let r = rx.recv_timeout(Duration::from_secs(20)).expect("response");
        assert!(r.is_ok(), "{:?}", r.error_msg());
        order.push(r.id);
    }
    let pos = |id: u64| order.iter().position(|&x| x == id).unwrap();
    assert!(
        pos(3) < pos(1) && pos(3) < pos(2),
        "high-priority request served after the bulk queue: {order:?}"
    );
    assert!(
        metric_value(&coord.metrics_text(), "ppd_sched_preemptions_total") >= 1.0,
        "out-of-order pickup was not counted as a preemption"
    );
}

// ---- request-lifecycle tracing & latency histograms ----

#[test]
fn scripted_fused_tick_records_gapless_span_chain_and_exact_latency() {
    // the flight recorder on a scripted clock: one fused request's whole
    // life is replayed at known timestamps, so every span boundary and
    // every latency sample has exactly one correct value
    let clock = Arc::new(ScriptedClock::new());
    let tracer = Tracer::new(64, clock.clone());
    tracer.set_enabled(true);
    let lat = Arc::new(RequestLatency::default());
    lat.set_keep_samples(true);
    let mut h = Harness::fused(2);
    h.sched.set_observer(SchedObserver {
        track: tracer.track("worker-0"),
        latency: Arc::clone(&lat),
    });

    // Job::new stamps enqueue_us = 0 (the scripted clock's origin)
    clock.set(100); // queued 100us before the worker dequeued it
    assert!(h.admit(mk_req(1, "traced request", 3)).0);
    clock.advance(50); // t=150: first fused tick emits token 1
    h.tick();
    clock.advance(25); // t=175: token 2
    h.tick();
    clock.advance(25); // t=200: token 3 finishes and retires
    h.tick();
    assert!(h.sched.is_empty());

    // exact samples off the scripted timeline, in recording order
    let s = lat.samples();
    assert_eq!(s.queue_wait_us, vec![100]);
    assert_eq!(s.ttft_us, vec![150]);
    assert_eq!(s.itl_us, vec![25, 25]);
    assert_eq!(s.e2e_us, vec![200]);
    // the always-on histograms saw the same events
    assert_eq!(lat.queue_wait().count(), 1);
    assert_eq!(lat.ttft().count(), 1);
    assert_eq!(lat.itl().count(), 2);
    assert_eq!(lat.e2e().count(), 1);

    let snap = tracer.snapshot();
    let (_, events) =
        snap.iter().find(|(name, _)| name == "worker-0").expect("worker track recorded");
    let req: Vec<&TraceEvent> = events.iter().filter(|e| e.req == 1).collect();
    let phases: Vec<Phase> = req.iter().map(|e| e.phase).collect();
    let mut want = vec![Phase::Enqueue, Phase::Admit];
    for _ in 0..3 {
        want.extend([Phase::Plan, Phase::Device, Phase::Apply, Phase::Emit]);
    }
    want.push(Phase::Retire);
    assert_eq!(phases, want);
    // gapless chain: every span starts exactly where the previous one
    // ended (Emit instants are markers, not chain links)
    let chain: Vec<&&TraceEvent> = req.iter().filter(|e| e.phase != Phase::Emit).collect();
    assert_eq!(chain[0].start_us, 0, "Enqueue must start at the enqueue origin");
    for w in chain.windows(2) {
        assert_eq!(
            w[1].start_us, w[0].end_us,
            "gap between {:?} and {:?}",
            w[0].phase, w[1].phase
        );
    }
    assert_eq!(chain.last().unwrap().end_us, 200, "Retire must close at the e2e timestamp");
    // per-tick attribution spans ride the same track, off-request,
    // numbered by the scheduler's tick counter
    let ticks: Vec<&TraceEvent> =
        events.iter().filter(|e| e.phase == Phase::Tick).collect();
    assert_eq!(ticks.len(), 3);
    for (i, t) in ticks.iter().enumerate() {
        assert_eq!(t.req, NO_REQ);
        assert_eq!(t.round, i as u64 + 1);
        assert_eq!(t.n, 1, "each tick touched exactly one row");
    }
    assert_eq!(tracer.dropped_total(), 0);
}

/// Device executor that parks inside the fused call until released —
/// the deterministic way to hold the pipelined dispatcher's device
/// stage busy while its collector stage assembles the next round.
struct GatingExec {
    entered: Mutex<mpsc::Sender<usize>>,
    release: Mutex<mpsc::Receiver<()>>,
}

impl DeviceExecutor for GatingExec {
    fn exec_forward(
        &self,
        _tokens: &[u32],
        _pos: &[u32],
        _slots: &[u32],
        _bias: &[f32],
        _cache: &[f32],
    ) -> Result<StepOutput> {
        bail!("gating exec only serves fused rounds")
    }

    fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.entered.lock().unwrap().send(items.len()).unwrap();
        self.release.lock().unwrap().recv().unwrap();
        Ok(items
            .iter()
            .map(|_| StepOutput { n: 1, logits: vec![0.0], hidden: vec![], new_kv: vec![] })
            .collect())
    }
}

#[test]
fn pipelined_dispatcher_trace_proves_collate_overlaps_device() {
    // the overlap acceptance proof: with the device stage held inside
    // round 1's execution, round 2 must be windowed AND collated before
    // round 1 finishes — visible both in the overlap counter and as a
    // collate(2) span strictly nested inside the device(1) span
    let (entered_tx, entered_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel();
    let exec =
        GatingExec { entered: Mutex::new(entered_tx), release: Mutex::new(release_rx) };
    let stats = Arc::new(DispatchStats::default());
    let (handle, mut dispatcher) =
        DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::clone(&stats));
    let tracer = Tracer::wall();
    tracer.set_enabled(true);
    dispatcher.set_pipelined(true);
    dispatcher.set_tracer(&tracer);

    let row = || TickRow {
        plan: PlanInputs {
            tokens: vec![1],
            pos: vec![0],
            slots: vec![0],
            bias: vec![0.0; SHAPE.1],
            max_ctx: SHAPE.1,
        },
        cache: HostKvCache::new(SHAPE.0, SHAPE.1, SHAPE.2),
    };

    std::thread::scope(|scope| {
        scope.spawn(|| dispatcher.run(&exec));
        // round 1 flushes immediately (no registered schedulers, so the
        // window never waits) and blocks inside the gated executor
        let rx1 = handle.submit_tick(0, vec![row()]).expect("submit round 1");
        assert_eq!(entered_rx.recv_timeout(Duration::from_secs(5)).unwrap(), 1);
        // round 2 arrives while the device still runs round 1: the
        // collector stage must assemble it NOW — that is the overlap
        let rx2 = handle.submit_tick(0, vec![row()]).expect("submit round 2");
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let collated = |t: &Tracer| {
            t.snapshot().iter().any(|(name, evs)| {
                name == "dispatcher"
                    && evs.iter().any(|e| e.phase == Phase::Collate && e.round == 2)
            })
        };
        while !collated(&tracer) {
            assert!(
                std::time::Instant::now() < deadline,
                "collate(2) never appeared while device(1) was executing"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        // hold the device a beat longer so device(1) strictly brackets
        // collate(2) even at microsecond clock resolution
        std::thread::sleep(Duration::from_millis(2));
        release_tx.send(()).unwrap();
        assert_eq!(entered_rx.recv_timeout(Duration::from_secs(5)).unwrap(), 1);
        release_tx.send(()).unwrap();
        assert!(rx1.recv_timeout(Duration::from_secs(5)).unwrap().outs.is_ok());
        assert!(rx2.recv_timeout(Duration::from_secs(5)).unwrap().outs.is_ok());
        drop(handle); // disconnect: collector flushes, device drains, run returns
    });

    assert!(stats.overlap_batches_total() >= 1, "overlap counter never fired");
    let snap = tracer.snapshot();
    let (_, evs) =
        snap.iter().find(|(name, _)| name == "dispatcher").expect("dispatcher track");
    let find = |phase: Phase, round: u64| {
        evs.iter()
            .find(|e| e.phase == phase && e.round == round)
            .unwrap_or_else(|| panic!("no {phase:?} span for round {round}"))
    };
    let dev1 = find(Phase::Device, 1);
    let col2 = find(Phase::Collate, 2);
    assert!(
        dev1.start_us <= col2.start_us && col2.end_us < dev1.end_us,
        "collate(2) [{}, {}] must nest inside device(1) [{}, {}]",
        col2.start_us,
        col2.end_us,
        dev1.start_us,
        dev1.end_us
    );
    // round 2 still got its own full window/collate/device record
    find(Phase::WindowWait, 2);
    find(Phase::Device, 2);
}

#[test]
fn coordinator_trace_chains_are_gapless_and_match_histograms() {
    // threads + queue + schedulers + pipelined dispatcher, flight
    // recorder on: every served request leaves one gapless recv→retire
    // chain, the Chrome export survives a JSON round-trip, and latency
    // quantiles recomputed from the trace equal the exported histograms
    let coord = Coordinator::spawn_with_backend_policy(
        std::sync::Arc::new(MockBackend { step_delay: Duration::from_millis(1) }),
        2,
        SchedPolicy {
            max_inflight: 2,
            shared_runtime: true,
            pipelined: true,
            ..Default::default()
        },
    )
    .expect("spawn");
    coord.tracer().set_enabled(true);
    coord.request_latency().set_keep_samples(true);
    let max_new = 6usize;
    let reqs: Vec<Request> =
        (0..8).map(|i| mk_req(i, &format!("traced e2e {i}"), max_new)).collect();
    let resps = coord.run_batch(reqs).expect("batch");
    assert!(resps.iter().all(|r| r.is_ok()));

    let snap = coord.tracer().snapshot();
    let (_, server) =
        snap.iter().find(|(name, _)| name == "server").expect("server track");
    let mut qw = Vec::new();
    let mut ttft = Vec::new();
    let mut itl = Vec::new();
    let mut e2e = Vec::new();
    let mut chains = 0;
    for (name, evs) in &snap {
        if !name.starts_with("worker-") {
            continue;
        }
        let mut by_req: std::collections::BTreeMap<u64, Vec<&TraceEvent>> =
            std::collections::BTreeMap::new();
        for e in evs {
            if e.req != NO_REQ {
                by_req.entry(e.req).or_default().push(e);
            }
        }
        for (id, req_evs) in by_req {
            chains += 1;
            let chain: Vec<&&TraceEvent> =
                req_evs.iter().filter(|e| e.phase != Phase::Emit).collect();
            assert_eq!(chain[0].phase, Phase::Enqueue, "request {id}");
            assert_eq!(chain[1].phase, Phase::Admit, "request {id}");
            assert_eq!(chain.last().unwrap().phase, Phase::Retire, "request {id}");
            for w in chain.windows(2) {
                assert_eq!(
                    w[1].start_us, w[0].end_us,
                    "request {id}: gap between {:?} and {:?}",
                    w[0].phase, w[1].phase
                );
            }
            // the server-side Recv instant shares the enqueue origin
            assert!(
                server.iter().any(|e| e.phase == Phase::Recv
                    && e.req == id
                    && e.start_us == chain[0].start_us),
                "request {id}: no Recv instant at its enqueue origin"
            );
            qw.push(chain[1].start_us - chain[0].start_us);
            e2e.push(chain.last().unwrap().end_us - chain[0].start_us);
            let emits: Vec<u64> = req_evs
                .iter()
                .filter(|e| e.phase == Phase::Emit)
                .map(|e| e.start_us)
                .collect();
            assert_eq!(emits.len(), max_new, "request {id} emit count");
            ttft.push(emits[0] - chain[0].start_us);
            for w in emits.windows(2) {
                itl.push(w[1] - w[0]);
            }
        }
    }
    assert_eq!(chains, 8, "every request must leave exactly one chain");
    // the pipelined shared path also recorded its dispatcher rounds
    let (_, disp) =
        snap.iter().find(|(name, _)| name == "dispatcher").expect("dispatcher track");
    assert!(disp.iter().any(|e| e.phase == Phase::Device && e.round > 0));
    assert!(disp.iter().any(|e| e.phase == Phase::Collate));
    assert!(snap
        .iter()
        .filter(|(name, _)| name.starts_with("worker-"))
        .any(|(_, evs)| evs.iter().any(|e| e.phase == Phase::Submit)));
    assert_eq!(coord.tracer().dropped_total(), 0);

    // trace-derived samples == recorded samples (one shared clock read
    // per event makes this an equality, not an approximation)
    let s = coord.request_latency().samples();
    let sorted = |mut v: Vec<u64>| {
        v.sort_unstable();
        v
    };
    assert_eq!(sorted(qw.clone()), sorted(s.queue_wait_us));
    assert_eq!(sorted(ttft.clone()), sorted(s.ttft_us));
    assert_eq!(sorted(itl.clone()), sorted(s.itl_us));
    assert_eq!(sorted(e2e.clone()), sorted(s.e2e_us));
    // and the exported histograms are exactly the bucketized trace
    let bucketize = |samples: &[u64]| {
        let mut counts = vec![0u64; REQUEST_US_BOUNDS.len() + 1];
        for &v in samples {
            counts[REQUEST_US_BOUNDS.partition_point(|&b| b < v)] += 1;
        }
        counts
    };
    let lat = coord.request_latency();
    let views: [(&str, &[u64], &ppd::metrics::UsHistogram); 4] = [
        ("queue_wait", &qw, lat.queue_wait()),
        ("ttft", &ttft, lat.ttft()),
        ("itl", &itl, lat.itl()),
        ("e2e", &e2e, lat.e2e()),
    ];
    for (what, samples, hist) in views {
        let counts = bucketize(samples);
        assert_eq!(counts, hist.bucket_counts(), "{what} bucket counts diverged");
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(
                us_bucket_quantile(&counts, q),
                hist.quantile_us(q),
                "{what} p{} diverged",
                (q * 100.0) as u32
            );
        }
    }

    // the Chrome export survives a JSON round-trip and carries the
    // track metadata Perfetto needs
    let chrome = coord.trace_json();
    let reparsed = ppd::util::json::Json::parse(&chrome.to_string())
        .expect("chrome trace JSON round-trip");
    let events = reparsed.req("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let named = |e: &ppd::util::json::Json, name: &str| {
        e.get("name").and_then(|n| n.as_str().ok()) == Some(name)
    };
    assert!(events.iter().any(|e| named(e, "thread_name")));
    assert!(events
        .iter()
        .any(|e| named(e, "retire") && e.get("args").and_then(|a| a.get("req")).is_some()));
    assert_eq!(reparsed.req("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    let dropped =
        reparsed.req("otherData").unwrap().req("dropped_events").unwrap().as_f64().unwrap();
    assert_eq!(dropped, 0.0);
}
