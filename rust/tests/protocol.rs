//! Wire-protocol round-trip tests for the versioned request/response
//! envelope, run against a live TCP server backed by the deterministic
//! bench engine (`spawn_sweep_coordinator` — artifact-free, token
//! output a pure function of `(prompt, seed)`).
//!
//! What these lock down:
//! * a v1 client (hand-formatted pre-envelope JSON, no `"v"` key)
//!   round-trips byte-for-byte unchanged against the v2-capable server;
//! * an unsupported `"v"` gets the typed protocol-level rejection,
//!   distinct from field-level errors;
//! * a v2 streamed reply reassembles to exactly the oneshot reply for
//!   the same `(prompt, seed)`, and the client survives the stream;
//! * multi-turn sessions over TCP land prefix-store hits, observable
//!   through the metrics scrape.
//!
//! Each test binds its own port (17961..) so the suite can run in
//! parallel with the other integration tests (which use 17917..17951).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use ppd::bench::{spawn_sweep_coordinator, SweepConfig, SweepMode};
use ppd::coordinator::server::{self, Client, Envelope};
use ppd::coordinator::ResponseEvent;
use ppd::util::json::Json;

/// Spawn a sweep-config coordinator serving `n` request lines on
/// `addr`, and give the listener a beat to bind before clients connect.
fn spawn_server(cfg: SweepConfig, addr: &'static str, n: u64) -> thread::JoinHandle<()> {
    let coord = spawn_sweep_coordinator(&cfg).expect("spawn coordinator");
    let handle = thread::spawn(move || {
        server::serve(coord, addr, Some(n)).expect("serve");
    });
    thread::sleep(Duration::from_millis(300));
    handle
}

/// A v1 client in miniature: write one raw line, read one reply line.
/// Deliberately does NOT go through [`Envelope`]/[`Client`] — the point
/// is that hand-formatted pre-envelope JSON still round-trips.
fn raw_roundtrip(addr: &str, line: &str) -> Json {
    let mut stream = TcpStream::connect(addr).expect("connect");
    writeln!(stream, "{line}").expect("write request line");
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("read reply line");
    Json::parse(reply.trim()).expect("reply parses as JSON")
}

/// Pull `name value` out of a Prometheus text block.
fn metric_value(text: &str, name: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(&format!("{name} ")))
        .unwrap_or_else(|| panic!("{name} missing from metrics scrape:\n{text}"))
        .trim()
        .parse()
        .unwrap_or_else(|e| panic!("{name}: unparsable value ({e})"))
}

/// A v1 line — no `"v"` key, fields in whatever order the client felt
/// like — answers with exactly one v1 response line: the flat object
/// with the historical keys, no event framing, no envelope metadata.
#[test]
fn v1_lines_round_trip_unchanged_against_v2_server() {
    let addr = "127.0.0.1:17961";
    let server = spawn_server(SweepConfig { workers: 2, ..Default::default() }, addr, 2);

    // hand-formatted, key order scrambled: the strictest v1 client
    let reply = raw_roundtrip(addr, r#"{"max_new": 6, "seed": 42, "prompt": "hello v1"}"#);
    assert!(reply.get("error").is_none(), "v1 request failed: {reply}");
    assert!(reply.get("event").is_none(), "v1 reply must not carry event framing: {reply}");
    assert!(reply.get("v").is_none(), "v1 reply must not grow envelope keys: {reply}");
    let v1_keys =
        ["id", "text", "tokens", "steps", "tau", "decode_s", "prefill_s", "queue_s", "worker"];
    for key in v1_keys {
        assert!(reply.get(key).is_some(), "v1 reply lost key '{key}': {reply}");
    }
    assert_eq!(reply.req("tokens").unwrap().as_usize().unwrap(), 6, "{reply}");

    // the library client's v1 path speaks the same dialect
    let mut client = Client::connect(addr).expect("connect");
    let reply2 = client.request("hello v1", 6).expect("v1 library call").into_json();
    assert!(reply2.get("error").is_none(), "{reply2}");
    assert_eq!(reply2.req("tokens").unwrap().as_usize().unwrap(), 6, "{reply2}");

    drop(client);
    server.join().unwrap();
}

/// An unsupported `"v"` is rejected at the protocol level — the error
/// names the version and the speakable range, prefixed `protocol
/// error:` so clients can tell it apart from field-level complaints
/// (which keep their plain v1-era messages).
#[test]
fn malformed_version_gets_typed_protocol_error() {
    let addr = "127.0.0.1:17963";
    let server = spawn_server(SweepConfig::default(), addr, 2);

    let reply = raw_roundtrip(addr, r#"{"v": 3, "prompt": "future speak", "max_new": 4}"#);
    let err = reply
        .req("error")
        .and_then(|e| e.as_str().map(str::to_string))
        .expect("v3 line must answer with an error reply");
    assert!(err.contains("protocol error"), "missing protocol-level prefix: {err}");
    assert!(
        err.contains("unsupported protocol version 3"),
        "error must name the offending version: {err}"
    );
    assert!(err.contains("v1 and v2"), "error must name the speakable versions: {err}");

    // a field-level v2 failure is NOT a protocol error: same connection
    // envelope, different rejection class
    let reply = raw_roundtrip(addr, r#"{"v":2,"prompt":"x","max_new":4,"priority":"urgent"}"#);
    let err = reply
        .req("error")
        .and_then(|e| e.as_str().map(str::to_string))
        .expect("bad priority must answer with an error reply");
    assert!(err.contains("bad 'priority' field"), "{err}");
    assert!(!err.contains("protocol error"), "field errors keep their plain message: {err}");

    server.join().unwrap();
}

/// A v2 streamed reply is `started`, then `tokens` frames, closed by
/// exactly one `done` — and the concatenated accepted tokens reassemble
/// the oneshot reply for the same `(prompt, seed)`.  The client stays
/// usable after the stream drains (persistent connection).
#[test]
fn v2_stream_reassembles_the_oneshot_reply() {
    let addr = "127.0.0.1:17965";
    let server = spawn_server(
        SweepConfig { mode: SweepMode::Shared, workers: 2, ..Default::default() },
        addr,
        2,
    );

    let mut client = Client::connect(addr).expect("connect");
    let env = Envelope::v2("stream me", 8).with_seed(42).with_stream(true);
    let mut started = 0usize;
    let mut accepted: Vec<u32> = Vec::new();
    let mut done_stats: Option<Json> = None;
    for ev in client.stream(&env).expect("stream") {
        match ev {
            ResponseEvent::Started { .. } => {
                assert!(accepted.is_empty(), "started must precede all tokens frames");
                started += 1;
            }
            ResponseEvent::Tokens { accepted: frame, .. } => {
                assert!(!frame.is_empty(), "tokens frames carry at least one token");
                accepted.extend(frame);
            }
            ResponseEvent::Done { stats, .. } => {
                assert!(done_stats.replace(stats).is_none(), "exactly one terminal frame");
            }
            ResponseEvent::Error { message, .. } => panic!("streamed request failed: {message}"),
        }
    }
    let stats = done_stats.expect("stream must close with a done frame");
    assert_eq!(started, 1, "exactly one started frame");
    assert_eq!(accepted.len(), 8, "streamed frames must cover every generated token");
    assert_eq!(
        stats.req("tokens").unwrap().as_usize().unwrap(),
        accepted.len(),
        "done frame's token count diverged from the streamed frames: {stats}"
    );

    // same client, same (prompt, seed), streaming off: one v1-shaped
    // line whose text matches what the stream reassembled
    let reply = client
        .call(&Envelope::v2("stream me", 8).with_seed(42).with_stream(false))
        .expect("oneshot after stream")
        .into_json();
    assert!(reply.get("event").is_none(), "unstreamed v2 reply is a single v1 line: {reply}");
    assert!(reply.get("error").is_none(), "{reply}");
    assert_eq!(
        reply.req("text").unwrap().as_str().unwrap(),
        stats.req("text").unwrap().as_str().unwrap(),
        "streamed and oneshot replies must decode the same text"
    );

    drop(client);
    server.join().unwrap();
}

/// Two turns of one session over TCP: the second turn resumes the
/// session and its admission finds the first turn's pages in the prefix
/// store — all observable from outside through the metrics scrape.
#[test]
fn session_turns_reuse_prefix_pages_over_tcp() {
    let addr = "127.0.0.1:17967";
    let server = spawn_server(
        SweepConfig { mode: SweepMode::Prefix, workers: 1, ..Default::default() },
        addr,
        3,
    );

    let mut client = Client::connect(addr).expect("connect");
    let turn = || Envelope::v2("session resume prompt", 6).with_seed(7).with_session("conv-1");
    // Client::call blocks for the reply, so turn 1's pages are in the
    // prefix store before turn 2 is admitted
    let r0 = client.call(&turn()).expect("turn 1").into_json();
    assert!(r0.get("error").is_none(), "{r0}");
    let r1 = client.call(&turn()).expect("turn 2").into_json();
    assert!(r1.get("error").is_none(), "{r1}");
    assert_eq!(
        r0.req("text").unwrap().as_str().unwrap(),
        r1.req("text").unwrap().as_str().unwrap(),
        "pinned seed: both turns decode identically"
    );

    let text = client.metrics().expect("metrics scrape");
    assert_eq!(
        metric_value(&text, "ppd_session_resumes_total"),
        1.0,
        "exactly the second turn resumes the session"
    );
    assert!(
        metric_value(&text, "ppd_session_prefix_turn_hits_total") >= 1.0,
        "the resumed turn must find its conversation's pages:\n{text}"
    );
    assert!(
        metric_value(&text, "ppd_prefix_hits_total") >= 1.0,
        "the prefix store must have served shared pages:\n{text}"
    );

    drop(client);
    server.join().unwrap();
}
