//! Coordinator concurrency tests.
//!
//! These run WITHOUT model artifacts: a mock `WorkerBackend` injects a
//! deterministic engine, while everything above the engine — the shared
//! work queue, worker threads, per-request seeding, cache pool,
//! response routing, backpressure and metrics — is the production code
//! path (`serve_jobs` is the same loop `ModelBackend` uses).
//!
//! Invariants covered:
//!  * N concurrent requests across ≥2 workers come back correctly
//!    matched to their request ids, with work actually spread over
//!    multiple workers;
//!  * multi-worker output is byte-identical to the single-worker path
//!    and to a directly-driven engine (same prompt/max_new/seed);
//!  * `CachePool.created` never exceeds workers × max-inflight, no
//!    matter how many batches flow through;
//!  * identical seeds give identical outputs regardless of which worker
//!    serves the request;
//!  * over-capacity submits are rejected and counted (backpressure);
//!  * the TCP server serves concurrent connections over the pool.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use ppd::coordinator::{serve_jobs, Coordinator, Request, SchedPolicy, WorkerBackend, WorkerCtx};
use ppd::decoding::{DecodeEngine, FinishReason, SeqState, StepOutcome};
use ppd::kvcache::HostKvCache;
use ppd::util::rng::Rng;
use ppd::workload;

/// Deterministic engine: output tokens are a pure function of
/// (prompt, max_new, seed) — drawn up front in `begin_seq` and emitted
/// one per step.  Commits the borrowed cache to exercise the pool and
/// sleeps a little during prefill so jobs genuinely overlap across
/// workers.
struct MockEngine {
    seed: u64,
    delay: Duration,
}

struct MockSeq {
    pending: VecDeque<u32>,
}

impl MockEngine {
    fn new(delay: Duration) -> Self {
        MockEngine { seed: 0, delay }
    }
}

impl DecodeEngine for MockEngine {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (2, 64, 4)
    }

    fn begin_request(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn request_seed(&self) -> u64 {
        self.seed
    }

    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        cache: &mut HostKvCache,
    ) -> Result<SeqState> {
        // token 0 is unreachable from workload::encode on real text;
        // tests use it to simulate a request that panics the engine
        if prompt.first() == Some(&0) {
            panic!("mock engine panic");
        }
        cache.reset();
        let want = prompt.len().min(cache.capacity());
        cache.commit_contiguous(want.saturating_sub(cache.committed()))?;
        std::thread::sleep(self.delay);
        let mut rng = Rng::new(seed);
        let base: u64 = prompt.iter().map(|&t| t as u64).sum();
        let pending: VecDeque<u32> = (0..max_new as u64)
            .map(|i| {
                let r = rng.below(97) as u64;
                ((base + i + r) % 127) as u32
            })
            .collect();
        let mut seq = SeqState::new(max_new, rng, Box::new(MockSeq { pending }));
        seq.res.decode_s = 1e-3;
        Ok(seq)
    }

    fn step(&mut self, seq: &mut SeqState, _cache: &mut HostKvCache) -> Result<StepOutcome> {
        if let Some(r) = seq.finished {
            return Ok(StepOutcome::Finished(r));
        }
        let tok = seq.inner.downcast_mut::<MockSeq>().expect("mock seq state").pending.pop_front();
        match tok {
            Some(t) => {
                seq.res.tokens.push(t);
                seq.res.steps += 1;
                seq.res.accepted_per_step.push(1);
                if seq.res.tokens.len() >= seq.max_new {
                    Ok(seq.finish(FinishReason::Budget))
                } else {
                    Ok(StepOutcome::Running)
                }
            }
            None => Ok(seq.finish(FinishReason::Budget)),
        }
    }
}

// no plan/apply split: under a fused policy this engine still steps
// per-sequence via the default StepPlan::Fallback
impl ppd::batch::BatchStepEngine for MockEngine {}

struct MockBackend {
    delay: Duration,
}

/// Shared-runtime device host for a deviceless mock: this engine has no
/// plan/apply split, so nothing ever reaches the dispatcher — but the
/// host thread must still exist for the topology (and its gauges) to
/// come up.
struct NoDeviceExec;

impl ppd::batch::dispatch::DeviceExecutor for NoDeviceExec {
    fn exec_forward(
        &self,
        _tokens: &[u32],
        _pos: &[u32],
        _slots: &[u32],
        _bias: &[f32],
        _cache: &[f32],
    ) -> Result<ppd::runtime::StepOutput> {
        anyhow::bail!("mock backend has no device")
    }

    fn exec_forward_batch(
        &self,
        _items: &[ppd::batch::BatchItem<'_>],
    ) -> Result<Vec<ppd::runtime::StepOutput>> {
        anyhow::bail!("mock backend has no device")
    }
}

impl WorkerBackend for MockBackend {
    fn run(&self, worker: usize, ctx: WorkerCtx) {
        let mut engine = MockEngine::new(self.delay);
        ctx.ready();
        serve_jobs(worker, &mut engine, &ctx);
    }

    fn run_device(&self, host: ppd::coordinator::DeviceHost) {
        host.serve(&NoDeviceExec);
    }
}

fn spawn_mock(workers: usize, delay_ms: u64) -> Coordinator {
    Coordinator::spawn_with_backend(
        Arc::new(MockBackend { delay: Duration::from_millis(delay_ms) }),
        workers,
    )
    .expect("spawn")
}

/// The reference single-engine path: what any worker must produce for
/// this (prompt, max_new, seed).
fn expected_tokens(prompt: &[u32], max_new: usize, seed: u64) -> Vec<u32> {
    let mut e = MockEngine::new(Duration::ZERO);
    e.begin_request(seed);
    e.generate(prompt, max_new).unwrap().tokens
}

fn mk_reqs(n: usize) -> Vec<Request> {
    (0..n as u64)
        .map(|i| {
            Request::builder(workload::encode(&format!("prompt number {i}")))
                .id(i)
                .max_new(8)
                .build()
        })
        .collect()
}

#[test]
fn batch_is_reassembled_by_id_across_workers() {
    let coord = spawn_mock(4, 10);
    let reqs = mk_reqs(32);
    let expect: Vec<Vec<u32>> = reqs
        .iter()
        .map(|r| expected_tokens(&r.prompt, r.max_new, r.seed))
        .collect();
    let resps = coord.run_batch(reqs).expect("batch");
    assert_eq!(resps.len(), 32);
    let mut workers_seen = std::collections::HashSet::new();
    for (i, resp) in resps.iter().enumerate() {
        assert_eq!(resp.id, i as u64, "responses must be reassembled in request order");
        assert!(resp.is_ok(), "{:?}", resp.error_msg());
        assert_eq!(resp.tokens(), &expect[i][..], "request {i} got another request's output");
        workers_seen.insert(resp.worker);
    }
    assert!(
        workers_seen.len() >= 2,
        "expected work spread over >=2 workers, got {workers_seen:?}"
    );
}

#[test]
fn multi_worker_matches_single_worker_byte_for_byte() {
    let multi = spawn_mock(3, 5);
    let single = spawn_mock(1, 0);
    let a = multi.run_batch(mk_reqs(12)).expect("multi");
    let b = single.run_batch(mk_reqs(12)).expect("single");
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.tokens(), y.tokens());
        assert_eq!(x.text(), y.text());
    }
}

#[test]
fn cache_pool_never_exceeds_admission_budget() {
    // with step-level batching the bound is workers × max_inflight —
    // one cache per admitted sequence, reused across batches
    let workers = 3;
    let max_inflight = 2;
    let coord = Coordinator::spawn_with_backend_policy(
        Arc::new(MockBackend { delay: Duration::from_millis(2) }),
        workers,
        SchedPolicy { max_inflight, ..Default::default() },
    )
    .expect("spawn");
    for _ in 0..5 {
        let resps = coord.run_batch(mk_reqs(24)).expect("batch");
        assert_eq!(resps.len(), 24);
        let created = coord.caches_created();
        assert!(created >= 1, "pool never used");
        assert!(
            created <= workers * max_inflight,
            "pool allocated {created} caches for {workers} workers × {max_inflight} inflight"
        );
    }
    assert_eq!(coord.caches_outstanding(), 0, "all caches must return to the pool");
}

#[test]
fn identical_seeds_identical_outputs_regardless_of_worker() {
    let coord = spawn_mock(4, 5);
    let prompt = workload::encode("the same request, many times");
    // same (prompt, max_new, seed) under different ids: every response
    // must be identical no matter which worker picked it up
    let reqs: Vec<Request> = (0..16u64)
        .map(|i| Request::builder(prompt.clone()).id(i).max_new(8).seed(42).build())
        .collect();
    let resps = coord.run_batch(reqs).expect("batch");
    let workers_seen: std::collections::HashSet<usize> =
        resps.iter().map(|r| r.worker).collect();
    assert!(workers_seen.len() >= 2, "need >=2 workers to make the point");
    let want = expected_tokens(&prompt, 8, 42);
    for r in &resps {
        assert_eq!(r.tokens(), &want[..]);
    }
    // and a different seed changes the sampled output
    let other = expected_tokens(&prompt, 8, 43);
    assert_ne!(want, other);
}

#[test]
fn backpressure_rejects_over_capacity() {
    let mut coord = spawn_mock(1, 300);
    coord.set_queue_capacity(1);
    let (tx, rx) = std::sync::mpsc::channel();
    // first job: picked up by the (only) worker almost immediately
    assert!(coord
        .try_submit_routed(Request::builder(vec![1]).max_new(4).build(), tx.clone())
        .unwrap());
    std::thread::sleep(Duration::from_millis(100));
    // worker is busy for ~300ms: the next job sits in the queue...
    assert!(coord
        .try_submit_routed(Request::builder(vec![1]).id(1).max_new(4).build(), tx.clone())
        .unwrap());
    // ...so the one after must bounce off the capacity limit
    let accepted = coord
        .try_submit_routed(Request::builder(vec![1]).id(2).max_new(4).build(), tx.clone())
        .unwrap();
    assert!(!accepted, "queue at capacity must reject");
    assert!(coord.queue_stats().rejected_total() >= 1);
    drop(tx);
    // the two accepted jobs still complete
    assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
    assert!(rx.recv_timeout(Duration::from_secs(5)).is_ok());
}

#[test]
fn queue_stats_settle_after_batches() {
    let coord = spawn_mock(2, 2);
    let n = 20;
    let resps = coord.run_batch(mk_reqs(n)).expect("batch");
    assert_eq!(resps.len(), n);
    let stats = coord.queue_stats();
    assert_eq!(stats.enqueued_total(), n as u64);
    assert_eq!(stats.completed_total(), n as u64);
    assert_eq!(stats.depth(), 0);
    assert_eq!(stats.in_flight(), 0);
    assert_eq!(stats.busy_workers(), 0);
    assert!(stats.max_depth() >= 1);
}

#[test]
fn submit_recv_collector_path_still_works() {
    let coord = spawn_mock(2, 2);
    for r in mk_reqs(6) {
        coord.submit(r).expect("submit");
    }
    let mut ids: Vec<u64> = (0..6).map(|_| coord.recv().expect("recv").id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
}

#[test]
fn panicking_request_gets_error_and_worker_survives() {
    // regression: a panic inside generate must not kill the worker —
    // with one worker a silently-dead thread would wedge every later
    // submitter forever
    let coord = spawn_mock(1, 0);
    let (tx, rx) = std::sync::mpsc::channel();
    coord
        .submit_routed(Request::builder(vec![0]).max_new(4).build(), tx.clone())
        .unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(5)).expect("panic response");
    assert!(
        resp.error_msg().unwrap_or("").contains("panic"),
        "{:?}",
        resp.error_msg()
    );
    // the (only) worker must still serve subsequent requests
    coord.submit_routed(Request::builder(vec![1, 2]).id(1).max_new(4).build(), tx).unwrap();
    let resp2 = rx.recv_timeout(Duration::from_secs(5)).expect("follow-up response");
    assert!(resp2.is_ok(), "{:?}", resp2.error_msg());
    assert_eq!(resp2.tokens(), &expected_tokens(&[1, 2], 4, 1)[..]);
}

#[test]
fn fused_policy_falls_back_for_engines_without_plans() {
    // this mock has no plan/apply split: a fused scheduler must serve
    // it through the monolithic step path, token-exactly, and the
    // fused-batch counters must stay at zero (nothing actually fused)
    let coord = Coordinator::spawn_with_backend_policy(
        Arc::new(MockBackend { delay: Duration::ZERO }),
        2,
        SchedPolicy { max_inflight: 4, fuse_steps: true, ..Default::default() },
    )
    .expect("spawn");
    let reqs = mk_reqs(12);
    let expect: Vec<Vec<u32>> = reqs
        .iter()
        .map(|r| expected_tokens(&r.prompt, r.max_new, r.seed))
        .collect();
    let resps = coord.run_batch(reqs).expect("batch");
    for (i, r) in resps.iter().enumerate() {
        assert!(r.is_ok(), "{:?}", r.error_msg());
        assert_eq!(r.tokens(), &expect[i][..], "fused fallback perturbed request {i}");
    }
    assert_eq!(coord.queue_stats().fused_batches_total(), 0);
}

#[test]
fn tcp_metrics_roundtrip_exports_queue_counters() {
    // shared-nothing metrics export: a scrape over the TCP line
    // protocol reflects the counters the served requests accumulated
    let coord = spawn_mock(2, 0);
    let addr = "127.0.0.1:17935";
    let server = std::thread::spawn(move || {
        ppd::coordinator::server::serve(coord, addr, Some(4)).unwrap();
    });
    std::thread::sleep(Duration::from_millis(200));
    for i in 0..2 {
        let resp =
            ppd::coordinator::server::client_request(addr, &format!("metrics req {i}"), 4)
                .unwrap();
        assert!(resp.get("error").is_none(), "{resp}");
    }
    let text = ppd::coordinator::server::client_metrics(addr).unwrap();
    // `"metrics": false` is NOT a scrape: it parses as a (bad)
    // generation request and gets an error response, not the dump
    {
        use std::io::{BufRead, BufReader, Write};
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        writeln!(stream, "{}", r#"{"metrics": false}"#).unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        let j = ppd::util::json::Json::parse(line.trim()).unwrap();
        assert!(j.get("error").is_some(), "metrics=false must not scrape: {j}");
    }
    server.join().unwrap();
    assert!(text.contains("ppd_queue_enqueued_total 2\n"), "{text}");
    assert!(text.contains("ppd_queue_completed_total 2\n"), "{text}");
    assert!(text.contains("ppd_queue_fused_batches_total 0\n"), "{text}");
    assert!(text.contains("ppd_workers 2\n"), "{text}");
    assert!(text.contains("ppd_caches_outstanding 0\n"), "{text}");
    // dispatcher gauges ride the same scrape (zero outside
    // --shared-runtime, but always present so dashboards need no
    // topology-conditional panels)
    assert!(text.contains("ppd_shared_runtime 0\n"), "{text}");
    assert!(text.contains("ppd_dispatch_batches_total 0\n"), "{text}");
    assert!(text.contains("ppd_dispatch_rows_total 0\n"), "{text}");
    assert!(text.contains("ppd_dispatch_queue_depth 0\n"), "{text}");
    assert!(text.contains("ppd_dispatch_max_width 0\n"), "{text}");
}

#[test]
fn metrics_text_carries_dispatcher_gauges_under_shared_runtime() {
    // under --shared-runtime the dispatcher gauges go live: batches,
    // cross-worker width histogram, queue depth — in metrics_text and
    // through the TCP client_metrics round trip
    let coord = Coordinator::spawn_with_backend_policy(
        Arc::new(MockBackend { delay: Duration::ZERO }),
        2,
        SchedPolicy { max_inflight: 2, shared_runtime: true, ..Default::default() },
    )
    .expect("spawn");
    // this mock has no plan/apply split, so its steps never reach the
    // dispatcher — but the topology line and gauges must still export
    let resps = coord.run_batch(mk_reqs(4)).expect("batch");
    assert!(resps.iter().all(|r| r.is_ok()));
    let text = coord.metrics_text();
    assert!(text.contains("ppd_shared_runtime 1\n"), "{text}");
    assert!(text.contains("ppd_dispatch_queue_depth 0\n"), "{text}");
    assert!(text.contains("ppd_dispatch_batches_total"), "{text}");

    let addr = "127.0.0.1:17937";
    let server = std::thread::spawn(move || {
        ppd::coordinator::server::serve(coord, addr, Some(1)).unwrap();
    });
    std::thread::sleep(Duration::from_millis(200));
    let scraped = ppd::coordinator::server::client_metrics(addr).unwrap();
    server.join().unwrap();
    assert!(scraped.contains("ppd_shared_runtime 1\n"), "{scraped}");
    assert!(scraped.contains("ppd_dispatch_queue_depth 0\n"), "{scraped}");
    assert!(scraped.contains("ppd_dispatch_solo_forwards_total 0\n"), "{scraped}");
}

#[test]
fn tcp_trace_roundtrip_returns_chrome_trace_snapshot() {
    // the flight recorder over the line protocol: with sampling on, a
    // `trace` request returns a Chrome trace-event snapshot whose spans
    // cover the requests the server just served
    let coord = spawn_mock(2, 0);
    coord.tracer().set_enabled(true);
    let addr = "127.0.0.1:17939";
    let server = std::thread::spawn(move || {
        ppd::coordinator::server::serve(coord, addr, Some(5)).unwrap();
    });
    std::thread::sleep(Duration::from_millis(200));
    for i in 0..2 {
        let resp =
            ppd::coordinator::server::client_request(addr, &format!("trace req {i}"), 4)
                .unwrap();
        assert!(resp.get("error").is_none(), "{resp}");
    }
    let trace = ppd::coordinator::server::client_trace(addr).unwrap();
    // the bare `trace` line works too, and returns the same wrapper
    let raw = {
        use std::io::{BufRead, BufReader, Write};
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        writeln!(stream, "trace").unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        ppd::util::json::Json::parse(line.trim()).unwrap()
    };
    assert!(raw.get("trace").is_some(), "bare `trace` line must scrape: {raw}");
    // `"trace": false` is NOT a scrape: it parses as a (bad) generation
    // request and gets an error response, not the snapshot
    {
        use std::io::{BufRead, BufReader, Write};
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        writeln!(stream, "{}", r#"{"trace": false}"#).unwrap();
        let mut line = String::new();
        BufReader::new(stream).read_line(&mut line).unwrap();
        let j = ppd::util::json::Json::parse(line.trim()).unwrap();
        assert!(j.get("error").is_some(), "trace=false must not scrape: {j}");
    }
    server.join().unwrap();
    let events = trace.req("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "served requests must leave trace events");
    let named = |e: &ppd::util::json::Json, name: &str| {
        e.get("name").and_then(|n| n.as_str().ok()) == Some(name)
    };
    // track metadata plus the lifecycle endpoints: a Recv instant on
    // the server track and a Retire span on a worker track
    assert!(events.iter().any(|e| named(e, "thread_name")));
    assert!(events.iter().any(|e| named(e, "recv")));
    assert!(events
        .iter()
        .any(|e| named(e, "retire") && e.get("args").and_then(|a| a.get("req")).is_some()));
    assert_eq!(trace.req("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    assert!(trace.req("otherData").unwrap().get("dropped_events").is_some());
}

#[test]
fn paged_coordinator_is_token_exact_and_exports_block_gauges() {
    // end-to-end --kv-blocks: a coordinator on the paged pool serves
    // the same tokens as the slab default, and metrics_text exports
    // live block accounting with real prefix hits (every request
    // shares the "prompt n" chunk of mk_reqs prompts)
    let policy = |kv| SchedPolicy { max_inflight: 2, kv_blocks: kv, ..Default::default() };
    let backend = || Arc::new(MockBackend { delay: Duration::ZERO });
    let paged = Coordinator::spawn_with_backend_policy(backend(), 1, policy(Some(64)))
        .expect("spawn paged");
    let slab = Coordinator::spawn_with_backend_policy(backend(), 1, policy(None))
        .expect("spawn slab");
    let a = paged.run_batch(mk_reqs(6)).expect("paged batch");
    let b = slab.run_batch(mk_reqs(6)).expect("slab batch");
    assert_eq!(a.len(), 6);
    for (x, y) in a.iter().zip(&b) {
        assert!(x.is_ok(), "{:?}", x.error_msg());
        assert_eq!(x.tokens(), y.tokens(), "paged KV perturbed request {}", x.id);
    }
    let text = paged.metrics_text();
    // request 0 publishes the shared chunk; the single worker
    // serializes admissions, so requests 1-5 all hit it
    assert!(text.contains("ppd_prefix_hits_total 5\n"), "{text}");
    assert!(text.contains("ppd_prefix_blocks_shared_total 5\n"), "{text}");
    // every served cache is back in the pool wiped; only the
    // store-pinned shared chunk is still a live page
    assert!(text.contains("ppd_kvcache_blocks_used 1\n"), "{text}");
    assert!(text.contains("ppd_kvcache_blocks_free 63\n"), "{text}");
    assert!(paged.resident_kv_bytes() > 0, "paged pool must report resident bytes");
    assert_eq!(paged.prefix_hits(), 5);
    // the slab coordinator reports no paged activity on the same gauges
    let text = slab.metrics_text();
    assert!(text.contains("ppd_prefix_hits_total 0\n"), "{text}");
    assert!(text.contains("ppd_kvcache_blocks_used 0\n"), "{text}");
    assert_eq!(slab.prefix_hits(), 0);
}

#[test]
fn warmed_metrics_text_matches_registry_and_exports_latency() {
    // the live exporter against the metric registry, from a coordinator
    // that actually served work: every emitted line must resolve to a
    // declared metric with declared label keys, and the per-request
    // latency histograms must carry the served requests
    let coord = spawn_mock(2, 0);
    let n = 8usize;
    let resps = coord.run_batch(mk_reqs(n)).expect("batch");
    assert!(resps.iter().all(|r| r.is_ok()));
    let text = coord.metrics_text();
    for line in text.lines() {
        let name_part = line.split(' ').next().expect("metric line");
        let (name, labels) = match name_part.split_once('{') {
            Some((n, rest)) => (n, Some(rest)),
            None => (name_part, None),
        };
        let decl = ppd::metrics::registry::find(name)
            .unwrap_or_else(|| panic!("metrics_text emits undeclared metric {name}"));
        if let Some(rest) = labels {
            for kv in rest.trim_end_matches('}').split(',') {
                let key = kv.split('=').next().expect("label key");
                assert!(decl.1.contains(&key), "metric {name} emits undeclared label {key}");
            }
        }
    }
    // 8 served requests: one queue-wait/ttft/e2e sample each, and
    // (max_new - 1) inter-token gaps each (mk_reqs uses max_new = 8)
    assert!(text.contains(&format!("ppd_request_queue_wait_us{{le=\"+Inf\"}} {n}\n")), "{text}");
    assert!(text.contains(&format!("ppd_request_ttft_us{{le=\"+Inf\"}} {n}\n")), "{text}");
    assert!(text.contains(&format!("ppd_request_e2e_us{{le=\"+Inf\"}} {n}\n")), "{text}");
    assert!(text.contains(&format!("ppd_request_itl_us{{le=\"+Inf\"}} {}\n", n * 7)), "{text}");
    // sampling stayed off, so nothing was recorded — let alone dropped
    assert!(text.contains("ppd_trace_ring_dropped_total 0\n"), "{text}");
    assert!(coord.tracer().snapshot().iter().all(|(_, evs)| evs.is_empty()));
}

#[test]
fn tcp_server_returns_despite_idle_connection() {
    // regression: serve(max_requests) must not hang joining a handler
    // whose client holds the socket open without ever sending a line
    let coord = spawn_mock(1, 0);
    let addr = "127.0.0.1:17933";
    let server = std::thread::spawn(move || {
        ppd::coordinator::server::serve(coord, addr, Some(1)).unwrap();
    });
    std::thread::sleep(Duration::from_millis(200));
    let _idle = std::net::TcpStream::connect(addr).unwrap(); // never sends
    let resp = ppd::coordinator::server::client_request(addr, "hi", 4).unwrap();
    assert!(resp.get("error").is_none(), "{resp}");
    server.join().unwrap();
}

#[test]
fn tcp_server_serves_concurrent_connections() {
    let coord = spawn_mock(2, 20);
    let addr = "127.0.0.1:17931";
    let server = std::thread::spawn(move || {
        ppd::coordinator::server::serve(coord, addr, Some(4)).unwrap();
    });
    std::thread::sleep(Duration::from_millis(200));
    let mut clients = Vec::new();
    for i in 0..4 {
        clients.push(std::thread::spawn(move || {
            ppd::coordinator::server::client_request(addr, &format!("hello {i}"), 6).unwrap()
        }));
    }
    for c in clients {
        let resp = c.join().unwrap();
        assert!(resp.get("error").is_none(), "{resp}");
        assert_eq!(resp.req("tokens").unwrap().as_usize().unwrap(), 6);
    }
    server.join().unwrap();
}
