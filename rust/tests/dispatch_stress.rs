//! Stress tests for the dispatcher's tick barrier under concurrent
//! register/submit/deregister churn and mid-window shutdown — on both
//! the classic single-loop path and the pipelined two-stage
//! (collector + device) path.
//!
//! These are the races the nightly ThreadSanitizer job is pointed at
//! (see `.github/workflows/sanitizers.yml`): the barrier in
//! `DeviceDispatcher::collect` reads the registered-scheduler count
//! while worker threads mutate it, `run` exits on channel disconnect
//! while a window may still be holding submissions, and the pipelined
//! collector assembles round k+1 while the device stage executes round
//! k (with shutdown possibly catching a round in each buffer).  The
//! iteration counts are deliberately small so the suite stays fast
//! under TSan's ~10x slowdown.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use ppd::batch::dispatch::{DeviceDispatcher, DeviceExecutor, DispatchStats, TickRow};
use ppd::batch::{BatchItem, PlanInputs};
use ppd::kvcache::HostKvCache;
use ppd::runtime::StepOutput;

/// Echoes each row's first token back as its logit, counting calls and
/// rows; the tiny sleep in the batch path widens the window in which a
/// deregistering scheduler can race the barrier.
#[derive(Default)]
struct EchoExec {
    calls: AtomicU64,
    rows: AtomicU64,
}

impl DeviceExecutor for EchoExec {
    fn exec_forward(
        &self,
        tokens: &[u32],
        _pos: &[u32],
        _slots: &[u32],
        _bias: &[f32],
        _cache: &[f32],
    ) -> Result<StepOutput> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(1, Ordering::Relaxed);
        Ok(StepOutput { n: 1, logits: vec![tokens[0] as f32], hidden: vec![], new_kv: vec![] })
    }

    fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(items.len() as u64, Ordering::Relaxed);
        thread::sleep(Duration::from_micros(200));
        Ok(items
            .iter()
            .map(|it| StepOutput {
                n: 1,
                logits: vec![it.plan.tokens[0] as f32],
                hidden: vec![],
                new_kv: vec![],
            })
            .collect())
    }
}

fn row(tag: u32) -> TickRow {
    TickRow {
        plan: PlanInputs {
            tokens: vec![tag],
            pos: vec![0],
            slots: vec![0],
            bias: vec![0.0; 8],
            max_ctx: 8,
        },
        cache: HostKvCache::new(1, 8, 2),
    }
}

/// Many schedulers registering, submitting, and deregistering in tight
/// loops against one live dispatcher thread: every submission must be
/// answered with its own echo, the queue must drain to zero, and the
/// dispatcher must exit once the last handle drops.
#[test]
fn tick_barrier_survives_register_deregister_churn() {
    const THREADS: usize = 8;
    const ITERS: u32 = 24;

    let stats = Arc::new(DispatchStats::default());
    let window = Duration::from_micros(500);
    let (handle, disp) = DeviceDispatcher::channel(window, Arc::clone(&stats));
    let exec = Arc::new(EchoExec::default());
    let dexec = Arc::clone(&exec);
    let disp_thread = thread::spawn(move || disp.run(&*dexec));

    let mut workers = Vec::new();
    for t in 0..THREADS {
        let h = handle.clone();
        workers.push(thread::spawn(move || {
            for i in 0..ITERS {
                let tag = (t as u32) * 1000 + i;
                h.register();
                let rx = h.submit_tick(t, vec![row(tag)]).expect("dispatcher alive");
                let reply = rx.recv().expect("reply must arrive");
                let outs = reply.outs.expect("echo step cannot fail");
                assert_eq!(outs.len(), 1);
                assert_eq!(outs[0].logits, vec![tag as f32], "reply misrouted");
                assert_eq!(reply.rows.len(), 1, "caches must come back with the reply");
                h.deregister();
            }
        }));
    }
    for w in workers {
        w.join().expect("churn thread panicked");
    }

    let expected = (THREADS as u64) * u64::from(ITERS);
    assert_eq!(stats.rows_total(), expected, "every submitted row must be dispatched");
    assert_eq!(exec.rows.load(Ordering::Relaxed), expected);
    assert_eq!(stats.queue_depth(), 0, "queue must drain after churn");
    assert_eq!(handle.active(), 0, "every register matched a deregister");

    drop(handle);
    disp_thread.join().expect("dispatcher must exit once all handles drop");
}

/// A scheduler that gives up on a tick (drops its reply receiver)
/// must not wedge or kill the dispatcher: later submissions — in the
/// same fused round and in later rounds — still get their replies.
#[test]
fn dropped_reply_receivers_do_not_wedge_the_dispatcher() {
    let stats = Arc::new(DispatchStats::default());
    let (handle, disp) = DeviceDispatcher::channel(Duration::from_micros(500), Arc::clone(&stats));
    let exec = EchoExec::default();

    for round in 0..32u32 {
        let kept = handle.submit_tick(0, vec![row(round)]).expect("dispatcher alive");
        drop(handle.submit_tick(1, vec![row(10_000 + round)]).expect("dispatcher alive"));
        disp.pump(&exec);
        let reply = kept.recv().expect("kept receiver must get its reply");
        assert_eq!(reply.outs.expect("echo step cannot fail")[0].logits, vec![round as f32]);
    }

    assert_eq!(stats.queue_depth(), 0, "abandoned ticks must still be drained");
    assert_eq!(stats.rows_total(), 64, "abandoned rows are dispatched, not dropped");
}

/// The shutdown race itself: a window opens waiting on a second
/// registered scheduler, and every handle is dropped before it ever
/// submits.  The disconnect must flush the half-full window (the
/// submitted row still gets its reply) and the dispatcher must exit
/// instead of waiting on the vanished scheduler.
#[test]
fn shutdown_mid_window_flushes_pending_rows_and_joins() {
    let stats = Arc::new(DispatchStats::default());
    let window = Duration::from_secs(30); // far longer than the test: only disconnect can end it
    let (handle, disp) = DeviceDispatcher::channel(window, Arc::clone(&stats));
    let exec = Arc::new(EchoExec::default());
    let dexec = Arc::clone(&exec);
    let disp_thread = thread::spawn(move || disp.run(&*dexec));

    handle.register();
    handle.register(); // second scheduler never submits
    let rx = handle.submit_tick(0, vec![row(5)]).expect("dispatcher alive");
    drop(handle);

    let reply = rx.recv().expect("half-full window must flush on disconnect");
    assert_eq!(reply.outs.expect("echo step cannot fail")[0].logits, vec![5.0]);
    disp_thread.join().expect("dispatcher must exit once all handles drop");
    assert_eq!(stats.queue_depth(), 0);
    assert_eq!(exec.calls.load(Ordering::Relaxed), 1);
}

/// Blocks inside each fused batch until the test releases it, reporting
/// entry over a channel — turns "the device is mid-round" from a race
/// into a deterministic state, so the pipelined tests can *prove*
/// rounds are assembled while the previous round executes rather than
/// hope a sleep lined up.
struct GateExec {
    rows: AtomicU64,
    entered: mpsc::Sender<()>,
    release: Mutex<mpsc::Receiver<()>>,
}

impl GateExec {
    /// `(executor, entered_rx, release_tx)`: recv on `entered_rx` to
    /// know a batch is executing, send on `release_tx` to let it finish.
    fn new() -> (Self, mpsc::Receiver<()>, mpsc::Sender<()>) {
        let (entered_tx, entered_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel();
        let exec = GateExec {
            rows: AtomicU64::new(0),
            entered: entered_tx,
            release: Mutex::new(release_rx),
        };
        (exec, entered_rx, release_tx)
    }
}

impl DeviceExecutor for GateExec {
    fn exec_forward(
        &self,
        tokens: &[u32],
        _pos: &[u32],
        _slots: &[u32],
        _bias: &[f32],
        _cache: &[f32],
    ) -> Result<StepOutput> {
        Ok(StepOutput { n: 1, logits: vec![tokens[0] as f32], hidden: vec![], new_kv: vec![] })
    }

    fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.rows.fetch_add(items.len() as u64, Ordering::Relaxed);
        let _ = self.entered.send(());
        // a dropped release sender must not wedge the device stage —
        // ignore the error and let the batch finish
        let _ = self.release.lock().expect("gate lock").recv();
        Ok(items
            .iter()
            .map(|it| StepOutput {
                n: 1,
                logits: vec![it.plan.tokens[0] as f32],
                hidden: vec![],
                new_kv: vec![],
            })
            .collect())
    }
}

/// The same register/submit/deregister churn as the first test, but
/// through the pipelined two-stage serve loop: every reply must still
/// be routed to its own submitter, the queue must drain, and the
/// collector + device stages must both exit once the last handle
/// drops.  The echo executor's in-batch sleep keeps the device stage
/// busy so the collector genuinely races it.
#[test]
fn pipelined_tick_barrier_survives_register_deregister_churn() {
    const THREADS: usize = 8;
    const ITERS: u32 = 24;

    let stats = Arc::new(DispatchStats::default());
    let (handle, mut disp) =
        DeviceDispatcher::channel(Duration::from_micros(500), Arc::clone(&stats));
    disp.set_pipelined(true);
    let exec = Arc::new(EchoExec::default());
    let dexec = Arc::clone(&exec);
    let disp_thread = thread::spawn(move || disp.run(&*dexec));

    let mut workers = Vec::new();
    for t in 0..THREADS {
        let h = handle.clone();
        workers.push(thread::spawn(move || {
            for i in 0..ITERS {
                let tag = (t as u32) * 1000 + i;
                h.register();
                let rx = h.submit_tick(t, vec![row(tag)]).expect("dispatcher alive");
                let reply = rx.recv().expect("reply must arrive");
                let outs = reply.outs.expect("echo step cannot fail");
                assert_eq!(outs.len(), 1);
                assert_eq!(outs[0].logits, vec![tag as f32], "reply misrouted");
                assert_eq!(reply.rows.len(), 1, "caches must come back with the reply");
                h.deregister();
            }
        }));
    }
    for w in workers {
        w.join().expect("churn thread panicked");
    }

    let expected = (THREADS as u64) * u64::from(ITERS);
    assert_eq!(stats.rows_total(), expected, "every submitted row must be dispatched");
    assert_eq!(exec.rows.load(Ordering::Relaxed), expected);
    assert_eq!(stats.queue_depth(), 0, "queue must drain after churn");
    assert_eq!(handle.active(), 0, "every register matched a deregister");
    assert!(stats.window_us() > 0, "collector must publish its adaptive window");
    assert!(stats.device_busy_us_total() > 0, "device busy time must accumulate");

    drop(handle);
    disp_thread.join().expect("both pipelined stages must exit once all handles drop");
}

/// The overlap the pipelined topology exists for, made deterministic:
/// with the device stage gated open inside round 1, rounds 2 and 3 are
/// submitted and must be fully assembled by the collector — and
/// counted as overlap — *before* round 1 is released.
#[test]
fn pipelined_collector_assembles_rounds_while_device_executes() {
    let stats = Arc::new(DispatchStats::default());
    let (handle, mut disp) =
        DeviceDispatcher::channel(Duration::from_micros(500), Arc::clone(&stats));
    disp.set_pipelined(true);
    let (exec, entered, release) = GateExec::new();
    let exec = Arc::new(exec);
    let dexec = Arc::clone(&exec);
    let disp_thread = thread::spawn(move || disp.run(&*dexec));

    let rx1 = handle.submit_tick(0, vec![row(21)]).expect("dispatcher alive");
    entered.recv().expect("device stage must enter round 1");
    // the device is now provably mid-round; these two rounds can only
    // be assembled during its execution
    let rx2 = handle.submit_tick(0, vec![row(22)]).expect("dispatcher alive");
    let rx3 = handle.submit_tick(0, vec![row(23)]).expect("dispatcher alive");
    let deadline = Instant::now() + Duration::from_secs(10);
    while stats.overlap_batches_total() < 2 {
        assert!(
            Instant::now() < deadline,
            "collector never assembled rounds 2 and 3 during round 1's execution"
        );
        thread::sleep(Duration::from_micros(200));
    }

    release.send(()).expect("device stage holds the gate");
    let r1 = rx1.recv().expect("round 1 reply");
    assert_eq!(r1.outs.expect("echo step cannot fail")[0].logits, vec![21.0]);
    for (rx, want) in [(rx2, 22.0), (rx3, 23.0)] {
        entered.recv().expect("device stage must take the staged round");
        release.send(()).expect("device stage holds the gate");
        let reply = rx.recv().expect("staged round reply");
        assert_eq!(reply.outs.expect("echo step cannot fail")[0].logits, vec![want]);
    }

    assert_eq!(stats.batches_total(), 3);
    assert_eq!(stats.rows_total(), 3);
    assert_eq!(stats.overlap_batches_total(), 2, "exactly rounds 2 and 3 overlapped");
    assert!(stats.device_busy_us_total() > 0);
    drop(handle);
    disp_thread.join().expect("both pipelined stages must exit once all handles drop");
}

/// Shutdown with work parked in *every* pipeline buffer: round 1 held
/// open on the device stage, round 2 staged in the depth-1 buffer,
/// round 3 at the collector — then every handle drops.  All three must
/// still be answered and both stages must join.
#[test]
fn pipelined_shutdown_with_rounds_in_both_buffers_stays_lossless() {
    let stats = Arc::new(DispatchStats::default());
    let (handle, mut disp) =
        DeviceDispatcher::channel(Duration::from_micros(500), Arc::clone(&stats));
    disp.set_pipelined(true);
    let (exec, entered, release) = GateExec::new();
    let exec = Arc::new(exec);
    let dexec = Arc::clone(&exec);
    let disp_thread = thread::spawn(move || disp.run(&*dexec));

    let rx1 = handle.submit_tick(0, vec![row(31)]).expect("dispatcher alive");
    entered.recv().expect("device stage must enter round 1");
    let rx2 = handle.submit_tick(0, vec![row(32)]).expect("dispatcher alive");
    let rx3 = handle.submit_tick(0, vec![row(33)]).expect("dispatcher alive");
    drop(handle);

    release.send(()).expect("device stage holds the gate");
    let r1 = rx1.recv().expect("round 1 must be answered despite shutdown");
    assert_eq!(r1.outs.expect("echo step cannot fail")[0].logits, vec![31.0]);
    for (rx, want) in [(rx2, 32.0), (rx3, 33.0)] {
        entered.recv().expect("buffered round must still reach the device stage");
        release.send(()).expect("device stage holds the gate");
        let reply = rx.recv().expect("buffered round must be answered despite shutdown");
        assert_eq!(reply.outs.expect("echo step cannot fail")[0].logits, vec![want]);
    }

    disp_thread.join().expect("both pipelined stages must exit after the lossless drain");
    assert_eq!(stats.rows_total(), 3, "no buffered round may be dropped at shutdown");
    assert_eq!(stats.queue_depth(), 0);
    assert_eq!(exec.rows.load(Ordering::Relaxed), 3);
}
