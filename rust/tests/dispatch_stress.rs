//! Stress tests for the dispatcher's tick barrier under concurrent
//! register/submit/deregister churn and mid-window shutdown.
//!
//! These are the races the nightly ThreadSanitizer job is pointed at
//! (see `.github/workflows/sanitizers.yml`): the barrier in
//! `DeviceDispatcher::collect` reads the registered-scheduler count
//! while worker threads mutate it, and `run` exits on channel
//! disconnect while a window may still be holding submissions.  The
//! iteration counts are deliberately small so the suite stays fast
//! under TSan's ~10x slowdown.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::Result;

use ppd::batch::dispatch::{DeviceDispatcher, DeviceExecutor, DispatchStats, TickRow};
use ppd::batch::{BatchItem, PlanInputs};
use ppd::kvcache::HostKvCache;
use ppd::runtime::StepOutput;

/// Echoes each row's first token back as its logit, counting calls and
/// rows; the tiny sleep in the batch path widens the window in which a
/// deregistering scheduler can race the barrier.
#[derive(Default)]
struct EchoExec {
    calls: AtomicU64,
    rows: AtomicU64,
}

impl DeviceExecutor for EchoExec {
    fn exec_forward(
        &self,
        tokens: &[u32],
        _pos: &[u32],
        _slots: &[u32],
        _bias: &[f32],
        _cache: &[f32],
    ) -> Result<StepOutput> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(1, Ordering::Relaxed);
        Ok(StepOutput { n: 1, logits: vec![tokens[0] as f32], hidden: vec![], new_kv: vec![] })
    }

    fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(items.len() as u64, Ordering::Relaxed);
        thread::sleep(Duration::from_micros(200));
        Ok(items
            .iter()
            .map(|it| StepOutput {
                n: 1,
                logits: vec![it.plan.tokens[0] as f32],
                hidden: vec![],
                new_kv: vec![],
            })
            .collect())
    }
}

fn row(tag: u32) -> TickRow {
    TickRow {
        plan: PlanInputs {
            tokens: vec![tag],
            pos: vec![0],
            slots: vec![0],
            bias: vec![0.0; 8],
            max_ctx: 8,
        },
        cache: HostKvCache::new(1, 8, 2),
    }
}

/// Many schedulers registering, submitting, and deregistering in tight
/// loops against one live dispatcher thread: every submission must be
/// answered with its own echo, the queue must drain to zero, and the
/// dispatcher must exit once the last handle drops.
#[test]
fn tick_barrier_survives_register_deregister_churn() {
    const THREADS: usize = 8;
    const ITERS: u32 = 24;

    let stats = Arc::new(DispatchStats::default());
    let window = Duration::from_micros(500);
    let (handle, disp) = DeviceDispatcher::channel(window, Arc::clone(&stats));
    let exec = Arc::new(EchoExec::default());
    let dexec = Arc::clone(&exec);
    let disp_thread = thread::spawn(move || disp.run(&*dexec));

    let mut workers = Vec::new();
    for t in 0..THREADS {
        let h = handle.clone();
        workers.push(thread::spawn(move || {
            for i in 0..ITERS {
                let tag = (t as u32) * 1000 + i;
                h.register();
                let rx = h.submit_tick(t, vec![row(tag)]).expect("dispatcher alive");
                let reply = rx.recv().expect("reply must arrive");
                let outs = reply.outs.expect("echo step cannot fail");
                assert_eq!(outs.len(), 1);
                assert_eq!(outs[0].logits, vec![tag as f32], "reply misrouted");
                assert_eq!(reply.rows.len(), 1, "caches must come back with the reply");
                h.deregister();
            }
        }));
    }
    for w in workers {
        w.join().expect("churn thread panicked");
    }

    let expected = (THREADS as u64) * u64::from(ITERS);
    assert_eq!(stats.rows_total(), expected, "every submitted row must be dispatched");
    assert_eq!(exec.rows.load(Ordering::Relaxed), expected);
    assert_eq!(stats.queue_depth(), 0, "queue must drain after churn");
    assert_eq!(handle.active(), 0, "every register matched a deregister");

    drop(handle);
    disp_thread.join().expect("dispatcher must exit once all handles drop");
}

/// A scheduler that gives up on a tick (drops its reply receiver)
/// must not wedge or kill the dispatcher: later submissions — in the
/// same fused round and in later rounds — still get their replies.
#[test]
fn dropped_reply_receivers_do_not_wedge_the_dispatcher() {
    let stats = Arc::new(DispatchStats::default());
    let (handle, disp) = DeviceDispatcher::channel(Duration::from_micros(500), Arc::clone(&stats));
    let exec = EchoExec::default();

    for round in 0..32u32 {
        let kept = handle.submit_tick(0, vec![row(round)]).expect("dispatcher alive");
        drop(handle.submit_tick(1, vec![row(10_000 + round)]).expect("dispatcher alive"));
        disp.pump(&exec);
        let reply = kept.recv().expect("kept receiver must get its reply");
        assert_eq!(reply.outs.expect("echo step cannot fail")[0].logits, vec![round as f32]);
    }

    assert_eq!(stats.queue_depth(), 0, "abandoned ticks must still be drained");
    assert_eq!(stats.rows_total(), 64, "abandoned rows are dispatched, not dropped");
}

/// The shutdown race itself: a window opens waiting on a second
/// registered scheduler, and every handle is dropped before it ever
/// submits.  The disconnect must flush the half-full window (the
/// submitted row still gets its reply) and the dispatcher must exit
/// instead of waiting on the vanished scheduler.
#[test]
fn shutdown_mid_window_flushes_pending_rows_and_joins() {
    let stats = Arc::new(DispatchStats::default());
    let window = Duration::from_secs(30); // far longer than the test: only disconnect can end it
    let (handle, disp) = DeviceDispatcher::channel(window, Arc::clone(&stats));
    let exec = Arc::new(EchoExec::default());
    let dexec = Arc::clone(&exec);
    let disp_thread = thread::spawn(move || disp.run(&*dexec));

    handle.register();
    handle.register(); // second scheduler never submits
    let rx = handle.submit_tick(0, vec![row(5)]).expect("dispatcher alive");
    drop(handle);

    let reply = rx.recv().expect("half-full window must flush on disconnect");
    assert_eq!(reply.outs.expect("echo step cannot fail")[0].logits, vec![5.0]);
    disp_thread.join().expect("dispatcher must exit once all handles drop");
    assert_eq!(stats.queue_depth(), 0);
    assert_eq!(exec.calls.load(Ordering::Relaxed), 1);
}
