//! Randomized property tests (proptest is not in the offline vendor
//! set; these use the crate's deterministic xorshift RNG with many
//! seeds — shrinkage-free but reproducible: every assertion message
//! carries the seed).
//!
//! Invariants covered:
//!  * dynamic-tree construction: structural validity, budget respect,
//!    stochastic transition rows, τ bounds, monotonicity vs stats
//!  * tree layout/bias assembly: ancestor-closure, sibling isolation,
//!    position/slot consistency under random tree shapes
//!  * KV cache: scatter/compact equals a reference simulator under
//!    random operation sequences
//!  * batch collation: ragged plans → pad → split round-trips every
//!    sequence's logits rows and KV entries for random tree shapes and
//!    batch sizes — and KV-length truncation (the `_s{kv}` batched
//!    variants) preserves every real bias/cache value while leaving
//!    the per-row splits unchanged
//!  * verification: greedy walk equals brute-force longest-matching path
//!  * chains_to_tree: merged tree reproduces every proposed chain
//!  * JSON: parse∘serialize is the identity on random values

use ppd::batch::collator::{collate, split};
use ppd::batch::{BatchItem, PlanInputs};
use ppd::decoding::lookup::chains_to_tree;
use ppd::decoding::verify::{verify, VerifyMode};
use ppd::kvcache::{BlockPool, HostKvCache};
use ppd::runtime::StepOutput;
use ppd::tree::builder::AcceptStats;
use ppd::tree::dynamic::DynamicTreeSet;
use ppd::tree::{assemble_step, GuessSet, SparseTree};
use ppd::util::json::Json;
use ppd::util::rng::Rng;

/// Seed count per property, overridable via `PPD_PROP_SEEDS` so slow
/// interpreters can bound runtime (the nightly Miri job runs with
/// `PPD_PROP_SEEDS=3`; an unset or unparsable value keeps the default).
fn seeds(default: u64) -> u64 {
    std::env::var("PPD_PROP_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn random_stats(rng: &mut Rng) -> AcceptStats {
    AcceptStats::synthetic(
        3,
        0.2 + 0.6 * rng.next_f64(),
        0.2 + 0.6 * rng.next_f64(),
        0.4 + 0.5 * rng.next_f64(),
    )
}

#[test]
fn prop_dynamic_tree_structure() {
    for seed in 0..seeds(40) {
        let mut rng = Rng::new(seed);
        let stats = random_stats(&mut rng);
        let nc = 1 + rng.below(24);
        let np = 3 + rng.below(40);
        let set = DynamicTreeSet::build(&stats, 3, nc, np, 10)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(set.trees.len(), 4, "seed {seed}");
        for (k, t) in set.trees.iter().enumerate() {
            t.validate().unwrap_or_else(|e| panic!("seed {seed} T_{k}: {e}"));
            assert!(t.nodes.iter().all(|n| n.depth <= k), "seed {seed}");
            if k > 0 {
                // prompt budget respected up to the floor (min 1 chain
                // per candidate + the pinned root chain)
                let floor = t.n_candidates() + 3;
                assert!(
                    t.n_prompt() <= np.max(floor) + 3,
                    "seed {seed}: {} > max({np},{floor})+3",
                    t.n_prompt()
                );
                // every candidate keeps at least one prompt token
                assert!(t.nodes.iter().skip(1).all(|n| n.prompt_len >= 1), "seed {seed}");
            }
        }
        // transition matrix is row-stochastic; steady state sums to 1
        for row in &set.transition {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6, "seed {seed}: {row:?}");
        }
        let s: f64 = set.steady.iter().sum();
        assert!((s - 1.0).abs() < 1e-6, "seed {seed}");
        // τ ∈ [1, 1 + n_c]
        assert!(set.tau() >= 1.0 && set.tau() <= 1.0 + nc as f64, "seed {seed}");
    }
}

#[test]
fn prop_better_stats_never_hurt_tau() {
    for seed in 0..seeds(20) {
        let mut rng = Rng::new(seed + 100);
        let top1 = 0.2 + 0.5 * rng.next_f64();
        let weak = AcceptStats::synthetic(3, top1, 0.4, 0.7);
        let strong = AcceptStats::synthetic(3, (top1 + 0.2).min(0.9), 0.4, 0.7);
        let a = DynamicTreeSet::build(&weak, 3, 8, 14, 10).unwrap();
        let b = DynamicTreeSet::build(&strong, 3, 8, 14, 10).unwrap();
        assert!(b.tau() + 1e-9 >= a.tau(), "seed {seed}: {} < {}", b.tau(), a.tau());
    }
}

#[test]
fn prop_layout_bias_closure() {
    // ancestors must be transitively closed and sibling-free for random
    // dynamic trees; bias rows expose exactly committed+ancestors+self
    for seed in 0..seeds(30) {
        let mut rng = Rng::new(seed + 7);
        let stats = random_stats(&mut rng);
        let set = DynamicTreeSet::build(&stats, 3, 1 + rng.below(16), 3 + rng.below(24), 10).unwrap();
        let tree = &set.trees[3];
        let layout = &set.layouts[3];
        let committed = rng.below(64);
        let max_ctx = 256;
        let guesses = GuessSet {
            per_distance: (0..3)
                .map(|_| (0..10).map(|r| (32 + r as u32, 0.1)).collect())
                .collect(),
        };
        let inputs = assemble_step(tree, layout, &guesses, 1, committed as u32, committed, max_ctx)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let n = tree.input_len();
        for t in 0..n {
            let row = &inputs.bias[t * max_ctx..(t + 1) * max_ctx];
            // committed region fully visible
            assert!(row[..committed].iter().all(|&b| b == 0.0), "seed {seed}");
            // self visible
            assert_eq!(row[committed + t], 0.0, "seed {seed}");
            // visible set within the tree = {root} ∪ ancestors ∪ {self}
            let visible: Vec<usize> = (0..n).filter(|&j| row[committed + j] == 0.0).collect();
            for &v in &visible {
                let ok = v == t
                    || v == 0
                    || layout.ancestors[t].contains(&v);
                assert!(ok, "seed {seed}: token {t} sees non-ancestor {v}");
            }
            // slots/pos are consistent
            assert_eq!(inputs.slots[t] as usize, committed + t, "seed {seed}");
            assert_eq!(
                inputs.pos[t] as usize,
                committed + layout.pos_offset[t],
                "seed {seed}"
            );
        }
    }
}

/// Reference simulator: a plain Vec<Vec<f32>> per plane.
struct RefCache {
    rows: Vec<Vec<Vec<f32>>>, // [plane][slot] -> row
    committed: usize,
}

#[test]
fn prop_kvcache_matches_reference_simulator() {
    let planes = 4;
    let s = 64;
    let d = 3;
    for seed in 0..seeds(30) {
        let mut rng = Rng::new(seed + 31);
        let mut cache = HostKvCache::new(planes / 2, s, d);
        let mut reference = RefCache {
            rows: vec![vec![vec![0.0; d]; s]; planes],
            committed: 0,
        };
        let mut next_val = 1.0f32;
        for _op in 0..30 {
            let committed = cache.committed();
            if committed + 10 >= cache.capacity() {
                break;
            }
            // scatter a random tree of k rows at committed..committed+k
            let k = 1 + rng.below(6);
            let slots: Vec<u32> = (0..k).map(|i| (committed + i) as u32).collect();
            let mut new_kv = Vec::new();
            for p in 0..planes {
                for i in 0..k {
                    for _ in 0..d {
                        new_kv.push(next_val + (p * 100 + i) as f32);
                    }
                }
            }
            next_val += 1000.0;
            cache.scatter(&new_kv, &slots).unwrap();
            for p in 0..planes {
                for (i, &slot) in slots.iter().enumerate() {
                    let base = (p * k + i) * d;
                    reference.rows[p][slot as usize] = new_kv[base..base + d].to_vec();
                }
            }
            // accept a random subset path (increasing slots, first = root)
            let mut accepted = vec![slots[0]];
            for &sl in &slots[1..] {
                if rng.next_f64() < 0.5 {
                    accepted.push(sl);
                }
            }
            cache.compact(&accepted).unwrap();
            for (i, &src) in accepted.iter().enumerate() {
                let dst = reference.committed + i;
                for p in 0..planes {
                    let row = reference.rows[p][src as usize].clone();
                    reference.rows[p][dst] = row;
                }
            }
            reference.committed += accepted.len();
            assert_eq!(cache.committed(), reference.committed, "seed {seed}");
            for p in 0..planes {
                for slot in 0..reference.committed {
                    assert_eq!(
                        cache.row(p, slot),
                        &reference.rows[p][slot][..],
                        "seed {seed} plane {p} slot {slot}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_cache_scatter_compact_truncate_roundtrip() {
    // scatter a block of scratch rows, accept a random increasing
    // subset, compact, then randomly truncate (mid-flight abort) and
    // rebuild: the committed region must always hold exactly the
    // accepted rows in order, and committed() must account for every
    // compact/truncate exactly
    let planes = 4;
    let s = 48;
    let d = 2;
    for seed in 0..seeds(40) {
        let mut rng = Rng::new(seed + 977);
        let mut cache = HostKvCache::new(planes / 2, s, d);
        // shadow model: the row values the committed region must hold
        let mut committed_rows: Vec<f32> = Vec::new(); // value tag per slot
        let mut next_val = 1.0f32;
        for _round in 0..12 {
            let committed = cache.committed();
            assert_eq!(committed, committed_rows.len(), "seed {seed}");
            if committed + 8 >= cache.capacity() {
                break;
            }
            // scatter k scratch rows at committed..committed+k, each row
            // filled with a unique tag value
            let k = 1 + rng.below(6);
            let slots: Vec<u32> = (0..k).map(|i| (committed + i) as u32).collect();
            let mut new_kv = Vec::with_capacity(planes * k * d);
            for p in 0..planes {
                for i in 0..k {
                    for _ in 0..d {
                        new_kv.push(next_val + (p * 100 + i) as f32);
                    }
                }
            }
            cache.scatter(&new_kv, &slots).unwrap();
            // accept a random increasing subset (always keep the root)
            let mut accepted = vec![slots[0]];
            let mut accepted_tags = vec![next_val];
            for (i, &sl) in slots.iter().enumerate().skip(1) {
                if rng.next_f64() < 0.6 {
                    accepted.push(sl);
                    accepted_tags.push(next_val + i as f32);
                }
            }
            next_val += 1000.0;
            cache.compact(&accepted).unwrap();
            committed_rows.extend_from_slice(&accepted_tags);
            assert_eq!(cache.committed(), committed_rows.len(), "seed {seed}");
            // every accepted row landed in the committed region, in
            // order, in every plane (row (p, i) was written as
            // tag + p*100, so the plane offset reconstructs exactly)
            for (slot, &tag) in committed_rows.iter().enumerate() {
                for p in 0..planes {
                    assert_eq!(
                        cache.row(p, slot)[0],
                        tag + (p * 100) as f32,
                        "seed {seed} plane {p} slot {slot}"
                    );
                }
            }
            // occasionally truncate (mid-flight abort / retry)
            if rng.next_f64() < 0.3 && cache.committed() > 0 {
                let keep = rng.below(cache.committed() + 1);
                cache.truncate(keep).unwrap();
                committed_rows.truncate(keep);
                assert_eq!(cache.committed(), keep, "seed {seed}");
                // rows below the truncation point are untouched
                for (slot, &tag) in committed_rows.iter().enumerate() {
                    assert_eq!(
                        cache.row(0, slot)[0],
                        tag,
                        "seed {seed} slot {slot} after truncate"
                    );
                }
            }
        }
        // reset round-trip: committed drops to zero, reuse works
        cache.reset();
        assert_eq!(cache.committed(), 0, "seed {seed}");
        assert_eq!(cache.remaining(), cache.capacity(), "seed {seed}");
    }
}

/// Assert that a slab cache and a paged cache hold the same *logical*
/// contents: equal committed length, and byte-identical committed
/// regions in every plane.  Rows above `committed` are deliberately
/// excluded — they are dead in both designs (the slab keeps stale
/// garbage there, the paged store reads zeros from released pages) and
/// the device masks them either way.
fn assert_logically_equal(slab: &HostKvCache, paged: &HostKvCache, ctx: &str) {
    assert_eq!(slab.committed(), paged.committed(), "{ctx}: committed");
    let (layers, _, d) = slab.shape();
    let planes = 2 * layers;
    let kv = slab.committed();
    let mut a = vec![0.0f32; kv * d];
    let mut b = vec![0.0f32; kv * d];
    for p in 0..planes {
        slab.copy_plane_prefix(p, kv, &mut a);
        paged.copy_plane_prefix(p, kv, &mut b);
        assert_eq!(a, b, "{ctx}: plane {p} committed region");
    }
}

#[test]
fn prop_paged_cache_matches_slab_on_random_ops() {
    // drive a slab cache and a paged cache (tiny 4-slot pages, so every
    // operation straddles page boundaries) through identical random
    // scatter / compact / truncate / prefill-commit sequences: after
    // every operation the two must agree on the committed logical
    // contents, and after drop the paged cache must return every page
    let (layers, s, d) = (2usize, 48usize, 3usize);
    let planes = 2 * layers;
    for seed in 0..seeds(30) {
        let mut rng = Rng::new(seed + 4242);
        let pool = BlockPool::new(layers, 4, d, 1024);
        let mut slab = HostKvCache::new(layers, s, d);
        let mut paged = HostKvCache::new_paged(layers, s, d, &pool);
        let mut next_val = 1.0f32;
        for round in 0..16 {
            let committed = slab.committed();
            let free = slab.capacity() - committed;
            let ctx = format!("seed {seed} round {round}");
            match rng.below(3) {
                // speculative step: scatter a scratch block, accept a
                // random increasing subset, compact
                0 | 1 if free > 0 => {
                    let k = (1 + rng.below(6)).min(free);
                    let slots: Vec<u32> =
                        (0..k).map(|i| (committed + i) as u32).collect();
                    let mut new_kv = Vec::with_capacity(planes * k * d);
                    for p in 0..planes {
                        for i in 0..k {
                            for c in 0..d {
                                new_kv.push(next_val + (p * 100 + i * 10 + c) as f32);
                            }
                        }
                    }
                    next_val += 1000.0;
                    slab.scatter(&new_kv, &slots).unwrap();
                    paged.scatter(&new_kv, &slots).unwrap();
                    // an increasing subset that keeps the root is always
                    // a valid acceptance path
                    let mut accepted = vec![slots[0]];
                    for &sl in slots.iter().skip(1) {
                        if rng.next_f64() < 0.6 {
                            accepted.push(sl);
                        }
                    }
                    slab.compact(&accepted).unwrap();
                    paged.compact(&accepted).unwrap();
                }
                // prefill-style step: write rows in place, then commit
                // them contiguously (prefill always scatters before it
                // commits, so committed rows are never unwritten)
                2 if free > 0 => {
                    let k = (1 + rng.below(4)).min(free);
                    let slots: Vec<u32> =
                        (0..k).map(|i| (committed + i) as u32).collect();
                    let row: Vec<f32> = (0..planes * k * d)
                        .map(|i| next_val + i as f32)
                        .collect();
                    next_val += 1000.0;
                    slab.scatter(&row, &slots).unwrap();
                    paged.scatter(&row, &slots).unwrap();
                    slab.commit_contiguous(k).unwrap();
                    paged.commit_contiguous(k).unwrap();
                }
                _ => {}
            }
            assert_logically_equal(&slab, &paged, &ctx);
            // occasionally truncate (mid-flight abort / retry)
            if rng.next_f64() < 0.3 && slab.committed() > 0 {
                let keep = rng.below(slab.committed() + 1);
                slab.truncate(keep).unwrap();
                paged.truncate(keep).unwrap();
                assert_logically_equal(&slab, &paged, &format!("{ctx} truncate"));
            }
        }
        // truncating to the committed length releases every scratch
        // page, leaving exactly the pages that cover the committed rows
        let kept = paged.committed();
        paged.truncate(kept).unwrap();
        let bs = pool.block_slots();
        assert_eq!(
            pool.blocks_used(),
            (kept + bs - 1) / bs,
            "seed {seed}: page count after truncate-to-committed ({kept} rows)"
        );
        drop(paged);
        assert_eq!(pool.blocks_used(), 0, "seed {seed}: pages leaked after drop");
    }
}

#[test]
fn prop_collate_pad_split_roundtrip_preserves_every_sequence() {
    // random ragged batches: k plans of random tree length, each with a
    // uniquely tagged cache; collation must place every real value in
    // its padded slot (pads masked/trash-routed), and splitting a
    // synthetic padded device output must hand every sequence exactly
    // its own logits rows and KV entries
    let (layers, s, d, vocab) = (2usize, 32usize, 3usize, 7usize);
    let planes = 2 * layers;
    let batch_buckets = [1usize, 2, 4, 8];
    let neg_inf = ppd::runtime::NEG_INF;
    for seed in 0..seeds(40) {
        let mut rng = Rng::new(seed + 4242);
        let k = 1 + rng.below(6); // 1..=6 sequences
        // build plans + caches (owned first; BatchItem borrows)
        let mut plans: Vec<PlanInputs> = Vec::new();
        let mut caches: Vec<HostKvCache> = Vec::new();
        for i in 0..k {
            let n_i = 1 + rng.below(6); // 1..=6 tree tokens
            let committed = rng.below(8);
            let tag = (seed * 100 + i as u64) as u32;
            let mut bias = vec![0.0f32; n_i * s];
            for (j, b) in bias.iter_mut().enumerate() {
                // addressable bias values so padding bugs show up
                *b = (tag as f32) + j as f32 * 0.25;
            }
            plans.push(PlanInputs {
                tokens: (0..n_i as u32).map(|j| tag + j).collect(),
                pos: (0..n_i as u32).map(|j| committed as u32 + j).collect(),
                slots: (0..n_i as u32).map(|j| committed as u32 + j).collect(),
                bias,
                max_ctx: s,
            });
            let mut cache = HostKvCache::new(layers, s, d);
            if committed > 0 {
                let kv: Vec<f32> = (0..planes * committed * d)
                    .map(|x| tag as f32 + x as f32)
                    .collect();
                let slots: Vec<u32> = (0..committed as u32).collect();
                cache.scatter(&kv, &slots).unwrap();
                cache.commit_contiguous(committed).unwrap();
            }
            caches.push(cache);
        }
        let items: Vec<BatchItem> = plans
            .iter()
            .zip(&caches)
            .map(|(plan, cache)| BatchItem { plan, cache })
            .collect();
        let max_n = plans.iter().map(|p| p.len()).max().unwrap();
        let n_bucket = max_n.next_power_of_two();
        let b_bucket = *batch_buckets.iter().find(|&&b| b >= k).unwrap();
        let c = collate(&items, b_bucket, n_bucket, planes, s, d, s)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));

        // padded layout holds every real value in place
        assert_eq!(c.rows, k, "seed {seed}");
        for (i, plan) in plans.iter().enumerate() {
            let n_i = plan.len();
            assert_eq!(c.row_lens[i], n_i, "seed {seed}");
            for j in 0..n_bucket {
                let idx = i * n_bucket + j;
                if j < n_i {
                    assert_eq!(c.tokens[idx], plan.tokens[j] as i32, "seed {seed}");
                    assert_eq!(c.pos[idx], plan.pos[j] as i32, "seed {seed}");
                    assert_eq!(c.slots[idx], plan.slots[j] as i32, "seed {seed}");
                    let brow = &c.bias[idx * s..(idx + 1) * s];
                    assert_eq!(brow, &plan.bias[j * s..(j + 1) * s], "seed {seed}");
                } else {
                    // pad columns: trash slot, fully masked
                    assert_eq!(c.slots[idx], (s - 1) as i32, "seed {seed}");
                    assert!(
                        c.bias[idx * s..(idx + 1) * s].iter().all(|&b| b == neg_inf),
                        "seed {seed}: pad column visible"
                    );
                }
            }
            // the row's cache snapshot rides along verbatim
            let base = i * planes * s * d;
            assert_eq!(
                &c.cache[base..base + planes * s * d],
                caches[i].as_slice(),
                "seed {seed}: cache block {i} corrupted"
            );
        }
        // pad rows fully masked + trash-routed
        for r in k..b_bucket {
            let base = r * n_bucket;
            assert!(
                c.slots[base..base + n_bucket].iter().all(|&sl| sl == (s - 1) as i32),
                "seed {seed}"
            );
            assert!(
                c.bias[base * s..(base + n_bucket) * s].iter().all(|&b| b == neg_inf),
                "seed {seed}: pad row visible"
            );
        }

        // synthesize the padded device output with addressable values
        // (a pure function of the padded coordinate)
        let logits: Vec<f32> =
            (0..b_bucket * n_bucket * vocab).map(|x| x as f32 * 0.5).collect();
        let hidden: Vec<f32> = (0..b_bucket * n_bucket * d).map(|x| x as f32 * 2.0).collect();
        let new_kv: Vec<f32> =
            (0..b_bucket * planes * n_bucket * d).map(|x| x as f32 * 3.0).collect();
        let outs = split(&c, &logits, &hidden, &new_kv, vocab)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(outs.len(), k, "seed {seed}");
        for (i, (out, plan)) in outs.iter().zip(&plans).enumerate() {
            let n_i = plan.len();
            assert_eq!(out.n, n_i, "seed {seed}");
            assert_eq!(out.logits.len(), n_i * vocab, "seed {seed}");
            assert_eq!(out.hidden.len(), n_i * d, "seed {seed}");
            assert_eq!(out.new_kv.len(), planes * n_i * d, "seed {seed}");
            // every logits row is exactly the padded row's prefix
            for j in 0..n_i {
                let src = (i * n_bucket + j) * vocab;
                assert_eq!(
                    &out.logits[j * vocab..(j + 1) * vocab],
                    &logits[src..src + vocab],
                    "seed {seed}: logits row ({i},{j}) misrouted"
                );
                let hsrc = (i * n_bucket + j) * d;
                assert_eq!(
                    &out.hidden[j * d..(j + 1) * d],
                    &hidden[hsrc..hsrc + d],
                    "seed {seed}: hidden row ({i},{j}) misrouted"
                );
            }
            // every KV entry: plane p, token j of row i
            for p in 0..planes {
                for j in 0..n_i {
                    let dst = (p * n_i + j) * d;
                    let src = ((i * planes + p) * n_bucket + j) * d;
                    assert_eq!(
                        &out.new_kv[dst..dst + d],
                        &new_kv[src..src + d],
                        "seed {seed}: kv entry ({i},{p},{j}) misrouted"
                    );
                }
            }
        }

        // KV-length truncation: collating the same batch at a short kv
        // bucket must (a) shrink the device bias/cache layouts, (b)
        // keep every real value in place, and (c) leave the per-row
        // splits byte-identical — kv bucketing is a pure transfer
        // optimization, invisible to the apply phase.
        let kv = 16usize; // slots reach committed(<8) + n_i(<=6) - 1 <= 12 < kv-1
        assert!(kv < s);
        let ck = collate(&items, b_bucket, n_bucket, planes, s, d, kv)
            .unwrap_or_else(|e| panic!("seed {seed}: kv collate: {e}"));
        assert_eq!(ck.kv, kv, "seed {seed}");
        assert_eq!(ck.bias.len(), b_bucket * n_bucket * kv, "seed {seed}");
        assert_eq!(
            ck.cache.len(),
            b_bucket * planes * kv * d,
            "seed {seed}: cache upload did not shrink"
        );
        for (i, plan) in plans.iter().enumerate() {
            let n_i = plan.len();
            for j in 0..n_bucket {
                let idx = i * n_bucket + j;
                if j < n_i {
                    // tokens/pos/slots unchanged by truncation
                    assert_eq!(ck.tokens[idx], c.tokens[idx], "seed {seed}");
                    assert_eq!(ck.pos[idx], c.pos[idx], "seed {seed}");
                    assert_eq!(ck.slots[idx], c.slots[idx], "seed {seed}");
                    // the bias row is the full row's first kv columns
                    assert_eq!(
                        &ck.bias[idx * kv..(idx + 1) * kv],
                        &plan.bias[j * s..j * s + kv],
                        "seed {seed}: truncated bias row ({i},{j})"
                    );
                } else {
                    // pads route to the TRUNCATED trash slot
                    assert_eq!(ck.slots[idx], (kv - 1) as i32, "seed {seed}");
                }
            }
            // every cache plane is the full plane's first kv slots
            let full = caches[i].as_slice();
            for p in 0..planes {
                let dst = (i * planes + p) * kv * d;
                let src = p * s * d;
                assert_eq!(
                    &ck.cache[dst..dst + kv * d],
                    &full[src..src + kv * d],
                    "seed {seed}: truncated cache plane ({i},{p})"
                );
            }
        }
        // splitting the same device output through the truncated batch
        // yields byte-identical per-row results
        let outs_kv = split(&ck, &logits, &hidden, &new_kv, vocab)
            .unwrap_or_else(|e| panic!("seed {seed}: kv split: {e}"));
        assert_eq!(outs_kv.len(), outs.len(), "seed {seed}");
        for (i, (a, b)) in outs.iter().zip(&outs_kv).enumerate() {
            assert_eq!(a.n, b.n, "seed {seed}");
            assert_eq!(a.logits, b.logits, "seed {seed}: kv truncation changed split {i}");
            assert_eq!(a.hidden, b.hidden, "seed {seed}: kv truncation changed split {i}");
            assert_eq!(a.new_kv, b.new_kv, "seed {seed}: kv truncation changed split {i}");
        }
    }
}

/// Brute force: deepest node whose whole path matches argmax chain.
fn brute_force_greedy(tree: &SparseTree, tokens: &[u32], argmax: &dyn Fn(usize) -> u32) -> Vec<usize> {
    let layout = tree.layout();
    let mut best: Vec<usize> = vec![];
    // DFS all paths
    fn dfs(
        layout: &ppd::tree::TreeLayout,
        tokens: &[u32],
        argmax: &dyn Fn(usize) -> u32,
        node: usize,
        path: &mut Vec<usize>,
        best: &mut Vec<usize>,
    ) {
        if path.len() > best.len() {
            *best = path.clone();
        }
        let want = argmax(layout.node_input[node]);
        for &c in &layout.children[node] {
            if tokens[layout.node_input[c]] == want {
                path.push(c);
                dfs(layout, tokens, argmax, c, path, best);
                path.pop();
            }
        }
    }
    let mut path = vec![];
    dfs(&layout, tokens, argmax, 0, &mut path, &mut best);
    best
}

#[test]
fn prop_greedy_verify_equals_brute_force() {
    let vocab = 16usize;
    for seed in 0..seeds(40) {
        let mut rng = Rng::new(seed + 57);
        let stats = random_stats(&mut rng);
        let set = DynamicTreeSet::build(&stats, 3, 1 + rng.below(12), 6 + rng.below(12), 6).unwrap();
        let tree = &set.trees[3];
        let layout = set.layouts[3].clone();
        let n = tree.input_len();
        // random candidate tokens + random logits
        let tokens: Vec<u32> = (0..n).map(|_| rng.below(vocab) as u32).collect();
        let logits: Vec<f32> = (0..n * vocab).map(|_| rng.next_f64() as f32).collect();
        let out = StepOutput { n, logits, hidden: vec![0.0; n], new_kv: vec![] };
        let mut vr = Rng::new(0);
        let v = verify(tree, &layout, &out, &tokens, VerifyMode::Greedy, vocab, &mut vr);
        let am = |row: usize| ppd::util::argmax(out.logits_row(row, vocab)) as u32;
        let brute = brute_force_greedy(tree, &tokens, &am);
        // the walk picks the FIRST matching child per level; brute force
        // finds the longest path — lengths must agree when candidate
        // tokens at the same level are distinct per parent (the builder
        // guarantees rank-distinct tokens only when guesses are
        // distinct, so compare lengths defensively)
        assert!(
            v.accepted_nodes.len() <= brute.len(),
            "seed {seed}: verify found longer path than brute force"
        );
        if tokens_distinct_per_parent(tree, &layout, &tokens) {
            assert_eq!(v.accepted_nodes.len(), brute.len(), "seed {seed}");
        }
        // emitted = accepted tokens + bonus
        assert_eq!(v.emitted.len(), v.accepted_nodes.len() + 1, "seed {seed}");
    }
}

fn tokens_distinct_per_parent(tree: &SparseTree, layout: &ppd::tree::TreeLayout, tokens: &[u32]) -> bool {
    for node in 0..tree.nodes.len() {
        let mut seen = std::collections::HashSet::new();
        for &c in &layout.children[node] {
            if !seen.insert(tokens[layout.node_input[c]]) {
                return false;
            }
        }
    }
    true
}

#[test]
fn prop_chains_to_tree_reproduces_chains() {
    for seed in 0..seeds(40) {
        let mut rng = Rng::new(seed + 91);
        let n_chains = 1 + rng.below(5);
        let chains: Vec<Vec<u32>> = (0..n_chains)
            .map(|_| (0..1 + rng.below(4)).map(|_| rng.below(8) as u32).collect())
            .collect();
        let (tree, guesses) = chains_to_tree(&chains, 4, 64);
        tree.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let layout = tree.layout();
        // every chain must be walkable root->down
        for chain in &chains {
            let mut node = 0usize;
            for (d, &tok) in chain.iter().take(4).enumerate() {
                let child = layout.children[node].iter().copied().find(|&c| {
                    tree.nodes[c].depth == d + 1
                        && guesses.token_at(d + 1, tree.nodes[c].rank) == Some(tok)
                });
                let Some(c) = child else {
                    panic!("seed {seed}: chain {chain:?} broken at depth {}", d + 1)
                };
                node = c;
            }
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.below(2_000_001) as f64 - 1e6) / 8.0),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| char::from(32 + rng.below(95) as u8))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..seeds(200) {
        let mut rng = Rng::new(seed + 3);
        let v = gen(&mut rng, 0);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e} in {text}"));
        assert_eq!(v, back, "seed {seed}: {text}");
    }
}
