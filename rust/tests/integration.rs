//! Integration tests over the real artifacts (`make artifacts` first).
//!
//! The crown-jewel invariant: **greedy PPD / Medusa / speculative
//! outputs are byte-identical to vanilla greedy decoding** — guess-and-
//! verify only accelerates, never changes, the distribution (paper
//! Table 1 "Same", Fig 5 caption).
//!
//! Tests skip (pass trivially with a note) when artifacts are missing so
//! a bare checkout still builds; CI/`make test` runs them for real.

use std::path::PathBuf;

use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::{build_engine, Coordinator, EngineKind, Request, SchedPolicy};
use ppd::decoding::vanilla::VanillaEngine;
use ppd::decoding::DecodeEngine;
use ppd::runtime::{Device, Runtime};
use ppd::workload;

/// `PPD_ARTIFACT_DIR` overrides the in-repo default so CI can point the
/// suite at a freshly built artifact set (the `artifacts` job); without
/// either, tests skip.
fn artifacts_root() -> Option<PathBuf> {
    let root = match std::env::var_os("PPD_ARTIFACT_DIR") {
        Some(dir) => PathBuf::from(dir),
        None => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    };
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("[skip] artifacts missing — run `make artifacts` or set PPD_ARTIFACT_DIR");
        None
    }
}

fn load(model: &str, root: &PathBuf) -> Runtime {
    Runtime::load(&ArtifactPaths::new(root.clone(), model)).expect("runtime load")
}

const PROMPTS: &[&str] = &[
    "user: what is your favorite color?\nassistant:",
    "calc: 12 + 34 = 46 ; calc: 9 + 8 = ",
    "def add_a_b(a, b):\n    result = a + b\n",
];

fn greedy_cfg() -> ServeConfig {
    ServeConfig { temperature: 0.0, n_candidates: 6, n_prompt_budget: 10, ..Default::default() }
}

#[test]
fn runtime_forward_shapes() {
    let Some(root) = artifacts_root() else { return };
    let rt = load("ppd-d", &root);
    let s = rt.cfg.max_ctx;
    let cache = vec![0f32; 2 * rt.cfg.n_layers * s * rt.cfg.d_model];
    let mut bias = vec![-1e9f32; 3 * s];
    for i in 0..3 {
        for j in 0..=i {
            bias[i * s + j] = 0.0;
        }
    }
    let out = rt.forward(&[65, 66, 67], &[0, 1, 2], &[0, 1, 2], &bias, &cache).unwrap();
    assert_eq!(out.n, 3);
    assert_eq!(out.logits.len(), 3 * rt.cfg.vocab);
    assert_eq!(out.hidden.len(), 3 * rt.cfg.d_model);
    assert_eq!(out.new_kv.len(), 2 * rt.cfg.n_layers * 3 * rt.cfg.d_model);
    assert!(out.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn forward_is_deterministic() {
    let Some(root) = artifacts_root() else { return };
    let rt = load("ppd-d", &root);
    let s = rt.cfg.max_ctx;
    let cache = vec![0f32; 2 * rt.cfg.n_layers * s * rt.cfg.d_model];
    let mut bias = vec![-1e9f32; s];
    bias[0] = 0.0;
    let a = rt.forward(&[80], &[0], &[0], &bias, &cache).unwrap();
    let b = rt.forward(&[80], &[0], &[0], &bias, &cache).unwrap();
    assert_eq!(a.logits, b.logits);
}

#[test]
fn bucket_padding_does_not_change_logits() {
    // the same single token through bucket 1 (exact) vs forcing bucket 4
    // (3 padding rows) must produce identical row-0 logits
    let Some(root) = artifacts_root() else { return };
    let rt = load("ppd-d", &root);
    let s = rt.cfg.max_ctx;
    let cache = vec![0f32; 2 * rt.cfg.n_layers * s * rt.cfg.d_model];
    let mut bias1 = vec![-1e9f32; s];
    bias1[0] = 0.0;
    let one = rt.forward(&[77], &[0], &[0], &bias1, &cache).unwrap();

    // two real tokens (bucket 2), then compare against a three-token
    // call that lands in bucket 4 with one pad row
    let mut bias2 = vec![-1e9f32; 2 * s];
    bias2[0] = 0.0;
    bias2[s] = 0.0;
    bias2[s + 1] = 0.0;
    let two = rt.forward(&[77, 78], &[0, 1], &[0, 1], &bias2, &cache).unwrap();
    let mut bias3 = vec![-1e9f32; 3 * s];
    bias3[0] = 0.0;
    bias3[s] = 0.0;
    bias3[s + 1] = 0.0;
    bias3[2 * s + 2] = 0.0; // third row: self only (content irrelevant)
    let three = rt.forward(&[77, 78, 0], &[0, 1, 0], &[0, 1, 2], &bias3, &cache).unwrap();
    let v = rt.cfg.vocab;
    for i in 0..v {
        assert!((one.logits[i] - three.logits[i]).abs() < 2e-4);
        assert!((two.logits[v + i] - three.logits[v + i]).abs() < 2e-4);
    }
}

#[test]
fn ppd_greedy_matches_vanilla_exactly() {
    let Some(root) = artifacts_root() else { return };
    for model in ["ppd-d", "ppd-s"] {
        let rt = load(model, &root);
        let paths = ArtifactPaths::new(root.clone(), model);
        let cfg = greedy_cfg();
        let mut vanilla = VanillaEngine::new(&rt, 0.0, 0);
        let mut engine = build_engine(EngineKind::Ppd, &rt, None, &paths, &cfg, 0).unwrap();
        for p in PROMPTS {
            let prompt = workload::encode(p);
            let a = vanilla.generate(&prompt, 40).unwrap();
            let b = engine.generate(&prompt, 40).unwrap();
            assert_eq!(a.tokens, b.tokens, "{model}: ppd diverged on {p:?}");
            assert!(b.steps <= a.steps, "{model}: ppd used more steps");
        }
    }
}

#[test]
fn medusa_greedy_matches_vanilla_exactly() {
    let Some(root) = artifacts_root() else { return };
    let rt = load("ppd-s", &root);
    let paths = ArtifactPaths::new(root.clone(), "ppd-s");
    let cfg = greedy_cfg();
    let mut vanilla = VanillaEngine::new(&rt, 0.0, 0);
    let mut engine = build_engine(EngineKind::Medusa, &rt, None, &paths, &cfg, 0).unwrap();
    for p in PROMPTS {
        let prompt = workload::encode(p);
        let a = vanilla.generate(&prompt, 40).unwrap();
        let b = engine.generate(&prompt, 40).unwrap();
        assert_eq!(a.tokens, b.tokens, "medusa diverged on {p:?}");
    }
}

#[test]
fn retrieval_engines_match_vanilla_exactly() {
    let Some(root) = artifacts_root() else { return };
    let rt = load("ppd-d", &root);
    let paths = ArtifactPaths::new(root.clone(), "ppd-d");
    let cfg = greedy_cfg();
    let mut vanilla = VanillaEngine::new(&rt, 0.0, 0);
    for kind in [EngineKind::Pld, EngineKind::Rest, EngineKind::Lookahead] {
        let mut engine = build_engine(kind, &rt, None, &paths, &cfg, 0).unwrap();
        for p in PROMPTS {
            let prompt = workload::encode(p);
            let a = vanilla.generate(&prompt, 32).unwrap();
            let b = engine.generate(&prompt, 32).unwrap();
            assert_eq!(a.tokens, b.tokens, "{:?} diverged on {p:?}", kind);
        }
    }
}

#[test]
fn speculative_engines_match_vanilla_exactly() {
    let Some(root) = artifacts_root() else { return };
    let rt = load("ppd-s", &root);
    let draft = load("ppd-d", &root);
    let paths = ArtifactPaths::new(root.clone(), "ppd-s");
    let cfg = greedy_cfg();
    let mut vanilla = VanillaEngine::new(&rt, 0.0, 0);
    for kind in [EngineKind::Spec, EngineKind::SpecPpd] {
        let mut engine =
            build_engine(kind, &rt, Some(&draft as &dyn Device), &paths, &cfg, 0).unwrap();
        for p in PROMPTS {
            let prompt = workload::encode(p);
            let a = vanilla.generate(&prompt, 32).unwrap();
            let b = engine.generate(&prompt, 32).unwrap();
            assert_eq!(a.tokens, b.tokens, "{kind:?} diverged on {p:?}");
        }
    }
}

#[test]
fn ppd_accelerates_long_generation_without_drift() {
    // long generation stresses KV compaction: any slot bookkeeping bug
    // shows up as divergence deep into the sequence
    let Some(root) = artifacts_root() else { return };
    let rt = load("ppd-d", &root);
    let paths = ArtifactPaths::new(root.clone(), "ppd-d");
    let cfg = greedy_cfg();
    let mut vanilla = VanillaEngine::new(&rt, 0.0, 0);
    let mut engine = build_engine(EngineKind::Ppd, &rt, None, &paths, &cfg, 0).unwrap();
    let prompt = workload::encode("calc: 10 + 11 = 21 ; calc: 3 + 4 = ");
    let a = vanilla.generate(&prompt, 200).unwrap();
    let b = engine.generate(&prompt, 200).unwrap();
    assert_eq!(a.tokens, b.tokens);
    assert!(b.tau() > 1.2, "tau {}", b.tau());
}

#[test]
fn typical_acceptance_produces_plausible_text() {
    let Some(root) = artifacts_root() else { return };
    let rt = load("ppd-s", &root);
    let paths = ArtifactPaths::new(root.clone(), "ppd-s");
    let cfg = ServeConfig { temperature: 0.7, ..greedy_cfg() };
    let mut engine = build_engine(EngineKind::Ppd, &rt, None, &paths, &cfg, 7).unwrap();
    let prompt = workload::encode(PROMPTS[0]);
    let r = engine.generate(&prompt, 48).unwrap();
    assert!(!r.tokens.is_empty());
    assert!(r.tokens.iter().all(|&t| t < 128), "non-vocab token emitted");
    assert!(r.tau() >= 1.0);
}

#[test]
fn coordinator_roundtrip() {
    let Some(root) = artifacts_root() else { return };
    let coord = Coordinator::spawn(
        root,
        "ppd-d".into(),
        None,
        EngineKind::Ppd,
        greedy_cfg(),
        1,
    )
    .unwrap();
    let reqs: Vec<Request> = (0..3)
        .map(|i| {
            Request::builder(workload::encode(PROMPTS[i as usize % 3])).id(i).max_new(16).build()
        })
        .collect();
    let resps = coord.run_batch(reqs).unwrap();
    assert_eq!(resps.len(), 3);
    for r in &resps {
        assert!(r.is_ok(), "{:?}", r.error_msg());
        assert!(!r.tokens().is_empty());
        assert!(r.tau() >= 1.0);
    }
}

#[test]
fn coordinator_multi_worker_matches_single_worker() {
    // the acceptance invariant for the serving refactor: with >=2
    // workers a mixed batch completes with responses matched to their
    // request ids, byte-identical greedy outputs to the single-worker
    // path, and cache checkouts served from the pool (created <= workers)
    let Some(root) = artifacts_root() else { return };
    // max_inflight 1 reproduces the strictly-serial PR 1 behavior: the
    // pool bound collapses back to one cache per worker
    let serial = SchedPolicy { max_inflight: 1, ..Default::default() };
    let spawn = |workers| {
        Coordinator::spawn_with_policy(
            root.clone(),
            "ppd-d".into(),
            None,
            EngineKind::Ppd,
            greedy_cfg(),
            workers,
            serial,
        )
        .unwrap()
    };
    let multi = spawn(2);
    let single = spawn(1);
    let mk = || -> Vec<Request> {
        (0..9)
            .map(|i| {
                Request::builder(workload::encode(PROMPTS[i as usize % 3]))
                    .id(i)
                    .max_new(24)
                    .build()
            })
            .collect()
    };
    let a = multi.run_batch(mk()).unwrap();
    let b = single.run_batch(mk()).unwrap();
    assert_eq!(a.len(), 9);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.id, i as u64);
        assert!(x.is_ok(), "{:?}", x.error_msg());
        assert_eq!(x.tokens(), y.tokens(), "request {i} diverged across worker counts");
    }
    assert!(multi.caches_created() <= 2, "pool leaked: {}", multi.caches_created());
    assert_eq!(single.caches_created(), 1);
}

#[test]
fn continuous_batching_matches_serial_on_real_ppd_engine() {
    // the step-scheduler acceptance invariant on the *real* engine:
    // interleaving many PPD sequences on one worker must be token-exact
    // with serving them one at a time — all per-sequence state (tree
    // cursor, guesses, RNG) travels with the sequence
    let Some(root) = artifacts_root() else { return };
    let spawn = |max_inflight| {
        Coordinator::spawn_with_policy(
            root.clone(),
            "ppd-d".into(),
            None,
            EngineKind::Ppd,
            greedy_cfg(),
            1,
            SchedPolicy { max_inflight, ..Default::default() },
        )
        .unwrap()
    };
    let batching = spawn(4);
    let serial = spawn(1);
    let mk = || -> Vec<Request> {
        (0..8)
            .map(|i| {
                Request::builder(workload::encode(PROMPTS[i as usize % 3]))
                    .id(i)
                    .max_new(16 + (i as usize % 3) * 4)
                    .build()
            })
            .collect()
    };
    let a = batching.run_batch(mk()).unwrap();
    let b = serial.run_batch(mk()).unwrap();
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(x.is_ok(), "{:?}", x.error_msg());
        assert_eq!(x.tokens(), y.tokens(), "request {i} perturbed by continuous batching");
    }
    assert!(batching.caches_created() <= 4);
    assert_eq!(batching.caches_outstanding(), 0);
    assert!(batching.queue_stats().max_inflight_seqs() >= 2, "batch never interleaved");
}

#[test]
fn fused_stepping_matches_unfused_on_real_ppd_engine() {
    // the fused-execution acceptance invariant on the *real* engine:
    // collecting every in-flight tree step into one forward_batch call
    // (batched HLO when present, per-row fallback otherwise) must be
    // token-exact with per-sequence stepping
    let Some(root) = artifacts_root() else { return };
    let spawn = |fuse_steps| {
        Coordinator::spawn_with_policy(
            root.clone(),
            "ppd-d".into(),
            None,
            EngineKind::Ppd,
            greedy_cfg(),
            1,
            SchedPolicy { max_inflight: 4, fuse_steps, ..Default::default() },
        )
        .unwrap()
    };
    let fused = spawn(true);
    let unfused = spawn(false);
    let mk = || -> Vec<Request> {
        (0..8)
            .map(|i| {
                Request::builder(workload::encode(PROMPTS[i as usize % 3]))
                    .id(i)
                    .max_new(16 + (i as usize % 3) * 4)
                    .build()
            })
            .collect()
    };
    let a = fused.run_batch(mk()).unwrap();
    let b = unfused.run_batch(mk()).unwrap();
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(x.is_ok(), "{:?}", x.error_msg());
        assert_eq!(x.tokens(), y.tokens(), "request {i} perturbed by fused stepping");
    }
    let stats = fused.queue_stats();
    assert!(stats.fused_batches_total() > 0, "fusion never engaged");
    assert!(stats.max_fused_batch() >= 2, "no tick ever fused >1 sequence");
    // the batched-HLO path must actually amortize device calls: if
    // forward_batch silently fell back to per-row forwards (missing /
    // mismatched fwd_b{B}_n{N} artifacts), fused device calls would
    // equal unfused and this catches it
    let fused_agg = fused.runtime_agg();
    let unfused_agg = unfused.runtime_agg();
    drop(fused);
    drop(unfused);
    let (f, u) = (fused_agg.snapshot(), unfused_agg.snapshot());
    assert!(f.forward_batches > 0);
    assert!(
        f.forwards < u.forwards,
        "fused path issued {} device calls vs {} unfused — batched HLO never engaged",
        f.forwards,
        u.forwards
    );
}

#[test]
fn batched_short_kv_buckets_match_full_ctx_on_real_ppd_engine() {
    // the KV-length-bucketing acceptance invariant on the *real*
    // engine: executing fused ticks on the short-KV batched graphs
    // (`fwd_b{B}_n{N}_s{kv}`, stacked cache union truncated to the
    // union's covering bucket) must be token-exact with full-context
    // batched execution — and the short buckets must demonstrably
    // execute, so a silently-missing `_s{kv}` artifact can't pass
    let Some(root) = artifacts_root() else { return };
    let max_ctx;
    {
        let rt = load("ppd-d", &root);
        max_ctx = rt.cfg.max_ctx;
        let short: Vec<usize> = rt
            .batch_kv_buckets()
            .into_iter()
            .filter(|&kv| kv < max_ctx)
            .collect();
        if short.is_empty() {
            // CI fails on this marker (did-not-skip guard): the
            // artifacts job must export the batched _s{kv} graphs
            eprintln!(
                "[skip] artifacts missing batched _s{{kv}} graphs — re-run compile.aot"
            );
            return;
        }
    }
    let spawn = || {
        Coordinator::spawn_with_policy(
            root.clone(),
            "ppd-d".into(),
            None,
            EngineKind::Ppd,
            greedy_cfg(),
            1,
            SchedPolicy { max_inflight: 4, fuse_steps: true, ..Default::default() },
        )
        .unwrap()
    };
    let mk = || -> Vec<Request> {
        (0..8)
            .map(|i| {
                Request::builder(workload::encode(PROMPTS[i as usize % 3]))
                    .id(i)
                    .max_new(16 + (i as usize % 3) * 4)
                    .build()
            })
            .collect()
    };
    // kv-bucketed run (default), then full-context run with bucketing
    // forced off via the programmatic override (NOT std::env::set_var:
    // mutating the env while sibling tests' worker threads getenv on
    // every forward is UB on glibc)
    let bucketed = spawn();
    let a = bucketed.run_batch(mk()).unwrap();
    let agg_b = bucketed.runtime_agg();
    drop(bucketed);
    ppd::runtime::set_kv_buckets_disabled(Some(true));
    let full = spawn();
    let b = full.run_batch(mk()).unwrap();
    let agg_f = full.runtime_agg();
    drop(full);
    ppd::runtime::set_kv_buckets_disabled(None);
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert!(x.is_ok(), "{:?}", x.error_msg());
        assert_eq!(x.tokens(), y.tokens(), "request {i} perturbed by batched kv bucketing");
    }
    let (sb, sf) = (agg_b.snapshot(), agg_f.snapshot());
    assert!(sb.forward_batches > 0, "fused stepping never engaged");
    // a short-KV BATCHED bucket actually executed the union…
    assert!(
        sb.batch_per_kv.keys().any(|&kv| kv < max_ctx),
        "batched _s{{kv}} graphs never executed: {:?}",
        sb.batch_per_kv
    );
    // …and the disabled run proves the toggle: full context only
    assert!(
        sf.batch_per_kv.keys().all(|&kv| kv == max_ctx),
        "PPD_DISABLE_KV_BUCKETS leaked short buckets: {:?}",
        sf.batch_per_kv
    );
}

#[test]
fn shared_runtime_matches_fused_and_serial_on_real_ppd_engine() {
    // the shared-dispatch acceptance invariant on the *real* engine:
    // routing every worker's fused tick through ONE device dispatcher
    // (one Runtime, one device queue) must be token-exact with the
    // per-worker-fused and strictly-serial topologies
    let Some(root) = artifacts_root() else { return };
    let spawn = |workers: usize, policy: SchedPolicy| {
        Coordinator::spawn_with_policy(
            root.clone(),
            "ppd-d".into(),
            None,
            EngineKind::Ppd,
            greedy_cfg(),
            workers,
            policy,
        )
        .unwrap()
    };
    let shared = spawn(
        2,
        SchedPolicy { max_inflight: 2, shared_runtime: true, ..Default::default() },
    );
    let fused = spawn(
        2,
        SchedPolicy { max_inflight: 2, fuse_steps: true, ..Default::default() },
    );
    let serial = spawn(1, SchedPolicy { max_inflight: 1, ..Default::default() });
    let mk = || -> Vec<Request> {
        (0..8)
            .map(|i| {
                let max_new = 14 + (i as usize % 3) * 4;
                Request::builder(workload::encode(PROMPTS[i as usize % 3]))
                    .id(i)
                    .max_new(max_new)
                    .build()
            })
            .collect()
    };
    let a = shared.run_batch(mk()).unwrap();
    let b = fused.run_batch(mk()).unwrap();
    let c = serial.run_batch(mk()).unwrap();
    for (i, ((x, y), z)) in a.iter().zip(&b).zip(&c).enumerate() {
        assert!(x.is_ok(), "{:?}", x.error_msg());
        assert_eq!(x.tokens(), y.tokens(), "request {i}: shared diverged from per-worker-fused");
        assert_eq!(x.tokens(), z.tokens(), "request {i}: shared diverged from serial");
    }
    let d = shared.dispatch_stats();
    assert!(d.batches_total() > 0, "shared dispatcher never fused a batch");
    assert_eq!(d.queue_depth(), 0, "submissions leaked in the dispatcher window");
    assert_eq!(shared.caches_outstanding(), 0);
    // every fused row is attributed to a submitting scheduler (solos —
    // prefill chunks — are counted separately), and the one runtime on
    // the device-host thread really executed batches (the exact
    // device-call-per-wall-tick claims live in the deterministic mock
    // harness, where the schedule is scripted)
    let rows: u64 = d.rows_by_worker().values().sum();
    assert_eq!(rows, d.rows_total());
    assert!(d.solo_forwards_total() > 0, "prefills never rode the dispatcher");
    let shared_agg = shared.runtime_agg();
    drop(shared);
    let s = shared_agg.snapshot();
    assert!(s.forward_batches > 0, "the shared runtime never ran a fused batch");
    assert!(!s.rows_by_worker.is_empty());
}

#[test]
fn tcp_server_roundtrip() {
    let Some(root) = artifacts_root() else { return };
    let coord = Coordinator::spawn(
        root,
        "ppd-d".into(),
        None,
        EngineKind::Ppd,
        greedy_cfg(),
        1,
    )
    .unwrap();
    let addr = "127.0.0.1:17917";
    let server = std::thread::spawn(move || {
        ppd::coordinator::server::serve(coord, addr, Some(1)).unwrap();
    });
    std::thread::sleep(std::time::Duration::from_millis(300));
    let resp = ppd::coordinator::server::client_request(addr, "calc: 1 + 2 = ", 8).unwrap();
    assert!(resp.get("error").is_none(), "{resp}");
    assert!(resp.req("tokens").unwrap().as_usize().unwrap() > 0);
    server.join().unwrap();
}
