//! Fig 4: latency speedup of PPD vs other parallel-decoding methods
//! (Medusa, PLD, REST, lookahead) on the chat (MT-Bench-analogue) trace.
//! PPD/Medusa at the default temperature (typical acceptance); the
//! retrieval methods greedy, as in the paper (appx C).

mod common;

use common::*;
use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::EngineKind;
use ppd::runtime::calibrate::Calibration;
use ppd::runtime::Runtime;
use ppd::util::bench::Table;

fn main() {
    let Some(root) = artifacts_root() else { return };
    let model = std::env::var("PPD_BENCH_MODEL").unwrap_or_else(|_| "ppd-s".into());
    println!("=== Fig 4: parallel decoding methods on chat trace ({model}) ===\n");
    let paths = ArtifactPaths::new(root, &model);
    let rt = Runtime::load(&paths).expect("runtime");
    let cal = Calibration::load_or_measure(&rt, &paths.calibration(), 8).unwrap();
    let envs = envelopes(&cal);
    let trace = load_task(&paths, "chat");
    let items = take_items(&trace, 12);
    let max_new = 48;

    let base_cfg = ServeConfig { n_candidates: 6, n_prompt_budget: 10, ..Default::default() };
    let vanilla = run_engine(EngineKind::Vanilla, &rt, None, &paths, &base_cfg, &items, max_new).unwrap();

    let mut table = Table::new(&["method", "tau", "speedup(cpu)", "speedup(a100)", "speedup(4090)"]);
    table.row(&["vanilla".into(), "1.00".into(), "1.00".into(), "1.00".into(), "1.00".into()]);
    let runs = [
        (EngineKind::Ppd, ServeConfig { temperature: 0.7, ..base_cfg.clone() }),
        (EngineKind::Medusa, ServeConfig { temperature: 0.7, ..base_cfg.clone() }),
        (EngineKind::Pld, base_cfg.clone()),
        (EngineKind::Rest, base_cfg.clone()),
        (EngineKind::Lookahead, base_cfg.clone()),
    ];
    let mut collected = Vec::new();
    for (kind, cfg) in runs {
        let r = run_engine(kind, &rt, None, &paths, &cfg, &items, max_new).unwrap();
        table.row(&[
            r.name.into(),
            format!("{:.2}", r.tau()),
            format!("{:.2}", r.throughput() / vanilla.throughput()),
            format!("{:.2}", project_speedup(&r, &envs[0])),
            format!("{:.2}", project_speedup(&r, &envs[1])),
        ]);
        collected.push((r.name, r.tau()));
    }
    table.print();
    let ppd_tau = collected.iter().find(|(n, _)| *n == "ppd").unwrap().1;
    let others_max = collected.iter().filter(|(n, _)| *n != "ppd" && *n != "medusa").map(|(_, t)| *t).fold(0.0, f64::max);
    println!(
        "\npaper shape: PPD > Medusa (slightly) and 2-3x over retrieval methods.\nhere: tau(ppd)={ppd_tau:.2} vs best retrieval tau={others_max:.2}"
    );
}
