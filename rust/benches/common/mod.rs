//! Shared bench plumbing (criterion is not vendored; these are
//! `harness = false` binaries using `ppd::util::bench`).

use std::path::PathBuf;

use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::{build_engine, EngineKind};
use ppd::decoding::{DecodeEngine, GenerationResult};
use ppd::runtime::calibrate::Calibration;
use ppd::runtime::{Device, Runtime};
use ppd::workload::{load_trace, TraceItem};

pub fn artifacts_root() -> Option<PathBuf> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("[bench skipped] artifacts missing — run `make artifacts` first");
        None
    }
}

/// Run `engine` over trace items, aggregating results.
pub struct EngineRun {
    pub name: &'static str,
    pub tokens: usize,
    pub steps: usize,
    pub draft_steps: usize,
    pub decode_s: f64,
    pub input_len_sum: usize,
    pub outputs: Vec<Vec<u32>>,
    /// target-model device calls during the run (from `RuntimeStats`) —
    /// differs from `steps` once forwards are batched
    pub forwards: usize,
}

impl EngineRun {
    pub fn throughput(&self) -> f64 {
        self.tokens as f64 / self.decode_s
    }

    pub fn tau(&self) -> f64 {
        self.tokens as f64 / self.steps as f64
    }

    pub fn mean_l_fp(&self) -> f64 {
        self.decode_s / self.steps as f64
    }

    pub fn mean_input(&self) -> f64 {
        self.input_len_sum as f64 / self.steps.max(1) as f64
    }

    /// Device calls per generated token — the batching-visibility
    /// metric: 1/τ when unbatched, lower once steps fuse.
    pub fn forwards_per_token(&self) -> f64 {
        self.forwards as f64 / self.tokens.max(1) as f64
    }
}

pub fn run_engine(
    kind: EngineKind,
    rt: &Runtime,
    draft: Option<&Runtime>,
    paths: &ArtifactPaths,
    cfg: &ServeConfig,
    items: &[&TraceItem],
    max_new: usize,
) -> anyhow::Result<EngineRun> {
    let mut engine =
        build_engine(kind, rt, draft.map(|d| d as &dyn Device), paths, cfg, 0)?;
    // one cache reused across the whole run (engines borrow per call;
    // allocating ~MBs per trace item would pollute the measurements)
    let (l, s, d) = engine.cache_shape();
    let mut cache = ppd::kvcache::HostKvCache::new(l, s, d);
    let mut agg = EngineRun {
        name: engine.name(),
        tokens: 0,
        steps: 0,
        draft_steps: 0,
        decode_s: 0.0,
        input_len_sum: 0,
        outputs: Vec::new(),
        forwards: 0,
    };
    // reset the runtime's device-call counters so `forwards` covers
    // exactly this run (prefill included — clients pay for it too)
    let _ = rt.take_stats();
    for it in items {
        let r: GenerationResult = engine.generate_with_cache(&it.prompt, max_new, &mut cache)?;
        agg.tokens += r.tokens.len();
        agg.steps += r.steps;
        agg.draft_steps += r.draft_steps;
        agg.decode_s += r.decode_s;
        agg.input_len_sum += r.input_lens.iter().sum::<usize>();
        agg.outputs.push(r.tokens);
    }
    agg.forwards = rt.take_stats().forwards;
    Ok(agg)
}

pub fn take_items(trace: &[TraceItem], n: usize) -> Vec<&TraceItem> {
    trace.iter().take(n).collect()
}

pub fn load_task(paths: &ArtifactPaths, task: &str) -> Vec<TraceItem> {
    load_trace(&paths.trace(task)).expect("trace")
}

/// GPU-like latency envelopes for speedup projection (DESIGN.md §2):
/// `a100`: wide trees nearly free (paper Table 1: L_fp(63)/L_fp(1)≈1.18);
/// `rtx4090`: moderately utilization-capped.
pub fn envelopes(measured: &Calibration) -> Vec<Calibration> {
    let base = measured.latency_s.get(&1).copied().unwrap_or(1e-3);
    let mk = |label: &str, per_tok_frac: f64| {
        let latency_s = measured
            .latency_s
            .keys()
            .map(|&b| (b, base * (1.0 + per_tok_frac * (b as f64 - 1.0))))
            .collect();
        Calibration { model: measured.model.clone(), envelope: label.into(), latency_s }
    };
    vec![mk("a100", 0.003), mk("rtx4090", 0.008)]
}

/// Project a measured run's speedup under a latency curve: vanilla takes
/// `tokens` steps of L(1); the engine took `steps` forwards of its mean
/// input length (bucket-quantized).
pub fn project_speedup(run: &EngineRun, cal: &Calibration) -> f64 {
    let l1 = cal.lookup(1).unwrap();
    let li = cal.lookup(run.mean_input().ceil() as usize).unwrap_or(l1);
    let vanilla = run.tokens as f64 * l1;
    let engine = run.steps as f64 * li;
    vanilla / engine
}
