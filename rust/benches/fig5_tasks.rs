//! Fig 5: PPD throughput across tasks (chat/math/code ~ MT-Bench /
//! GSM8K / HumanEval) and "hardware" (measured CPU + the two latency
//! envelopes), greedy (temperature 0) with exact-match verification —
//! the generated output provably equals the vanilla model's.

mod common;

use common::*;
use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::EngineKind;
use ppd::runtime::calibrate::Calibration;
use ppd::runtime::Runtime;
use ppd::util::bench::Table;

fn main() {
    let Some(root) = artifacts_root() else { return };
    println!("=== Fig 5: PPD throughput per task x hardware (greedy, exact match) ===\n");
    let mut table = Table::new(&[
        "model", "task", "tau", "vanilla tok/s", "ppd tok/s", "speedup(cpu)", "speedup(a100)", "speedup(4090)", "exact",
    ]);
    for model in ["ppd-s", "ppd-m"] {
        let paths = ArtifactPaths::new(root.clone(), model);
        let rt = Runtime::load(&paths).expect("runtime");
        let cal = Calibration::load_or_measure(&rt, &paths.calibration(), 8).unwrap();
        let envs = envelopes(&cal);
        let cfg = ServeConfig { n_candidates: 6, n_prompt_budget: 10, ..Default::default() };
        let max_new = 48;
        for task in ["chat", "math", "code"] {
            let trace = load_task(&paths, task);
            let items = take_items(&trace, 10);
            let v = run_engine(EngineKind::Vanilla, &rt, None, &paths, &cfg, &items, max_new).unwrap();
            let p = run_engine(EngineKind::Ppd, &rt, None, &paths, &cfg, &items, max_new).unwrap();
            table.row(&[
                model.into(),
                task.into(),
                format!("{:.2}", p.tau()),
                format!("{:.0}", v.throughput()),
                format!("{:.0}", p.throughput()),
                format!("{:.2}", p.throughput() / v.throughput()),
                format!("{:.2}", project_speedup(&p, &envs[0])),
                format!("{:.2}", project_speedup(&p, &envs[1])),
                if p.outputs == v.outputs { "yes".into() } else { "NO".into() },
            ]);
        }
    }
    table.print();
    println!("\npaper shape: code/math > chat (formulaic text predicts better); exact column must be all-yes.");
}
