//! Fig 7 (+ Fig 1's memory axis): model memory overhead of PPD vs the
//! Medusa-heads and Eagle-style baselines — measured on our artifacts
//! and projected at the paper's Vicuna-7B scale.

mod common;

use common::artifacts_root;
use ppd::baselines::memory::{eagle_overhead, medusa_overhead, paper_scale_rows, ppd_overhead};
use ppd::config::{ArtifactPaths, ModelConfig};
use ppd::util::bench::Table;

fn main() {
    let Some(root) = artifacts_root() else { return };
    println!("=== Fig 7: extra model memory (measured artifacts) ===\n");
    let mut t = Table::new(&["model", "method", "extra params", "extra bytes", "% of base"]);
    for model in ["ppd-s", "ppd-m", "ppd-l"] {
        let cfg = ModelConfig::load(&ArtifactPaths::new(root.clone(), model).model_dir()).unwrap();
        for row in [
            ppd_overhead(&cfg, cfg.param_count),
            medusa_overhead(&cfg, cfg.param_count, 3),
            eagle_overhead(&cfg, cfg.param_count),
        ] {
            t.row(&[
                model.into(),
                row.method.into(),
                format!("{}", row.extra_params),
                format!("{}", row.extra_bytes_f32),
                format!("{:.5}", 100.0 * row.fraction_of_base),
            ]);
        }
    }
    t.print();

    println!("\n=== Fig 7 projected at Vicuna-7B scale (d=4096, V=32000) ===\n");
    let mut t2 = Table::new(&["method", "extra params", "extra MB (f16)", "% of base", "ratio vs ppd"]);
    let rows = paper_scale_rows();
    let ppd_params = rows[0].extra_params as f64;
    for row in &rows {
        t2.row(&[
            row.method.into(),
            format!("{}", row.extra_params),
            format!("{:.2}", row.extra_params as f64 * 2.0 / 1e6),
            format!("{:.6}", 100.0 * row.fraction_of_base),
            format!("{:.0}x", row.extra_params as f64 / ppd_params),
        ]);
    }
    t2.print();
    println!("\npaper: PPD overhead ~0.0004% runtime memory; ~0.004% of Medusa's and ~0.007% of Eagle's extra memory.");
}
