//! Fig 6: accumulative (top-k) accuracy of guesses per token distance.
//!
//! Two sources:
//!  (a) the build-time python estimates (`accept_stats.json`, the same
//!      numbers that drive tree construction), including the EPT and
//!      model-size ablation variants (Fig 6b/6c);
//!  (b) an independent **rust-side re-measurement** through the PJRT
//!      path: teacher-forced roots along the chat-trace references with
//!      prompt chains attached, counting top-k hits — cross-checking the
//!      python estimator against the serving stack's numerics.

mod common;

use common::*;
use ppd::config::{ArtifactPaths, PROMPT_ID0};
use ppd::kvcache::HostKvCache;
use ppd::runtime::{Runtime, NEG_INF};
use ppd::tree::builder::AcceptStats;
use ppd::util::bench::Table;
use ppd::util::topk;

fn main() {
    let Some(root) = artifacts_root() else { return };
    println!("=== Fig 6a: accumulative accuracy by distance (python estimates) ===\n");
    let mut t = Table::new(&["model", "method", "@1 top1", "@1 top10", "@2 top1", "@2 top10", "@3 top1", "@3 top10"]);
    for model in ["ppd-s", "ppd-m", "ppd-l"] {
        let paths = ArtifactPaths::new(root.clone(), model);
        for method in ["ppd", "medusa"] {
            if let Ok(s) = AcceptStats::load(&paths.accept_stats(None), method) {
                t.row(&[
                    model.into(),
                    method.into(),
                    format!("{:.3}", s.cum[0][0]),
                    format!("{:.3}", s.cum[0][9]),
                    format!("{:.3}", s.cum[1][0]),
                    format!("{:.3}", s.cum[1][9]),
                    format!("{:.3}", s.cum[2][0]),
                    format!("{:.3}", s.cum[2][9]),
                ]);
            }
        }
    }
    t.print();

    println!("\n=== Fig 6b: EPT ablation variants (model ppd-s) ===\n");
    let paths_s = ArtifactPaths::new(root.clone(), "ppd-s");
    let mut t2 = Table::new(&["variant", "@1 top1", "@1 top10", "@2 top1", "@2 top10"]);
    for variant in ["ept1", "ept4", "ept16"] {
        let p = paths_s.accept_stats(Some(variant));
        let p = if variant == "ept1" && !p.exists() { paths_s.accept_stats(None) } else { p };
        if let Ok(s) = AcceptStats::load(&p, "ppd") {
            t2.row(&[
                variant.into(),
                format!("{:.3}", s.cum[0][0]),
                format!("{:.3}", s.cum[0][9]),
                format!("{:.3}", s.cum[1][0]),
                format!("{:.3}", s.cum[1][9]),
            ]);
        }
    }
    t2.print();

    println!("\n=== Fig 6 cross-check: rust-side re-measurement over PJRT ({}) ===\n", "ppd-s");
    let rt = Runtime::load(&paths_s).expect("runtime");
    let (hits, totals) = measure_rust(&rt, &paths_s, 8, 24);
    let mut t3 = Table::new(&["distance", "top-1 (rust)", "top-5 (rust)", "top-10 (rust)", "top-10 (python)"]);
    let py = AcceptStats::load(&paths_s.accept_stats(None), "ppd").unwrap();
    for d in 0..hits.len() {
        let tot = totals[d].max(1) as f64;
        t3.row(&[
            format!("@{}", d + 1),
            format!("{:.3}", hits[d][0] as f64 / tot),
            format!("{:.3}", hits[d][..5].iter().sum::<usize>() as f64 / tot),
            format!("{:.3}", hits[d].iter().sum::<usize>() as f64 / tot),
            format!("{:.3}", py.cum[d][9]),
        ]);
    }
    t3.print();
    println!("\npaper shape: accuracy decays with distance; the PPD-vs-Medusa gap widens with distance; more EPTs help modestly; larger models help modestly.");
}

/// Teacher-forced prompt-chain accuracy through the serving runtime.
fn measure_rust(rt: &Runtime, paths: &ArtifactPaths, n_items: usize, steps_per_item: usize) -> (Vec<Vec<usize>>, Vec<usize>) {
    let m = rt.cfg.n_prompt;
    let s = rt.cfg.max_ctx;
    let vocab = rt.cfg.vocab;
    let trace = load_task(paths, "chat");
    let mut hits = vec![vec![0usize; 10]; m];
    let mut totals = vec![0usize; m];
    for it in trace.iter().take(n_items) {
        let full: Vec<u32> = it.prompt.iter().chain(it.reference.iter()).copied().collect();
        if full.len() < 24 {
            continue;
        }
        let mut cache = HostKvCache::new(rt.cfg.n_layers, rt.cfg.max_ctx, rt.cfg.d_model);
        // prefill everything except a tail we walk teacher-forced
        let tail = steps_per_item.min(full.len() - 9);
        let split = full.len() - tail;
        let _ = ppd::decoding::prefill(rt, &mut cache, &full[..split]).expect("prefill");
        for i in 0..tail.saturating_sub(m + 2) {
            let committed = cache.committed();
            // root = true token at position split+i, chain of m prompts
            let n = 1 + m;
            let mut tokens = vec![full[split + i]];
            let mut pos = vec![committed as u32];
            let mut slots = vec![committed as u32];
            for k in 0..m {
                tokens.push(PROMPT_ID0 + k as u32);
                pos.push((committed + 1 + k) as u32);
                slots.push((committed + 1 + k) as u32);
            }
            let mut bias = vec![NEG_INF; n * s];
            for r in 0..n {
                for j in 0..committed {
                    bias[r * s + j] = 0.0;
                }
                for j in 0..=r {
                    bias[r * s + committed + j] = 0.0;
                }
            }
            let out = rt.forward(&tokens, &pos, &slots, &bias, cache.as_slice()).expect("fwd");
            // commit only the root row (teacher forcing)
            cache.scatter(&out.new_kv[..], &slots).unwrap();
            cache.compact(&[committed as u32]).unwrap();
            // prompt k predicts distance k+1 => true token full[split+i+k+2]
            for k in 0..m {
                let idx = split + i + k + 2;
                if idx >= full.len() {
                    continue;
                }
                let row = out.logits_row(1 + k, vocab);
                let top = topk(row, 10);
                totals[k] += 1;
                if let Some(r) = top.iter().position(|&t| t as u32 == full[idx]) {
                    hits[k][r] += 1;
                }
            }
        }
    }
    (hits, totals)
}
