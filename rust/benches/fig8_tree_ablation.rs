//! Fig 8: dynamic sparse tree evaluation.
//!   (a) acceptance length τ of dynamic vs static vs random trees across
//!       tree sizes — *measured* by running PPD on the val-ish chat trace
//!   (b) theoretical speedup τ(n)/L_fp(n) under the measured CPU curve
//!       and the two hardware envelopes — the argmax is the optimal size
//!   (c) actual speedup at three sizes per latency curve (CPU measured;
//!       envelopes projected from measured τ and step counts)

mod common;

use common::*;
use ppd::config::{ArtifactPaths, ServeConfig};
use ppd::coordinator::EngineKind;
use ppd::decoding::ppd::PpdEngine;
use ppd::decoding::DecodeEngine;
use ppd::kvcache::HostKvCache;
use ppd::runtime::calibrate::Calibration;
use ppd::runtime::Runtime;
use ppd::tree::builder::AcceptStats;
use ppd::tree::dynamic::DynamicTreeSet;
use ppd::tree::hardware::sweep;
use ppd::util::bench::Table;
use ppd::util::rng::Rng;

fn main() {
    let Some(root) = artifacts_root() else { return };
    let model = "ppd-s";
    let paths = ArtifactPaths::new(root, model);
    let rt = Runtime::load(&paths).expect("runtime");
    let stats = AcceptStats::load(&paths.accept_stats(None), "ppd").unwrap();
    let cal = Calibration::load_or_measure(&rt, &paths.calibration(), 8).unwrap();
    let envs = envelopes(&cal);
    let m = rt.cfg.n_prompt;
    let trace = load_task(&paths, "chat");
    let items = take_items(&trace, 8);
    let max_new = 48;
    let cfg = ServeConfig::default();

    println!("=== Fig 8a: acceptance length, dynamic vs static vs random trees ===\n");
    let mut t = Table::new(&["total size", "dynamic tau", "static tau", "random tau"]);
    for (nc, np) in [(2, 4), (4, 7), (6, 10), (10, 16), (16, 24)] {
        let taus: Vec<f64> = [
            DynamicTreeSet::build(&stats, m, nc, np, 10).unwrap(),
            DynamicTreeSet::build_static(&stats, m, nc + np, 10).unwrap(),
            DynamicTreeSet::build_random(&stats, m, nc, np, &mut Rng::new(42)).unwrap(),
        ]
        .into_iter()
        .map(|set| {
            let mut engine = PpdEngine::with_tree_set(&rt, set, &cfg, 0);
            let (l, s, d) = engine.cache_shape();
            let mut cache = HostKvCache::new(l, s, d);
            let (mut tok, mut steps) = (0usize, 0usize);
            for it in &items {
                let r = engine.generate_with_cache(&it.prompt, max_new, &mut cache).unwrap();
                tok += r.tokens.len();
                steps += r.steps;
            }
            tok as f64 / steps as f64
        })
        .collect();
        t.row(&[
            format!("{}", nc + np),
            format!("{:.3}", taus[0]),
            format!("{:.3}", taus[1]),
            format!("{:.3}", taus[2]),
        ]);
    }
    t.print();

    println!("\n=== Fig 8b: theoretical speedup vs tree size per hardware ===\n");
    let budgets = [4usize, 7, 11, 15, 23, 31, 47, 63];
    let mut t2 = Table::new(&["budget", "tau (model)", "cpu", "a100", "rtx4090"]);
    let curves: Vec<_> = std::iter::once(&cal)
        .chain(envs.iter())
        .map(|c| sweep(&stats, m, &budgets, c, 10).unwrap())
        .collect();
    for (i, &b) in budgets.iter().enumerate() {
        t2.row(&[
            format!("{b}"),
            format!("{:.3}", curves[0].points[i].tau),
            format!("{:.3}", curves[0].points[i].speedup),
            format!("{:.3}", curves[1].points[i].speedup),
            format!("{:.3}", curves[2].points[i].speedup),
        ]);
    }
    t2.print();
    for c in &curves {
        let best = c.best().unwrap();
        println!("optimal size [{}]: budget={} speedup={:.2}", c.envelope, best.total_budget, best.speedup);
    }

    println!("\n=== Fig 8c: actual speedup vs tree size (measured tau, per curve) ===\n");
    let mut t3 = Table::new(&["budget", "tau (measured)", "cpu (measured)", "a100 (proj)", "rtx4090 (proj)"]);
    let vanilla = run_engine(EngineKind::Vanilla, &rt, None, &paths, &cfg, &items, max_new).unwrap();
    for (nc, np) in [(1, 3), (3, 8), (6, 10), (13, 18), (25, 38)] {
        let scfg = ServeConfig { n_candidates: nc, n_prompt_budget: np, ..Default::default() };
        let r = run_engine(EngineKind::Ppd, &rt, None, &paths, &scfg, &items, max_new).unwrap();
        t3.row(&[
            format!("{}", nc + np),
            format!("{:.3}", r.tau()),
            format!("{:.3}", r.throughput() / vanilla.throughput()),
            format!("{:.3}", project_speedup(&r, &envs[0])),
            format!("{:.3}", project_speedup(&r, &envs[1])),
        ]);
    }
    t3.print();
    println!("\npaper shape: dynamic >= static >= random (a); optimal size grows with hardware speed (b); the theoretical argmax matches the measured peak (c).");
}
