//! Table 1: throughput T, accept length τ, forward latency L_fp, output
//! quality, trainable-parameter fraction P_tr, tree sizes S_tr and input
//! length S_input for vanilla / Medusa / PPD on the S (greedy) and M/L
//! (typical-acceptance) models.
//!
//! Quality at temperature 0 is exact-output-match vs vanilla (paper:
//! "Same"); at temperature>0 we report it as the fraction of requests
//! whose output stays within the model's vocab and terminates (sampled
//! outputs differ by design).  Speedups are reported measured-on-CPU and
//! projected under the a100/rtx4090 latency envelopes (DESIGN.md §2).

mod common;

use common::*;
use ppd::config::{ArtifactPaths, ModelConfig, ServeConfig};
use ppd::coordinator::EngineKind;
use ppd::runtime::calibrate::Calibration;
use ppd::runtime::Runtime;
use ppd::tree::builder::AcceptStats;
use ppd::tree::dynamic::DynamicTreeSet;
use ppd::util::bench::Table;

fn main() {
    let Some(root) = artifacts_root() else { return };
    println!("=== Table 1: vanilla vs Medusa vs PPD ===\n");
    let mut table = Table::new(&[
        "model", "method", "T tok/s", "tau", "fwd/tok", "L_fp ms", "quality", "P_tr %", "S_tr",
        "S_input", "speedup(cpu)", "speedup(a100)", "speedup(4090)",
    ]);

    // paper: MobileLLaMA greedy; Vicuna-7B/13B non-greedy
    for (model, temp) in [("ppd-s", 0.0f32), ("ppd-m", 0.7), ("ppd-l", 0.7)] {
        let paths = ArtifactPaths::new(root.clone(), model);
        let rt = Runtime::load(&paths).expect("runtime");
        let mcfg = ModelConfig::load(&paths.model_dir()).unwrap();
        let cal = Calibration::load_or_measure(&rt, &paths.calibration(), 8).unwrap();
        let envs = envelopes(&cal);
        let trace = load_task(&paths, "chat");
        let items = take_items(&trace, 10);
        let max_new = 48;

        let cfg = ServeConfig { temperature: temp, n_candidates: 6, n_prompt_budget: 10, ..Default::default() };
        let greedy_cfg = ServeConfig { temperature: 0.0, ..cfg.clone() };

        // vanilla reference (same temperature; greedy for quality refs)
        let vruns = run_engine(EngineKind::Vanilla, &rt, None, &paths, &greedy_cfg, &items, max_new).unwrap();

        let stats = AcceptStats::load(&paths.accept_stats(None), "ppd").unwrap();
        let set = DynamicTreeSet::build(&stats, mcfg.n_prompt, cfg.n_candidates, cfg.n_prompt_budget, cfg.top_r).unwrap();
        let s_tr = format!("{:?}", set.size_tuple());
        let s_input = format!("{:?}", set.trees.iter().skip(1).map(|t| t.input_len()).collect::<Vec<_>>());

        for kind in [EngineKind::Vanilla, EngineKind::Medusa, EngineKind::Ppd] {
            // exact-match quality is defined at temperature 0
            let qcfg = greedy_cfg.clone();
            let q = run_engine(kind, &rt, None, &paths, &qcfg, &items, max_new).unwrap();
            let quality = if kind == EngineKind::Vanilla {
                "-".to_string()
            } else if q.outputs == vruns.outputs {
                "Same".to_string()
            } else {
                let same = q.outputs.iter().zip(&vruns.outputs).filter(|(a, b)| a == b).count();
                format!("{}/{}", same, vruns.outputs.len())
            };
            // throughput measured at the table's temperature
            let r = run_engine(kind, &rt, None, &paths, &cfg, &items, max_new).unwrap();
            let ptr = match kind {
                EngineKind::Vanilla => "NA".into(),
                EngineKind::Medusa => format!(
                    "{:.4}",
                    100.0 * (3 * (mcfg.d_model * mcfg.d_model)) as f64 / mcfg.param_count as f64
                ),
                _ => format!("{:.5}", 100.0 * mcfg.trainable_fraction()),
            };
            let (st, si) = match kind {
                EngineKind::Ppd => (s_tr.clone(), s_input.clone()),
                EngineKind::Medusa => {
                    let n = cfg.n_candidates + cfg.n_prompt_budget;
                    (format!("{n}"), format!("{}", n + 1))
                }
                _ => ("NA".into(), "1".into()),
            };
            table.row(&[
                model.into(),
                format!("{:?}", kind).to_lowercase(),
                format!("{:.0}", r.throughput()),
                format!("{:.2}", r.tau()),
                // device calls per token from RuntimeStats: the metric
                // step fusion shrinks (1/τ plus prefill when unbatched)
                format!("{:.3}", r.forwards_per_token()),
                format!("{:.2}", r.mean_l_fp() * 1e3),
                quality,
                ptr,
                st,
                si,
                format!("{:.2}", r.throughput() / vruns.throughput()),
                format!("{:.2}", project_speedup(&r, &envs[0])),
                format!("{:.2}", project_speedup(&r, &envs[1])),
            ]);
        }
    }
    table.print();
    println!("\npaper shape: PPD ~ Medusa throughput with 1/3-1/2 the tree and ~1e4x fewer trainable params;\nCPU wallclock favors vanilla (1-core compute-bound — paper limitation 2); envelope columns show the GPU regime.");
}
