//! Request-lifecycle tracing: a bounded, low-overhead flight recorder.
//!
//! Every stage a request passes through — recv → enqueue → admit → plan →
//! submit → window-wait → collate → device → apply → emit → retire — is
//! recorded as a span into a fixed-capacity [`TraceRing`], one ring per
//! track ("worker-N" for each scheduler thread, "dispatcher" for the shared
//! device loop, "server" for the submission side).  Rings never grow: when
//! full, the oldest event is overwritten and a drop counter ticks, so the
//! recorder is safe to leave attached to a long-running server and always
//! holds the most recent window of activity.
//!
//! Design points:
//!
//! - **Clock injection.**  All timestamps come from a [`TraceClock`];
//!   production uses [`WallClock`] (µs since tracer creation) while the
//!   deterministic test harness uses [`ScriptedClock`] to script time and
//!   pin exact span layouts.
//! - **Sampling gate.**  The whole recorder sits behind one relaxed
//!   [`AtomicBool`]; when disabled (the default — enable with
//!   `--trace-sample`), instrumentation sites cost a single atomic load and
//!   no ring is touched.  Latency *histograms* are recorded regardless —
//!   they are cheap fixed-size atomics and always exported.
//! - **Gapless chains.**  Instrumentation passes a per-request `mark`
//!   cursor forward: every span starts where the previous one ended, so a
//!   retired request tiles `[enqueue, retire]` with no gaps — a property
//!   the harness asserts.
//! - **Chrome trace export.**  [`Tracer::chrome_trace_json`] merges the
//!   rings into Chrome trace-event JSON (`"X"` complete events on one
//!   named track per ring) loadable in Perfetto / `chrome://tracing`; the
//!   TCP `trace` request serves the same snapshot remotely.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::Json;

/// Default per-track ring capacity (events).  At ~40 bytes per event a
/// full ring is ~320 KiB per track — a few MiB for a large worker pool.
pub const DEFAULT_RING_CAP: usize = 8192;

/// Sentinel request id for events that are batch- or tick-scoped rather
/// than tied to a single request (dispatcher rounds, scheduler ticks).
pub const NO_REQ: u64 = u64::MAX;

/// Lifecycle phase of a span.  Ordering here mirrors the order phases
/// occur in within one request's life.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Request arrived at the coordinator (instant, server track).
    Recv,
    /// Waiting in the work queue (enqueue → admit).
    Enqueue,
    /// Admission: cache checkout + prefill (`begin_seq`).
    Admit,
    /// Decode-plan construction for one step.
    Plan,
    /// Handing planned rows to the shared dispatcher.
    Submit,
    /// Dispatcher batching window (dispatcher track).
    WindowWait,
    /// Cross-worker collation of a round (dispatcher track).
    Collate,
    /// Device execution (worker-side wait, or dispatcher-side busy span).
    Device,
    /// Applying device outputs back onto the sequence.
    Apply,
    /// New tokens became visible (instant; `n` = tokens emitted).
    Emit,
    /// Request left the scheduler (response sent).
    Retire,
    /// One whole scheduler tick (worker track; `n` = rows touched).
    Tick,
    /// A solo (unbatched) forward served inline by the dispatcher.
    Solo,
}

impl Phase {
    /// Stable lower-case name used in the Chrome trace export.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Recv => "recv",
            Phase::Enqueue => "enqueue",
            Phase::Admit => "admit",
            Phase::Plan => "plan",
            Phase::Submit => "submit",
            Phase::WindowWait => "window_wait",
            Phase::Collate => "collate",
            Phase::Device => "device",
            Phase::Apply => "apply",
            Phase::Emit => "emit",
            Phase::Retire => "retire",
            Phase::Tick => "tick",
            Phase::Solo => "solo",
        }
    }
}

/// One recorded span (or instant, when `start_us == end_us`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub phase: Phase,
    /// Request id, or [`NO_REQ`] for batch/tick-scoped events.
    pub req: u64,
    /// Dispatch round (dispatcher track) or scheduler tick sequence
    /// (worker tracks); 0 when not applicable.
    pub round: u64,
    /// Payload count: rows in a batch, tokens emitted; 0 when unused.
    pub n: u32,
    pub start_us: u64,
    pub end_us: u64,
}

/// Injectable monotonic clock; all trace timestamps and latency samples
/// come from one of these so scripted tests control time exactly.
pub trait TraceClock: Send + Sync {
    /// Microseconds since an arbitrary (per-clock) origin.
    fn now_us(&self) -> u64;
}

/// Production clock: µs elapsed since construction.
pub struct WallClock(Instant);

impl WallClock {
    pub fn new() -> Self {
        WallClock(Instant::now())
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceClock for WallClock {
    fn now_us(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }
}

/// Deterministic clock for the test harness: time only moves when the
/// script says so.
#[derive(Default)]
pub struct ScriptedClock(AtomicU64);

impl ScriptedClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance(&self, us: u64) {
        self.0.fetch_add(us, Ordering::SeqCst);
    }

    pub fn set(&self, us: u64) {
        self.0.store(us, Ordering::SeqCst);
    }
}

impl TraceClock for ScriptedClock {
    fn now_us(&self) -> u64 {
        self.0.load(Ordering::SeqCst)
    }
}

/// Fixed-capacity event ring: push is O(1), the oldest event is
/// overwritten when full and every overwrite increments a drop counter.
/// Writers on the same ring (the dispatcher's collector and device
/// threads share one track) serialize on a short mutex hold.
pub struct TraceRing {
    cap: usize,
    events: Mutex<VecDeque<TraceEvent>>,
    dropped: AtomicU64,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        TraceRing {
            cap: cap.max(1),
            events: Mutex::new(VecDeque::with_capacity(cap.max(1))),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn record(&self, ev: TraceEvent) {
        let mut g = match self.events.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if g.len() == self.cap {
            g.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        g.push_back(ev);
    }

    pub fn len(&self) -> usize {
        match self.events.lock() {
            Ok(g) => g.len(),
            Err(p) => p.into_inner().len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events currently held, oldest first.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        match self.events.lock() {
            Ok(g) => g.iter().copied().collect(),
            Err(p) => p.into_inner().iter().copied().collect(),
        }
    }

    /// Events overwritten since creation.
    pub fn dropped_total(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// The recorder: a set of named tracks plus the shared clock and the
/// sampling gate.  Cheap to share (`Arc`); instrumentation sites hold a
/// [`TraceTrack`] handle so the hot path never touches the track map.
pub struct Tracer {
    clock: Arc<dyn TraceClock>,
    enabled: AtomicBool,
    cap: usize,
    tracks: Mutex<BTreeMap<String, (u64, Arc<TraceRing>)>>,
}

impl Tracer {
    /// Recorder with an injected clock; starts *disabled* (sampling off).
    pub fn new(cap: usize, clock: Arc<dyn TraceClock>) -> Arc<Self> {
        Arc::new(Tracer {
            clock,
            enabled: AtomicBool::new(false),
            cap: cap.max(1),
            tracks: Mutex::new(BTreeMap::new()),
        })
    }

    /// Recorder on the wall clock with the default ring capacity.
    pub fn wall() -> Arc<Self> {
        Self::new(DEFAULT_RING_CAP, Arc::new(WallClock::new()))
    }

    /// Flip the sampling gate.  May be toggled at any time; handles pick
    /// the change up on their next event.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Current time on the injected clock.  Always live (independent of
    /// the sampling gate) — latency histograms use the same timeline.
    pub fn now_us(&self) -> u64 {
        self.clock.now_us()
    }

    /// Get-or-create the named track and hand back a recording handle.
    pub fn track(self: &Arc<Self>, name: &str) -> TraceTrack {
        let mut g = match self.tracks.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let next_tid = g.len() as u64 + 1;
        let (_, ring) = g
            .entry(name.to_string())
            .or_insert_with(|| (next_tid, Arc::new(TraceRing::new(self.cap))))
            .clone();
        TraceTrack {
            tracer: Arc::clone(self),
            ring,
        }
    }

    /// Total events overwritten across all tracks.
    pub fn dropped_total(&self) -> u64 {
        let g = match self.tracks.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        g.values().map(|(_, r)| r.dropped_total()).sum()
    }

    /// Per-track snapshot, sorted by track name.
    pub fn snapshot(&self) -> Vec<(String, Vec<TraceEvent>)> {
        let g = match self.tracks.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        g.iter()
            .map(|(name, (_, ring))| (name.clone(), ring.snapshot()))
            .collect()
    }

    /// Merge the rings into a Chrome trace-event JSON object:
    /// `{"traceEvents": [...]}` with one `pid=1` process, one named `tid`
    /// per track (thread-name metadata events included), and `"X"`
    /// complete events carrying `ts`/`dur` in µs — the native unit of the
    /// Chrome trace format, so the file loads directly in Perfetto.
    pub fn chrome_trace_json(&self) -> Json {
        let g = match self.tracks.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let mut events = Vec::new();
        for (name, (tid, ring)) in g.iter() {
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(*tid as f64)),
                ("args", Json::obj(vec![("name", Json::str(name))])),
            ]));
            for ev in ring.snapshot() {
                let mut args = Vec::new();
                if ev.req != NO_REQ {
                    args.push(("req", Json::Num(ev.req as f64)));
                }
                args.push(("round", Json::Num(ev.round as f64)));
                if ev.n > 0 {
                    args.push(("n", Json::Num(ev.n as f64)));
                }
                events.push(Json::obj(vec![
                    ("name", Json::str(ev.phase.name())),
                    ("ph", Json::str("X")),
                    ("ts", Json::Num(ev.start_us as f64)),
                    ("dur", Json::Num(ev.end_us.saturating_sub(ev.start_us) as f64)),
                    ("pid", Json::Num(1.0)),
                    ("tid", Json::Num(*tid as f64)),
                    ("args", Json::obj(args)),
                ]));
            }
        }
        drop(g);
        Json::obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            (
                "otherData",
                Json::obj(vec![(
                    "dropped_events",
                    Json::Num(self.dropped_total() as f64),
                )]),
            ),
        ])
    }
}

/// Recording handle for one track.  Clone-cheap; safe to share across
/// the threads that feed the same track.
#[derive(Clone)]
pub struct TraceTrack {
    tracer: Arc<Tracer>,
    ring: Arc<TraceRing>,
}

impl TraceTrack {
    pub fn enabled(&self) -> bool {
        self.tracer.enabled()
    }

    /// Clock read (always live, gate-independent).
    pub fn now_us(&self) -> u64 {
        self.tracer.now_us()
    }

    /// Record a span; no-op when sampling is off.
    pub fn span(&self, phase: Phase, req: u64, round: u64, n: u32, start_us: u64, end_us: u64) {
        if !self.enabled() {
            return;
        }
        self.ring.record(TraceEvent {
            phase,
            req,
            round,
            n,
            start_us,
            end_us,
        });
    }

    /// Record a zero-duration instant; no-op when sampling is off.
    pub fn instant(&self, phase: Phase, req: u64, round: u64, n: u32, at_us: u64) {
        self.span(phase, req, round, n, at_us, at_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn ev(req: u64, start: u64, end: u64) -> TraceEvent {
        TraceEvent {
            phase: Phase::Device,
            req,
            round: 0,
            n: 0,
            start_us: start,
            end_us: end,
        }
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let ring = TraceRing::new(4);
        for i in 0..10 {
            ring.record(ev(i, i, i + 1));
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.dropped_total(), 6);
        // The newest events survive (flight-recorder semantics).
        let got: Vec<u64> = ring.snapshot().iter().map(|e| e.req).collect();
        assert_eq!(got, vec![6, 7, 8, 9]);
    }

    #[test]
    fn ring_under_capacity_drops_nothing() {
        let ring = TraceRing::new(8);
        for i in 0..5 {
            ring.record(ev(i, i, i));
        }
        assert_eq!(ring.len(), 5);
        assert_eq!(ring.dropped_total(), 0);
    }

    #[test]
    fn concurrent_writers_lose_no_events_under_capacity() {
        let ring = Arc::new(TraceRing::new(4096));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let r = Arc::clone(&ring);
            handles.push(thread::spawn(move || {
                for i in 0..512u64 {
                    r.record(ev(t * 1000 + i, i, i + 1));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ring.len(), 2048);
        assert_eq!(ring.dropped_total(), 0);
        // Every writer's events are all present.
        let snap = ring.snapshot();
        for t in 0..4u64 {
            let n = snap.iter().filter(|e| e.req / 1000 == t).count();
            assert_eq!(n, 512, "writer {t} lost events");
        }
    }

    #[test]
    fn concurrent_writers_account_for_every_overwrite() {
        let ring = Arc::new(TraceRing::new(64));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let r = Arc::clone(&ring);
            handles.push(thread::spawn(move || {
                for i in 0..256u64 {
                    r.record(ev(t, i, i));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // recorded = kept + dropped, exactly.
        assert_eq!(ring.len() as u64 + ring.dropped_total(), 4 * 256);
        assert_eq!(ring.len(), 64);
    }

    #[test]
    fn tracer_gate_suppresses_recording_but_not_the_clock() {
        let clock = Arc::new(ScriptedClock::new());
        let tracer = Tracer::new(16, clock.clone());
        let track = tracer.track("worker-0");
        clock.set(42);
        assert_eq!(track.now_us(), 42);
        track.span(Phase::Plan, 1, 0, 0, 0, 42);
        assert_eq!(tracer.snapshot()[0].1.len(), 0, "disabled tracer recorded");
        tracer.set_enabled(true);
        track.span(Phase::Plan, 1, 0, 0, 0, 42);
        assert_eq!(tracer.snapshot()[0].1.len(), 1);
    }

    #[test]
    fn track_handles_share_one_ring_per_name() {
        let tracer = Tracer::new(16, Arc::new(ScriptedClock::new()));
        tracer.set_enabled(true);
        let a = tracer.track("dispatcher");
        let b = tracer.track("dispatcher");
        a.instant(Phase::Collate, NO_REQ, 1, 0, 5);
        b.instant(Phase::Device, NO_REQ, 1, 0, 6);
        let snap = tracer.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].1.len(), 2);
    }

    #[test]
    fn chrome_export_parses_and_names_tracks() {
        let tracer = Tracer::new(16, Arc::new(ScriptedClock::new()));
        tracer.set_enabled(true);
        tracer.track("worker-0").span(Phase::Device, 7, 3, 2, 10, 25);
        tracer.track("dispatcher").instant(Phase::Collate, NO_REQ, 3, 4, 12);
        let json = tracer.chrome_trace_json();
        // Round-trip through the serializer: parse what we printed.
        let parsed = Json::parse(&json.to_string()).expect("chrome trace JSON parses");
        let evs = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 2 data events.
        assert_eq!(evs.len(), 4);
        let names: Vec<String> = evs
            .iter()
            .map(|e| e.req("name").unwrap().as_str().unwrap().to_string())
            .collect();
        assert!(names.iter().any(|n| n == "device"));
        assert!(names.iter().any(|n| n == "collate"));
        assert_eq!(names.iter().filter(|n| *n == "thread_name").count(), 2);
        // The span's ts/dur survive in µs.
        let dev = evs
            .iter()
            .find(|e| e.req("name").unwrap().as_str().unwrap() == "device")
            .unwrap();
        assert_eq!(dev.req("ts").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(dev.req("dur").unwrap().as_f64().unwrap(), 15.0);
        // NO_REQ events carry no "req" arg.
        let col = evs
            .iter()
            .find(|e| e.req("name").unwrap().as_str().unwrap() == "collate")
            .unwrap();
        assert!(col.req("args").unwrap().get("req").is_none());
    }

    #[test]
    fn scripted_clock_advances_on_demand() {
        let c = ScriptedClock::new();
        assert_eq!(c.now_us(), 0);
        c.advance(100);
        c.advance(17);
        assert_eq!(c.now_us(), 117);
    }
}
