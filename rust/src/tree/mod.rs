//! Sparse-tree machinery: tree representation, input-layout/attention
//! bias assembly for a decode step, and the guess-set plumbing between
//! steps.  The construction algorithms live in `builder`; the dynamic
//! state machine (Props 4.1–4.4) in `dynamic`; the hardware-aware sizer
//! in `hardware`.
//!
//! A decode-step input is laid out as:
//!
//! ```text
//!   [ root | candidate nodes (tree order) | prompt chains (node order) ]
//! ```
//!
//! The root is the last *emitted* (bonus) token — its KV is not yet in
//! the cache, so it occupies the first tree slot.  Every candidate node
//! carries a `prompt_len`-long chain of prompt tokens used to produce
//! the *next* step's guesses if that node ends up the deepest accepted
//! one (Fig 3 of the paper).

pub mod builder;
pub mod dynamic;
pub mod hardware;

use anyhow::{bail, Result};

use crate::config::PROMPT_ID0;
use crate::runtime::NEG_INF;

/// One candidate node of a sparse tree.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeNode {
    /// parent node index (`0` = root); root itself has `parent == usize::MAX`
    pub parent: usize,
    /// candidate depth, 1-based (root is depth 0)
    pub depth: usize,
    /// rank of this candidate among the guesses at its depth (0-based)
    pub rank: usize,
    /// number of prompt tokens chained after this node
    pub prompt_len: usize,
}

/// A sparse tree: `nodes[0]` is the root; candidates follow in
/// parent-before-child order.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseTree {
    pub nodes: Vec<TreeNode>,
    /// candidate-subtree max depth — the `k` of state `T_k`
    pub state: usize,
}

/// Input-token kinds in layout order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TokKind {
    Root,
    /// candidate node index (into `SparseTree::nodes`)
    Cand(usize),
    /// (owner node index, chain offset j — predicts distance j+1)
    Prompt(usize, usize),
}

/// Flattened layout of a tree for one decode step.
#[derive(Debug, Clone)]
pub struct TreeLayout {
    pub kinds: Vec<TokKind>,
    /// input index of each node (root = nodes[0])
    pub node_input: Vec<usize>,
    /// input indices of each node's prompt chain
    pub prompt_input: Vec<Vec<usize>>,
    /// children (node indices) per node
    pub children: Vec<Vec<usize>>,
    /// position offset of each input token relative to the root position
    pub pos_offset: Vec<usize>,
    /// ancestor input-indices (within the tree, excluding self) per token
    pub ancestors: Vec<Vec<usize>>,
}

impl SparseTree {
    /// Root-only tree (state 0): no candidates, `m` prompt tokens on the
    /// root.  Used for the first step after prefill and as the fallback
    /// state.
    pub fn root_only(m: usize) -> SparseTree {
        SparseTree {
            nodes: vec![TreeNode { parent: usize::MAX, depth: 0, rank: 0, prompt_len: m }],
            state: 0,
        }
    }

    pub fn n_candidates(&self) -> usize {
        self.nodes.len() - 1
    }

    pub fn n_prompt(&self) -> usize {
        self.nodes.iter().map(|n| n.prompt_len).sum()
    }

    /// Total input tokens for the decode step (root + candidates + prompts).
    pub fn input_len(&self) -> usize {
        self.nodes.len() + self.n_prompt()
    }

    /// Validate structural invariants (parents precede children, depths
    /// consistent, root first).
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() || self.nodes[0].depth != 0 {
            bail!("tree must start with a depth-0 root");
        }
        for (i, n) in self.nodes.iter().enumerate().skip(1) {
            if n.parent >= i {
                bail!("node {i} has parent {} not before it", n.parent);
            }
            if n.depth != self.nodes[n.parent].depth + 1 {
                bail!("node {i} depth {} inconsistent with parent", n.depth);
            }
            if n.depth > self.state {
                bail!("node {i} deeper than state {}", self.state);
            }
        }
        Ok(())
    }

    /// Compute the flattened layout.
    pub fn layout(&self) -> TreeLayout {
        let nn = self.nodes.len();
        let mut kinds = Vec::with_capacity(self.input_len());
        let mut node_input = vec![0usize; nn];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); nn];
        kinds.push(TokKind::Root);
        for i in 1..nn {
            node_input[i] = kinds.len();
            kinds.push(TokKind::Cand(i));
            children[self.nodes[i].parent].push(i);
        }
        let mut prompt_input: Vec<Vec<usize>> = vec![Vec::new(); nn];
        for i in 0..nn {
            for j in 0..self.nodes[i].prompt_len {
                prompt_input[i].push(kinds.len());
                kinds.push(TokKind::Prompt(i, j));
            }
        }

        // ancestors + positions
        let mut ancestors: Vec<Vec<usize>> = vec![Vec::new(); kinds.len()];
        let mut pos_offset = vec![0usize; kinds.len()];
        // node ancestor chains (input indices)
        let mut node_anc: Vec<Vec<usize>> = vec![Vec::new(); nn];
        for i in 1..nn {
            let p = self.nodes[i].parent;
            let mut a = node_anc[p].clone();
            a.push(node_input[p]);
            node_anc[i] = a;
        }
        for (t, kind) in kinds.iter().enumerate() {
            match *kind {
                TokKind::Root => {
                    pos_offset[t] = 0;
                }
                TokKind::Cand(i) => {
                    pos_offset[t] = self.nodes[i].depth;
                    ancestors[t] = node_anc[i].clone();
                }
                TokKind::Prompt(i, j) => {
                    pos_offset[t] = self.nodes[i].depth + 1 + j;
                    let mut a = node_anc[i].clone();
                    a.push(node_input[i]);
                    // earlier prompt tokens of the same chain
                    a.extend(prompt_input[i][..j].iter().copied());
                    ancestors[t] = a;
                }
            }
        }
        TreeLayout { kinds, node_input, prompt_input, children, pos_offset, ancestors }
    }
}

/// Per-step guesses: for each token distance d (1-based), the top-R
/// candidate tokens with their probabilities, extracted from the prompt
/// chain of the previously accepted node.
#[derive(Debug, Clone, Default)]
pub struct GuessSet {
    /// guesses[d-1] = Vec<(token, prob)> sorted by prob descending
    pub per_distance: Vec<Vec<(u32, f32)>>,
}

impl GuessSet {
    pub fn depth(&self) -> usize {
        self.per_distance.len()
    }

    pub fn token_at(&self, depth: usize, rank: usize) -> Option<u32> {
        self.per_distance
            .get(depth - 1)
            .and_then(|v| v.get(rank))
            .map(|&(t, _)| t)
    }
}

/// Assembled inputs for one decode step over a tree.
#[derive(Debug, Clone)]
pub struct StepInputs {
    pub tokens: Vec<u32>,
    pub pos: Vec<u32>,
    pub slots: Vec<u32>,
    pub bias: Vec<f32>,
}

/// Fill tokens/pos/slots/bias for a decode step.
///
/// * `root_token` — the bonus token emitted by the previous step
/// * `guesses` — token values per (depth, rank); candidates whose guess
///   is missing (shallow guess set) get the root token and zero
///   acceptance chance — callers should pass trees whose state matches
///   `guesses.depth()`.
/// * `committed` — cache rows already finalized; root goes to slot
///   `committed`, tree token i to `committed + i`.
pub fn assemble_step(
    tree: &SparseTree,
    layout: &TreeLayout,
    guesses: &GuessSet,
    root_token: u32,
    root_pos: u32,
    committed: usize,
    max_ctx: usize,
) -> Result<StepInputs> {
    let n = tree.input_len();
    if committed + n + 1 >= max_ctx {
        bail!("tree of {n} tokens does not fit: committed={committed} max_ctx={max_ctx}");
    }
    let mut tokens = Vec::with_capacity(n);
    let mut pos = Vec::with_capacity(n);
    let mut slots = Vec::with_capacity(n);
    let mut bias = vec![NEG_INF; n * max_ctx];

    for (t, kind) in layout.kinds.iter().enumerate() {
        let tok = match *kind {
            TokKind::Root => root_token,
            TokKind::Cand(i) => {
                let node = &tree.nodes[i];
                guesses.token_at(node.depth, node.rank).unwrap_or(root_token)
            }
            TokKind::Prompt(_, j) => PROMPT_ID0 + j as u32,
        };
        tokens.push(tok);
        pos.push(root_pos + layout.pos_offset[t] as u32);
        slots.push((committed + t) as u32);
        // visibility: committed context + ancestors + self
        let row = &mut bias[t * max_ctx..(t + 1) * max_ctx];
        for slot in row.iter_mut().take(committed) {
            *slot = 0.0;
        }
        row[committed + t] = 0.0;
        if !matches!(kind, TokKind::Root) {
            row[committed] = 0.0; // root is an ancestor of everything
        }
        for &a in &layout.ancestors[t] {
            row[committed + a] = 0.0;
        }
    }
    Ok(StepInputs { tokens, pos, slots, bias })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// root + 2 depth-1 candidates (ranks 0,1) + 1 depth-2 under the
    /// first; chains: root 3, node1 2, node2 1, node3 1.
    pub(crate) fn demo_tree() -> SparseTree {
        SparseTree {
            nodes: vec![
                TreeNode { parent: usize::MAX, depth: 0, rank: 0, prompt_len: 3 },
                TreeNode { parent: 0, depth: 1, rank: 0, prompt_len: 2 },
                TreeNode { parent: 0, depth: 1, rank: 1, prompt_len: 1 },
                TreeNode { parent: 1, depth: 2, rank: 0, prompt_len: 1 },
            ],
            state: 2,
        }
    }

    fn demo_guesses() -> GuessSet {
        GuessSet {
            per_distance: vec![
                vec![(65, 0.6), (66, 0.2)],
                vec![(67, 0.5)],
            ],
        }
    }

    #[test]
    fn counts_and_validate() {
        let t = demo_tree();
        t.validate().unwrap();
        assert_eq!(t.n_candidates(), 3);
        assert_eq!(t.n_prompt(), 7);
        assert_eq!(t.input_len(), 11);
    }

    #[test]
    fn validate_rejects_bad_parent() {
        let mut t = demo_tree();
        t.nodes[1].parent = 3;
        assert!(t.validate().is_err());
    }

    #[test]
    fn layout_orders_and_children() {
        let t = demo_tree();
        let l = t.layout();
        assert_eq!(l.kinds[0], TokKind::Root);
        assert_eq!(l.kinds[1], TokKind::Cand(1));
        assert_eq!(l.children[0], vec![1, 2]);
        assert_eq!(l.children[1], vec![3]);
        assert_eq!(l.prompt_input[0].len(), 3);
        // prompt chains come after all candidates
        assert!(l.prompt_input[0][0] > l.node_input[3]);
    }

    #[test]
    fn layout_positions() {
        let t = demo_tree();
        let l = t.layout();
        assert_eq!(l.pos_offset[l.node_input[3]], 2);
        // prompt j of root: offset 1+j
        assert_eq!(l.pos_offset[l.prompt_input[0][2]], 3);
        // prompt j of node3 (depth 2): offset 3
        assert_eq!(l.pos_offset[l.prompt_input[3][0]], 3);
    }

    #[test]
    fn ancestors_follow_paths() {
        let t = demo_tree();
        let l = t.layout();
        // node3's ancestors = [root, node1]
        assert_eq!(l.ancestors[l.node_input[3]], vec![0, l.node_input[1]]);
        // prompt 1 of node1: ancestors = node1 + prompt 0 of node1
        let p1 = l.prompt_input[1][1];
        assert!(l.ancestors[p1].contains(&l.node_input[1]));
        assert!(l.ancestors[p1].contains(&l.prompt_input[1][0]));
        // sibling isolation: node2's ancestors exclude node1
        assert!(!l.ancestors[l.node_input[2]].contains(&l.node_input[1]));
    }

    #[test]
    fn assemble_fills_tokens_and_bias() {
        let t = demo_tree();
        let l = t.layout();
        let g = demo_guesses();
        let s = 64;
        let inp = assemble_step(&t, &l, &g, 42, 10, 10, s).unwrap();
        assert_eq!(inp.tokens.len(), 11);
        assert_eq!(inp.tokens[0], 42);
        assert_eq!(inp.tokens[1], 65); // depth1 rank0
        assert_eq!(inp.tokens[2], 66); // depth1 rank1
        assert_eq!(inp.tokens[3], 67); // depth2 rank0
        assert_eq!(inp.tokens[l.prompt_input[0][1]], PROMPT_ID0 + 1);
        assert_eq!(inp.pos[0], 10);
        assert_eq!(inp.pos[3], 12);
        assert_eq!(inp.slots[0], 10);
        assert_eq!(inp.slots[5], 15);
        // bias row of node3: committed(10) + root(10) + node1(11) + self(13)
        let row = &inp.bias[3 * s..4 * s];
        for j in 0..10 {
            assert_eq!(row[j], 0.0);
        }
        assert_eq!(row[10], 0.0);
        assert_eq!(row[11], 0.0);
        assert_eq!(row[12], NEG_INF); // sibling node2
        assert_eq!(row[13], 0.0);
        assert_eq!(row[14], NEG_INF);
    }

    #[test]
    fn assemble_rejects_overflow() {
        let t = demo_tree();
        let l = t.layout();
        let g = demo_guesses();
        assert!(assemble_step(&t, &l, &g, 1, 60, 60, 64).is_err());
    }

    #[test]
    fn root_only_tree() {
        let t = SparseTree::root_only(3);
        t.validate().unwrap();
        assert_eq!(t.input_len(), 4);
        assert_eq!(t.state, 0);
    }
}
