//! Dynamic-sparse-tree construction (paper §4.2):
//!
//! 1. **Optimal candidate trees** per depth cap `k` — greedy frontier
//!    expansion that always adds the unadded node with the highest path
//!    probability (the Medusa/Sequoia algorithm).  Path probability of a
//!    rank-path (r_1..r_j) is `Π_d exact[d][r_d]` under the independence
//!    approximation (Prop 4.1).
//! 2. **Appending prompt tokens** — attach the maximum `m` to every
//!    candidate (and always `m` to the root, which feeds the next step
//!    whenever verification stops at the root).
//! 3. **Greedy prompt-token removal** — repeatedly remove the prompt
//!    token with the smallest ΔF = p(c)·(f(T_i) − f(T_{i−1})) until the
//!    prompt budget holds (Prop 4.3).

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::{SparseTree, TreeNode};

/// Acceptance statistics estimated on the validation set
/// (`python/train/eval_accept.py` -> `accept_stats.json`).
#[derive(Debug, Clone)]
pub struct AcceptStats {
    /// exact[d][r]: P(rank-(r+1) guess at distance d+1 is the true token)
    pub exact: Vec<Vec<f64>>,
    /// accumulative top-k accuracy (Fig 6 series)
    pub cum: Vec<Vec<f64>>,
    /// next-token (LM head) rank accuracies — distance 0
    pub lm_exact: Vec<f64>,
}

impl AcceptStats {
    pub fn load(path: &Path, method: &str) -> Result<AcceptStats> {
        let j = Json::from_file(path)?;
        let sec = j
            .get(method)
            .with_context(|| format!("accept stats for '{method}' missing in {}", path.display()))?;
        let lm = j.req("lm")?;
        Ok(AcceptStats {
            exact: sec.req("exact")?.as_f64_mat()?,
            cum: sec.req("cum")?.as_f64_mat()?,
            lm_exact: lm.req("exact")?.as_f64_mat()?.into_iter().next().unwrap_or_default(),
        })
    }

    /// Max usable candidate depth.
    pub fn max_depth(&self) -> usize {
        self.exact.len()
    }

    /// Acceptance probability of a rank-`r` candidate at depth `d`
    /// (1-based depth; clamped to the table).
    pub fn p(&self, depth: usize, rank: usize) -> f64 {
        if depth == 0 || depth > self.exact.len() {
            return 0.0;
        }
        self.exact[depth - 1].get(rank).copied().unwrap_or(0.0)
    }

    /// Synthetic stats for tests/simulations: geometric decay over rank
    /// and distance.  Rank rows are capped so exact-rank probabilities
    /// (disjoint events) sum below 1, like real measurements.
    pub fn synthetic(m: usize, top1: f64, rank_decay: f64, dist_decay: f64) -> AcceptStats {
        let mut exact = Vec::new();
        for d in 0..m {
            let base = top1 * dist_decay.powi(d as i32);
            let mut row: Vec<f64> = (0..10).map(|r| base * rank_decay.powi(r as i32)).collect();
            let sum: f64 = row.iter().sum();
            if sum > 0.95 {
                for x in row.iter_mut() {
                    *x *= 0.95 / sum;
                }
            }
            exact.push(row);
        }
        let cum = exact
            .iter()
            .map(|row| {
                row.iter()
                    .scan(0.0, |acc, &x| {
                        *acc += x;
                        Some(*acc)
                    })
                    .collect()
            })
            .collect();
        let lm_exact: Vec<f64> = (0..10).map(|r| 0.8 * rank_decay.powi(r as i32)).collect();
        AcceptStats { exact, cum, lm_exact }
    }
}

#[derive(PartialEq)]
struct Frontier {
    value: f64,
    depth: usize,
    rank: usize,
    parent: usize,
}

impl Eq for Frontier {}

impl Ord for Frontier {
    fn cmp(&self, other: &Self) -> Ordering {
        self.value
            .partial_cmp(&other.value)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.depth.cmp(&self.depth))
            .then_with(|| other.rank.cmp(&self.rank))
    }
}

impl PartialOrd for Frontier {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Step 1: candidate-only optimal tree with `n_candidates` nodes, depth
/// capped at `k`, using top-`top_r` ranks per level.
pub fn build_candidate_tree(
    stats: &AcceptStats,
    k: usize,
    n_candidates: usize,
    top_r: usize,
) -> SparseTree {
    let mut nodes = vec![TreeNode { parent: usize::MAX, depth: 0, rank: 0, prompt_len: 0 }];
    let mut path_prob = vec![1.0f64];
    let mut heap = BinaryHeap::new();
    if k >= 1 {
        heap.push(Frontier { value: stats.p(1, 0), depth: 1, rank: 0, parent: 0 });
    }
    while nodes.len() - 1 < n_candidates {
        let Some(f) = heap.pop() else { break };
        if f.value <= 0.0 {
            break;
        }
        let idx = nodes.len();
        nodes.push(TreeNode { parent: f.parent, depth: f.depth, rank: f.rank, prompt_len: 0 });
        path_prob.push(f.value);
        // next sibling (same parent, next rank)
        if f.rank + 1 < top_r {
            let parent_val = path_prob[f.parent];
            heap.push(Frontier {
                value: parent_val * stats.p(f.depth, f.rank + 1),
                depth: f.depth,
                rank: f.rank + 1,
                parent: f.parent,
            });
        }
        // first child
        if f.depth < k {
            heap.push(Frontier {
                value: f.value * stats.p(f.depth + 1, 0),
                depth: f.depth + 1,
                rank: 0,
                parent: idx,
            });
        }
    }
    SparseTree { nodes, state: k }
}

/// Path probability of every node (root = 1).
pub fn path_probs(tree: &SparseTree, stats: &AcceptStats) -> Vec<f64> {
    let mut probs = vec![0.0; tree.nodes.len()];
    probs[0] = 1.0;
    for (i, n) in tree.nodes.iter().enumerate().skip(1) {
        probs[i] = probs[n.parent] * stats.p(n.depth, n.rank);
    }
    probs
}

/// Prop 4.1: f(T) = expected number of accepted *candidate* tokens.
pub fn expected_accepted(tree: &SparseTree, stats: &AcceptStats) -> f64 {
    path_probs(tree, stats).iter().skip(1).sum()
}

/// Steps 2+3: attach `m` prompt tokens everywhere, then greedily remove
/// the lowest-ΔF prompt token until at most `budget` prompt tokens
/// remain.  The root's chain is pinned at `m` (it feeds the next step
/// whenever verification stops at the root) and candidate chains never
/// drop below `min_chain`.
///
/// `f_by_state[i]` is f(T_i) — the next-step candidate value if the
/// accepted node carries `i` prompt tokens (f_by_state[0] = 0).
pub fn attach_and_prune_prompts(
    tree: &mut SparseTree,
    stats: &AcceptStats,
    m: usize,
    budget: usize,
    f_by_state: &[f64],
    min_chain: usize,
) {
    let probs = path_probs(tree, stats);
    for n in tree.nodes.iter_mut() {
        n.prompt_len = m;
    }
    let f = |i: usize| f_by_state.get(i).copied().unwrap_or(0.0);
    loop {
        let total: usize = tree.n_prompt();
        if total <= budget {
            break;
        }
        // smallest ΔF among candidate nodes with chain > min_chain
        let mut best: Option<(usize, f64)> = None;
        for (i, n) in tree.nodes.iter().enumerate().skip(1) {
            if n.prompt_len > min_chain {
                let df = probs[i] * (f(n.prompt_len) - f(n.prompt_len - 1));
                if best.map_or(true, |(_, b)| df < b) {
                    best = Some((i, df));
                }
            }
        }
        match best {
            Some((i, _)) => tree.nodes[i].prompt_len -= 1,
            None => break, // cannot shrink further (root pinned)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> AcceptStats {
        AcceptStats::synthetic(3, 0.6, 0.45, 0.7)
    }

    #[test]
    fn candidate_tree_is_valid_and_sized() {
        let t = build_candidate_tree(&stats(), 3, 12, 10);
        t.validate().unwrap();
        assert_eq!(t.n_candidates(), 12);
        assert_eq!(t.state, 3);
    }

    #[test]
    fn candidate_tree_prefers_high_prob_nodes() {
        let t = build_candidate_tree(&stats(), 3, 6, 10);
        // the first added candidate must be depth-1 rank-0
        assert_eq!(t.nodes[1].depth, 1);
        assert_eq!(t.nodes[1].rank, 0);
        // a depth-2 rank-0 under it beats depth-1 rank-3:
        // 0.6*0.42 = 0.25 vs 0.6*0.45^3 = 0.054
        assert!(t
            .nodes
            .iter()
            .any(|n| n.depth == 2 && n.rank == 0));
    }

    #[test]
    fn depth_cap_respected() {
        let t = build_candidate_tree(&stats(), 1, 8, 10);
        assert!(t.nodes.iter().all(|n| n.depth <= 1));
    }

    #[test]
    fn expected_accepted_monotone_in_size() {
        let s = stats();
        let f4 = expected_accepted(&build_candidate_tree(&s, 3, 4, 10), &s);
        let f12 = expected_accepted(&build_candidate_tree(&s, 3, 12, 10), &s);
        assert!(f12 > f4);
        assert!(f4 > 0.5); // top-1 alone is 0.6
    }

    #[test]
    fn prune_respects_budget_and_pins_root() {
        let s = stats();
        let mut t = build_candidate_tree(&s, 3, 8, 10);
        let f_by_state = [0.0, 0.6, 0.9, 1.1];
        attach_and_prune_prompts(&mut t, &s, 3, 14, &f_by_state, 1);
        assert!(t.n_prompt() <= 14);
        assert_eq!(t.nodes[0].prompt_len, 3);
        assert!(t.nodes.iter().skip(1).all(|n| n.prompt_len >= 1));
    }

    #[test]
    fn prune_removes_from_unlikely_nodes_first() {
        let s = stats();
        let mut t = build_candidate_tree(&s, 2, 6, 10);
        let f_by_state = [0.0, 0.6, 0.9, 1.1];
        let budget = t.n_candidates() * 3 + 3 - 2;
        attach_and_prune_prompts(&mut t, &s, 3, budget, &f_by_state, 1);
        // exactly 2 prompt tokens removed; the most probable candidate
        // (nodes[1], depth1 rank0) must keep its full chain
        assert_eq!(t.nodes[1].prompt_len, 3);
    }

    #[test]
    fn synthetic_stats_shape() {
        let s = stats();
        assert_eq!(s.max_depth(), 3);
        assert!(s.p(1, 0) > s.p(2, 0));
        assert!(s.p(1, 0) > s.p(1, 1));
        assert_eq!(s.p(4, 0), 0.0);
        assert_eq!(s.p(0, 0), 0.0);
    }

    #[test]
    fn load_from_json() {
        let dir = std::env::temp_dir().join("ppd_stats_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("accept_stats.json");
        std::fs::write(
            &p,
            r#"{"lm":{"exact":[[0.8,0.05]],"cum":[[0.8,0.85]],"n":[10]},
                "ppd":{"exact":[[0.5,0.1],[0.3,0.08]],
                        "cum":[[0.5,0.6],[0.3,0.38]],"n":[5,5]}}"#,
        )
        .unwrap();
        let s = AcceptStats::load(&p, "ppd").unwrap();
        assert_eq!(s.exact[1][0], 0.3);
        assert_eq!(s.lm_exact[0], 0.8);
        assert!(AcceptStats::load(&p, "medusa").is_err());
    }
}
