//! Hardware-aware tree sizing (paper §4.2 "Hardware-awareness", Fig 8b/8c).
//!
//! For a grid of total tree sizes n, search the (n_c, n_p) split that
//! maximizes the amortized acceptance τ (Prop 4.4), then pick the n that
//! maximizes the *theoretical speedup*
//! `Speedup(n) = τ(n) / L_fp(input_len(n)) · L_fp(1)`
//! with the latency curve `L_fp` measured on this machine (or an
//! emulated hardware envelope — see `runtime::calibrate`).

use anyhow::{bail, Result};

use crate::runtime::calibrate::Calibration;

use super::builder::AcceptStats;
use super::dynamic::DynamicTreeSet;

/// One point of the Fig 8b sweep.
#[derive(Debug, Clone)]
pub struct SizePoint {
    pub total_budget: usize,
    pub n_candidates: usize,
    pub n_prompt: usize,
    pub input_len: usize,
    pub tau: f64,
    pub latency_s: f64,
    pub speedup: f64,
}

#[derive(Debug, Clone)]
pub struct SpeedupModel {
    pub envelope: String,
    pub points: Vec<SizePoint>,
}

impl SpeedupModel {
    pub fn best(&self) -> Option<&SizePoint> {
        // NaN speedups (degenerate calibration, e.g. zero latency) are
        // excluded outright: total_cmp alone would rank NaN above every
        // finite speedup and silently pick a garbage point
        self.points
            .iter()
            .filter(|p| !p.speedup.is_nan())
            .max_by(|a, b| a.speedup.total_cmp(&b.speedup))
    }
}

/// Sweep total tree budgets and produce the theoretical-speedup curve.
///
/// `budgets` are total tree-token budgets (candidates + prompt tokens,
/// excluding the root).  For each, candidate counts are scanned and the
/// split with the best τ kept.
pub fn sweep(
    stats: &AcceptStats,
    m: usize,
    budgets: &[usize],
    calib: &Calibration,
    top_r: usize,
) -> Result<SpeedupModel> {
    // vanilla baseline: one-token decode step
    let l1 = match calib.lookup(1) {
        Some(l) => l,
        None => bail!("calibration has no small bucket"),
    };
    let mut points = Vec::new();
    for &budget in budgets {
        let mut best: Option<(f64, DynamicTreeSet)> = None;
        let max_nc = budget.saturating_sub(m).max(1);
        let mut nc = 1;
        while nc <= max_nc {
            let np = budget.saturating_sub(nc);
            if np >= nc.min(m) {
                if let Ok(set) = DynamicTreeSet::build(stats, m, nc, np, top_r) {
                    // only feasible if the prompt budget allows >=1 per node
                    if set.trees[m].n_prompt() <= np + m {
                        let tau = set.tau();
                        if best.as_ref().map_or(true, |(t, _)| tau > *t) {
                            best = Some((tau, set));
                        }
                    }
                }
            }
            nc += 1.max(max_nc / 16); // coarse grid for large budgets
        }
        let Some((tau, set)) = best else { continue };
        let input_len = set.max_input_len();
        let Some(latency) = calib.lookup(input_len) else {
            continue; // budget exceeds compiled buckets
        };
        points.push(SizePoint {
            total_budget: budget,
            n_candidates: set.n_candidates,
            n_prompt: set.trees[m].n_prompt(),
            input_len,
            tau,
            latency_s: latency,
            speedup: tau * l1 / latency,
        });
    }
    if points.is_empty() {
        bail!("no feasible tree size in sweep");
    }
    Ok(SpeedupModel { envelope: calib.envelope.clone(), points })
}

/// Default budget grid used by benches + serving auto-config.
pub fn default_budgets() -> Vec<usize> {
    vec![4, 7, 11, 15, 23, 31, 47, 63, 95, 127]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn calib(per_token: f64) -> Calibration {
        let mut latency_s = BTreeMap::new();
        for b in [1usize, 2, 4, 8, 16, 32, 64, 128, 256] {
            latency_s.insert(b, 1e-3 + per_token * b as f64);
        }
        Calibration { model: "t".into(), envelope: "cpu".into(), latency_s }
    }

    fn stats() -> AcceptStats {
        AcceptStats::synthetic(3, 0.6, 0.45, 0.7)
    }

    #[test]
    fn sweep_produces_curve() {
        let m = sweep(&stats(), 3, &default_budgets(), &calib(1e-6), 10).unwrap();
        assert!(m.points.len() >= 5);
        let best = m.best().unwrap();
        assert!(best.speedup > 1.0);
        assert!(best.tau > 1.0);
    }

    #[test]
    fn flat_latency_prefers_bigger_trees() {
        // when extra tokens are nearly free, bigger budgets win
        let m = sweep(&stats(), 3, &[7, 63], &calib(1e-9), 10).unwrap();
        let s7 = m.points.iter().find(|p| p.total_budget == 7).unwrap();
        let s63 = m.points.iter().find(|p| p.total_budget == 63).unwrap();
        assert!(s63.speedup >= s7.speedup);
    }

    #[test]
    fn steep_latency_prefers_smaller_trees() {
        // the "slow hardware" envelope: heavy per-token cost
        let m = sweep(&stats(), 3, &[7, 63], &calib(5e-4), 10).unwrap();
        let s7 = m.points.iter().find(|p| p.total_budget == 7).unwrap();
        let s63 = m.points.iter().find(|p| p.total_budget == 63).unwrap();
        assert!(s7.speedup >= s63.speedup);
    }

    #[test]
    fn optimal_size_shifts_with_hardware() {
        // Fig 8b: different envelopes -> different argmax n
        let fast = sweep(&stats(), 3, &default_budgets(), &calib(1e-7), 10).unwrap();
        let slow = sweep(&stats(), 3, &default_budgets(), &calib(1e-3), 10).unwrap();
        let bf = fast.best().unwrap().total_budget;
        let bs = slow.best().unwrap().total_budget;
        assert!(bf >= bs, "fast {bf} vs slow {bs}");
    }
}
