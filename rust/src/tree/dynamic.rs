//! The dynamic sparse tree state machine (paper §4, Props 4.1–4.4).
//!
//! A `DynamicTreeSet` holds one tree per state `T_0..T_m` (state = the
//! candidate-subtree depth usable next step = prompt-chain length of the
//! node where verification stopped), the per-state expected candidate
//! counts f(T_k) (Prop 4.1), the state-transition matrix p(s_i|s_k)
//! (derived from the tree structure + acceptance stats), its steady
//! state, and the amortized value R(T) = Σ π_i f(T_i) (Prop 4.4).

use anyhow::Result;

use super::builder::{
    attach_and_prune_prompts, build_candidate_tree, expected_accepted, path_probs, AcceptStats,
};
use super::{SparseTree, TreeLayout};

#[derive(Debug, Clone)]
pub struct DynamicTreeSet {
    /// trees[k] = T_k for k in 0..=m (T_0 = root-only fallback)
    pub trees: Vec<SparseTree>,
    pub layouts: Vec<TreeLayout>,
    /// f(T_k) — Prop 4.1
    pub f: Vec<f64>,
    /// transition[k][i] = p(s_i | s_k)
    pub transition: Vec<Vec<f64>>,
    /// steady-state distribution π over states 0..=m
    pub steady: Vec<f64>,
    /// amortized expected accepted candidates per step — Prop 4.4
    pub r_value: f64,
    pub n_candidates: usize,
    pub n_prompt_budget: usize,
}

impl DynamicTreeSet {
    /// Build the full state set for a (candidate, prompt) budget.
    ///
    /// `mode` selects the ablation arm of Fig 8a:
    /// * `Dynamic` — per-node prompt chains pruned by ΔF (the paper)
    /// * `Static`  — every candidate keeps the full `m`-chain; the
    ///   candidate budget shrinks to keep the same total size
    /// * `Random`  — random tree topology with uniform chains
    pub fn build(
        stats: &AcceptStats,
        m: usize,
        n_candidates: usize,
        n_prompt_budget: usize,
        top_r: usize,
    ) -> Result<DynamicTreeSet> {
        // f estimates from candidate-only trees (used for ΔF pruning)
        let f_est: Vec<f64> = (0..=m)
            .map(|k| {
                if k == 0 {
                    0.0
                } else {
                    expected_accepted(&build_candidate_tree(stats, k, n_candidates, top_r), stats)
                }
            })
            .collect();

        let mut trees = Vec::with_capacity(m + 1);
        for k in 0..=m {
            let mut t = if k == 0 {
                SparseTree::root_only(m)
            } else {
                build_candidate_tree(stats, k, n_candidates, top_r)
            };
            if k > 0 {
                attach_and_prune_prompts(&mut t, stats, m, n_prompt_budget, &f_est, 1);
            }
            t.validate()?;
            trees.push(t);
        }
        Self::finish(trees, stats, m, n_candidates, n_prompt_budget)
    }

    /// Fig 8a "static" arm: full chains everywhere, fewer candidates.
    pub fn build_static(
        stats: &AcceptStats,
        m: usize,
        total_budget: usize,
        top_r: usize,
    ) -> Result<DynamicTreeSet> {
        // every candidate costs 1 + m tokens
        let n_candidates = (total_budget.saturating_sub(m)) / (1 + m);
        let mut trees = Vec::new();
        for k in 0..=m {
            let mut t = if k == 0 {
                SparseTree::root_only(m)
            } else {
                build_candidate_tree(stats, k, n_candidates.max(1), top_r)
            };
            for n in t.nodes.iter_mut() {
                n.prompt_len = m;
            }
            t.validate()?;
            trees.push(t);
        }
        let np = trees[m].n_prompt();
        Self::finish(trees, stats, m, n_candidates, np)
    }

    /// Fig 8a "random" arm: random topology, uniform chains.
    pub fn build_random(
        stats: &AcceptStats,
        m: usize,
        n_candidates: usize,
        n_prompt_budget: usize,
        rng: &mut crate::util::rng::Rng,
    ) -> Result<DynamicTreeSet> {
        let mut trees = Vec::new();
        for k in 0..=m {
            let mut t = SparseTree::root_only(m);
            if k > 0 {
                t.state = k;
                for _ in 0..n_candidates {
                    // random parent among existing nodes with depth < k
                    let parents: Vec<usize> = t
                        .nodes
                        .iter()
                        .enumerate()
                        .filter(|(_, n)| n.depth < k)
                        .map(|(i, _)| i)
                        .collect();
                    let p = parents[rng.below(parents.len())];
                    let depth = t.nodes[p].depth + 1;
                    let rank = t.nodes.iter().filter(|n| n.parent == p).count();
                    t.nodes.push(super::TreeNode { parent: p, depth, rank, prompt_len: 0 });
                }
                // uniform chains within budget
                let per = (n_prompt_budget / (n_candidates + 1)).max(1).min(m);
                for n in t.nodes.iter_mut() {
                    n.prompt_len = per;
                }
                t.nodes[0].prompt_len = m;
            }
            t.validate()?;
            trees.push(t);
        }
        Self::finish(trees, stats, m, n_candidates, n_prompt_budget)
    }

    fn finish(
        trees: Vec<SparseTree>,
        stats: &AcceptStats,
        m: usize,
        n_candidates: usize,
        n_prompt_budget: usize,
    ) -> Result<DynamicTreeSet> {
        let f: Vec<f64> = trees.iter().map(|t| expected_accepted(t, stats)).collect();
        let transition: Vec<Vec<f64>> =
            trees.iter().map(|t| transition_row(t, stats, m)).collect();
        let steady = steady_state(&transition);
        let r_value: f64 = steady.iter().zip(&f).map(|(p, f)| p * f).sum();
        let layouts = trees.iter().map(|t| t.layout()).collect();
        Ok(DynamicTreeSet {
            trees,
            layouts,
            f,
            transition,
            steady,
            r_value,
            n_candidates,
            n_prompt_budget,
        })
    }

    /// Amortized acceptance length τ = 1 bonus token + R (Prop 4.4).
    pub fn tau(&self) -> f64 {
        1.0 + self.r_value
    }

    /// Expected input length across states (weighted by steady state).
    pub fn expected_input_len(&self) -> f64 {
        self.steady
            .iter()
            .zip(&self.trees)
            .map(|(p, t)| p * t.input_len() as f64)
            .sum()
    }

    /// Largest input length over states (the bucket serving must fit).
    pub fn max_input_len(&self) -> usize {
        self.trees.iter().map(|t| t.input_len()).max().unwrap_or(1)
    }

    /// Tree-size tuple like the paper's S_tr column.
    pub fn size_tuple(&self) -> Vec<usize> {
        self.trees.iter().skip(1).map(|t| t.nodes.len() + t.n_prompt() - 1).collect()
    }
}

/// P(verification stops at node v) for every node, under the
/// independence approximation: pathprob(v) × (1 − Σ_children p(child)).
pub fn stop_probs(tree: &SparseTree, stats: &AcceptStats) -> Vec<f64> {
    let probs = path_probs(tree, stats);
    let mut child_mass = vec![0.0; tree.nodes.len()];
    for n in tree.nodes.iter().skip(1) {
        child_mass[n.parent] += stats.p(n.depth, n.rank);
    }
    probs
        .iter()
        .zip(&child_mass)
        .map(|(&p, &c)| p * (1.0 - c.min(1.0)))
        .collect()
}

/// Transition row for state k: p(s_i | s_k) = Σ over nodes whose chain
/// length is i of P(stop at node).
fn transition_row(tree: &SparseTree, stats: &AcceptStats, m: usize) -> Vec<f64> {
    let stops = stop_probs(tree, stats);
    let mut row = vec![0.0; m + 1];
    for (node, &p) in tree.nodes.iter().zip(&stops) {
        row[node.prompt_len.min(m)] += p;
    }
    // normalize (safety against truncation error)
    let s: f64 = row.iter().sum();
    if s > 0.0 {
        for x in &mut row {
            *x /= s;
        }
    }
    row
}

/// Power iteration for the steady state of a row-stochastic matrix.
pub fn steady_state(transition: &[Vec<f64>]) -> Vec<f64> {
    let n = transition.len();
    let mut pi = vec![1.0 / n as f64; n];
    for _ in 0..200 {
        let mut next = vec![0.0; n];
        for (k, row) in transition.iter().enumerate() {
            for (i, &p) in row.iter().enumerate() {
                next[i] += pi[k] * p;
            }
        }
        let s: f64 = next.iter().sum();
        for x in &mut next {
            *x /= s.max(1e-12);
        }
        let delta: f64 = next.iter().zip(&pi).map(|(a, b)| (a - b).abs()).sum();
        pi = next;
        if delta < 1e-12 {
            break;
        }
    }
    pi
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats() -> AcceptStats {
        AcceptStats::synthetic(3, 0.6, 0.45, 0.7)
    }

    #[test]
    fn builds_all_states() {
        let set = DynamicTreeSet::build(&stats(), 3, 10, 16, 10).unwrap();
        assert_eq!(set.trees.len(), 4);
        assert_eq!(set.trees[0].n_candidates(), 0);
        assert_eq!(set.trees[3].n_candidates(), 10);
        assert!(set.trees[3].n_prompt() <= 16);
        assert!(set.tau() > 1.0);
    }

    #[test]
    fn f_monotone_in_state_depth() {
        let set = DynamicTreeSet::build(&stats(), 3, 10, 16, 10).unwrap();
        assert_eq!(set.f[0], 0.0);
        assert!(set.f[1] <= set.f[2] + 1e-9);
        assert!(set.f[2] <= set.f[3] + 1e-9);
    }

    #[test]
    fn transition_rows_stochastic() {
        let set = DynamicTreeSet::build(&stats(), 3, 10, 16, 10).unwrap();
        for row in &set.transition {
            let s: f64 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "{row:?}");
            assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn steady_state_fixed_point() {
        let t = vec![vec![0.9, 0.1], vec![0.5, 0.5]];
        let pi = steady_state(&t);
        // analytic: pi0 = 5/6
        assert!((pi[0] - 5.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn stop_probs_sum_to_one() {
        let s = stats();
        let t = build_candidate_tree(&s, 3, 10, 10);
        let stops = stop_probs(&t, &s);
        let total: f64 = stops.iter().sum();
        assert!((total - 1.0).abs() < 0.2, "{total}"); // approx (independence)
        // the root retains the no-child-accepted mass
        assert!(stops[0] > 0.01);
    }

    #[test]
    fn dynamic_beats_static_at_same_budget() {
        // The Fig 8a claim: at the same total tree size, dynamic trees
        // achieve a higher amortized value.
        let s = stats();
        let dyn_set = DynamicTreeSet::build(&s, 3, 12, 20, 10).unwrap();
        let total = dyn_set.size_tuple().iter().max().copied().unwrap();
        let static_set = DynamicTreeSet::build_static(&s, 3, total, 10).unwrap();
        assert!(
            dyn_set.tau() >= static_set.tau() - 1e-9,
            "dyn {} vs static {}",
            dyn_set.tau(),
            static_set.tau()
        );
    }

    #[test]
    fn random_tree_is_worse() {
        let s = stats();
        let mut rng = crate::util::rng::Rng::new(1);
        let dyn_set = DynamicTreeSet::build(&s, 3, 12, 20, 10).unwrap();
        let rand_set = DynamicTreeSet::build_random(&s, 3, 12, 20, &mut rng).unwrap();
        assert!(dyn_set.tau() >= rand_set.tau());
    }

    #[test]
    fn size_tuple_matches_trees() {
        let set = DynamicTreeSet::build(&stats(), 3, 8, 12, 10).unwrap();
        let tup = set.size_tuple();
        assert_eq!(tup.len(), 3);
        assert_eq!(tup[2], set.trees[3].nodes.len() + set.trees[3].n_prompt() - 1);
    }
}
