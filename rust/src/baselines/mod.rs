//! Baseline accounting that needs no runtime: the Fig 7 / Table 1 memory
//! and parameter models for PPD vs Medusa heads vs an Eagle-style draft
//! network.

pub mod memory;
