//! Memory/parameter accounting (paper Fig 7 + Table 1's P_tr column).
//!
//! * **PPD** — extra state is just the prompt-token embeddings:
//!   `n_prompt · n_ept · d` floats.
//! * **Medusa** — K decoding heads, each a d×d resblock + a d×V LM head
//!   (the LM heads dominate and scale with vocab; in the paper's models
//!   V = 32000 which is why Medusa's overhead is ~GBs).
//! * **Eagle** — a one-layer transformer draft head: attention (4 d²) +
//!   MLP (3 d·d_mlp) + embeddings/head (2 d·V).
//!
//! We report both the *measured* overhead of our artifacts (what this
//! repo actually allocates) and the *projected* overhead at Vicuna-7B
//! scale (d=4096, V=32000) to reproduce the paper's memory figure shape.

use crate::config::ModelConfig;

#[derive(Debug, Clone)]
pub struct MemoryRow {
    pub method: &'static str,
    pub extra_params: usize,
    pub extra_bytes_f32: usize,
    pub fraction_of_base: f64,
}

/// PPD overhead for a model config (1 EPT at inference, like the paper).
pub fn ppd_overhead(cfg: &ModelConfig, base_params: usize) -> MemoryRow {
    let p = cfg.n_prompt * cfg.d_model;
    row("ppd", p, base_params)
}

/// Medusa overhead: K heads of (d² resblock + d·V LM head).
pub fn medusa_overhead(cfg: &ModelConfig, base_params: usize, k: usize) -> MemoryRow {
    let p = k * (cfg.d_model * cfg.d_model + cfg.d_model * cfg.vocab);
    row("medusa", p, base_params)
}

/// Eagle-style overhead: 1-layer decoder + embedding/LM tables.
pub fn eagle_overhead(cfg: &ModelConfig, base_params: usize) -> MemoryRow {
    let d = cfg.d_model;
    let p = 4 * d * d + 3 * d * cfg.d_mlp + 2 * d * cfg.vocab;
    row("eagle", p, base_params)
}

/// Paper-scale projection (Vicuna-7B-like dims) — reproduces the Fig 7
/// ratios independent of our tiny testbed.
pub fn paper_scale_rows() -> Vec<MemoryRow> {
    let d = 4096usize;
    let v = 32000usize;
    let d_mlp = 11008usize;
    let base = 6_700_000_000usize; // ~6.7B params
    vec![
        row("ppd", 3 * d, base),
        row("medusa", 3 * (d * d + d * v), base),
        row("eagle", 4 * d * d + 3 * d * d_mlp + 2 * d * v, base),
    ]
}

fn row(method: &'static str, extra_params: usize, base_params: usize) -> MemoryRow {
    MemoryRow {
        method,
        extra_params,
        extra_bytes_f32: extra_params * 4,
        fraction_of_base: extra_params as f64 / base_params as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 128,
            d_model: 160,
            n_layers: 4,
            n_heads: 4,
            d_head: 40,
            d_mlp: 432,
            max_ctx: 512,
            n_prompt: 3,
            rope_theta: 1e4,
            buckets: vec![1],
            trained: true,
            medusa: true,
            param_count: 2_000_000,
            prompt_param_count: 480,
        }
    }

    #[test]
    fn ordering_matches_paper() {
        let c = cfg();
        let ppd = ppd_overhead(&c, c.param_count);
        let med = medusa_overhead(&c, c.param_count, 3);
        let eag = eagle_overhead(&c, c.param_count);
        assert!(ppd.extra_params < med.extra_params);
        assert!(med.extra_params < eag.extra_params);
        assert!(ppd.fraction_of_base < 1e-3);
    }

    #[test]
    fn paper_scale_ratios() {
        let rows = paper_scale_rows();
        let ppd = &rows[0];
        let med = &rows[1];
        let eag = &rows[2];
        // paper: PPD is ~0.004% of Medusa's and ~0.007% of Eagle's size
        assert!((ppd.extra_params as f64 / med.extra_params as f64) < 1e-3);
        assert!((ppd.extra_params as f64 / eag.extra_params as f64) < 1e-3);
        // PPD headline: ~0.0002% trainable params
        assert!(ppd.fraction_of_base < 1e-5);
    }
}
