//! Stub PJRT backend used when the `xla` feature is off (the bindings
//! crate and the XLA extension libraries are not in the offline vendor
//! set).  It mirrors the slice of the `xla` crate API the runtime uses:
//!
//! * [`Literal`] is **functional** — it is just a typed host buffer, so
//!   the literal helpers (and their unit tests) behave identically with
//!   or without the real backend;
//! * the PJRT types ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`PjRtBuffer`], [`HloModuleProto`], [`XlaComputation`]) compile but
//!   fail at *client construction* with a clear "build with `--features
//!   xla`" error, so `Runtime::load` returns an error before any
//!   artifact I/O and the artifact-gated tests/benches skip cleanly.

use std::path::Path;

/// Error type standing in for `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT backend not compiled in; rebuild with `--features xla`".to_string(),
    ))
}

/// Element types the artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        4
    }
}

/// A typed host buffer with a shape — the only part of the stub that is
/// fully functional (literal construction/extraction is pure host work).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

/// Native types extractable from a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n * ty.byte_size() != data.len() {
            return Err(Error(format!(
                "literal shape {dims:?} wants {} bytes, got {}",
                n * ty.byte_size(),
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error(format!("literal is {:?}, not {:?}", self.ty, T::TY)));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple3(self) -> Result<(Literal, Literal, Literal)> {
        unavailable()
    }
}

/// Parsed HLO module (stub: load fails, executables can never exist).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_is_functional() {
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes).unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_rejects_shape_mismatch() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 8])
            .is_err());
    }

    #[test]
    fn pjrt_construction_fails_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("--features xla"), "{err}");
    }
}
