//! Literal construction/extraction helpers over the `xla` crate.

use anyhow::{bail, Result};
#[cfg(feature = "xla")]
use xla::{ElementType, Literal};

#[cfg(not(feature = "xla"))]
use super::stub::{ElementType, Literal};

/// f32 literal with an arbitrary shape.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_f32: {} elements for shape {:?}", data.len(), dims);
    }
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        dims,
        bytes,
    )?)
}

/// i32 literal with an arbitrary shape.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        bail!("lit_i32: {} elements for shape {:?}", data.len(), dims);
    }
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        dims,
        bytes,
    )?)
}

/// u32 token ids -> i32 literal (the graphs take i32).
pub fn lit_tokens(tokens: &[u32], dims: &[usize]) -> Result<Literal> {
    let as_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
    lit_i32(&as_i32, dims)
}

/// Extract a Vec<f32> from a literal.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(to_f32_vec(&lit).unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1], &[2]).is_err());
    }

    #[test]
    fn token_literal() {
        let lit = lit_tokens(&[0, 127, 130], &[3]).unwrap();
        assert_eq!(lit.element_count(), 3);
    }
}
