//! PJRT runtime: loads the AOT'd HLO-text forward graphs, keeps the
//! model weights device-resident, and exposes a bucketed `forward` the
//! decode engines call on the hot path.
//!
//! Design (DESIGN.md §3): PJRT returns multi-output results as a single
//! *tuple* buffer (no device-side untuple in the `xla` crate), so the
//! executables return only the small per-step tensors
//! `(logits [n,V], hidden [n,d], new_kv [2L,n,d])` while the
//! authoritative KV cache lives host-side (`kvcache::HostKvCache`) and is
//! uploaded as an input buffer each step.  Weights are uploaded once.

pub mod calibrate;
pub mod literal;
pub mod stub;
pub mod weights;

use std::cell::RefCell;
use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};
#[cfg(feature = "xla")]
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

#[cfg(not(feature = "xla"))]
use stub::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::{ArtifactPaths, ModelConfig};
use crate::util::json::Json;
use literal::{lit_f32, lit_i32, to_f32_vec};
use weights::Weights;

pub const NEG_INF: f32 = -1e9;

/// Per-step output of one forward call, truncated to the real (unpadded)
/// token count `n`.
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub n: usize,
    /// [n * vocab]
    pub logits: Vec<f32>,
    /// [n * d_model]
    pub hidden: Vec<f32>,
    /// [2L * n * d_model] — row-major (layer-kv, token, feature)
    pub new_kv: Vec<f32>,
}

impl StepOutput {
    pub fn logits_row(&self, i: usize, vocab: usize) -> &[f32] {
        &self.logits[i * vocab..(i + 1) * vocab]
    }

    pub fn hidden_row(&self, i: usize, d: usize) -> &[f32] {
        &self.hidden[i * d..(i + 1) * d]
    }
}

/// Execution counters (perf pass + metrics).
///
/// `forwards` counts *device calls*: a fused `forward_batch` over k
/// sequences that hits a batched executable bumps it by 1 (that is the
/// whole point), while its serial fallback bumps it k times.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub forwards: usize,
    pub forward_s: f64,
    pub upload_s: f64,
    pub download_s: f64,
    /// device calls + time keyed by `(tree_len_bucket, kv_context)`:
    /// a short-KV variant (`fwd_n{N}_s{kv}` / `fwd_b{B}_n{N}_s{kv}`)
    /// gets its own line instead of being aggregated into the full-ctx
    /// bucket, so the scrape shows which contexts actually executed
    pub per_bucket: BTreeMap<(usize, usize), (usize, f64)>,
    /// device calls per selected KV context (the kv-bucketing win:
    /// counts move from the full-ctx key to the short buckets)
    pub per_kv: BTreeMap<usize, usize>,
    /// batched (`fwd_b{B}_n{N}[_s{kv}]`) executions per selected KV
    /// context — split out from `per_kv` so "did the BATCHED short-KV
    /// graphs engage" is answerable without guessing which single-
    /// sequence forwards (prefill chunks) contributed which counts
    pub batch_per_kv: BTreeMap<usize, usize>,
    /// `forward_batch` invocations (fused or fallen back)
    pub forward_batches: usize,
    /// sequences served through `forward_batch`
    pub batch_rows: usize,
    /// batch-size histogram of `forward_batch` calls
    pub per_batch: BTreeMap<usize, usize>,
    /// fused rows attributed to the worker that planned them.  In the
    /// worker-owned-runtime topology each worker flushes its own rows
    /// under its own id; under `--shared-runtime` the device dispatcher
    /// attributes every row of every cross-worker batch to its
    /// submitting scheduler, so the post-drain aggregate still answers
    /// "who drove the device".
    pub rows_by_worker: BTreeMap<usize, usize>,
}

impl RuntimeStats {
    pub fn absorb(&mut self, other: &RuntimeStats) {
        self.forwards += other.forwards;
        self.forward_s += other.forward_s;
        self.upload_s += other.upload_s;
        self.download_s += other.download_s;
        for (&b, &(c, s)) in &other.per_bucket {
            let e = self.per_bucket.entry(b).or_insert((0, 0.0));
            e.0 += c;
            e.1 += s;
        }
        for (&kv, &c) in &other.per_kv {
            *self.per_kv.entry(kv).or_insert(0) += c;
        }
        for (&kv, &c) in &other.batch_per_kv {
            *self.batch_per_kv.entry(kv).or_insert(0) += c;
        }
        self.forward_batches += other.forward_batches;
        self.batch_rows += other.batch_rows;
        for (&b, &c) in &other.per_batch {
            *self.per_batch.entry(b).or_insert(0) += c;
        }
        for (&w, &r) in &other.rows_by_worker {
            *self.rows_by_worker.entry(w).or_insert(0) += r;
        }
    }

    /// Mean sequences per `forward_batch` call — the amortization
    /// factor fused stepping achieved (0 when it never ran).
    pub fn mean_batch_rows(&self) -> f64 {
        if self.forward_batches == 0 {
            0.0
        } else {
            self.batch_rows as f64 / self.forward_batches as f64
        }
    }
}

pub struct Runtime {
    pub cfg: ModelConfig,
    client: PjRtClient,
    executables: BTreeMap<(usize, usize), PjRtLoadedExecutable>,
    /// batched forward graphs present in the artifact set, keyed
    /// `(batch, tree_len, kv_context)` — full-context graphs under
    /// `kv = max_ctx`, short-KV variants (`fwd_b{B}_n{N}_s{kv}`) under
    /// their truncated context (empty on pre-v2 artifacts).  Compiled
    /// **lazily** on first `forward_batch` use: most runtime users
    /// (generate, calibrate, benches, unfused serving) never fuse, and
    /// on a real backend each compile costs seconds of startup.
    batch_graphs: BTreeMap<(usize, usize, usize), std::path::PathBuf>,
    batch_executables: RefCell<BTreeMap<(usize, usize, usize), PjRtLoadedExecutable>>,
    /// available KV context lengths, ascending (e.g. [256, 512])
    kv_buckets: Vec<usize>,
    weight_bufs: Vec<PjRtBuffer>,
    /// PJRT's buffer_from_host_literal is asynchronous/zero-copy: the
    /// source literal MUST outlive the device buffer, so the weight
    /// literals are retained for the runtime's lifetime.
    _weight_lits: Vec<Literal>,
    pub weights_host: Weights,
    medusa: Option<MedusaRuntime>,
    pub stats: RefCell<RuntimeStats>,
    /// reusable padded-input scratch (perf: no per-step allocation)
    scratch: RefCell<Scratch>,
}

struct MedusaRuntime {
    exe: PjRtLoadedExecutable,
    bufs: Vec<PjRtBuffer>,
    _lits: Vec<Literal>,
    n_heads: usize,
}

#[derive(Default)]
struct Scratch {
    tokens: Vec<i32>,
    pos: Vec<i32>,
    slots: Vec<i32>,
    bias: Vec<f32>,
    cache: Vec<f32>,
}

/// Perf toggles for the EXPERIMENTS.md §Perf A/B runs.
fn upload_via_literal() -> bool {
    std::env::var("PPD_UPLOAD_VIA_LITERAL").is_ok()
}

/// Process-wide override for `PPD_DISABLE_KV_BUCKETS`: 0 = follow the
/// env var, 1 = force-disable, 2 = force-enable.  Tests A/B the toggle
/// through [`set_kv_buckets_disabled`] instead of `std::env::set_var` —
/// mutating the environment while worker threads `getenv` on every
/// forward is undefined behavior on glibc.
static KV_DISABLE_OVERRIDE: std::sync::atomic::AtomicU8 = std::sync::atomic::AtomicU8::new(0);

/// Force KV-length bucketing off (`Some(true)`), on (`Some(false)`), or
/// back under `PPD_DISABLE_KV_BUCKETS` control (`None`).
pub fn set_kv_buckets_disabled(force: Option<bool>) {
    let v = match force {
        None => 0,
        Some(true) => 1,
        Some(false) => 2,
    };
    KV_DISABLE_OVERRIDE.store(v, std::sync::atomic::Ordering::Relaxed);
}

fn kv_buckets_disabled() -> bool {
    match KV_DISABLE_OVERRIDE.load(std::sync::atomic::Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => std::env::var("PPD_DISABLE_KV_BUCKETS").is_ok(),
    }
}

impl Runtime {
    /// Load every bucket executable + weights for one model.
    pub fn load(paths: &ArtifactPaths) -> Result<Self> {
        let cfg = ModelConfig::load(&paths.model_dir())?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;

        let mut executables = BTreeMap::new();
        let mut kv_buckets = vec![cfg.max_ctx];
        for &b in &cfg.buckets {
            let path = paths.fwd_hlo(b);
            let proto = HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling bucket {b}: {e}"))?;
            executables.insert((b, cfg.max_ctx), exe);
            // optional short-context variants (perf: KV-length bucketing)
            for &kb in cfg.kv_buckets.iter().filter(|&&kb| kb < cfg.max_ctx) {
                let p = paths.fwd_hlo_kv(b, kb);
                if p.exists() {
                    let proto = HloModuleProto::from_text_file(&p)
                        .map_err(|e| anyhow!("loading {}: {e}", p.display()))?;
                    let exe = client
                        .compile(&XlaComputation::from_proto(&proto))
                        .map_err(|e| anyhow!("compiling bucket ({b},{kb}): {e}"))?;
                    executables.insert((b, kb), exe);
                    if !kv_buckets.contains(&kb) {
                        kv_buckets.push(kb);
                    }
                }
            }
        }
        kv_buckets.sort_unstable();

        // batched forward graphs (fused step execution): record which
        // (batch, tree_len, kv) combinations the AOT step emitted, but
        // defer compilation to first use — cheap stat calls here
        let mut batch_graphs = BTreeMap::new();
        for &b in cfg.batch_buckets.iter().filter(|&&b| b > 1) {
            for &n in &cfg.buckets {
                let p = paths.fwd_hlo_batch(b, n);
                if p.exists() {
                    batch_graphs.insert((b, n, cfg.max_ctx), p);
                    // short-KV variants of the batched graph: the fused
                    // tick's stacked cache-union upload shrinks to
                    // [B, 2L, kv, d] when the union fits
                    for &kb in cfg.kv_buckets.iter().filter(|&&kb| kb < cfg.max_ctx) {
                        let pk = paths.fwd_hlo_batch_kv(b, n, kb);
                        if pk.exists() {
                            batch_graphs.insert((b, n, kb), pk);
                        }
                    }
                }
            }
        }

        let weights_host = Weights::load(&paths.weights_bin(), &paths.weights_manifest())?;
        let mut weight_bufs = Vec::with_capacity(weights_host.entries.len());
        let mut weight_lits = Vec::with_capacity(weights_host.entries.len());
        for e in &weights_host.entries {
            let lit = lit_f32(weights_host.slice(e), &e.shape)?;
            weight_bufs.push(
                client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e2| anyhow!("uploading weight {}: {e2}", e.name))?,
            );
            weight_lits.push(lit); // keep alive: async host->device copy
        }

        let medusa = if cfg.medusa && paths.medusa_hlo().exists() {
            Some(Self::load_medusa(&client, paths)?)
        } else {
            None
        };

        Ok(Runtime {
            cfg,
            client,
            executables,
            batch_graphs,
            batch_executables: RefCell::new(BTreeMap::new()),
            kv_buckets,
            weight_bufs,
            _weight_lits: weight_lits,
            weights_host,
            medusa,
            stats: RefCell::new(RuntimeStats::default()),
            scratch: RefCell::new(Scratch::default()),
        })
    }

    fn load_medusa(client: &PjRtClient, paths: &ArtifactPaths) -> Result<MedusaRuntime> {
        let proto = HloModuleProto::from_text_file(&paths.medusa_hlo())
            .map_err(|e| anyhow!("loading medusa hlo: {e}"))?;
        let exe = client
            .compile(&XlaComputation::from_proto(&proto))
            .map_err(|e| anyhow!("compiling medusa heads: {e}"))?;
        let (bin, man) = paths.medusa_weights();
        let w = Weights::load(&bin, &man)?;
        let mut bufs = Vec::new();
        let mut lits = Vec::new();
        let mut n_heads = 3;
        for e in &w.entries {
            if e.name == "wk" {
                n_heads = e.shape[0];
            }
            let lit = lit_f32(w.slice(e), &e.shape)?;
            bufs.push(
                client
                    .buffer_from_host_literal(None, &lit)
                    .map_err(|e2| anyhow!("uploading medusa weight: {e2}"))?,
            );
            lits.push(lit);
        }
        Ok(MedusaRuntime { exe, bufs, _lits: lits, n_heads })
    }

    pub fn has_medusa(&self) -> bool {
        self.medusa.is_some()
    }

    pub fn medusa_n_heads(&self) -> usize {
        self.medusa.as_ref().map(|m| m.n_heads).unwrap_or(0)
    }

    /// One forward step over `n` tree tokens.
    ///
    /// * `tokens` — token ids (prompt tokens are `PROMPT_ID0 + k`)
    /// * `pos`    — RoPE positions
    /// * `slots`  — cache write rows (the KV of token i lands in slot i)
    /// * `bias`   — `[n, max_ctx]` additive visibility mask
    /// * `cache`  — host cache snapshot `[2L, max_ctx, d]`
    ///
    /// Padding to the bucket size happens here: pad tokens are masked
    /// everywhere and their KV is routed to the reserved trash slot
    /// (`max_ctx - 1`), which generation never reaches (the kv-cache
    /// manager caps usable context at `max_ctx - 2`).
    pub fn forward(
        &self,
        tokens: &[u32],
        pos: &[u32],
        slots: &[u32],
        bias: &[f32],
        cache: &[f32],
    ) -> Result<StepOutput> {
        let n = tokens.len();
        let s = self.cfg.max_ctx;
        let d = self.cfg.d_model;
        let l2 = 2 * self.cfg.n_layers;
        if pos.len() != n || slots.len() != n {
            bail!("forward: inconsistent input lengths");
        }
        if bias.len() != n * s {
            bail!("forward: bias is {} values, want {}", bias.len(), n * s);
        }
        if cache.len() != l2 * s * d {
            bail!("forward: cache is {} values, want {}", cache.len(), l2 * s * d);
        }
        let bucket = self.cfg.bucket_for(n)?;
        // KV-length bucketing (perf pass, EXPERIMENTS.md §Perf): pick the
        // smallest compiled context length that covers every referenced
        // slot — halves the cache upload AND the attention compute for
        // short contexts.
        let max_slot = slots.iter().copied().max().unwrap_or(0) as usize;
        let s_sel = crate::batch::select_kv_bucket(
            &self.kv_buckets,
            s,
            max_slot,
            kv_buckets_disabled(),
            |kb| self.executables.contains_key(&(bucket, kb)),
        );
        let exe = self
            .executables
            .get(&(bucket, s_sel))
            .ok_or_else(|| anyhow!("bucket ({bucket},{s_sel}) not loaded"))?;

        let t0 = std::time::Instant::now();
        // pad inputs into the reusable scratch
        let mut sc = self.scratch.borrow_mut();
        sc.tokens.clear();
        sc.tokens.extend(tokens.iter().map(|&t| t as i32));
        sc.tokens.resize(bucket, 0);
        sc.pos.clear();
        sc.pos.extend(pos.iter().map(|&p| p as i32));
        sc.pos.resize(bucket, 0);
        sc.slots.clear();
        sc.slots.extend(slots.iter().map(|&p| p as i32));
        sc.slots.resize(bucket, (s_sel - 1) as i32); // trash slot
        // bias rows truncated to the selected context length
        sc.bias.clear();
        sc.bias.reserve(bucket * s_sel);
        for r in 0..n {
            sc.bias.extend_from_slice(&bias[r * s..r * s + s_sel]);
        }
        sc.bias.resize(bucket * s_sel, NEG_INF);
        // cache planes truncated to the selected context length
        let cache_view: &[f32] = if s_sel == s {
            cache
        } else {
            sc.cache.clear();
            sc.cache.reserve(l2 * s_sel * d);
            for p in 0..l2 {
                let base = p * s * d;
                sc.cache.extend_from_slice(&cache[base..base + s_sel * d]);
            }
            &[]
        };

        let mut bufs: Vec<PjRtBuffer> = Vec::with_capacity(5);
        let mut lits: Vec<Literal> = Vec::new();
        if upload_via_literal() {
            // baseline path (pre-optimization): literal + async upload
            let cache_src = if s_sel == s { cache } else { &sc.cache };
            for lit in [
                lit_i32(&sc.tokens, &[bucket])?,
                lit_i32(&sc.pos, &[bucket])?,
                lit_i32(&sc.slots, &[bucket])?,
                lit_f32(&sc.bias, &[bucket, s_sel])?,
                lit_f32(cache_src, &[l2, s_sel, d])?,
            ] {
                bufs.push(
                    self.client
                        .buffer_from_host_literal(None, &lit)
                        .map_err(|e| anyhow!("uploading step input: {e}"))?,
                );
                lits.push(lit);
            }
        } else {
            // optimized path: direct host-buffer upload, no literal copy
            let cache_src = if s_sel == s { cache } else { &sc.cache };
            bufs.push(self.client.buffer_from_host_buffer(&sc.tokens, &[bucket], None).map_err(|e| anyhow!("{e}"))?);
            bufs.push(self.client.buffer_from_host_buffer(&sc.pos, &[bucket], None).map_err(|e| anyhow!("{e}"))?);
            bufs.push(self.client.buffer_from_host_buffer(&sc.slots, &[bucket], None).map_err(|e| anyhow!("{e}"))?);
            bufs.push(self.client.buffer_from_host_buffer(&sc.bias, &[bucket, s_sel], None).map_err(|e| anyhow!("{e}"))?);
            bufs.push(self.client.buffer_from_host_buffer(cache_src, &[l2, s_sel, d], None).map_err(|e| anyhow!("{e}"))?);
        }
        let _ = cache_view;
        let upload_s = t0.elapsed().as_secs_f64();

        let mut args: Vec<&PjRtBuffer> = bufs.iter().collect();
        args.extend(self.weight_bufs.iter());

        let t1 = std::time::Instant::now();
        let outs = exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("forward bucket {bucket}: {e}"))?;
        let result = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching step output: {e}"))?;
        let exec_s = t1.elapsed().as_secs_f64();

        let t2 = std::time::Instant::now();
        let (l_logits, l_hidden, l_kv) = result
            .to_tuple3()
            .map_err(|e| anyhow!("untupling step output: {e}"))?;
        let logits_full = to_f32_vec(&l_logits)?;
        let hidden_full = to_f32_vec(&l_hidden)?;
        let kv_full = to_f32_vec(&l_kv)?;
        let vocab = self.cfg.vocab;
        let mut new_kv = Vec::with_capacity(l2 * n * d);
        for layer in 0..l2 {
            let base = layer * bucket * d;
            new_kv.extend_from_slice(&kv_full[base..base + n * d]);
        }
        let out = StepOutput {
            n,
            logits: logits_full[..n * vocab].to_vec(),
            hidden: hidden_full[..n * d].to_vec(),
            new_kv,
        };
        let download_s = t2.elapsed().as_secs_f64();

        let mut st = self.stats.borrow_mut();
        st.forwards += 1;
        st.forward_s += exec_s;
        st.upload_s += upload_s;
        st.download_s += download_s;
        let e = st.per_bucket.entry((bucket, s_sel)).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += exec_s + upload_s + download_s;
        *st.per_kv.entry(s_sel).or_insert(0) += 1;
        Ok(out)
    }

    /// One fused forward over many sequences' tree steps: the core of
    /// batched step execution (`--fuse-steps`).  `items[i]` pairs one
    /// sequence's planned step with a snapshot of its own KV cache;
    /// `results[i]` is that sequence's output, trimmed to its real row
    /// count — byte-compatible with calling [`Runtime::forward`] per
    /// item.
    ///
    /// Dispatch policy: pick the smallest `(batch, tree_len)` bucket
    /// covering the batch from the AOT'd `fwd_b{B}_n{N}` graphs, then
    /// the smallest KV context whose `_s{kv}` variant covers the
    /// union's max occupied slot (shrinking the stacked cache upload —
    /// the dominant transfer under `--shared-runtime`); when the
    /// artifact set carries no batched graph that fits (pre-v2
    /// artifacts, or an oversized batch), fall back to per-row
    /// `forward` calls — the scheduler stays correct, it just loses
    /// the dispatch amortization.  Stats record every call either way
    /// so the fallback is visible in `per_batch` vs `forwards`.
    pub fn forward_batch(
        &self,
        items: &[crate::batch::BatchItem<'_>],
    ) -> Result<Vec<StepOutput>> {
        self.forward_batch_meta(items).map(|(outs, _)| outs)
    }

    /// [`Runtime::forward_batch`] plus execution metadata (the selected
    /// KV bucket) — the device dispatcher records it so the kv win is
    /// visible live in the `ppd_dispatch_kv_bucket` scrape counters.
    pub fn forward_batch_meta(
        &self,
        items: &[crate::batch::BatchItem<'_>],
    ) -> Result<(Vec<StepOutput>, crate::batch::BatchMeta)> {
        let k = items.len();
        if k == 0 {
            return Ok((Vec::new(), crate::batch::BatchMeta::default()));
        }
        if k == 1 {
            self.note_batch_call(1);
            // a lone rider gets the plain single-sequence graph: the
            // smallest batched bucket is b=2, which would double the
            // cache upload (the dominant transfer) for no benefit —
            // the single-sequence path runs its own kv bucketing
            let it = &items[0];
            let out = self.forward(
                &it.plan.tokens,
                &it.plan.pos,
                &it.plan.slots,
                &it.plan.bias,
                &it.cache.device_snapshot(),
            )?;
            return Ok((vec![out], crate::batch::BatchMeta::default()));
        }
        let s = self.cfg.max_ctx;
        let d = self.cfg.d_model;
        let l2 = 2 * self.cfg.n_layers;
        let max_n = items.iter().map(|it| it.plan.len()).max().unwrap_or(0);
        let key = self.cfg.bucket_for(max_n).ok().and_then(|n_bucket| {
            crate::batch::select_batch_bucket(&self.cfg.batch_buckets, k, n_bucket, |b, n| {
                self.batch_graphs.contains_key(&(b, n, s))
            })
            .map(|b| (b, n_bucket))
        });
        let Some((b_bucket, n_bucket)) = key else {
            // serial fallback: no batched graph covers this batch
            self.note_batch_call(k);
            let outs = items
                .iter()
                .map(|it| {
                    self.forward(
                        &it.plan.tokens,
                        &it.plan.pos,
                        &it.plan.slots,
                        &it.plan.bias,
                        &it.cache.device_snapshot(),
                    )
                })
                .collect::<Result<Vec<_>>>()?;
            return Ok((outs, crate::batch::BatchMeta::default()));
        };
        // KV-length bucketing over the UNION: the smallest `_s{kv}`
        // variant covering the highest slot any rider references —
        // computed across the whole (cross-worker) batch before
        // collation, so one long rider keeps the full context while
        // all-short riders shrink every row's share of the upload.
        // Candidates come from the CONFIG ladder, not the loaded
        // single-sequence variants: a batched `_s{kv}` graph must stay
        // selectable even if its single-sequence sibling is missing
        // (the availability closure does the real per-graph check).
        let max_slot = crate::batch::union_max_slot(items);
        let s_sel = crate::batch::select_kv_bucket(
            &self.cfg.kv_buckets,
            s,
            max_slot,
            kv_buckets_disabled(),
            |kv| self.batch_graphs.contains_key(&(b_bucket, n_bucket, kv)),
        );
        let c = crate::batch::collator::collate(items, b_bucket, n_bucket, l2, s, d, s_sel)?;
        self.forward_collated(&c)
    }

    /// Batched-call accounting shared by every `forward_batch` entry
    /// path (fused, lone-rider, serial fallback, pre-collated).
    fn note_batch_call(&self, rows: usize) {
        let mut st = self.stats.borrow_mut();
        st.forward_batches += 1;
        st.batch_rows += rows;
        *st.per_batch.entry(rows).or_insert(0) += 1;
    }

    /// A `Send`-safe snapshot of the batched-graph inventory (ladders,
    /// available `(b, n, kv)` triples, dims), or `None` when the
    /// artifact set carries no batched graphs.  The device dispatcher's
    /// pipelined collector stage plans and collates round k+1's union
    /// against this while round k executes here.
    pub fn batch_inventory(&self) -> Option<crate::batch::BatchInventory> {
        if self.batch_graphs.is_empty() {
            return None;
        }
        Some(crate::batch::BatchInventory {
            tree_buckets: self.cfg.buckets.clone(),
            batch_buckets: self.cfg.batch_buckets.clone(),
            kv_buckets: self.cfg.kv_buckets.clone(),
            available: self.batch_graphs.keys().copied().collect(),
            planes: 2 * self.cfg.n_layers,
            max_ctx: self.cfg.max_ctx,
            d: self.cfg.d_model,
            kv_disabled: kv_buckets_disabled(),
        })
    }

    /// Execute an already-collated batch on its `(batch, n, kv)` bucket
    /// graph: the device half of [`Runtime::forward_batch_meta`], also
    /// reachable directly by the dispatcher when collation happened on
    /// its collector stage (pipelined mode).  Byte-identical outputs
    /// either way — both paths run the same collator and the same
    /// executable.
    pub fn forward_collated(
        &self,
        c: &crate::batch::collator::CollatedBatch,
    ) -> Result<(Vec<StepOutput>, crate::batch::BatchMeta)> {
        self.note_batch_call(c.rows);
        let (b_bucket, n_bucket, s_sel) = (c.batch, c.n, c.kv);
        let (l2, d) = (c.planes, c.d);
        if d != self.cfg.d_model || l2 != 2 * self.cfg.n_layers || c.max_ctx != self.cfg.max_ctx {
            bail!(
                "collated batch shaped for a different model: planes {l2} d {d} ctx {}",
                c.max_ctx
            );
        }
        // lazy compile: the first fused call for this bucket pays the
        // compile; everyone who never fuses pays nothing at load
        let mut exes = self.batch_executables.borrow_mut();
        if !exes.contains_key(&(b_bucket, n_bucket, s_sel)) {
            let p = self
                .batch_graphs
                .get(&(b_bucket, n_bucket, s_sel))
                .ok_or_else(|| anyhow!("no batched graph for ({b_bucket},{n_bucket},{s_sel})"))?;
            let proto = HloModuleProto::from_text_file(p)
                .map_err(|e| anyhow!("loading {}: {e}", p.display()))?;
            let exe = self
                .client
                .compile(&XlaComputation::from_proto(&proto))
                .map_err(|e| {
                    anyhow!("compiling batch bucket ({b_bucket},{n_bucket},{s_sel}): {e}")
                })?;
            exes.insert((b_bucket, n_bucket, s_sel), exe);
        }
        let exe = exes.get(&(b_bucket, n_bucket, s_sel)).expect("just compiled");

        let t0 = std::time::Instant::now();
        let mut bufs: Vec<PjRtBuffer> = Vec::with_capacity(5);
        bufs.push(
            self.client
                .buffer_from_host_buffer(&c.tokens, &[b_bucket, n_bucket], None)
                .map_err(|e| anyhow!("{e}"))?,
        );
        bufs.push(
            self.client
                .buffer_from_host_buffer(&c.pos, &[b_bucket, n_bucket], None)
                .map_err(|e| anyhow!("{e}"))?,
        );
        bufs.push(
            self.client
                .buffer_from_host_buffer(&c.slots, &[b_bucket, n_bucket], None)
                .map_err(|e| anyhow!("{e}"))?,
        );
        bufs.push(
            self.client
                .buffer_from_host_buffer(&c.bias, &[b_bucket, n_bucket, s_sel], None)
                .map_err(|e| anyhow!("{e}"))?,
        );
        bufs.push(
            self.client
                .buffer_from_host_buffer(&c.cache, &[b_bucket, l2, s_sel, d], None)
                .map_err(|e| anyhow!("{e}"))?,
        );
        let upload_s = t0.elapsed().as_secs_f64();

        let mut args: Vec<&PjRtBuffer> = bufs.iter().collect();
        args.extend(self.weight_bufs.iter());

        let t1 = std::time::Instant::now();
        let outs = exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("forward_batch bucket ({b_bucket},{n_bucket},{s_sel}): {e}"))?;
        let result = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching batched step output: {e}"))?;
        let exec_s = t1.elapsed().as_secs_f64();

        let t2 = std::time::Instant::now();
        let (l_logits, l_hidden, l_kv) = result
            .to_tuple3()
            .map_err(|e| anyhow!("untupling batched step output: {e}"))?;
        let logits = to_f32_vec(&l_logits)?;
        let hidden = to_f32_vec(&l_hidden)?;
        let kv = to_f32_vec(&l_kv)?;
        let split = crate::batch::collator::split(&c, &logits, &hidden, &kv, self.cfg.vocab)?;
        let download_s = t2.elapsed().as_secs_f64();

        let mut st = self.stats.borrow_mut();
        // one device call, however many sequences rode along
        st.forwards += 1;
        st.forward_s += exec_s;
        st.upload_s += upload_s;
        st.download_s += download_s;
        let e = st.per_bucket.entry((n_bucket, s_sel)).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += exec_s + upload_s + download_s;
        *st.per_kv.entry(s_sel).or_insert(0) += 1;
        *st.batch_per_kv.entry(s_sel).or_insert(0) += 1;
        Ok((split, crate::batch::BatchMeta { kv: Some(s_sel) }))
    }

    /// Batch buckets with at least one batched graph in the artifact
    /// set (compiled lazily on first fused use).
    pub fn batch_buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.batch_graphs.keys().map(|&(b, _, _)| b).collect();
        b.sort_unstable();
        b.dedup();
        b
    }

    /// KV contexts the batched graphs were additionally lowered at
    /// (ascending, full context included) — the artifact-gated tests
    /// use this to assert the `_s{kv}` variants shipped.
    pub fn batch_kv_buckets(&self) -> Vec<usize> {
        let mut kv: Vec<usize> = self.batch_graphs.keys().map(|&(_, _, kv)| kv).collect();
        kv.sort_unstable();
        kv.dedup();
        kv
    }

    /// Medusa-baseline heads: hidden row -> [K][vocab] logits.
    pub fn medusa_heads(&self, hidden: &[f32]) -> Result<Vec<Vec<f32>>> {
        let m = self
            .medusa
            .as_ref()
            .ok_or_else(|| anyhow!("model has no medusa heads artifact"))?;
        let d = self.cfg.d_model;
        if hidden.len() != d {
            bail!("medusa_heads: hidden len {} != d {}", hidden.len(), d);
        }
        let lit = lit_f32(hidden, &[d])?;
        let hb = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow!("uploading hidden: {e}"))?;
        let mut args: Vec<&PjRtBuffer> = vec![&hb];
        args.extend(m.bufs.iter());
        let outs = m
            .exe
            .execute_b::<&PjRtBuffer>(&args)
            .map_err(|e| anyhow!("medusa heads: {e}"))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching medusa output: {e}"))?;
        let flat = to_f32_vec(&lit.to_tuple1().map_err(|e| anyhow!("{e}"))?)?;
        let v = self.cfg.vocab;
        Ok(flat.chunks(v).map(|c| c.to_vec()).collect())
    }

    pub fn buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.executables.keys().map(|&(n, _)| n).collect();
        b.sort_unstable();
        b.dedup();
        b
    }

    pub fn kv_buckets(&self) -> &[usize] {
        &self.kv_buckets
    }

    pub fn take_stats(&self) -> RuntimeStats {
        std::mem::take(&mut *self.stats.borrow_mut())
    }
}

/// The device surface decode engines run against.
///
/// [`Runtime`] is the worker-owned implementation (each worker thread
/// owns a PJRT client — it is not `Send`).  Under `--shared-runtime`
/// the workers instead hold a [`crate::batch::dispatch::SharedRuntime`]
/// handle that round-trips every call through the single
/// `DeviceDispatcher`-owned runtime, which is what lets N schedulers
/// share one device queue.  Engines are written against `&dyn Device`
/// so the two topologies run the *same* decode code.
pub trait Device {
    /// Model + bucket metadata (shape math, bucket selection, vocab).
    fn cfg(&self) -> &ModelConfig;

    /// One forward step over `n` tree tokens (see [`Runtime::forward`]).
    fn forward(
        &self,
        tokens: &[u32],
        pos: &[u32],
        slots: &[u32],
        bias: &[f32],
        cache: &[f32],
    ) -> Result<StepOutput>;

    /// One fused forward over many sequences' planned steps (see
    /// [`Runtime::forward_batch`]).
    fn forward_batch(
        &self,
        items: &[crate::batch::BatchItem<'_>],
    ) -> Result<Vec<StepOutput>>;

    fn has_medusa(&self) -> bool {
        false
    }

    fn medusa_n_heads(&self) -> usize {
        0
    }

    fn medusa_heads(&self, _hidden: &[f32]) -> Result<Vec<Vec<f32>>> {
        bail!("device has no medusa heads")
    }
}

impl Device for Runtime {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn forward(
        &self,
        tokens: &[u32],
        pos: &[u32],
        slots: &[u32],
        bias: &[f32],
        cache: &[f32],
    ) -> Result<StepOutput> {
        Runtime::forward(self, tokens, pos, slots, bias, cache)
    }

    fn forward_batch(
        &self,
        items: &[crate::batch::BatchItem<'_>],
    ) -> Result<Vec<StepOutput>> {
        Runtime::forward_batch(self, items)
    }

    fn has_medusa(&self) -> bool {
        Runtime::has_medusa(self)
    }

    fn medusa_n_heads(&self) -> usize {
        Runtime::medusa_n_heads(self)
    }

    fn medusa_heads(&self, hidden: &[f32]) -> Result<Vec<Vec<f32>>> {
        Runtime::medusa_heads(self, hidden)
    }
}

/// Load the top-level artifacts manifest.
pub fn load_manifest(root: &std::path::Path) -> Result<Json> {
    Json::from_file(&root.join("manifest.json"))
        .context("artifacts/manifest.json missing — run `make artifacts`")
}
