//! Weight loading: `weights.bin` (flat f32 LE) + `weights.json` manifest,
//! in the exact parameter order the AOT'd HLO expects (the order contract
//! is `compile.model.weight_names` on the python side).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_f32: usize,
    pub len_f32: usize,
}

#[derive(Debug)]
pub struct Weights {
    pub entries: Vec<WeightEntry>,
    pub data: Vec<f32>,
}

impl Weights {
    pub fn load(bin: &Path, manifest: &Path) -> Result<Self> {
        let j = Json::from_file(manifest)?;
        let mut entries = Vec::new();
        for e in j.as_arr()? {
            entries.push(WeightEntry {
                name: e.req("name")?.as_str()?.to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                offset_f32: e.req("offset_f32")?.as_usize()?,
                len_f32: e.req("len_f32")?.as_usize()?,
            });
        }
        let bytes = std::fs::read(bin)
            .with_context(|| format!("reading {}", bin.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("weights.bin size {} not a multiple of 4", bytes.len());
        }
        let mut data = vec![0f32; bytes.len() / 4];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        let total: usize = entries.iter().map(|e| e.len_f32).sum();
        if total != data.len() {
            bail!("manifest covers {total} f32s but bin has {}", data.len());
        }
        // validate contiguity + shape/len agreement
        let mut off = 0;
        for e in &entries {
            if e.offset_f32 != off {
                bail!("non-contiguous weight '{}' at {}", e.name, e.offset_f32);
            }
            let prod: usize = e.shape.iter().product();
            if prod != e.len_f32 {
                bail!("weight '{}' shape {:?} != len {}", e.name, e.shape, e.len_f32);
            }
            off += e.len_f32;
        }
        Ok(Weights { entries, data })
    }

    pub fn slice(&self, e: &WeightEntry) -> &[f32] {
        &self.data[e.offset_f32..e.offset_f32 + e.len_f32]
    }

    pub fn by_name(&self, name: &str) -> Option<(&WeightEntry, &[f32])> {
        let e = self.entries.iter().find(|e| e.name == name)?;
        Some((e, self.slice(e)))
    }

    pub fn total_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) -> (std::path::PathBuf, std::path::PathBuf) {
        std::fs::create_dir_all(dir).unwrap();
        let bin = dir.join("w.bin");
        let man = dir.join("w.json");
        let data: Vec<f32> = (0..10).map(|x| x as f32).collect();
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&bin, bytes).unwrap();
        std::fs::write(
            &man,
            r#"[{"name":"a","shape":[2,3],"offset_f32":0,"len_f32":6},
               {"name":"b","shape":[4],"offset_f32":6,"len_f32":4}]"#,
        )
        .unwrap();
        (bin, man)
    }

    #[test]
    fn loads_and_slices() {
        let dir = std::env::temp_dir().join("ppd_w_test");
        let (bin, man) = write_fixture(&dir);
        let w = Weights::load(&bin, &man).unwrap();
        assert_eq!(w.entries.len(), 2);
        let (e, s) = w.by_name("b").unwrap();
        assert_eq!(e.shape, vec![4]);
        assert_eq!(s, &[6.0, 7.0, 8.0, 9.0]);
        assert_eq!(w.total_bytes(), 40);
    }

    #[test]
    fn rejects_bad_manifest() {
        let dir = std::env::temp_dir().join("ppd_w_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let bin = dir.join("w.bin");
        std::fs::write(&bin, [0u8; 8]).unwrap();
        let man = dir.join("w.json");
        // len mismatch with shape
        std::fs::write(
            &man,
            r#"[{"name":"a","shape":[3],"offset_f32":0,"len_f32":2}]"#,
        )
        .unwrap();
        assert!(Weights::load(&bin, &man).is_err());
    }
}
