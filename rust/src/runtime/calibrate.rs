//! Hardware calibration: measure the forward-pass latency `L_fp(n)` for
//! every AOT bucket on *this* machine.  This is the hardware-dependent
//! half of the paper's speedup model `Speedup(n) = tau(n) / L_fp(n)`
//! (§4.2 "Hardware-awareness"); the dynamic-sparse-tree sizer consumes it.
//!
//! Results are cached in `artifacts/<model>/calibration.json` so serving
//! starts fast; `ppd calibrate --force` re-measures.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::runtime::{Runtime, NEG_INF};
use crate::util::bench::bench;
use crate::util::json::Json;

/// Measured (or synthetic) per-bucket forward latency in seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// model name the measurement belongs to
    pub model: String,
    /// latency-envelope label ("cpu" = measured; others are emulated
    /// hardware profiles for the Fig 8 reproduction)
    pub envelope: String,
    pub latency_s: BTreeMap<usize, f64>,
}

impl Calibration {
    /// Measure every bucket with synthetic single-context inputs.
    pub fn measure(rt: &Runtime, warmup: usize, iters: usize) -> Result<Calibration> {
        let cfg = &rt.cfg;
        let s = cfg.max_ctx;
        let d = cfg.d_model;
        let cache = vec![0f32; 2 * cfg.n_layers * s * d];
        let mut latency_s = BTreeMap::new();
        for &b in &cfg.buckets {
            let tokens: Vec<u32> = (0..b).map(|i| 32 + (i as u32 % 64)) .collect();
            let pos: Vec<u32> = (0..b as u32).collect();
            let slots: Vec<u32> = (0..b as u32).collect();
            let mut bias = vec![NEG_INF; b * s];
            for i in 0..b {
                for j in 0..=i {
                    bias[i * s + j] = 0.0;
                }
            }
            let stats = bench(warmup, iters, || {
                rt.forward(&tokens, &pos, &slots, &bias, &cache).expect("calibration forward");
            });
            latency_s.insert(b, stats.median_s);
        }
        Ok(Calibration { model: cfg.name.clone(), envelope: "cpu".into(), latency_s })
    }

    /// Emulated latency envelope: scales the measured curve so that the
    /// *shape* differs — `alpha` is a fixed per-step overhead multiplier
    /// and `beta` an extra per-token cost.  "fast" hardware has high
    /// fixed overhead relative to per-token cost (big GPUs: kernel
    /// launch dominates, wide trees are nearly free); "slow" hardware is
    /// compute-bound (per-token cost dominates, wide trees hurt).  This
    /// reproduces the A100-vs-RTX4090 divergence of Fig 8b/8c.
    pub fn envelope(&self, label: &str, alpha: f64, beta_per_token_s: f64) -> Calibration {
        let base = self.latency_s.get(&1).copied().unwrap_or(1e-3);
        let latency_s = self
            .latency_s
            .iter()
            .map(|(&b, &l)| (b, alpha * base + (l - base).max(0.0) + beta_per_token_s * b as f64))
            .collect();
        Calibration { model: self.model.clone(), envelope: label.into(), latency_s }
    }

    /// Latency for an input of `n` tokens (bucket-quantized).
    pub fn lookup(&self, n: usize) -> Option<f64> {
        self.latency_s
            .iter()
            .filter(|(&b, _)| b >= n)
            .map(|(_, &l)| l)
            .next()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let entries: Vec<Json> = self
            .latency_s
            .iter()
            .map(|(&b, &l)| Json::obj(vec![("bucket", Json::Num(b as f64)), ("latency_s", Json::Num(l))]))
            .collect();
        Json::obj(vec![
            ("model", Json::str(&self.model)),
            ("envelope", Json::str(&self.envelope)),
            ("entries", Json::Arr(entries)),
        ])
        .write_file(path)
    }

    pub fn load(path: &Path) -> Result<Calibration> {
        let j = Json::from_file(path)?;
        let mut latency_s = BTreeMap::new();
        for e in j.req("entries")?.as_arr()? {
            latency_s.insert(e.req("bucket")?.as_usize()?, e.req("latency_s")?.as_f64()?);
        }
        Ok(Calibration {
            model: j.req("model")?.as_str()?.to_string(),
            envelope: j.req("envelope")?.as_str()?.to_string(),
            latency_s,
        })
    }

    /// Load if cached, else measure and cache.
    pub fn load_or_measure(rt: &Runtime, path: &Path, iters: usize) -> Result<Calibration> {
        if path.exists() {
            let c = Calibration::load(path)?;
            if c.model == rt.cfg.name {
                return Ok(c);
            }
        }
        let c = Calibration::measure(rt, 2, iters)?;
        c.save(path)?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic() -> Calibration {
        let mut latency_s = BTreeMap::new();
        for (b, l) in [(1, 1.0e-3), (8, 1.2e-3), (64, 3.0e-3)] {
            latency_s.insert(b, l);
        }
        Calibration { model: "t".into(), envelope: "cpu".into(), latency_s }
    }

    #[test]
    fn lookup_quantizes_up() {
        let c = synthetic();
        assert_eq!(c.lookup(1), Some(1.0e-3));
        assert_eq!(c.lookup(2), Some(1.2e-3));
        assert_eq!(c.lookup(9), Some(3.0e-3));
        assert_eq!(c.lookup(65), None);
    }

    #[test]
    fn save_load_roundtrip() {
        let c = synthetic();
        let p = std::env::temp_dir().join("ppd_cal_test.json");
        c.save(&p).unwrap();
        let c2 = Calibration::load(&p).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn envelope_changes_shape() {
        let c = synthetic();
        // slow envelope: heavy per-token cost -> larger buckets much worse
        let slow = c.envelope("slow", 1.0, 1e-4);
        let fast = c.envelope("fast", 4.0, 0.0);
        let ratio_slow = slow.lookup(64).unwrap() / slow.lookup(1).unwrap();
        let ratio_fast = fast.lookup(64).unwrap() / fast.lookup(1).unwrap();
        assert!(ratio_slow > ratio_fast);
    }
}
