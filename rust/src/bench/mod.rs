//! Deterministic scheduler throughput sweep — the CI `bench-smoke`
//! trajectory (`BENCH_sched.json`).
//!
//! The serving stack's perf claims (fused stepping cuts device calls,
//! the shared runtime fuses across workers) were only ever asserted as
//! *inequalities* in tests; nothing recorded the actual numbers, so a
//! regression that kept the inequality true but halved the win was
//! invisible.  This module runs the full coordinator (queue →
//! schedulers → pool → dispatcher) over a deterministic mock engine
//! with a fixed per-device-call latency, so the resulting tokens/s and
//! device-calls-per-token are a pure function of the *scheduling*
//! machinery — comparable run over run, machine over machine, without
//! model artifacts.
//!
//! The mock models the one cost that matters to the scheduler: each
//! device call (fused or not) costs `device_latency` wallclock.  Serial
//! stepping pays it per sequence per tick; fused stepping pays it once
//! per worker tick; the shared runtime pays it once per *wall* tick.
//! The sweep surfaces exactly that ladder.
//!
//! The [`SweepMode::Prefix`] point additionally runs the paged KV pool
//! (`--kv-blocks`) with a shared prompt preamble, and every point
//! reports `resident_kv_bytes`/`prefix_hits` so the memory half of the
//! paper's claim rides the same trajectory (carried by
//! `tools/bench_gate.py`, never gated — see `docs/ARCHITECTURE.md`).
//!
//! Used by `examples/bench_sched.rs`, which writes the JSON artifact CI
//! uploads on every run.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::batch::dispatch::DeviceExecutor;
use crate::batch::{BatchItem, BatchStepEngine, PlanInputs, StepPlan, StepResult};
use crate::coordinator::{
    serve_jobs, Coordinator, DeviceHost, Priority, QueueDiscipline, Request, SchedPolicy,
    WorkerBackend, WorkerCtx,
};
use crate::decoding::{DecodeEngine, FinishReason, SeqState, StepOutcome};
use crate::kvcache::HostKvCache;
use crate::metrics::ServeReport;
use crate::runtime::{RuntimeStats, StepOutput};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload;

/// Cache shape the bench engine generates against (tiny: the bench
/// measures scheduling, not transfers).
const SHAPE: (usize, usize, usize) = (2, 64, 4);

/// Scheduler topology a sweep point runs under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepMode {
    /// one device call per sequence per tick (PR 2 behavior)
    Serial,
    /// `--fuse-steps`: one device call per worker tick
    Fused,
    /// `--shared-runtime`: one device call per wall tick, all workers
    Shared,
    /// `--shared-runtime --pipelined`: one device call per wall tick,
    /// with host planning/admission overlapping device execution
    Pipelined,
    /// `--fuse-steps --kv-blocks`: paged KV cache with prefix reuse —
    /// every request shares a common prompt preamble, so riders check
    /// the preamble's pages out of the prefix store instead of
    /// recomputing (the sweep's memory story: `resident_kv_bytes` and
    /// `prefix_hits` go live on this point)
    Prefix,
    /// `--fuse-steps --sched-policy slo` over the trace-driven workload
    /// mix ([`workload::WorkloadGen::mix_trace`]): chat/summarize/code
    /// requests with long-tail output lengths, mapped to SLO priority
    /// classes and per-tenant fairness buckets.  The point exercises the
    /// SLO queue discipline under a realistic blend (carried by
    /// `tools/bench_gate.py`, not gated, until its trajectory seeds)
    Mix,
}

impl SweepMode {
    pub fn name(self) -> &'static str {
        match self {
            SweepMode::Serial => "serial",
            SweepMode::Fused => "fused",
            SweepMode::Shared => "shared",
            SweepMode::Pipelined => "pipelined",
            SweepMode::Prefix => "prefix",
            SweepMode::Mix => "mix",
        }
    }

    pub fn all() -> [SweepMode; 6] {
        [
            SweepMode::Serial,
            SweepMode::Fused,
            SweepMode::Shared,
            SweepMode::Pipelined,
            SweepMode::Prefix,
            SweepMode::Mix,
        ]
    }
}

/// Common prompt preamble every `Prefix`-mode request starts with —
/// long enough to span several KV pages at the bench shape (page size
/// [`crate::kvcache::block_slots_for`]\(64\) = 8 slots), so the prefix
/// store has real chunks to share.
const PREFIX_PREAMBLE: &str = "you are a careful assistant; ";

/// Page budget for the `Prefix` sweep point: roomy enough that no
/// bench request is refused (the point measures reuse, not pressure).
const PREFIX_KV_BLOCKS: usize = 192;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub mode: SweepMode,
    pub workers: usize,
    pub max_inflight: usize,
    pub requests: usize,
    pub max_new: usize,
    /// modeled device latency charged per device call
    pub device_latency: Duration,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            mode: SweepMode::Serial,
            workers: 1,
            max_inflight: 4,
            requests: 24,
            max_new: 12,
            device_latency: Duration::from_micros(200),
        }
    }
}

/// Deterministic mock engine: token `i` of a request is
/// `3 + (sum(prompt) + i + rng_i) % 124` — a pure function of
/// `(prompt, seed)` that never emits control ids, so bench outputs are
/// reproducible, order-independent, and always exactly `max_new` tokens
/// long.  Every device call (unfused step or fused batch) sleeps
/// `delay` and bumps the call counters the report reads.
struct BenchEngine {
    seed: u64,
    delay: Duration,
    forwards: usize,
    batch_calls: usize,
    batch_rows: usize,
}

struct BenchSeq {
    base: u64,
}

fn bench_tag(base: u64, emitted: usize) -> u32 {
    ((base + emitted as u64) % 1009) as u32
}

impl BenchEngine {
    fn new(delay: Duration) -> Self {
        BenchEngine { seed: 0, delay, forwards: 0, batch_calls: 0, batch_rows: 0 }
    }

    fn charge(&self) {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
    }

    fn advance(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome> {
        let base = seq.inner.downcast_ref::<BenchSeq>().expect("bench seq").base;
        if cache.remaining() > 0 {
            cache.commit_contiguous(1)?;
        }
        let i = seq.res.tokens.len() as u64;
        let r = seq.rng.below(97) as u64;
        // offset past the PAD/BOS/EOS ids so every request emits
        // exactly max_new tokens (no surprise EOS truncation — the
        // sweep's token totals must be a constant of the config)
        seq.res.tokens.push(3 + ((base + i + r) % 124) as u32);
        seq.res.steps += 1;
        seq.res.accepted_per_step.push(1);
        seq.res.input_lens.push(1);
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(seq.finish(FinishReason::Budget));
        }
        Ok(StepOutcome::Running)
    }
}

impl DecodeEngine for BenchEngine {
    fn name(&self) -> &'static str {
        "bench-sweep"
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        SHAPE
    }

    fn begin_request(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn request_seed(&self) -> u64 {
        self.seed
    }

    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        cache: &mut HostKvCache,
    ) -> Result<SeqState> {
        cache.reset();
        // prefix-aware "prefill": a seeded cache already holds its
        // first committed() prompt rows, so only the remainder commits
        let want = prompt.len().min(cache.capacity());
        cache.commit_contiguous(want.saturating_sub(cache.committed()))?;
        let base: u64 = prompt.iter().map(|&t| t as u64).sum();
        Ok(SeqState::new(max_new, Rng::new(seed), Box::new(BenchSeq { base })))
    }

    fn step(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome> {
        if let Some(r) = seq.finished {
            return Ok(StepOutcome::Finished(r));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(seq.finish(FinishReason::Budget));
        }
        self.forwards += 1; // one device call per unfused step
        self.charge();
        self.advance(seq, cache)
    }
}

impl BatchStepEngine for BenchEngine {
    fn plan_step(&mut self, seq: &mut SeqState, cache: &HostKvCache) -> Result<StepPlan> {
        if let Some(r) = seq.finished {
            return Ok(StepPlan::Finished(StepOutcome::Finished(r)));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Budget)));
        }
        let base = seq.inner.downcast_ref::<BenchSeq>().expect("bench seq").base;
        let tag = bench_tag(base, seq.res.tokens.len());
        Ok(StepPlan::Forward(PlanInputs {
            tokens: vec![tag],
            pos: vec![cache.committed() as u32],
            slots: vec![cache.committed() as u32],
            bias: vec![0.0; SHAPE.1],
            max_ctx: SHAPE.1,
        }))
    }

    fn apply_step(
        &mut self,
        seq: &mut SeqState,
        res: &StepResult<'_>,
        cache: &mut HostKvCache,
    ) -> Result<StepOutcome> {
        let base = seq.inner.downcast_ref::<BenchSeq>().expect("bench seq").base;
        let want = bench_tag(base, seq.res.tokens.len()) as f32;
        if res.out.logits != [want] {
            bail!("bench row routed to the wrong sequence");
        }
        self.advance(seq, cache)
    }

    fn forward_batch(&mut self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.forwards += 1; // ONE device call for the whole batch
        self.batch_calls += 1;
        self.batch_rows += items.len();
        self.charge();
        Ok(items
            .iter()
            .map(|it| StepOutput {
                n: 1,
                logits: vec![it.plan.tokens[0] as f32],
                hidden: vec![],
                new_kv: vec![],
            })
            .collect())
    }
}

/// Dispatcher-side executor for the shared topology: same echo
/// contract, same modeled latency, counters flushed on drain.
struct BenchExec {
    delay: Duration,
    forwards: AtomicUsize,
    batches: AtomicUsize,
    rows: AtomicUsize,
}

impl DeviceExecutor for BenchExec {
    fn exec_forward(
        &self,
        tokens: &[u32],
        _pos: &[u32],
        _slots: &[u32],
        _bias: &[f32],
        _cache: &[f32],
    ) -> Result<StepOutput> {
        self.forwards.fetch_add(1, Ordering::Relaxed);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(StepOutput { n: 1, logits: vec![tokens[0] as f32], hidden: vec![], new_kv: vec![] })
    }

    fn exec_forward_batch(&self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.forwards.fetch_add(1, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(items.len(), Ordering::Relaxed);
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(items
            .iter()
            .map(|it| StepOutput {
                n: 1,
                logits: vec![it.plan.tokens[0] as f32],
                hidden: vec![],
                new_kv: vec![],
            })
            .collect())
    }
}

struct BenchBackend {
    delay: Duration,
}

impl WorkerBackend for BenchBackend {
    fn run(&self, worker: usize, ctx: WorkerCtx) {
        let mut engine = BenchEngine::new(self.delay);
        ctx.ready();
        serve_jobs(worker, &mut engine, &ctx);
        let mut rows_by_worker = std::collections::BTreeMap::new();
        if engine.batch_rows > 0 {
            rows_by_worker.insert(worker, engine.batch_rows);
        }
        ctx.absorb_runtime_stats(&RuntimeStats {
            forwards: engine.forwards,
            forward_batches: engine.batch_calls,
            batch_rows: engine.batch_rows,
            rows_by_worker,
            ..Default::default()
        });
    }

    fn run_device(&self, host: DeviceHost) {
        let exec = BenchExec {
            delay: self.delay,
            forwards: AtomicUsize::new(0),
            batches: AtomicUsize::new(0),
            rows: AtomicUsize::new(0),
        };
        let agg = host.runtime_agg();
        host.serve(&exec);
        agg.absorb(&RuntimeStats {
            forwards: exec.forwards.load(Ordering::Relaxed),
            forward_batches: exec.batches.load(Ordering::Relaxed),
            batch_rows: exec.rows.load(Ordering::Relaxed),
            ..Default::default()
        });
    }
}

/// Spawn a config's mock-backend coordinator without running the
/// sweep: the topology knobs map to `SchedPolicy` exactly as the sweep
/// maps them.  Shared with `examples/trace_record.rs`, which serves the
/// coordinator over TCP to record a live Chrome trace artifact-free.
pub fn spawn_sweep_coordinator(cfg: &SweepConfig) -> Result<Coordinator> {
    let policy = SchedPolicy {
        max_inflight: cfg.max_inflight,
        fuse_steps: matches!(cfg.mode, SweepMode::Fused | SweepMode::Prefix | SweepMode::Mix),
        shared_runtime: matches!(cfg.mode, SweepMode::Shared | SweepMode::Pipelined),
        pipelined: cfg.mode == SweepMode::Pipelined,
        kv_blocks: (cfg.mode == SweepMode::Prefix).then_some(PREFIX_KV_BLOCKS),
        sched_policy: if cfg.mode == SweepMode::Mix {
            QueueDiscipline::Slo
        } else {
            QueueDiscipline::Fifo
        },
        ..Default::default()
    };
    Coordinator::spawn_with_backend_policy(
        Arc::new(BenchBackend { delay: cfg.device_latency }),
        cfg.workers,
        policy,
    )
}

/// Run one sweep point through the full coordinator and report it as a
/// JSON object (tokens/s, device calls per token, mean fused width).
pub fn run_sweep(cfg: &SweepConfig) -> Result<Json> {
    if cfg.requests == 0 || cfg.max_new == 0 {
        bail!("sweep needs requests > 0 and max_new > 0");
    }
    let coord = spawn_sweep_coordinator(cfg)?;
    // keep raw latency samples so the report carries exact interpolated
    // quantiles, not bucket-boundary estimates (must precede any submit)
    coord.request_latency().set_keep_samples(true);
    let reqs: Vec<Request> = if cfg.mode == SweepMode::Mix {
        // the mix point offers the trace-driven blend: per-request
        // output budgets come from the trace's long-tail lengths, and
        // task classes map to SLO priorities + fairness tenants (chat is
        // the latency-sensitive class; code is throughput traffic).
        // `run_batch` submits the whole trace at once, so the SLO
        // discipline — not arrival order — decides pickup.
        workload::WorkloadGen::new(7)
            .mix_trace(cfg.requests)
            .into_iter()
            .enumerate()
            .map(|(i, item)| {
                let (priority, tenant) = match item.kind {
                    workload::MixKind::Chat => (Priority::High, "chat"),
                    workload::MixKind::Summarize => (Priority::Normal, "summarize"),
                    workload::MixKind::Code => (Priority::Low, "code"),
                };
                Request::builder(item.prompt)
                    .id(i as u64)
                    .max_new(item.max_new)
                    .priority(priority)
                    .tenant(tenant)
                    .build()
            })
            .collect()
    } else {
        (0..cfg.requests)
            .map(|i| {
                // the prefix point models system-prompt traffic: every
                // request opens with the same preamble, so its KV pages
                // are computed once and shared by reference
                let text = match cfg.mode {
                    SweepMode::Prefix => format!("{PREFIX_PREAMBLE}bench request {i}"),
                    _ => format!("bench request {i}"),
                };
                Request::builder(workload::encode(&text)).id(i as u64).max_new(cfg.max_new).build()
            })
            .collect()
    };
    let t0 = Instant::now();
    let resps = coord.run_batch(reqs)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let mut tokens = 0usize;
    for r in &resps {
        if let Some(e) = r.error_msg() {
            bail!("bench request {} failed: {e}", r.id);
        }
        tokens += r.tokens().len();
    }
    if tokens == 0 {
        bail!("bench produced no tokens");
    }
    let mut report = ServeReport::new();
    report.absorb_queue_stats(coord.queue_stats());
    // mean rows per device dispatch: per-worker fused width locally,
    // cross-worker union width under the shared runtime
    let mean_width = match cfg.mode {
        SweepMode::Shared | SweepMode::Pipelined => coord.dispatch_stats().mean_width(),
        _ => report.mean_fused_batch(),
    };
    let samples = coord.request_latency().samples();
    let agg = coord.runtime_agg();
    // memory accounting, read while the pool is still alive
    let resident_kv_bytes = coord.resident_kv_bytes();
    let prefix_hits = coord.prefix_hits();
    drop(coord); // workers + device host flush their counters on drain
    let rt = agg.snapshot();
    if rt.forwards == 0 {
        bail!("backend flushed no device calls");
    }
    Ok(Json::obj(vec![
        ("mode", Json::Str(cfg.mode.name().into())),
        ("workers", Json::Num(cfg.workers as f64)),
        ("max_inflight", Json::Num(cfg.max_inflight as f64)),
        ("requests", Json::Num(resps.len() as f64)),
        ("generated_tokens", Json::Num(tokens as f64)),
        ("wall_s", Json::Num(wall_s)),
        ("tokens_per_s", Json::Num(tokens as f64 / wall_s.max(1e-9))),
        ("device_calls", Json::Num(rt.forwards as f64)),
        ("device_calls_per_token", Json::Num(rt.forwards as f64 / tokens as f64)),
        ("mean_fused_width", Json::Num(mean_width)),
        ("ttft_p50_us", Json::Num(sample_quantile_us(&samples.ttft_us, 0.50))),
        ("ttft_p95_us", Json::Num(sample_quantile_us(&samples.ttft_us, 0.95))),
        ("ttft_p99_us", Json::Num(sample_quantile_us(&samples.ttft_us, 0.99))),
        ("itl_p50_us", Json::Num(sample_quantile_us(&samples.itl_us, 0.50))),
        ("itl_p95_us", Json::Num(sample_quantile_us(&samples.itl_us, 0.95))),
        ("itl_p99_us", Json::Num(sample_quantile_us(&samples.itl_us, 0.99))),
        ("resident_kv_bytes", Json::Num(resident_kv_bytes as f64)),
        ("prefix_hits", Json::Num(prefix_hits as f64)),
    ]))
}

/// Exact interpolated quantile (µs) over the raw latency samples the
/// coordinator kept; 0.0 for an empty set (e.g. a sweep whose requests
/// finish in one step records no inter-token gaps).
fn sample_quantile_us(us: &[u64], q: f64) -> f64 {
    if us.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = us.iter().map(|&u| u as f64).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    crate::util::bench::quantile(&sorted, q)
}

/// Keys every sweep-point object must carry, with finite numeric values
/// — the contract `BENCH_sched.json` consumers (the CI trajectory)
/// parse against.
pub const RUN_KEYS: &[&str] = &[
    "mode",
    "workers",
    "max_inflight",
    "requests",
    "generated_tokens",
    "wall_s",
    "tokens_per_s",
    "device_calls",
    "device_calls_per_token",
    "mean_fused_width",
    "ttft_p50_us",
    "ttft_p95_us",
    "ttft_p99_us",
    "itl_p50_us",
    "itl_p95_us",
    "itl_p99_us",
    "resident_kv_bytes",
    "prefix_hits",
];

/// Validate a full bench report (`{"bench": "sched", "schema": 1,
/// "runs": [...]}`): the example refuses to write malformed output,
/// and CI re-validates the written artifact.
pub fn validate_report(j: &Json) -> Result<()> {
    if j.req("bench")?.as_str()? != "sched" {
        bail!("bench field must be \"sched\"");
    }
    let _ = j.req("schema")?.as_usize()?;
    let runs = j.req("runs")?.as_arr()?;
    if runs.is_empty() {
        bail!("report carries no runs");
    }
    for (i, run) in runs.iter().enumerate() {
        for &key in RUN_KEYS {
            let v = run
                .get(key)
                .ok_or_else(|| anyhow!("run {i} is missing key {key}"))?;
            if key == "mode" {
                let m = v.as_str()?;
                if !SweepMode::all().iter().any(|s| s.name() == m) {
                    bail!("run {i}: unknown mode {m}");
                }
            } else {
                let x = v.as_f64()?;
                if !x.is_finite() || x < 0.0 {
                    bail!("run {i}: {key} is {x}");
                }
            }
        }
        if run.req("generated_tokens")?.as_f64()? <= 0.0 {
            bail!("run {i} generated no tokens");
        }
        if run.req("device_calls")?.as_f64()? <= 0.0 {
            bail!("run {i} recorded no device calls");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(mode: SweepMode, workers: usize) -> SweepConfig {
        SweepConfig {
            mode,
            workers,
            requests: 8,
            max_new: 6,
            device_latency: Duration::ZERO,
            ..Default::default()
        }
    }

    #[test]
    fn sweep_reports_are_well_formed_for_every_mode() {
        let mut runs = Vec::new();
        for mode in SweepMode::all() {
            let j = run_sweep(&quick(mode, 2)).expect("sweep");
            // every required key present and sane
            for &key in RUN_KEYS {
                assert!(j.get(key).is_some(), "{mode:?} missing {key}");
            }
            assert_eq!(j.req("mode").unwrap().as_str().unwrap(), mode.name());
            let tokens = j.req("generated_tokens").unwrap().as_usize().unwrap();
            if mode == SweepMode::Mix {
                // mix budgets come from the trace's long-tail lengths,
                // not the sweep's uniform max_new
                assert!(tokens > 0, "mix generated no tokens");
            } else {
                assert_eq!(tokens, 8 * 6);
            }
            assert!(j.req("device_calls").unwrap().as_f64().unwrap() > 0.0);
            // latency quantiles are ordered (p50 ≤ p95 ≤ p99) and the
            // multi-step requests must have recorded inter-token gaps
            let q = |k: &str| j.req(k).unwrap().as_f64().unwrap();
            assert!(q("ttft_p50_us") <= q("ttft_p95_us"), "{mode:?} ttft order");
            assert!(q("ttft_p95_us") <= q("ttft_p99_us"), "{mode:?} ttft order");
            assert!(q("itl_p50_us") <= q("itl_p95_us"), "{mode:?} itl order");
            assert!(q("itl_p95_us") <= q("itl_p99_us"), "{mode:?} itl order");
            runs.push(j);
        }
        let report = Json::obj(vec![
            ("bench", Json::Str("sched".into())),
            ("schema", Json::Num(1.0)),
            ("runs", Json::Arr(runs)),
        ]);
        validate_report(&report).expect("assembled report validates");
    }

    #[test]
    fn fused_cuts_device_calls_vs_serial() {
        // the first rung of the ladder the bench records (the shared
        // rung depends on wall-tick alignment, so only the CI
        // trajectory tracks it numerically)
        let calls = |mode| {
            run_sweep(&quick(mode, 2))
                .unwrap()
                .req("device_calls")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        let serial = calls(SweepMode::Serial);
        let fused = calls(SweepMode::Fused);
        assert!(
            fused < serial,
            "fused {fused} must issue fewer device calls than serial {serial}"
        );
        // fused widths engaged
        let j = run_sweep(&quick(SweepMode::Fused, 1)).unwrap();
        assert!(j.req("mean_fused_width").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn prefix_mode_shares_pages_and_shrinks_resident_kv() {
        let fused = run_sweep(&quick(SweepMode::Fused, 2)).expect("fused sweep");
        let prefix = run_sweep(&quick(SweepMode::Prefix, 2)).expect("prefix sweep");
        let hits = prefix.req("prefix_hits").unwrap().as_f64().unwrap();
        assert!(hits > 0.0, "prefix sweep must serve shared prompt pages");
        assert_eq!(fused.req("prefix_hits").unwrap().as_f64().unwrap(), 0.0);
        // paged high-water pages are far smaller than whole slabs, even
        // though the prefix prompts are LONGER (shared preamble)
        let slab = fused.req("resident_kv_bytes").unwrap().as_f64().unwrap();
        let paged = prefix.req("resident_kv_bytes").unwrap().as_f64().unwrap();
        assert!(paged > 0.0 && slab > 0.0);
        assert!(
            paged < slab,
            "paged resident {paged} must undercut slab resident {slab}"
        );
    }

    #[test]
    fn validate_report_rejects_malformed_output() {
        assert!(validate_report(&Json::obj(vec![])).is_err(), "empty object");
        let no_runs = Json::obj(vec![
            ("bench", Json::Str("sched".into())),
            ("schema", Json::Num(1.0)),
            ("runs", Json::Arr(vec![])),
        ]);
        assert!(validate_report(&no_runs).is_err(), "no runs");
        let bad_run = Json::obj(vec![
            ("bench", Json::Str("sched".into())),
            ("schema", Json::Num(1.0)),
            ("runs", Json::Arr(vec![Json::obj(vec![("mode", Json::Str("serial".into()))])])),
        ]);
        assert!(validate_report(&bad_run).is_err(), "missing keys");
    }
}
