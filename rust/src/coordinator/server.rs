//! Line-protocol TCP server: one JSON request per line, one JSON
//! response per line.  std-only (tokio is not in the offline vendor
//! set); an acceptor thread per connection feeds the single-worker
//! coordinator — request-level concurrency with model-level FIFO, the
//! paper's batch-size-1 serving setting.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::{parse_request_line, Coordinator, Response};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Serve forever (or until `max_requests` when Some — used by tests).
pub fn serve(coord: Coordinator, addr: &str, max_requests: Option<u64>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!("[ppd] serving on {addr}");
    let coord = Arc::new(Mutex::new(coord));
    let mut served = 0u64;
    for stream in listener.incoming() {
        let stream = stream?;
        let coord = Arc::clone(&coord);
        let handled = handle_conn(stream, &coord)?;
        served += handled;
        if let Some(max) = max_requests {
            if served >= max {
                break;
            }
        }
    }
    Ok(())
}

/// Handle one connection synchronously; returns #requests served.
/// (The worker is single-threaded anyway — the paper measures batch=1 —
/// so per-connection threads would only reorder the queue.)
fn handle_conn(stream: TcpStream, coord: &Arc<Mutex<Coordinator>>) -> Result<u64> {
    let peer = stream.peer_addr().ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    let mut count = 0;
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        let resp = match parse_request_line(trimmed, id) {
            Ok(req) => {
                let c = coord.lock().unwrap();
                match c.submit(req).and_then(|_| c.recv()) {
                    Ok(r) => r,
                    Err(e) => Response::error(id, format!("{e:#}")),
                }
            }
            Err(e) => Response::error(id, e),
        };
        writeln!(out, "{}", resp.to_json())?;
        count += 1;
    }
    let _ = peer;
    Ok(count)
}

/// Minimal client for examples/tests: send one request, read one line.
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<crate::util::json::Json> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let req = crate::util::json::Json::obj(vec![
        ("prompt", crate::util::json::Json::str(prompt)),
        ("max_new", crate::util::json::Json::Num(max_new as f64)),
    ]);
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    crate::util::json::Json::parse(line.trim())
}
