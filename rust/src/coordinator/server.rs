//! Line-protocol TCP server: one JSON request per line, one JSON
//! response per line.  std-only (tokio is not in the offline vendor
//! set).  A thread per connection feeds the multi-worker coordinator
//! through `try_submit_routed`: each in-flight request carries its own
//! reply channel, so concurrent connections are served genuinely in
//! parallel (up to the worker count) and each connection only ever
//! sees its own responses.  Over-capacity submits get an immediate
//! `error` response instead of unbounded queueing (backpressure).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{Context, Result};

use super::{parse_request_line, Coordinator, Response};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// How often blocked readers wake to check the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(200);

/// Serve forever (or until `max_requests` responses when Some — used
/// by tests).  Connections are accepted concurrently; the listener
/// polls so it can notice the stop condition reached by handler
/// threads, and handlers poll their sockets so an idle connection
/// (open but silent) cannot keep `serve` from returning.
pub fn serve(coord: Coordinator, addr: &str, max_requests: Option<u64>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener
        .set_nonblocking(true)
        .context("listener nonblocking")?;
    eprintln!("[ppd] serving on {addr} ({} workers)", coord.workers());
    let coord = Arc::new(coord);
    let served = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let coord = Arc::clone(&coord);
                let served = Arc::clone(&served);
                let stop = Arc::clone(&stop);
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &coord, &served, &stop) {
                        eprintln!("[ppd] connection error: {e:#}");
                    }
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accept"),
        }
        if let Some(max) = max_requests {
            if served.load(Ordering::Relaxed) >= max {
                break;
            }
        }
        handles.retain(|h| !h.is_finished());
    }
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Handle one connection: requests stream in line by line; responses
/// stream back in completion order with ids for client-side matching.
fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    served: &AtomicU64,
    stop: &AtomicBool,
) -> Result<()> {
    // periodic read timeouts let the handler notice `stop` even while
    // a client holds the connection open without sending anything
    stream
        .set_read_timeout(Some(READ_TICK))
        .context("read timeout")?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    let resp = serve_line(coord, trimmed);
                    writeln!(out, "{}", resp.to_json())?;
                    served.fetch_add(1, Ordering::Relaxed);
                }
                line.clear();
                // checked here too: an *actively sending* client never
                // hits the timeout branch, and would otherwise keep
                // serve(max_requests) from joining this handler
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // partial line (if any) stays buffered in `line`
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(e) => return Err(e).context("reading request line"),
        }
    }
    Ok(())
}

fn serve_line(coord: &Coordinator, trimmed: &str) -> Response {
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    match parse_request_line(trimmed, id) {
        Ok(req) => {
            let (tx, rx) = mpsc::channel();
            match coord.try_submit_routed(req, tx) {
                Ok(true) => rx
                    .recv()
                    .unwrap_or_else(|_| Response::error(id, "workers gone".into())),
                Ok(false) => Response::error(
                    id,
                    format!(
                        "server overloaded: queue depth {} at capacity {}",
                        coord.queue_stats().depth(),
                        coord.queue_capacity()
                    ),
                ),
                Err(e) => Response::error(id, format!("{e:#}")),
            }
        }
        Err(e) => Response::error(id, e),
    }
}

/// Minimal client for examples/tests: send one request, read one line.
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<crate::util::json::Json> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let req = crate::util::json::Json::obj(vec![
        ("prompt", crate::util::json::Json::str(prompt)),
        ("max_new", crate::util::json::Json::Num(max_new as f64)),
    ]);
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    crate::util::json::Json::parse(line.trim())
}
