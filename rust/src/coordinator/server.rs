//! Line-protocol TCP server: one JSON request per line.  std-only
//! (tokio is not in the offline vendor set).  A thread per connection
//! feeds the multi-worker coordinator through the backpressure-aware
//! submit path: each in-flight request carries its own reply channel,
//! so concurrent connections are served genuinely in parallel (up to
//! workers × max-inflight) and each connection only ever sees its own
//! responses.  Over-capacity submits get an immediate `error` response
//! instead of unbounded queueing (backpressure).
//!
//! Two reply shapes share the connection (see
//! [`super::request::parse_envelope`] for the envelope):
//! * **v1** (no `"v"` key, or `"v": 1`) — one [`Response`] line per
//!   request line, exactly as every PR since the seed.
//! * **v2 streamed** (`"v": 2` with `"stream": true`, or the server's
//!   `--stream` default) — newline-delimited [`ResponseEvent`] frames:
//!   `started`, then `tokens` frames as decode steps accept, closed by
//!   exactly one terminal `done`/`error` frame.  A v2 line with
//!   streaming off answers with the single v1 response line.
//!
//! **Disconnect cancellation**: while a request is in flight its
//! handler thread polls the socket for EOF; a client that goes away
//! flips the request's [`CancelFlag`], and the step scheduler aborts
//! the sequence at its next decode step, returning the KV cache to the
//! pool instead of finishing work nobody will read.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{Context, Result};

use super::{parse_envelope, CancelFlag, Coordinator, ParseError, Request, Response, ResponseEvent};
use crate::util::json::Json;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// How often blocked readers wake to check the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(200);

/// How often a streaming handler drains its event channel — the upper
/// bound it adds to inter-token latency on the wire.
const STREAM_TICK: Duration = Duration::from_millis(5);

/// Streamed-path disconnect probes run every this many quiet stream
/// ticks: the EOF peek blocks up to the socket's `READ_TICK` timeout,
/// so probing every tick would stall frame forwarding.
const GONE_PROBE_TICKS: u32 = 40;

/// Serve forever (or until `max_requests` requests when Some — used
/// by tests).  Connections are accepted concurrently; the listener
/// polls so it can notice the stop condition reached by handler
/// threads, and handlers poll their sockets so an idle connection
/// (open but silent) cannot keep `serve` from returning.
pub fn serve(coord: Coordinator, addr: &str, max_requests: Option<u64>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener
        .set_nonblocking(true)
        .context("listener nonblocking")?;
    eprintln!("[ppd] serving on {addr} ({} workers)", coord.workers());
    let coord = Arc::new(coord);
    let served = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let coord = Arc::clone(&coord);
                let served = Arc::clone(&served);
                let stop = Arc::clone(&stop);
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &coord, &served, &stop) {
                        eprintln!("[ppd] connection error: {e:#}");
                    }
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accept"),
        }
        if let Some(max) = max_requests {
            if served.load(Ordering::Relaxed) >= max {
                break;
            }
        }
        handles.retain(|h| !h.is_finished());
    }
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Handle one connection: requests stream in line by line; replies
/// stream back in completion order with ids for client-side matching.
/// Each request line counts once toward `served`, whether it answered
/// with one v1 line or a v2 event stream.
fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    served: &AtomicU64,
    stop: &AtomicBool,
) -> Result<()> {
    // periodic read timeouts let the handler notice `stop` even while
    // a client holds the connection open without sending anything
    stream
        .set_read_timeout(Some(READ_TICK))
        .context("read timeout")?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    if is_metrics_request(trimmed) {
                        // scrapes answer from live counters without
                        // touching the queue; they still count toward
                        // `max_requests` (every handled request does)
                        writeln!(out, "{}", metrics_response(coord))?;
                    } else if is_trace_request(trimmed) {
                        writeln!(out, "{}", trace_response(coord))?;
                    } else {
                        serve_line(coord, trimmed, &mut out)?;
                    }
                    served.fetch_add(1, Ordering::Relaxed);
                }
                line.clear();
                // checked here too: an *actively sending* client never
                // hits the timeout branch, and would otherwise keep
                // serve(max_requests) from joining this handler
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // partial line (if any) stays buffered in `line`
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(e) => return Err(e).context("reading request line"),
        }
    }
    Ok(())
}

/// Is this line a metrics scrape rather than a generation request?
/// Accepted forms: the bare word `metrics` or a JSON object with
/// `"metrics": true` — a `metrics` key with any other value is NOT a
/// scrape (a generation request carrying a stray `metrics` field must
/// not silently get a metrics dump instead of its completion).
fn is_metrics_request(trimmed: &str) -> bool {
    trimmed == "metrics"
        || Json::parse(trimmed)
            .ok()
            .and_then(|j| j.get("metrics").and_then(|v| v.as_bool().ok()))
            == Some(true)
}

/// Shared-nothing metrics export: the full Prometheus text block rides
/// in one JSON line (`{"metrics": "ppd_queue_...\n..."}`), so scrapers
/// reuse the line protocol instead of needing a second port.
fn metrics_response(coord: &Coordinator) -> Json {
    Json::obj(vec![("metrics", Json::str(&coord.metrics_text()))])
}

/// Is this line a flight-recorder snapshot request?  Same strict shape
/// as metrics scrapes: the bare word `trace` or `"trace": true` — any
/// other `trace` value belongs to a generation request.
fn is_trace_request(trimmed: &str) -> bool {
    trimmed == "trace"
        || Json::parse(trimmed)
            .ok()
            .and_then(|j| j.get("trace").and_then(|v| v.as_bool().ok()))
            == Some(true)
}

/// Trace export: the Chrome trace-event snapshot rides in one JSON line
/// (`{"trace": {"traceEvents": [...]}}`).  Save the inner object to a
/// file and open it in Perfetto / `chrome://tracing`.
fn trace_response(coord: &Coordinator) -> Json {
    Json::obj(vec![("trace", coord.trace_json())])
}

/// Parse one generation request line under the versioned envelope and
/// answer it — one v1 response line, or a v2 event stream.
fn serve_line(coord: &Coordinator, trimmed: &str, out: &mut TcpStream) -> std::io::Result<()> {
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    let env = match parse_envelope(trimmed, id) {
        Ok(env) => env,
        Err(e) => {
            // version rejections are protocol-level: answered distinctly
            // so a misconfigured client can tell "you spoke v3" from
            // "your prompt was bad"
            let msg = match &e {
                ParseError::BadVersion(_) => format!("protocol error: {e}"),
                _ => e.to_string(),
            };
            return writeln!(out, "{}", Response::error(id, msg).to_json());
        }
    };
    // v1 lines never stream; a v2 line defers to the server's --stream
    // default unless it carries an explicit "stream" choice
    let stream_mode = env.v >= 2 && env.stream.unwrap_or(coord.policy().stream);
    if stream_mode {
        serve_streamed(coord, env.req, out)
    } else {
        let resp = serve_oneshot(coord, env.req, out);
        writeln!(out, "{}", resp.to_json())
    }
}

/// The classic request path: submit, block for the terminal response,
/// watch the socket for disconnect while waiting.
fn serve_oneshot(coord: &Coordinator, req: Request, stream: &TcpStream) -> Response {
    let id = req.id;
    let (tx, rx) = mpsc::channel();
    let cancel = CancelFlag::new();
    match coord.try_submit_cancellable(req, tx, cancel.clone()) {
        Ok(true) => loop {
            match rx.recv_timeout(READ_TICK) {
                Ok(resp) => break resp,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    // while the request is queued/in flight, watch the
                    // socket: a vanished client flips the cancel flag
                    // and the scheduler aborts the sequence at its next
                    // step
                    if client_gone(stream) {
                        cancel.cancel();
                    }
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    break Response::error(id, "workers gone".into())
                }
            }
        },
        Ok(false) => Response::error(id, overloaded_msg(coord)),
        Err(e) => Response::error(id, format!("{e:#}")),
    }
}

/// The v2 streamed path: progress frames are forwarded as the scheduler
/// emits them, and the stream closes with exactly one terminal frame
/// synthesized from the final [`Response`] — so every retirement path
/// (refuse, expiry, cancel, worker teardown) closes the stream without
/// scheduler-side plumbing.
fn serve_streamed(coord: &Coordinator, req: Request, out: &mut TcpStream) -> std::io::Result<()> {
    let id = req.id;
    let (tx, rx) = mpsc::channel();
    let (etx, erx) = mpsc::channel();
    let cancel = CancelFlag::new();
    let resp = match coord.try_submit_streaming(req, tx, etx, cancel.clone()) {
        Ok(true) => {
            let mut quiet_ticks = 0u32;
            loop {
                let mut progressed = false;
                while let Ok(ev) = erx.try_recv() {
                    writeln!(out, "{}", ev.to_json())?;
                    progressed = true;
                }
                match rx.recv_timeout(STREAM_TICK) {
                    Ok(resp) => break resp,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        quiet_ticks = if progressed { 0 } else { quiet_ticks + 1 };
                        // only probe for EOF after a quiet stretch: the
                        // peek blocks up to READ_TICK, which would gate
                        // frame forwarding if run every tick
                        if quiet_ticks >= GONE_PROBE_TICKS {
                            quiet_ticks = 0;
                            if client_gone(out) {
                                cancel.cancel();
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        break Response::error(id, "workers gone".into())
                    }
                }
            }
        }
        Ok(false) => Response::error(id, overloaded_msg(coord)),
        Err(e) => Response::error(id, format!("{e:#}")),
    };
    // drain frames that raced the terminal response, then close the
    // stream with it
    while let Ok(ev) = erx.try_recv() {
        writeln!(out, "{}", ev.to_json())?;
    }
    coord.queue_stats().on_stream_events(1);
    writeln!(out, "{}", ResponseEvent::terminal(&resp).to_json())
}

fn overloaded_msg(coord: &Coordinator) -> String {
    format!(
        "server overloaded: queue depth {} at capacity {}",
        coord.queue_stats().depth(),
        coord.queue_capacity()
    )
}

/// EOF probe for disconnect detection: `peek` returns `Ok(0)` once the
/// peer's write side is closed and the receive buffer is drained.  The
/// socket's read timeout (set in `handle_conn`) bounds the wait;
/// timeout/would-block means the client is simply quiet, which is not
/// a disconnect.
///
/// Note this cannot distinguish a full close from a half-close
/// (`shutdown(SHUT_WR)` by a client still reading): in this line
/// protocol an open write side *is* the liveness signal, so a
/// half-closing client gets its in-flight request cancelled.  Clients
/// must keep the connection fully open until the terminal line arrives
/// (as [`Client`] does).
fn client_gone(stream: &TcpStream) -> bool {
    let mut probe = [0u8; 1];
    match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => !matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock
                | std::io::ErrorKind::TimedOut
                | std::io::ErrorKind::Interrupted
        ),
    }
}

/// One request line ready to put on the wire: a v1/v2 generation
/// request or a metrics/trace control line.  Built with the
/// constructors + `with_*` chainers so examples and tests never
/// hand-format protocol JSON.
#[derive(Debug, Clone)]
pub struct Envelope(Json);

impl Envelope {
    /// A v1 generation request (no `"v"` key — the pre-envelope shape).
    pub fn generate(prompt: &str, max_new: usize) -> Self {
        Envelope(Json::obj(vec![
            ("prompt", Json::str(prompt)),
            ("max_new", Json::Num(max_new as f64)),
        ]))
    }

    /// A v2 generation request; add streaming/session/SLO fields with
    /// the `with_*` chainers.
    pub fn v2(prompt: &str, max_new: usize) -> Self {
        Envelope::generate(prompt, max_new).set("v", Json::Num(2.0))
    }

    /// A metrics scrape line.
    pub fn metrics() -> Self {
        Envelope(Json::obj(vec![("metrics", Json::Bool(true))]))
    }

    /// A flight-recorder snapshot line.
    pub fn trace() -> Self {
        Envelope(Json::obj(vec![("trace", Json::Bool(true))]))
    }

    fn set(mut self, key: &str, val: Json) -> Self {
        if let Json::Obj(m) = &mut self.0 {
            m.insert(key.to_string(), val);
        }
        self
    }

    pub fn with_seed(self, seed: u64) -> Self {
        self.set("seed", Json::Num(seed as f64))
    }

    /// v2: explicit streaming choice (overrides the server's `--stream`
    /// default).
    pub fn with_stream(self, on: bool) -> Self {
        self.set("stream", Json::Bool(on))
    }

    /// v2: multi-turn session id (prefix affinity across turns).
    pub fn with_session(self, sid: &str) -> Self {
        self.set("session", Json::str(sid))
    }

    /// v2: SLO priority class (`"high"`/`"normal"`/`"low"`).
    pub fn with_priority(self, p: &str) -> Self {
        self.set("priority", Json::str(p))
    }

    /// v2: drop the request if still queued after this many ms.
    pub fn with_deadline_ms(self, ms: u64) -> Self {
        self.set("deadline_ms", Json::Num(ms as f64))
    }

    /// v2: fairness bucket for the `slo` discipline.
    pub fn with_tenant(self, t: &str) -> Self {
        self.set("tenant", Json::str(t))
    }

    /// The wire line (one JSON object, no trailing newline).
    pub fn line(&self) -> String {
        self.0.to_string()
    }
}

/// One reply line, parsed.
#[derive(Debug, Clone)]
pub struct Reply(Json);

impl Reply {
    pub fn json(&self) -> &Json {
        &self.0
    }

    pub fn into_json(self) -> Json {
        self.0
    }
}

/// Protocol client over one persistent connection.  Every interaction
/// routes through [`Client::call`] (one line out, one line in) except
/// [`Client::stream`], which reads event frames until the terminal one.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let reader = BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(Client { reader, writer: stream })
    }

    /// Send one envelope, read one reply line — the core of every
    /// non-streaming interaction.
    pub fn call(&mut self, env: &Envelope) -> Result<Reply> {
        writeln!(self.writer, "{}", env.line())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Reply(Json::parse(line.trim())?))
    }

    /// Convenience v1 generation call.
    pub fn request(&mut self, prompt: &str, max_new: usize) -> Result<Reply> {
        self.call(&Envelope::generate(prompt, max_new))
    }

    /// Send a streaming envelope and iterate its event frames.  The
    /// iterator ends after the terminal `done`/`error` frame (or on a
    /// broken connection), leaving the client ready for the next call.
    pub fn stream(&mut self, env: &Envelope) -> Result<impl Iterator<Item = ResponseEvent> + '_> {
        writeln!(self.writer, "{}", env.line())?;
        Ok(EventStream { reader: &mut self.reader, done: false })
    }

    /// Scrape the server's metrics line and return the decoded
    /// Prometheus text block.
    pub fn metrics(&mut self) -> Result<String> {
        let r = self.call(&Envelope::metrics())?;
        Ok(r.json().req("metrics")?.as_str()?.to_string())
    }

    /// Fetch the flight-recorder snapshot (the Chrome trace-event
    /// object under `"trace"`), ready to write to a `.json` file for
    /// Perfetto.
    pub fn trace(&mut self) -> Result<Json> {
        let r = self.call(&Envelope::trace())?;
        Ok(r.json().req("trace")?.clone())
    }
}

/// Streamed-reply iterator: yields frames until the terminal one.
struct EventStream<'a> {
    reader: &'a mut BufReader<TcpStream>,
    done: bool,
}

impl Iterator for EventStream<'_> {
    type Item = ResponseEvent;

    fn next(&mut self) -> Option<ResponseEvent> {
        if self.done {
            return None;
        }
        let mut line = String::new();
        loop {
            line.clear();
            match self.reader.read_line(&mut line) {
                Ok(0) | Err(_) => {
                    self.done = true;
                    return None;
                }
                Ok(_) => {
                    let trimmed = line.trim();
                    if trimmed.is_empty() {
                        continue;
                    }
                    match Json::parse(trimmed)
                        .ok()
                        .and_then(|j| ResponseEvent::from_json(&j).ok())
                    {
                        Some(ev) => {
                            self.done = ev.is_terminal();
                            return Some(ev);
                        }
                        None => {
                            // an unparsable frame poisons the stream;
                            // stop rather than spin on garbage
                            self.done = true;
                            return None;
                        }
                    }
                }
            }
        }
    }
}

/// Minimal one-shot client for examples/tests: send one request, read
/// one line.  Thin wrapper over [`Client`].
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<Json> {
    let mut c = Client::connect(addr)?;
    Ok(c.request(prompt, max_new)?.into_json())
}

/// Scrape the server's metrics line and return the decoded Prometheus
/// text block.
pub fn client_metrics(addr: &str) -> Result<String> {
    Client::connect(addr)?.metrics()
}

/// Fetch the server's flight-recorder snapshot and return the Chrome
/// trace-event object (the value under `"trace"`), ready to write to a
/// `.json` file for Perfetto.
pub fn client_trace(addr: &str) -> Result<Json> {
    Client::connect(addr)?.trace()
}
