//! Line-protocol TCP server: one JSON request per line, one JSON
//! response per line.  std-only (tokio is not in the offline vendor
//! set).  A thread per connection feeds the multi-worker coordinator
//! through `try_submit_cancellable`: each in-flight request carries its
//! own reply channel, so concurrent connections are served genuinely in
//! parallel (up to workers × max-inflight) and each connection only
//! ever sees its own responses.  Over-capacity submits get an immediate
//! `error` response instead of unbounded queueing (backpressure).
//!
//! **Disconnect cancellation**: while a request is in flight its
//! handler thread polls the socket for EOF; a client that goes away
//! flips the request's [`CancelFlag`], and the step scheduler aborts
//! the sequence at its next decode step, returning the KV cache to the
//! pool instead of finishing work nobody will read.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{Context, Result};

use super::{parse_request_line, CancelFlag, Coordinator, Response};

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// How often blocked readers wake to check the shutdown flag.
const READ_TICK: Duration = Duration::from_millis(200);

/// Serve forever (or until `max_requests` responses when Some — used
/// by tests).  Connections are accepted concurrently; the listener
/// polls so it can notice the stop condition reached by handler
/// threads, and handlers poll their sockets so an idle connection
/// (open but silent) cannot keep `serve` from returning.
pub fn serve(coord: Coordinator, addr: &str, max_requests: Option<u64>) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener
        .set_nonblocking(true)
        .context("listener nonblocking")?;
    eprintln!("[ppd] serving on {addr} ({} workers)", coord.workers());
    let coord = Arc::new(coord);
    let served = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let coord = Arc::clone(&coord);
                let served = Arc::clone(&served);
                let stop = Arc::clone(&stop);
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &coord, &served, &stop) {
                        eprintln!("[ppd] connection error: {e:#}");
                    }
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => return Err(e).context("accept"),
        }
        if let Some(max) = max_requests {
            if served.load(Ordering::Relaxed) >= max {
                break;
            }
        }
        handles.retain(|h| !h.is_finished());
    }
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// Handle one connection: requests stream in line by line; responses
/// stream back in completion order with ids for client-side matching.
fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    served: &AtomicU64,
    stop: &AtomicBool,
) -> Result<()> {
    // periodic read timeouts let the handler notice `stop` even while
    // a client holds the connection open without sending anything
    stream
        .set_read_timeout(Some(READ_TICK))
        .context("read timeout")?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                let trimmed = line.trim();
                if !trimmed.is_empty() {
                    if is_metrics_request(trimmed) {
                        // scrapes answer from live counters without
                        // touching the queue; they still count toward
                        // `max_requests` (every response line does)
                        writeln!(out, "{}", metrics_response(coord))?;
                    } else if is_trace_request(trimmed) {
                        writeln!(out, "{}", trace_response(coord))?;
                    } else {
                        let resp = serve_line(coord, trimmed, &out);
                        writeln!(out, "{}", resp.to_json())?;
                    }
                    served.fetch_add(1, Ordering::Relaxed);
                }
                line.clear();
                // checked here too: an *actively sending* client never
                // hits the timeout branch, and would otherwise keep
                // serve(max_requests) from joining this handler
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // partial line (if any) stays buffered in `line`
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(e) => return Err(e).context("reading request line"),
        }
    }
    Ok(())
}

/// Is this line a metrics scrape rather than a generation request?
/// Accepted forms: the bare word `metrics` or a JSON object with
/// `"metrics": true` — a `metrics` key with any other value is NOT a
/// scrape (a generation request carrying a stray `metrics` field must
/// not silently get a metrics dump instead of its completion).
fn is_metrics_request(trimmed: &str) -> bool {
    trimmed == "metrics"
        || crate::util::json::Json::parse(trimmed)
            .ok()
            .and_then(|j| j.get("metrics").and_then(|v| v.as_bool().ok()))
            == Some(true)
}

/// Shared-nothing metrics export: the full Prometheus text block rides
/// in one JSON line (`{"metrics": "ppd_queue_...\n..."}`), so scrapers
/// reuse the line protocol instead of needing a second port.
fn metrics_response(coord: &Coordinator) -> crate::util::json::Json {
    crate::util::json::Json::obj(vec![(
        "metrics",
        crate::util::json::Json::str(&coord.metrics_text()),
    )])
}

/// Is this line a flight-recorder snapshot request?  Same strict shape
/// as metrics scrapes: the bare word `trace` or `"trace": true` — any
/// other `trace` value belongs to a generation request.
fn is_trace_request(trimmed: &str) -> bool {
    trimmed == "trace"
        || crate::util::json::Json::parse(trimmed)
            .ok()
            .and_then(|j| j.get("trace").and_then(|v| v.as_bool().ok()))
            == Some(true)
}

/// Trace export: the Chrome trace-event snapshot rides in one JSON line
/// (`{"trace": {"traceEvents": [...]}}`).  Save the inner object to a
/// file and open it in Perfetto / `chrome://tracing`.
fn trace_response(coord: &Coordinator) -> crate::util::json::Json {
    crate::util::json::Json::obj(vec![("trace", coord.trace_json())])
}

fn serve_line(coord: &Coordinator, trimmed: &str, stream: &TcpStream) -> Response {
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    match parse_request_line(trimmed, id) {
        Ok(req) => {
            let (tx, rx) = mpsc::channel();
            let cancel = CancelFlag::new();
            match coord.try_submit_cancellable(req, tx, cancel.clone()) {
                Ok(true) => loop {
                    match rx.recv_timeout(READ_TICK) {
                        Ok(resp) => break resp,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            // while the request is queued/in flight,
                            // watch the socket: a vanished client flips
                            // the cancel flag and the scheduler aborts
                            // the sequence at its next step
                            if client_gone(stream) {
                                cancel.cancel();
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            break Response::error(id, "workers gone".into())
                        }
                    }
                },
                Ok(false) => Response::error(
                    id,
                    format!(
                        "server overloaded: queue depth {} at capacity {}",
                        coord.queue_stats().depth(),
                        coord.queue_capacity()
                    ),
                ),
                Err(e) => Response::error(id, format!("{e:#}")),
            }
        }
        Err(e) => Response::error(id, e),
    }
}

/// EOF probe for disconnect detection: `peek` returns `Ok(0)` once the
/// peer's write side is closed and the receive buffer is drained.  The
/// socket's read timeout (set in `handle_conn`) bounds the wait;
/// timeout/would-block means the client is simply quiet, which is not
/// a disconnect.
///
/// Note this cannot distinguish a full close from a half-close
/// (`shutdown(SHUT_WR)` by a client still reading): in this line
/// protocol an open write side *is* the liveness signal, so a
/// half-closing client gets its in-flight request cancelled.  Clients
/// must keep the connection fully open until the response line arrives
/// (as `client_request` does).
fn client_gone(stream: &TcpStream) -> bool {
    let mut probe = [0u8; 1];
    match stream.peek(&mut probe) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => !matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock
                | std::io::ErrorKind::TimedOut
                | std::io::ErrorKind::Interrupted
        ),
    }
}

/// Minimal client for examples/tests: send one request, read one line.
pub fn client_request(addr: &str, prompt: &str, max_new: usize) -> Result<crate::util::json::Json> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let req = crate::util::json::Json::obj(vec![
        ("prompt", crate::util::json::Json::str(prompt)),
        ("max_new", crate::util::json::Json::Num(max_new as f64)),
    ]);
    writeln!(stream, "{req}")?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    crate::util::json::Json::parse(line.trim())
}

/// Scrape the server's metrics line and return the decoded Prometheus
/// text block.
pub fn client_metrics(addr: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    writeln!(stream, "{}", r#"{"metrics": true}"#)?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = crate::util::json::Json::parse(line.trim())?;
    Ok(j.req("metrics")?.as_str()?.to_string())
}

/// Fetch the server's flight-recorder snapshot and return the Chrome
/// trace-event object (the value under `"trace"`), ready to write to a
/// `.json` file for Perfetto.
pub fn client_trace(addr: &str) -> Result<crate::util::json::Json> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    writeln!(stream, "{}", r#"{"trace": true}"#)?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let j = crate::util::json::Json::parse(line.trim())?;
    Ok(j.req("trace")?.clone())
}
