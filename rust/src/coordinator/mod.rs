//! L3 coordinator: a multi-worker serving layer with step-level
//! continuous batching.
//!
//! ```text
//!   submitters (TCP conns, batch drivers)
//!        │  submit / try_submit (backpressure)
//!        ▼
//!   ┌──────────────┐      ┌─────────────────────────────────────┐
//!   │  WorkQueue   │ ───▶ │ worker 0..N: Runtime + engine       │──▶ reply
//!   │ (mutex+cv)   │      │  StepScheduler: ≤ max-inflight seqs │    channels
//!   └──────────────┘      │  caches ⇄ SharedCachePool (capped)  │
//!                         └─────────────────────────────────────┘
//!
//!   --shared-runtime inverts the worker↔runtime ownership:
//!   ┌──────────────┐   ┌──────────────────────────┐  ticks  ┌────────────┐
//!   │  WorkQueue   │──▶│ worker 0..N: engine over │ ──────▶ │ Device-    │
//!   └──────────────┘   │ SharedRuntime handle     │ ◀────── │ Dispatcher │
//!                      └──────────────────────────┘ replies │ + Runtime  │
//!                        (schedulers → dispatcher → device)  └────────────┘
//! ```
//!
//! * The PJRT client is not `Send`, so each worker thread *owns* its
//!   `Runtime` and engine (vLLM's router/worker split at miniature
//!   scale).  Workers pull from one shared [`queue::WorkQueue`].
//! * Under `SchedPolicy::shared_runtime` (`--shared-runtime`) the
//!   topology inverts: ONE device-host thread owns THE runtime behind a
//!   [`crate::batch::dispatch::DeviceDispatcher`], workers build their
//!   engines over a [`crate::batch::dispatch::SharedRuntime`] handle,
//!   and every worker's fused tick coalesces into one device call per
//!   wall tick (cross-worker fusion).
//! * Each worker runs a [`scheduler::StepScheduler`]: it holds up to
//!   `--max-inflight` sequences, admits new jobs from the queue
//!   *between decode steps*, round-robins one PPD tree step per
//!   sequence per tick, and retires sequences on EOS/budget — so a
//!   short request never waits behind a long one (continuous batching).
//! * Completions are **out of order**: every job carries its own reply
//!   channel, so concurrent submitters each get exactly their
//!   responses, and [`Coordinator::run_batch`] reassembles batch
//!   results by request id.
//! * KV caches are checked out of a [`SharedCachePool`] per admitted
//!   sequence — capped at `workers × max_inflight` allocations, ever —
//!   instead of living inside engines.
//! * Each request carries an RNG seed and all per-sequence state
//!   (RNG, proposer pools, tree cursor, draft cache) lives in the
//!   sequence's `SeqState`, so output is a pure function of
//!   (prompt, max_new, seed): identical across worker counts,
//!   placements, and interleavings, byte-identical to the
//!   run-to-completion path.
//! * Jobs carry a [`queue::CancelFlag`] (set on TCP disconnect) and are
//!   dropped at admission once older than the policy's max queue age.
//! * Queue depth / backpressure / admission / in-flight-depth
//!   accounting lives in [`crate::metrics::QueueStats`].
//!
//! Workers are abstracted behind [`WorkerBackend`] so the concurrency
//! machinery is testable without model artifacts (see
//! `rust/tests/coordinator.rs` and the deterministic scheduler harness
//! in `rust/tests/scheduler.rs`); [`ModelBackend`] is the production
//! implementation that loads artifacts and builds a real engine.

pub mod queue;
pub mod request;
pub mod scheduler;
pub mod server;

use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::batch::dispatch::{
    DeviceDispatcher, DispatcherHandle, DispatchStats, SharedRuntime, DEFAULT_WINDOW,
};
use crate::batch::BatchStepEngine;
use crate::config::{ArtifactPaths, ServeConfig};
use crate::decoding::lookup::{ChainEngine, LookaheadProposer, PldProposer, RestProposer};
use crate::decoding::medusa::MedusaEngine;
use crate::decoding::ppd::PpdEngine;
use crate::decoding::speculative::SpeculativeEngine;
use crate::decoding::vanilla::VanillaEngine;
use crate::kvcache::SharedCachePool;
use crate::metrics::{QueueStats, RequestLatency, RuntimeAgg};
use crate::runtime::{Device, Runtime, RuntimeStats};
use crate::trace::{Phase, TraceTrack, Tracer};
use crate::tree::builder::AcceptStats;
use crate::workload;

use queue::{Job, Polled, WorkQueue};
pub use queue::CancelFlag;
pub use request::{
    parse_envelope, parse_request_line, Outcome, ParseError, Priority, Request, RequestBuilder,
    RequestEnvelope, Response, ResponseEvent, Timing,
};
pub use scheduler::{QueueDiscipline, SchedPolicy, StepScheduler, DEFAULT_MAX_INFLIGHT};

/// Soft queue bound per worker used by the backpressure-aware submit.
pub const DEFAULT_QUEUE_PER_WORKER: usize = 64;

/// Which engine the workers run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Vanilla,
    Ppd,
    Medusa,
    Pld,
    Rest,
    Lookahead,
    Spec,
    SpecPpd,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "vanilla" => EngineKind::Vanilla,
            "ppd" => EngineKind::Ppd,
            "medusa" => EngineKind::Medusa,
            "pld" => EngineKind::Pld,
            "rest" => EngineKind::Rest,
            "lookahead" => EngineKind::Lookahead,
            "spec" => EngineKind::Spec,
            "spec+ppd" | "spec-ppd" => EngineKind::SpecPpd,
            other => return Err(anyhow!("unknown engine '{other}'")),
        })
    }

    pub fn all() -> &'static [&'static str] {
        &["vanilla", "ppd", "medusa", "pld", "rest", "lookahead", "spec", "spec+ppd"]
    }
}

/// Build an engine over runtimes the caller owns (single-threaded use:
/// examples, benches).  `draft` is required for the speculative kinds.
/// Every engine is a [`BatchStepEngine`] — plan-native ones
/// (vanilla/ppd/medusa) fuse under `--fuse-steps`, the rest fall back
/// to per-sequence stepping.
pub fn build_engine<'rt>(
    kind: EngineKind,
    rt: &'rt dyn Device,
    draft: Option<&'rt dyn Device>,
    paths: &ArtifactPaths,
    cfg: &ServeConfig,
    seed: u64,
) -> Result<Box<dyn BatchStepEngine + 'rt>> {
    let stats_path = paths.accept_stats(None);
    Ok(match kind {
        EngineKind::Vanilla => Box::new(VanillaEngine::new(rt, cfg.temperature, seed)),
        EngineKind::Ppd => {
            let stats = AcceptStats::load(&stats_path, "ppd")?;
            Box::new(PpdEngine::new(rt, &stats, cfg, seed)?)
        }
        EngineKind::Medusa => {
            let stats = AcceptStats::load(&stats_path, "medusa")?;
            // Medusa's static tree gets the same *total* token budget
            // (candidates + prompts) PPD uses, per the paper's equal-
            // budget comparisons
            let n = cfg.n_candidates + cfg.n_prompt_budget;
            Box::new(MedusaEngine::new(rt, &stats, cfg, n, seed)?)
        }
        EngineKind::Pld => {
            Box::new(ChainEngine::new(rt, PldProposer { span: 4 }, 4, 16, seed))
        }
        EngineKind::Rest => {
            let datastore = std::sync::Arc::new(workload::load_val_stream(&paths.root)?);
            Box::new(ChainEngine::new(
                rt,
                RestProposer { datastore, span: 4, max_hits: 3 },
                4,
                16,
                seed,
            ))
        }
        EngineKind::Lookahead => {
            Box::new(ChainEngine::new(rt, LookaheadProposer::new(4), 4, 16, seed))
        }
        EngineKind::Spec => {
            let draft = draft.ok_or_else(|| anyhow!("spec engine needs a draft model"))?;
            Box::new(SpeculativeEngine::new_vanilla(rt, draft, 4, seed))
        }
        EngineKind::SpecPpd => {
            let draft = draft.ok_or_else(|| anyhow!("spec+ppd engine needs a draft model"))?;
            let draft_paths = ArtifactPaths::new(paths.root.clone(), &draft.cfg().name);
            let stats = AcceptStats::load(&draft_paths.accept_stats(None), "ppd")?;
            Box::new(SpeculativeEngine::new_ppd(rt, draft, &stats, cfg, 4, seed)?)
        }
    })
}

/// Shared state handed to every worker thread.
pub struct WorkerCtx {
    queue: Arc<WorkQueue>,
    pool: Arc<SharedCachePool>,
    stats: Arc<QueueStats>,
    rt_agg: Arc<RuntimeAgg>,
    policy: SchedPolicy,
    /// shared-runtime mode: the handle this worker submits device work
    /// through (`None` when each worker owns its own `Runtime`)
    dispatch: Option<DispatcherHandle>,
    /// the pool's flight recorder — each worker records onto its own
    /// "worker-N" track; whether anything lands in the rings is decided
    /// by the tracer's sampling gate (`--trace-sample`)
    trace: Arc<Tracer>,
    /// per-request latency histograms (always on; atomic buckets)
    latency: Arc<RequestLatency>,
    /// one-shot startup signal (taken on first use so a worker that
    /// panics before signaling drops its sender and fails spawn fast)
    ready: Mutex<Option<mpsc::Sender<Result<()>>>>,
}

impl WorkerCtx {
    fn signal(&self, r: Result<()>) {
        if let Some(tx) = self.ready.lock().unwrap().take() {
            let _ = tx.send(r);
        }
    }

    /// Report successful startup; unblocks `Coordinator::spawn`.
    pub fn ready(&self) {
        self.signal(Ok(()));
    }

    /// Report failed startup; `Coordinator::spawn` returns this error.
    pub fn fail(&self, e: anyhow::Error) {
        self.signal(Err(e));
    }

    /// Shared-runtime mode: the dispatcher handle this worker's
    /// scheduler (and `SharedRuntime`-backed engine) submit through.
    pub fn dispatcher(&self) -> Option<&DispatcherHandle> {
        self.dispatch.as_ref()
    }

    /// Flush a worker's device-call counters into the coordinator's
    /// aggregate (call when the worker drains: each thread owns its
    /// `Runtime`, so the counters only become shareable here).
    pub fn absorb_runtime_stats(&self, stats: &RuntimeStats) {
        self.rt_agg.absorb(stats);
    }
}

/// Context for the dedicated device-host thread spawned under
/// `--shared-runtime`: it owns the [`DeviceDispatcher`] (and, in
/// production, THE `Runtime` — the PJRT client never leaves this
/// thread).  Backends signal startup exactly like workers do, then hand
/// an executor to [`DeviceHost::serve`].
pub struct DeviceHost {
    dispatcher: DeviceDispatcher,
    rt_agg: Arc<RuntimeAgg>,
    ready: Mutex<Option<mpsc::Sender<Result<()>>>>,
}

impl DeviceHost {
    fn signal(&self, r: Result<()>) {
        if let Some(tx) = self.ready.lock().unwrap().take() {
            let _ = tx.send(r);
        }
    }

    /// Report failed device startup; `Coordinator::spawn` returns this
    /// error (and the dispatcher drops, failing worker round-trips
    /// fast).
    pub fn fail(&self, e: anyhow::Error) {
        self.signal(Err(e));
    }

    /// Handle to the coordinator's post-drain runtime aggregate, for
    /// backends that flush executor counters after [`DeviceHost::serve`]
    /// returns.
    pub fn runtime_agg(&self) -> Arc<RuntimeAgg> {
        Arc::clone(&self.rt_agg)
    }

    /// Signal readiness and serve dispatch requests until every worker
    /// (handle clone) is gone, then flush the dispatcher's per-worker
    /// row attribution into the runtime aggregate.
    pub fn serve(self, exec: &dyn crate::batch::dispatch::DeviceExecutor) {
        self.signal(Ok(()));
        let stats = self.dispatcher.stats();
        self.dispatcher.run(exec);
        let rows_by_worker = stats
            .rows_by_worker()
            .into_iter()
            .map(|(w, r)| (w, r as usize))
            .collect();
        self.rt_agg.absorb(&RuntimeStats { rows_by_worker, ..Default::default() });
    }
}

/// Builds one worker's engine and serves jobs until the queue closes.
/// Implementations call `ctx.ready()` (or `ctx.fail(e)`) once setup is
/// done, then hand their engine to [`serve_jobs`].
pub trait WorkerBackend: Send + Sync + 'static {
    fn run(&self, worker: usize, ctx: WorkerCtx);

    /// Shared-runtime mode: run the device-host thread that owns the
    /// one runtime/executor.  Called on a dedicated thread when the
    /// policy sets `shared_runtime`; backends that support it override
    /// this with a [`DeviceHost::serve`] call.
    fn run_device(&self, host: DeviceHost) {
        host.fail(anyhow!("backend has no shared-runtime device host"));
    }
}

/// The shared worker loop, now a step-level scheduler: block for work
/// when idle, admit queued jobs between decode steps up to the
/// `--max-inflight` budget, round-robin one decode step per in-flight
/// sequence per iteration, and retire sequences out of order through
/// their per-job reply channels.  Split out of [`WorkerBackend`] impls
/// so mock backends in tests exercise the exact production path.
///
/// Panics inside `begin_seq`/`step` are caught by the scheduler and
/// turned into error responses: a silently-dead worker would leave
/// queued jobs holding reply senders forever and wedge every submitter
/// — the worker must outlive any one bad request.
pub fn serve_jobs(worker: usize, engine: &mut dyn BatchStepEngine, ctx: &WorkerCtx) {
    let mut sched = match ctx.dispatcher() {
        // shared-runtime mode: fused ticks go to the coordinator's one
        // device dispatcher and coalesce across workers; the pool/stats
        // handles let a tearing-down scheduler reconcile a tick that is
        // still at the dispatcher
        Some(h) => StepScheduler::with_dispatcher(
            worker,
            ctx.policy,
            h.clone(),
            Arc::clone(&ctx.pool),
            Arc::clone(&ctx.stats),
        ),
        None => StepScheduler::new(worker, ctx.policy),
    };
    // every scheduler reports onto its own trace track and into the one
    // shared latency recorder; the tracer's gate keeps the span side
    // near-free when sampling is off
    sched.set_observer(scheduler::SchedObserver {
        track: ctx.trace.track(&format!("worker-{worker}")),
        latency: Arc::clone(&ctx.latency),
    });
    if ctx.policy.pipelined && ctx.dispatcher().is_some() {
        return serve_jobs_pipelined(engine, ctx, &mut sched);
    }
    loop {
        if sched.is_empty() {
            // idle: block until work arrives; `None` means the queue is
            // closed and drained, and nothing is in flight — exit
            match ctx.queue.pop() {
                Some(job) => {
                    sched.admit(engine, &ctx.pool, &ctx.stats, job);
                }
                None => return,
            }
        }
        // opportunistic admission between decode steps — this is the
        // continuous-batching move: new work joins a busy worker
        // without waiting for its current sequences to finish.  At most
        // one admission per tick: draining the queue to max_inflight in
        // one go would let a single worker hoover a whole burst while
        // its siblings sit idle in pop(), serializing work PR 1 ran in
        // parallel — pacing admissions gives the other workers a tick's
        // worth of time to claim their share
        if sched.has_capacity() {
            if let Polled::Job(job) = ctx.queue.try_pop() {
                sched.admit(engine, &ctx.pool, &ctx.stats, *job);
            }
        }
        // one decode step for every in-flight sequence; finished
        // sequences retire and free their caches inside
        sched.tick(engine, &ctx.pool, &ctx.stats);
    }
}

/// The pipelined shared-runtime worker loop (`--pipelined`): the tick
/// splits into submit / complete halves so the host-side work of the
/// NEXT round — queue admission, prefill, planning — runs while the
/// device executes the round already submitted, instead of the worker
/// sitting blocked in `recv` the whole time.
///
/// Admission here is fuse-aware rather than one-per-tick: under backlog
/// the worker fills to the next `fwd_b{B}` bucket boundary
/// ([`scheduler::admission_quota`]) because a wider fused round is what
/// actually amortizes the device call; without backlog it degrades to
/// the unpipelined loop's one-admission-per-tick pacing so a lone
/// worker still cannot hoover a burst away from its idle siblings.
fn serve_jobs_pipelined(
    engine: &mut dyn BatchStepEngine,
    ctx: &WorkerCtx,
    sched: &mut StepScheduler,
) {
    loop {
        if sched.is_empty() {
            match ctx.queue.pop() {
                Some(job) => {
                    sched.admit(engine, &ctx.pool, &ctx.stats, job);
                }
                None => return,
            }
        }
        // phase A: plan this round and hand it to the dispatcher — the
        // device can start as soon as every registered worker has done
        // the same
        sched.tick_shared_submit(engine, &ctx.pool, &ctx.stats);
        // overlap window: the device is (or will shortly be) executing
        // the submitted round; spend it admitting and prefilling the
        // next round's sequences instead of blocking on the reply
        let quota = scheduler::admission_quota(
            ctx.queue.depth(),
            sched.len(),
            ctx.policy.max_inflight,
            scheduler::FUSE_ADMIT_BUCKETS,
        );
        for _ in 0..quota {
            match ctx.queue.try_pop() {
                Polled::Job(job) => {
                    sched.admit(engine, &ctx.pool, &ctx.stats, *job);
                }
                _ => break,
            }
        }
        // phase B: collect the submitted round's outputs and apply them
        sched.tick_shared_complete(engine, &ctx.pool, &ctx.stats);
    }
}

/// Production backend: loads the model (and optional draft model) from
/// artifacts and serves with a [`build_engine`] engine.
pub struct ModelBackend {
    pub root: std::path::PathBuf,
    pub model: String,
    pub draft_model: Option<String>,
    pub kind: EngineKind,
    pub cfg: ServeConfig,
}

impl WorkerBackend for ModelBackend {
    fn run(&self, worker: usize, ctx: WorkerCtx) {
        let paths = ArtifactPaths::new(self.root.clone(), &self.model);
        // draft models stay worker-owned even in shared mode: their
        // forwards are a different hot path (and model) than the fused
        // target steps
        let draft_rt = match &self.draft_model {
            Some(dm) => match Runtime::load(&ArtifactPaths::new(self.root.clone(), dm)) {
                Ok(rt) => Some(rt),
                Err(e) => return ctx.fail(e),
            },
            None => None,
        };
        let draft_dev = draft_rt.as_ref().map(|d| d as &dyn Device);
        if let Some(handle) = ctx.dispatcher() {
            // shared-runtime topology: no worker-local target runtime —
            // every device call round-trips through the dispatcher
            let shared = match SharedRuntime::connect(&paths, worker, handle.clone()) {
                Ok(s) => s,
                Err(e) => return ctx.fail(e),
            };
            let mut engine = match build_engine(
                self.kind,
                &shared,
                draft_dev,
                &paths,
                &self.cfg,
                worker as u64,
            ) {
                Ok(e) => e,
                Err(e) => return ctx.fail(e),
            };
            ctx.ready();
            serve_jobs(worker, engine.as_mut(), &ctx);
            return;
        }
        let rt = match Runtime::load(&paths) {
            Ok(rt) => rt,
            Err(e) => return ctx.fail(e),
        };
        let mut engine =
            match build_engine(self.kind, &rt, draft_dev, &paths, &self.cfg, worker as u64) {
                Ok(e) => e,
                Err(e) => return ctx.fail(e),
            };
        ctx.ready();
        serve_jobs(worker, engine.as_mut(), &ctx);
        // queue closed and drained: flush this worker's device-call
        // counters (target model only — draft forwards are a different
        // hot path and would skew forwards-per-token)
        drop(engine);
        let mut stats = rt.take_stats();
        // attribute this worker-owned runtime's fused rows to the worker
        if stats.batch_rows > 0 {
            stats.rows_by_worker.insert(worker, stats.batch_rows);
        }
        ctx.absorb_runtime_stats(&stats);
    }

    fn run_device(&self, host: DeviceHost) {
        // shared-runtime device host: loads THE runtime and serves every
        // worker's submissions from this one thread (PJRT clients are
        // not Send, so the runtime lives and dies here)
        let paths = ArtifactPaths::new(self.root.clone(), &self.model);
        let rt = match Runtime::load(&paths) {
            Ok(rt) => rt,
            Err(e) => return host.fail(e),
        };
        let agg = host.runtime_agg();
        host.serve(&rt);
        agg.absorb(&rt.take_stats());
    }
}

/// Handle to a running worker pool.
pub struct Coordinator {
    queue: Arc<WorkQueue>,
    pool: Arc<SharedCachePool>,
    stats: Arc<QueueStats>,
    rt_agg: Arc<RuntimeAgg>,
    dispatch_stats: Arc<DispatchStats>,
    collector_tx: mpsc::Sender<Response>,
    collector_rx: Mutex<mpsc::Receiver<Response>>,
    queue_capacity: usize,
    n_workers: usize,
    policy: SchedPolicy,
    tracer: Arc<Tracer>,
    latency: Arc<RequestLatency>,
    /// submission-side track: one Recv instant per accepted request
    server_track: TraceTrack,
    /// turns served per session id — a session seen before is a
    /// *resume*, and its admission checkout is expected to hit the
    /// prefix store instead of re-prefilling the conversation
    sessions: Mutex<HashMap<String, u64>>,
    workers: Vec<JoinHandle<()>>,
    /// the shared-runtime device-host thread (policy.shared_runtime);
    /// joined after the workers so its request senders are gone first
    device: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn `workers` threads, each loading the model and serving
    /// requests from the shared queue under the default scheduling
    /// policy.  Blocks until every worker is ready (or one fails).
    pub fn spawn(
        root: std::path::PathBuf,
        model: String,
        draft_model: Option<String>,
        kind: EngineKind,
        cfg: ServeConfig,
        workers: usize,
    ) -> Result<Coordinator> {
        Self::spawn_with_policy(root, model, draft_model, kind, cfg, workers, SchedPolicy::default())
    }

    /// [`Coordinator::spawn`] with an explicit step-scheduling policy
    /// (`--max-inflight`, max queue age).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn_with_policy(
        root: std::path::PathBuf,
        model: String,
        draft_model: Option<String>,
        kind: EngineKind,
        cfg: ServeConfig,
        workers: usize,
        policy: SchedPolicy,
    ) -> Result<Coordinator> {
        Self::spawn_with_backend_policy(
            Arc::new(ModelBackend { root, model, draft_model, kind, cfg }),
            workers,
            policy,
        )
    }

    /// Spawn over an arbitrary backend with the default policy.
    pub fn spawn_with_backend(
        backend: Arc<dyn WorkerBackend>,
        workers: usize,
    ) -> Result<Coordinator> {
        Self::spawn_with_backend_policy(backend, workers, SchedPolicy::default())
    }

    /// Spawn over an arbitrary backend (tests inject engine mocks here;
    /// everything above the engine — queue, scheduler, pool, seeds,
    /// routing, metrics — is the production code path).
    pub fn spawn_with_backend_policy(
        backend: Arc<dyn WorkerBackend>,
        workers: usize,
        policy: SchedPolicy,
    ) -> Result<Coordinator> {
        if workers == 0 {
            return Err(anyhow!("coordinator needs at least one worker"));
        }
        if policy.max_inflight == 0 {
            return Err(anyhow!("max_inflight must be at least 1"));
        }
        let queue = Arc::new(WorkQueue::with_discipline(policy.sched_policy));
        // the pool cap is exactly the admission budget: one cache per
        // in-flight sequence, across all workers.  With --kv-blocks the
        // caches are paged and jointly bounded by the page budget too,
        // with prefix sharing on.
        let cache_cap = workers * policy.max_inflight;
        let pool = Arc::new(match policy.kv_blocks {
            Some(blocks) => SharedCachePool::with_block_budget(cache_cap, blocks),
            None => SharedCachePool::new(cache_cap),
        });
        let stats = Arc::new(QueueStats::new());
        let rt_agg = Arc::new(RuntimeAgg::default());
        let dispatch_stats = Arc::new(DispatchStats::default());
        let tracer = Tracer::wall();
        let latency = Arc::new(RequestLatency::default());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        // shared-runtime topology: ONE device-host thread owns the
        // runtime; workers get dispatcher handles instead
        let mut ready_count = workers;
        let (dispatch_handle, device) = if policy.shared_runtime {
            let (handle, mut dispatcher) =
                DeviceDispatcher::channel(DEFAULT_WINDOW, Arc::clone(&dispatch_stats));
            // --pipelined: the dispatcher double-buffers — a collector
            // stage stages round k+1 (window + collation) while the
            // device stage executes round k
            dispatcher.set_pipelined(policy.pipelined);
            dispatcher.set_tracer(&tracer);
            let host = DeviceHost {
                dispatcher,
                rt_agg: Arc::clone(&rt_agg),
                ready: Mutex::new(Some(ready_tx.clone())),
            };
            let backend = Arc::clone(&backend);
            ready_count += 1;
            (
                Some(handle),
                Some(std::thread::spawn(move || backend.run_device(host))),
            )
        } else {
            (None, None)
        };

        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let ctx = WorkerCtx {
                queue: Arc::clone(&queue),
                pool: Arc::clone(&pool),
                stats: Arc::clone(&stats),
                rt_agg: Arc::clone(&rt_agg),
                policy,
                dispatch: dispatch_handle.clone(),
                trace: Arc::clone(&tracer),
                latency: Arc::clone(&latency),
                ready: Mutex::new(Some(ready_tx.clone())),
            };
            let backend = Arc::clone(&backend);
            handles.push(std::thread::spawn(move || backend.run(w, ctx)));
        }
        drop(ready_tx);
        // workers hold the only live dispatcher senders from here on:
        // when the pool drains, the dispatcher sees disconnect and exits
        drop(dispatch_handle);

        let mut startup: Result<()> = Ok(());
        for _ in 0..ready_count {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    startup = Err(e);
                    break;
                }
                Err(_) => {
                    startup = Err(anyhow!("worker died during startup"));
                    break;
                }
            }
        }
        if let Err(e) = startup {
            queue.close();
            for h in handles {
                let _ = h.join();
            }
            if let Some(d) = device {
                let _ = d.join();
            }
            return Err(e);
        }

        let (collector_tx, collector_rx) = mpsc::channel();
        let server_track = tracer.track("server");
        Ok(Coordinator {
            queue,
            pool,
            stats,
            rt_agg,
            dispatch_stats,
            collector_tx,
            collector_rx: Mutex::new(collector_rx),
            queue_capacity: workers * DEFAULT_QUEUE_PER_WORKER,
            n_workers: workers,
            policy,
            tracer,
            latency,
            server_track,
            sessions: Mutex::new(HashMap::new()),
            workers: handles,
            device,
        })
    }

    pub fn workers(&self) -> usize {
        self.n_workers
    }

    /// The step-scheduling policy every worker runs under.
    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Queue/backpressure counters (live).
    pub fn queue_stats(&self) -> &QueueStats {
        &self.stats
    }

    /// Handle to the workers' aggregated device-call counters.  Workers
    /// flush on drain, so the snapshot is complete only after the
    /// coordinator is dropped — keep a clone of this handle across the
    /// drop to read final forwards-per-token (see
    /// `examples/serve_requests.rs`).
    pub fn runtime_agg(&self) -> Arc<RuntimeAgg> {
        Arc::clone(&self.rt_agg)
    }

    /// Dispatcher-side counters (cross-worker fused widths, queue
    /// depth).  All-zero unless the policy runs `--shared-runtime`.
    pub fn dispatch_stats(&self) -> &DispatchStats {
        &self.dispatch_stats
    }

    /// The pool's flight recorder: flip its sampling gate
    /// (`--trace-sample`), inspect its rings, or snapshot it.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Per-request latency recorder (always-on histograms; optional
    /// raw-sample retention for benches and tests).
    pub fn request_latency(&self) -> &Arc<RequestLatency> {
        &self.latency
    }

    /// Chrome trace-event snapshot of the flight recorder — the payload
    /// of the TCP protocol's `trace` request, loadable in Perfetto.
    pub fn trace_json(&self) -> crate::util::json::Json {
        self.tracer.chrome_trace_json()
    }

    /// Live serving metrics as one Prometheus-exposition text block —
    /// the payload of the TCP protocol's `metrics` request.
    pub fn metrics_text(&self) -> String {
        let mut text = self.stats.to_prometheus();
        text.push_str(&self.dispatch_stats.to_prometheus());
        // runtime-side device-call counters, keyed (tree bucket, kv
        // context) so short-KV variant executions are not aggregated
        // into the full-ctx bucket line.  Workers flush on drain, so
        // these go live at end-of-run; the live view of kv selection
        // is ppd_dispatch_kv_bucket above.
        let rt = self.rt_agg.snapshot();
        for (&(n, kv), &(c, _)) in &rt.per_bucket {
            text.push_str(&format!(
                "ppd_runtime_bucket_forwards_total{{n=\"{n}\",kv=\"{kv}\"}} {c}\n"
            ));
        }
        for (&kv, &c) in &rt.per_kv {
            text.push_str(&format!("ppd_runtime_kv_forwards_total{{kv=\"{kv}\"}} {c}\n"));
        }
        for (&kv, &c) in &rt.batch_per_kv {
            text.push_str(&format!(
                "ppd_runtime_batch_kv_forwards_total{{kv=\"{kv}\"}} {c}\n"
            ));
        }
        text.push_str(&format!("ppd_workers {}\n", self.n_workers));
        text.push_str(&format!(
            "ppd_shared_runtime {}\n",
            u8::from(self.policy.shared_runtime)
        ));
        text.push_str(&format!("ppd_caches_created {}\n", self.pool.created()));
        text.push_str(&format!("ppd_caches_outstanding {}\n", self.pool.outstanding()));
        // paged-KV accounting: all four read zero on slab pools (no
        // --kv-blocks), so the lines are stable either way
        text.push_str(&format!("ppd_kvcache_blocks_used {}\n", self.pool.blocks_used()));
        text.push_str(&format!("ppd_kvcache_blocks_free {}\n", self.pool.blocks_free()));
        text.push_str(&format!("ppd_prefix_hits_total {}\n", self.pool.prefix_hits()));
        text.push_str(&format!(
            "ppd_prefix_blocks_shared_total {}\n",
            self.pool.prefix_blocks_shared()
        ));
        text.push_str(&format!("ppd_queue_capacity {}\n", self.queue_capacity));
        // streaming + session + SLO-scheduling counters (PR 10)
        text.push_str(&format!(
            "ppd_stream_events_total {}\n",
            self.stats.stream_events_total()
        ));
        text.push_str(&format!(
            "ppd_session_resumes_total {}\n",
            self.stats.session_resumes_total()
        ));
        text.push_str(&format!(
            "ppd_session_prefix_turn_hits_total {}\n",
            self.stats.session_prefix_turn_hits_total()
        ));
        text.push_str(&format!(
            "ppd_sched_preemptions_total {}\n",
            self.queue.preemptions()
        ));
        text.push_str(&self.latency.to_prometheus());
        text.push_str(&format!(
            "ppd_trace_ring_dropped_total {}\n",
            self.tracer.dropped_total()
        ));
        text
    }

    /// Total KV caches the pool ever allocated
    /// (≤ workers × max_inflight).
    pub fn caches_created(&self) -> usize {
        self.pool.created()
    }

    /// KV caches currently checked out (one per in-flight sequence).
    pub fn caches_outstanding(&self) -> usize {
        self.pool.outstanding()
    }

    /// Peak resident KV bytes across the run: live pages at high water
    /// for block-budgeted pools, whole slabs for classic pools.
    pub fn resident_kv_bytes(&self) -> usize {
        self.pool.resident_kv_bytes()
    }

    /// Prompt-prefix store hits served so far (0 without `--kv-blocks`).
    pub fn prefix_hits(&self) -> u64 {
        self.pool.prefix_hits()
    }

    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    pub fn set_queue_capacity(&mut self, cap: usize) {
        self.queue_capacity = cap.max(1);
    }

    /// Submit to the coordinator's own collector; pair with [`recv`].
    ///
    /// [`recv`]: Coordinator::recv
    pub fn submit(&self, req: Request) -> Result<()> {
        self.submit_routed(req, self.collector_tx.clone())
    }

    /// Submit with a caller-owned reply channel (one sender per TCP
    /// connection / batch — the out-of-order completion routing).
    pub fn submit_routed(&self, req: Request, reply: mpsc::Sender<Response>) -> Result<()> {
        self.submit_cancellable(req, reply, CancelFlag::new())
    }

    /// [`Coordinator::submit_routed`] with a caller-held cancel flag:
    /// setting the flag aborts the request wherever it is — dropped at
    /// admission if still queued, or retired mid-flight with its KV
    /// cache returned to the pool.
    pub fn submit_cancellable(
        &self,
        req: Request,
        reply: mpsc::Sender<Response>,
        cancel: CancelFlag,
    ) -> Result<()> {
        self.submit_inner(req, reply, cancel, None)
    }

    /// Streaming submit: `Started`/`Tokens` frames flow through
    /// `events` as the request progresses, and the terminal `Response`
    /// still arrives on `reply` (the server synthesizes the terminal
    /// `Done`/`Error` frame from it, so every retirement path — refuse,
    /// expiry, worker teardown — closes the stream without extra
    /// plumbing).
    pub fn submit_streaming(
        &self,
        req: Request,
        reply: mpsc::Sender<Response>,
        events: mpsc::Sender<ResponseEvent>,
        cancel: CancelFlag,
    ) -> Result<()> {
        self.submit_inner(req, reply, cancel, Some(events))
    }

    /// Backpressure-aware [`Coordinator::submit_streaming`]:
    /// `Ok(false)` when the queue is at capacity.
    pub fn try_submit_streaming(
        &self,
        req: Request,
        reply: mpsc::Sender<Response>,
        events: mpsc::Sender<ResponseEvent>,
        cancel: CancelFlag,
    ) -> Result<bool> {
        if self.queue.depth() >= self.queue_capacity {
            self.stats.on_reject();
            return Ok(false);
        }
        self.submit_inner(req, reply, cancel, Some(events))?;
        Ok(true)
    }

    fn submit_inner(
        &self,
        req: Request,
        reply: mpsc::Sender<Response>,
        cancel: CancelFlag,
        events: Option<mpsc::Sender<ResponseEvent>>,
    ) -> Result<()> {
        // one clock read stamps both the Recv instant and the job's
        // enqueue origin, so queue-wait/TTFT/e2e samples and the trace
        // chain share a timeline exactly
        let now_us = self.tracer.now_us();
        self.server_track.instant(Phase::Recv, req.id, 0, 0, now_us);
        // session affinity: count turns per session id so admission can
        // attribute prefix-store hits to resumed conversations
        let resumed = match &req.session {
            Some(sid) => {
                let mut sessions = self.sessions.lock().unwrap();
                let turns = sessions.entry(sid.clone()).or_insert(0);
                let resumed = *turns > 0;
                *turns += 1;
                resumed
            }
            None => false,
        };
        if resumed {
            self.stats.on_session_resume();
        }
        let job = Job {
            req,
            enqueued: Instant::now(),
            enqueue_us: now_us,
            cancel,
            reply,
            events,
            resumed,
        };
        match self.queue.push(job) {
            Ok(depth) => {
                self.stats.on_enqueue(depth);
                Ok(())
            }
            Err(_) => Err(anyhow!("coordinator is shut down")),
        }
    }

    /// Backpressure-aware submit: `Ok(false)` (and a rejected-counter
    /// bump) when the queue is at capacity, instead of queueing without
    /// bound.
    pub fn try_submit_routed(
        &self,
        req: Request,
        reply: mpsc::Sender<Response>,
    ) -> Result<bool> {
        self.try_submit_cancellable(req, reply, CancelFlag::new())
    }

    /// Backpressure-aware submit with a caller-held cancel flag.
    pub fn try_submit_cancellable(
        &self,
        req: Request,
        reply: mpsc::Sender<Response>,
        cancel: CancelFlag,
    ) -> Result<bool> {
        if self.queue.depth() >= self.queue_capacity {
            self.stats.on_reject();
            return Ok(false);
        }
        self.submit_cancellable(req, reply, cancel)?;
        Ok(true)
    }

    /// Next completed response from [`submit`] (completion order, not
    /// submission order).
    ///
    /// [`submit`]: Coordinator::submit
    pub fn recv(&self) -> Result<Response> {
        self.collector_rx
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| anyhow!("workers gone"))
    }

    /// Submit a batch and collect all responses, reassembled into the
    /// order of `reqs` by request id (workers complete out of order).
    pub fn run_batch(&self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let order: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        let n = reqs.len();
        let (tx, rx) = mpsc::channel();
        for r in reqs {
            self.submit_routed(r, tx.clone())?;
        }
        drop(tx);
        let mut by_id: HashMap<u64, Vec<Response>> = HashMap::new();
        for _ in 0..n {
            let resp = rx.recv().map_err(|_| anyhow!("workers gone"))?;
            by_id.entry(resp.id).or_default().push(resp);
        }
        order
            .into_iter()
            .map(|id| {
                by_id
                    .get_mut(&id)
                    .and_then(|v| v.pop())
                    .ok_or_else(|| anyhow!("missing response for request {id}"))
            })
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // workers are gone, so their dispatcher senders are dropped and
        // the device host's run loop exits; join it last
        if let Some(d) = self.device.take() {
            let _ = d.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::parse("ppd").unwrap(), EngineKind::Ppd);
        assert_eq!(EngineKind::parse("spec+ppd").unwrap(), EngineKind::SpecPpd);
        assert!(EngineKind::parse("nope").is_err());
        for k in EngineKind::all() {
            EngineKind::parse(k).unwrap();
        }
    }

    #[test]
    fn zero_workers_rejected() {
        struct Noop;
        impl WorkerBackend for Noop {
            fn run(&self, _w: usize, ctx: WorkerCtx) {
                ctx.ready();
            }
        }
        assert!(Coordinator::spawn_with_backend(Arc::new(Noop), 0).is_err());
    }

    #[test]
    fn zero_inflight_rejected() {
        struct Noop;
        impl WorkerBackend for Noop {
            fn run(&self, _w: usize, ctx: WorkerCtx) {
                ctx.ready();
            }
        }
        let policy = SchedPolicy { max_inflight: 0, ..Default::default() };
        assert!(Coordinator::spawn_with_backend_policy(Arc::new(Noop), 1, policy).is_err());
    }

    #[test]
    fn failed_worker_fails_spawn() {
        struct Failing;
        impl WorkerBackend for Failing {
            fn run(&self, _w: usize, ctx: WorkerCtx) {
                ctx.fail(anyhow!("no artifacts here"));
            }
        }
        let err = Coordinator::spawn_with_backend(Arc::new(Failing), 2).unwrap_err();
        assert!(format!("{err}").contains("no artifacts"));
    }
}
