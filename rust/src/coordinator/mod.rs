//! L3 coordinator: request queue + worker loop + TCP server.
//!
//! The PJRT client is not `Send`, so the worker thread *owns* its
//! `Runtime` and engine — the coordinator hands requests over an mpsc
//! channel and receives responses on another (vLLM's
//! router/worker split at miniature scale, batch size 1 per the paper's
//! evaluation setting).

pub mod request;
pub mod server;

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{ArtifactPaths, ServeConfig};
use crate::decoding::lookup::{ChainEngine, LookaheadProposer, PldProposer, RestProposer};
use crate::decoding::medusa::MedusaEngine;
use crate::decoding::ppd::PpdEngine;
use crate::decoding::speculative::SpeculativeEngine;
use crate::decoding::vanilla::VanillaEngine;
use crate::decoding::DecodeEngine;
use crate::runtime::Runtime;
use crate::tree::builder::AcceptStats;
use crate::workload;

pub use request::{parse_request_line, Request, Response};

/// Which engine the worker runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Vanilla,
    Ppd,
    Medusa,
    Pld,
    Rest,
    Lookahead,
    Spec,
    SpecPpd,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "vanilla" => EngineKind::Vanilla,
            "ppd" => EngineKind::Ppd,
            "medusa" => EngineKind::Medusa,
            "pld" => EngineKind::Pld,
            "rest" => EngineKind::Rest,
            "lookahead" => EngineKind::Lookahead,
            "spec" => EngineKind::Spec,
            "spec+ppd" | "spec-ppd" => EngineKind::SpecPpd,
            other => return Err(anyhow!("unknown engine '{other}'")),
        })
    }

    pub fn all() -> &'static [&'static str] {
        &["vanilla", "ppd", "medusa", "pld", "rest", "lookahead", "spec", "spec+ppd"]
    }
}

/// Build an engine over runtimes the caller owns (single-threaded use:
/// examples, benches).  `draft` is required for the speculative kinds.
pub fn build_engine<'rt>(
    kind: EngineKind,
    rt: &'rt Runtime,
    draft: Option<&'rt Runtime>,
    paths: &ArtifactPaths,
    cfg: &ServeConfig,
    seed: u64,
) -> Result<Box<dyn DecodeEngine + 'rt>> {
    let stats_path = paths.accept_stats(None);
    Ok(match kind {
        EngineKind::Vanilla => Box::new(VanillaEngine::new(rt, cfg.temperature, seed)),
        EngineKind::Ppd => {
            let stats = AcceptStats::load(&stats_path, "ppd")?;
            Box::new(PpdEngine::new(rt, &stats, cfg, seed)?)
        }
        EngineKind::Medusa => {
            let stats = AcceptStats::load(&stats_path, "medusa")?;
            // Medusa's static tree gets the same *total* token budget
            // (candidates + prompts) PPD uses, per the paper's equal-
            // budget comparisons
            let n = cfg.n_candidates + cfg.n_prompt_budget;
            Box::new(MedusaEngine::new(rt, &stats, cfg, n, seed)?)
        }
        EngineKind::Pld => {
            Box::new(ChainEngine::new(rt, PldProposer { span: 4 }, 4, 16, seed))
        }
        EngineKind::Rest => {
            let datastore = workload::load_val_stream(&paths.root)?;
            Box::new(ChainEngine::new(
                rt,
                RestProposer { datastore, span: 4, max_hits: 3 },
                4,
                16,
                seed,
            ))
        }
        EngineKind::Lookahead => {
            Box::new(ChainEngine::new(rt, LookaheadProposer::new(4), 4, 16, seed))
        }
        EngineKind::Spec => {
            let draft = draft.ok_or_else(|| anyhow!("spec engine needs a draft model"))?;
            Box::new(SpeculativeEngine::new_vanilla(rt, draft, 4, seed))
        }
        EngineKind::SpecPpd => {
            let draft = draft.ok_or_else(|| anyhow!("spec+ppd engine needs a draft model"))?;
            let draft_paths = ArtifactPaths::new(paths.root.clone(), &draft.cfg.name);
            let stats = AcceptStats::load(&draft_paths.accept_stats(None), "ppd")?;
            Box::new(SpeculativeEngine::new_ppd(rt, draft, &stats, cfg, 4, seed)?)
        }
    })
}

/// Handle to a running worker.
pub struct Coordinator {
    tx: mpsc::Sender<(Request, Instant)>,
    rx: mpsc::Receiver<Response>,
    worker: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn a worker that loads the model and serves requests FIFO.
    pub fn spawn(
        root: std::path::PathBuf,
        model: String,
        draft_model: Option<String>,
        kind: EngineKind,
        cfg: ServeConfig,
    ) -> Result<Coordinator> {
        let (tx, work_rx) = mpsc::channel::<(Request, Instant)>();
        let (resp_tx, rx) = mpsc::channel::<Response>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let worker = std::thread::spawn(move || {
            let paths = ArtifactPaths::new(root.clone(), &model);
            let rt = match Runtime::load(&paths) {
                Ok(rt) => rt,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let draft_rt = match draft_model {
                Some(dm) => match Runtime::load(&ArtifactPaths::new(root.clone(), &dm)) {
                    Ok(rt) => Some(rt),
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                },
                None => None,
            };
            let mut engine = match build_engine(kind, &rt, draft_rt.as_ref(), &paths, &cfg, 0) {
                Ok(e) => e,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let _ = ready_tx.send(Ok(()));
            while let Ok((req, enqueued)) = work_rx.recv() {
                let queue_s = enqueued.elapsed().as_secs_f64();
                let resp = match engine.generate(&req.prompt, req.max_new) {
                    Ok(r) => Response {
                        id: req.id,
                        text: workload::decode(&r.tokens),
                        tau: r.tau(),
                        steps: r.steps,
                        decode_s: r.decode_s,
                        prefill_s: r.prefill_s,
                        queue_s,
                        tokens: r.tokens,
                        error: None,
                    },
                    Err(e) => Response::error(req.id, format!("{e:#}")),
                };
                if resp_tx.send(resp).is_err() {
                    break;
                }
            }
        });

        ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))??;
        Ok(Coordinator { tx, rx, worker: Some(worker) })
    }

    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .send((req, Instant::now()))
            .map_err(|_| anyhow!("worker gone"))
    }

    pub fn recv(&self) -> Result<Response> {
        self.rx.recv().map_err(|_| anyhow!("worker gone"))
    }

    /// Submit a batch and collect all responses (FIFO order).
    pub fn run_batch(&self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let n = reqs.len();
        for r in reqs {
            self.submit(r)?;
        }
        (0..n).map(|_| self.recv()).collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // closing tx ends the worker loop
        let (dead_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, dead_tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::parse("ppd").unwrap(), EngineKind::Ppd);
        assert_eq!(EngineKind::parse("spec+ppd").unwrap(), EngineKind::SpecPpd);
        assert!(EngineKind::parse("nope").is_err());
        for k in EngineKind::all() {
            EngineKind::parse(k).unwrap();
        }
    }
}
