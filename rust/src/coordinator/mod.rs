//! L3 coordinator: a multi-worker serving layer.
//!
//! ```text
//!   submitters (TCP conns, batch drivers)
//!        │  submit / try_submit (backpressure)
//!        ▼
//!   ┌──────────────┐      ┌───────────────────────────────┐
//!   │  WorkQueue   │ ───▶ │ worker 0..N: Runtime + engine │──▶ reply
//!   │ (mutex+cv)   │      │  cache ⇄ SharedCachePool      │    channels
//!   └──────────────┘      └───────────────────────────────┘
//! ```
//!
//! * The PJRT client is not `Send`, so each worker thread *owns* its
//!   `Runtime` and engine (vLLM's router/worker split at miniature
//!   scale).  Workers pull from one shared [`queue::WorkQueue`].
//! * Completions are **out of order**: every job carries its own reply
//!   channel, so concurrent submitters each get exactly their
//!   responses, and [`Coordinator::run_batch`] reassembles batch
//!   results by request id.
//! * KV caches are checked out of a [`SharedCachePool`] per request —
//!   at most one cache allocation per worker, ever — instead of living
//!   inside engines.
//! * Each request carries an RNG seed and workers call
//!   `engine.begin_request(seed)` first, so output is a pure function
//!   of (prompt, max_new, seed): identical across worker counts and
//!   placements, byte-identical to the single-worker path.
//! * Queue depth / backpressure / busy-worker accounting lives in
//!   [`crate::metrics::QueueStats`].
//!
//! Workers are abstracted behind [`WorkerBackend`] so the concurrency
//! machinery is testable without model artifacts (see
//! `rust/tests/coordinator.rs`); [`ModelBackend`] is the production
//! implementation that loads artifacts and builds a real engine.

pub mod queue;
pub mod request;
pub mod server;

use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{ArtifactPaths, ServeConfig};
use crate::decoding::lookup::{ChainEngine, LookaheadProposer, PldProposer, RestProposer};
use crate::decoding::medusa::MedusaEngine;
use crate::decoding::ppd::PpdEngine;
use crate::decoding::speculative::SpeculativeEngine;
use crate::decoding::vanilla::VanillaEngine;
use crate::decoding::DecodeEngine;
use crate::kvcache::SharedCachePool;
use crate::metrics::QueueStats;
use crate::runtime::Runtime;
use crate::tree::builder::AcceptStats;
use crate::workload;

use queue::{Job, WorkQueue};
pub use request::{parse_request_line, Request, Response};

/// Soft queue bound per worker used by the backpressure-aware submit.
pub const DEFAULT_QUEUE_PER_WORKER: usize = 64;

/// Which engine the workers run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Vanilla,
    Ppd,
    Medusa,
    Pld,
    Rest,
    Lookahead,
    Spec,
    SpecPpd,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "vanilla" => EngineKind::Vanilla,
            "ppd" => EngineKind::Ppd,
            "medusa" => EngineKind::Medusa,
            "pld" => EngineKind::Pld,
            "rest" => EngineKind::Rest,
            "lookahead" => EngineKind::Lookahead,
            "spec" => EngineKind::Spec,
            "spec+ppd" | "spec-ppd" => EngineKind::SpecPpd,
            other => return Err(anyhow!("unknown engine '{other}'")),
        })
    }

    pub fn all() -> &'static [&'static str] {
        &["vanilla", "ppd", "medusa", "pld", "rest", "lookahead", "spec", "spec+ppd"]
    }
}

/// Build an engine over runtimes the caller owns (single-threaded use:
/// examples, benches).  `draft` is required for the speculative kinds.
pub fn build_engine<'rt>(
    kind: EngineKind,
    rt: &'rt Runtime,
    draft: Option<&'rt Runtime>,
    paths: &ArtifactPaths,
    cfg: &ServeConfig,
    seed: u64,
) -> Result<Box<dyn DecodeEngine + 'rt>> {
    let stats_path = paths.accept_stats(None);
    Ok(match kind {
        EngineKind::Vanilla => Box::new(VanillaEngine::new(rt, cfg.temperature, seed)),
        EngineKind::Ppd => {
            let stats = AcceptStats::load(&stats_path, "ppd")?;
            Box::new(PpdEngine::new(rt, &stats, cfg, seed)?)
        }
        EngineKind::Medusa => {
            let stats = AcceptStats::load(&stats_path, "medusa")?;
            // Medusa's static tree gets the same *total* token budget
            // (candidates + prompts) PPD uses, per the paper's equal-
            // budget comparisons
            let n = cfg.n_candidates + cfg.n_prompt_budget;
            Box::new(MedusaEngine::new(rt, &stats, cfg, n, seed)?)
        }
        EngineKind::Pld => {
            Box::new(ChainEngine::new(rt, PldProposer { span: 4 }, 4, 16, seed))
        }
        EngineKind::Rest => {
            let datastore = workload::load_val_stream(&paths.root)?;
            Box::new(ChainEngine::new(
                rt,
                RestProposer { datastore, span: 4, max_hits: 3 },
                4,
                16,
                seed,
            ))
        }
        EngineKind::Lookahead => {
            Box::new(ChainEngine::new(rt, LookaheadProposer::new(4), 4, 16, seed))
        }
        EngineKind::Spec => {
            let draft = draft.ok_or_else(|| anyhow!("spec engine needs a draft model"))?;
            Box::new(SpeculativeEngine::new_vanilla(rt, draft, 4, seed))
        }
        EngineKind::SpecPpd => {
            let draft = draft.ok_or_else(|| anyhow!("spec+ppd engine needs a draft model"))?;
            let draft_paths = ArtifactPaths::new(paths.root.clone(), &draft.cfg.name);
            let stats = AcceptStats::load(&draft_paths.accept_stats(None), "ppd")?;
            Box::new(SpeculativeEngine::new_ppd(rt, draft, &stats, cfg, 4, seed)?)
        }
    })
}

/// Shared state handed to every worker thread.
pub struct WorkerCtx {
    queue: Arc<WorkQueue>,
    pool: Arc<SharedCachePool>,
    stats: Arc<QueueStats>,
    /// one-shot startup signal (taken on first use so a worker that
    /// panics before signaling drops its sender and fails spawn fast)
    ready: Mutex<Option<mpsc::Sender<Result<()>>>>,
}

impl WorkerCtx {
    fn signal(&self, r: Result<()>) {
        if let Some(tx) = self.ready.lock().unwrap().take() {
            let _ = tx.send(r);
        }
    }

    /// Report successful startup; unblocks `Coordinator::spawn`.
    pub fn ready(&self) {
        self.signal(Ok(()));
    }

    /// Report failed startup; `Coordinator::spawn` returns this error.
    pub fn fail(&self, e: anyhow::Error) {
        self.signal(Err(e));
    }
}

/// Builds one worker's engine and serves jobs until the queue closes.
/// Implementations call `ctx.ready()` (or `ctx.fail(e)`) once setup is
/// done, then hand their engine to [`serve_jobs`].
pub trait WorkerBackend: Send + Sync + 'static {
    fn run(&self, worker: usize, ctx: WorkerCtx);
}

/// The shared worker loop: pop → checkout cache → seed → generate →
/// checkin → reply.  Split out of [`WorkerBackend`] impls so mock
/// backends in tests exercise the exact production path.
///
/// A panic inside `generate_with_cache` is caught and turned into an
/// error response: with the single-threaded mpsc design a dead worker
/// surfaced as "worker gone", but here a silently-dead worker would
/// leave queued jobs holding reply senders forever and wedge every
/// submitter — the worker must outlive any one bad request.
pub fn serve_jobs(worker: usize, engine: &mut dyn DecodeEngine, ctx: &WorkerCtx) {
    while let Some(job) = ctx.queue.pop() {
        ctx.stats.on_dequeue();
        let queue_s = job.enqueued.elapsed().as_secs_f64();
        let (l, s, d) = engine.cache_shape();
        let mut cache = ctx.pool.checkout(l, s, d);
        engine.begin_request(job.req.seed);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.generate_with_cache(&job.req.prompt, job.req.max_new, &mut cache)
        }));
        let resp = match outcome {
            Ok(Ok(r)) => Response {
                id: job.req.id,
                text: workload::decode(&r.tokens),
                tau: r.tau(),
                steps: r.steps,
                decode_s: r.decode_s,
                prefill_s: r.prefill_s,
                queue_s,
                worker,
                tokens: r.tokens,
                error: None,
            },
            Ok(Err(e)) => {
                let mut resp = Response::error(job.req.id, format!("{e:#}"));
                resp.queue_s = queue_s;
                resp.worker = worker;
                resp
            }
            Err(panic) => {
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "unknown panic".into());
                let mut resp = Response::error(job.req.id, format!("worker panicked: {msg}"));
                resp.queue_s = queue_s;
                resp.worker = worker;
                resp
            }
        };
        ctx.pool.checkin(cache);
        ctx.stats.on_complete();
        // a submitter that went away just discards its response
        let _ = job.reply.send(resp);
    }
}

/// Production backend: loads the model (and optional draft model) from
/// artifacts and serves with a [`build_engine`] engine.
pub struct ModelBackend {
    pub root: std::path::PathBuf,
    pub model: String,
    pub draft_model: Option<String>,
    pub kind: EngineKind,
    pub cfg: ServeConfig,
}

impl WorkerBackend for ModelBackend {
    fn run(&self, worker: usize, ctx: WorkerCtx) {
        let paths = ArtifactPaths::new(self.root.clone(), &self.model);
        let rt = match Runtime::load(&paths) {
            Ok(rt) => rt,
            Err(e) => return ctx.fail(e),
        };
        let draft_rt = match &self.draft_model {
            Some(dm) => match Runtime::load(&ArtifactPaths::new(self.root.clone(), dm)) {
                Ok(rt) => Some(rt),
                Err(e) => return ctx.fail(e),
            },
            None => None,
        };
        let mut engine =
            match build_engine(self.kind, &rt, draft_rt.as_ref(), &paths, &self.cfg, worker as u64)
            {
                Ok(e) => e,
                Err(e) => return ctx.fail(e),
            };
        ctx.ready();
        serve_jobs(worker, engine.as_mut(), &ctx);
    }
}

/// Handle to a running worker pool.
pub struct Coordinator {
    queue: Arc<WorkQueue>,
    pool: Arc<SharedCachePool>,
    stats: Arc<QueueStats>,
    collector_tx: mpsc::Sender<Response>,
    collector_rx: Mutex<mpsc::Receiver<Response>>,
    queue_capacity: usize,
    n_workers: usize,
    workers: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn `workers` threads, each loading the model and serving
    /// requests from the shared queue.  Blocks until every worker is
    /// ready (or one fails).
    pub fn spawn(
        root: std::path::PathBuf,
        model: String,
        draft_model: Option<String>,
        kind: EngineKind,
        cfg: ServeConfig,
        workers: usize,
    ) -> Result<Coordinator> {
        Self::spawn_with_backend(
            Arc::new(ModelBackend { root, model, draft_model, kind, cfg }),
            workers,
        )
    }

    /// Spawn over an arbitrary backend (tests inject engine mocks here;
    /// everything above the engine — queue, pool, seeds, routing,
    /// metrics — is the production code path).
    pub fn spawn_with_backend(
        backend: Arc<dyn WorkerBackend>,
        workers: usize,
    ) -> Result<Coordinator> {
        if workers == 0 {
            return Err(anyhow!("coordinator needs at least one worker"));
        }
        let queue = Arc::new(WorkQueue::new());
        let pool = Arc::new(SharedCachePool::new());
        let stats = Arc::new(QueueStats::new());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let ctx = WorkerCtx {
                queue: Arc::clone(&queue),
                pool: Arc::clone(&pool),
                stats: Arc::clone(&stats),
                ready: Mutex::new(Some(ready_tx.clone())),
            };
            let backend = Arc::clone(&backend);
            handles.push(std::thread::spawn(move || backend.run(w, ctx)));
        }
        drop(ready_tx);

        let mut startup: Result<()> = Ok(());
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    startup = Err(e);
                    break;
                }
                Err(_) => {
                    startup = Err(anyhow!("worker died during startup"));
                    break;
                }
            }
        }
        if let Err(e) = startup {
            queue.close();
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }

        let (collector_tx, collector_rx) = mpsc::channel();
        Ok(Coordinator {
            queue,
            pool,
            stats,
            collector_tx,
            collector_rx: Mutex::new(collector_rx),
            queue_capacity: workers * DEFAULT_QUEUE_PER_WORKER,
            n_workers: workers,
            workers: handles,
        })
    }

    pub fn workers(&self) -> usize {
        self.n_workers
    }

    /// Queue/backpressure counters (live).
    pub fn queue_stats(&self) -> &QueueStats {
        &self.stats
    }

    /// Total KV caches the pool ever allocated (≤ worker count).
    pub fn caches_created(&self) -> usize {
        self.pool.created()
    }

    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    pub fn set_queue_capacity(&mut self, cap: usize) {
        self.queue_capacity = cap.max(1);
    }

    /// Submit to the coordinator's own collector; pair with [`recv`].
    ///
    /// [`recv`]: Coordinator::recv
    pub fn submit(&self, req: Request) -> Result<()> {
        self.submit_routed(req, self.collector_tx.clone())
    }

    /// Submit with a caller-owned reply channel (one sender per TCP
    /// connection / batch — the out-of-order completion routing).
    pub fn submit_routed(&self, req: Request, reply: mpsc::Sender<Response>) -> Result<()> {
        let job = Job { req, enqueued: Instant::now(), reply };
        match self.queue.push(job) {
            Ok(depth) => {
                self.stats.on_enqueue(depth);
                Ok(())
            }
            Err(_) => Err(anyhow!("coordinator is shut down")),
        }
    }

    /// Backpressure-aware submit: `Ok(false)` (and a rejected-counter
    /// bump) when the queue is at capacity, instead of queueing without
    /// bound.
    pub fn try_submit_routed(
        &self,
        req: Request,
        reply: mpsc::Sender<Response>,
    ) -> Result<bool> {
        if self.queue.depth() >= self.queue_capacity {
            self.stats.on_reject();
            return Ok(false);
        }
        self.submit_routed(req, reply)?;
        Ok(true)
    }

    /// Next completed response from [`submit`] (completion order, not
    /// submission order).
    ///
    /// [`submit`]: Coordinator::submit
    pub fn recv(&self) -> Result<Response> {
        self.collector_rx
            .lock()
            .unwrap()
            .recv()
            .map_err(|_| anyhow!("workers gone"))
    }

    /// Submit a batch and collect all responses, reassembled into the
    /// order of `reqs` by request id (workers complete out of order).
    pub fn run_batch(&self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let order: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        let n = reqs.len();
        let (tx, rx) = mpsc::channel();
        for r in reqs {
            self.submit_routed(r, tx.clone())?;
        }
        drop(tx);
        let mut by_id: HashMap<u64, Vec<Response>> = HashMap::new();
        for _ in 0..n {
            let resp = rx.recv().map_err(|_| anyhow!("workers gone"))?;
            by_id.entry(resp.id).or_default().push(resp);
        }
        order
            .into_iter()
            .map(|id| {
                by_id
                    .get_mut(&id)
                    .and_then(|v| v.pop())
                    .ok_or_else(|| anyhow!("missing response for request {id}"))
            })
            .collect()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_kind_parsing() {
        assert_eq!(EngineKind::parse("ppd").unwrap(), EngineKind::Ppd);
        assert_eq!(EngineKind::parse("spec+ppd").unwrap(), EngineKind::SpecPpd);
        assert!(EngineKind::parse("nope").is_err());
        for k in EngineKind::all() {
            EngineKind::parse(k).unwrap();
        }
    }

    #[test]
    fn zero_workers_rejected() {
        struct Noop;
        impl WorkerBackend for Noop {
            fn run(&self, _w: usize, ctx: WorkerCtx) {
                ctx.ready();
            }
        }
        assert!(Coordinator::spawn_with_backend(Arc::new(Noop), 0).is_err());
    }

    #[test]
    fn failed_worker_fails_spawn() {
        struct Failing;
        impl WorkerBackend for Failing {
            fn run(&self, _w: usize, ctx: WorkerCtx) {
                ctx.fail(anyhow!("no artifacts here"));
            }
        }
        let err = Coordinator::spawn_with_backend(Arc::new(Failing), 2).unwrap_err();
        assert!(format!("{err}").contains("no artifacts"));
    }
}
