//! Step-level continuous batching: one worker, many in-flight
//! sequences, one PPD tree step per sequence per tick — and, under
//! `--fuse-steps`, **one device call** per tick for all of them.
//!
//! ```text
//!            WorkQueue ──try_pop──┐  (admission between steps,
//!                                 ▼   up to --max-inflight)
//!   ┌──────────────── StepScheduler ────────────────┐
//!   │ seq A ──step──▶ seq B ──step──▶ seq C ──step─▶│  round-robin
//!   │   │ cache A        │ cache B       │ cache C  │  one tick
//!   └───┼────────────────┼──────────────┼───────────┘
//!       ▼ retired on EOS/budget/cancel  ▼
//!     reply channel (out-of-order)    cache → SharedCachePool
//!
//!   fused tick (--fuse-steps):
//!     plan(A) plan(B) plan(C) ──▶ forward_batch ──▶ apply(A..C)
//!                                   (1 call)
//! ```
//!
//! This replaces the run-to-completion worker loop: a short request
//! admitted behind a long one no longer waits for the long one to
//! drain — it interleaves at the decode-step granularity (vLLM-style
//! continuous batching, the deployment metric speculative-decoding
//! papers neglect).  Correctness rests on the [`SeqState`] refactor:
//! every piece of per-sequence state (tokens, RNG, proposer pools, the
//! speculative draft cache) travels with the sequence, so admitting a
//! sequence mid-flight can never perturb another's output — asserted
//! token-exactly by `rust/tests/scheduler.rs`.
//!
//! The scheduler is deliberately synchronous and free of threads: the
//! worker loop ([`super::serve_jobs`]) drives it with `admit`/`tick`
//! calls, and the deterministic test harness scripts those same calls
//! directly to pin down admission/step/retire orderings.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use crate::batch::{BatchItem, BatchStepEngine, PlanInputs, StepPlan, StepResult};
use crate::decoding::{SeqState, StepOutcome};
use crate::kvcache::{HostKvCache, SharedCachePool};
use crate::metrics::QueueStats;
use crate::workload;

use super::queue::Job;
use super::request::Response;

/// Default per-worker in-flight sequence budget (`--max-inflight`).
pub const DEFAULT_MAX_INFLIGHT: usize = 4;

/// Per-worker scheduling policy.
#[derive(Debug, Clone, Copy)]
pub struct SchedPolicy {
    /// sequences a worker interleaves at once (≥ 1); 1 reproduces the
    /// run-to-completion behavior exactly
    pub max_inflight: usize,
    /// drop jobs older than this at admission (stale work never reaches
    /// a decode step); `None` disables the age check
    pub max_queue_age: Option<Duration>,
    /// fuse every in-flight sequence's decode step into one
    /// `forward_batch` device call per tick (`--fuse-steps`); engines
    /// without a plan/apply split still step per-sequence, token-exact
    /// either way
    pub fuse_steps: bool,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy {
            max_inflight: DEFAULT_MAX_INFLIGHT,
            max_queue_age: None,
            fuse_steps: false,
        }
    }
}

/// One admitted sequence: its job (id, reply channel, cancel flag), its
/// resumable decode state, and the KV cache checked out for its
/// lifetime.
struct Inflight {
    job: Job,
    queue_s: f64,
    seq: SeqState,
    cache: HostKvCache,
}

/// The per-worker step scheduler.  Drive it with [`StepScheduler::admit`]
/// (one popped job) and [`StepScheduler::tick`] (one round-robin pass);
/// it owns the in-flight set and returns every cache to the pool on
/// retirement, including error/cancel paths.
pub struct StepScheduler {
    worker: usize,
    policy: SchedPolicy,
    running: VecDeque<Inflight>,
}

impl StepScheduler {
    pub fn new(worker: usize, policy: SchedPolicy) -> Self {
        StepScheduler { worker, policy, running: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.running.len()
    }

    pub fn is_empty(&self) -> bool {
        self.running.is_empty()
    }

    pub fn has_capacity(&self) -> bool {
        self.running.len() < self.policy.max_inflight.max(1)
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Admit one job popped off the work queue: run the queue-age and
    /// cancellation checks, check a KV cache out of the pool, and
    /// prefill via [`crate::decoding::DecodeEngine::begin_seq`].
    /// Returns `true` when the
    /// job joined the in-flight set; on every refusal path the job's
    /// reply channel gets an error [`Response`] instead.
    pub fn admit(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
        job: Job,
    ) -> bool {
        stats.on_dequeue();
        let queue_s = job.enqueued.elapsed().as_secs_f64();
        if job.cancel.is_cancelled() {
            stats.on_cancel();
            self.refuse(stats, job, queue_s, "cancelled before admission".into());
            return false;
        }
        if let Some(age) = self.policy.max_queue_age {
            if job.enqueued.elapsed() > age {
                stats.on_expire();
                self.refuse(
                    stats,
                    job,
                    queue_s,
                    format!("dropped: queued {queue_s:.3}s > max queue age {:.3}s", age.as_secs_f64()),
                );
                return false;
            }
        }
        let (l, s, d) = engine.cache_shape();
        let mut cache = match pool.checkout(l, s, d) {
            Ok(c) => c,
            Err(e) => {
                self.refuse(stats, job, queue_s, format!("{e}"));
                return false;
            }
        };
        let begun = catch_unwind(AssertUnwindSafe(|| {
            engine.begin_seq(&job.req.prompt, job.req.max_new, job.req.seed, &mut cache)
        }));
        match begun {
            Ok(Ok(seq)) => {
                stats.on_admit(self.running.len() + 1);
                self.running.push_back(Inflight { job, queue_s, seq, cache });
                true
            }
            Ok(Err(e)) => {
                pool.checkin(cache);
                self.refuse(stats, job, queue_s, format!("{e:#}"));
                false
            }
            Err(panic) => {
                pool.checkin(cache);
                self.refuse(stats, job, queue_s, format!("worker panicked: {}", panic_msg(panic)));
                false
            }
        }
    }

    /// One round-robin pass: every in-flight sequence takes exactly one
    /// decode step (cancelled sequences are aborted instead), finished
    /// sequences retire with their response, and their caches go back
    /// to the pool.  Returns the number of sequences still in flight.
    ///
    /// Under `fuse_steps` the pass runs in two phases — collect every
    /// sequence's [`BatchStepEngine::plan_step`], issue **one**
    /// `forward_batch` over all collected plans, then apply each
    /// sequence's slice of the result.  Sequences whose engine has no
    /// plan/apply split fall back to the monolithic `step` inside the
    /// same tick, so mixed support stays correct.
    pub fn tick(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) -> usize {
        if self.policy.fuse_steps {
            self.tick_fused(engine, pool, stats)
        } else {
            self.tick_serial(engine, pool, stats)
        }
    }

    /// Route one sequence's step/apply result: keep it running, retire
    /// it with its response, or retire it with the error/panic message.
    /// Shared by the serial tick, the fused tick's fallback arm, and
    /// the fused apply phase, so the three paths cannot drift.
    fn settle(
        &mut self,
        fl: Inflight,
        stepped: std::thread::Result<anyhow::Result<StepOutcome>>,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) {
        match stepped {
            Ok(Ok(StepOutcome::Running)) => self.running.push_back(fl),
            Ok(Ok(StepOutcome::Finished(_))) => self.retire_ok(fl, pool, stats),
            Ok(Err(e)) => self.retire_err(fl, pool, stats, format!("{e:#}")),
            Err(panic) => {
                self.retire_err(fl, pool, stats, format!("worker panicked: {}", panic_msg(panic)))
            }
        }
    }

    /// The unfused pass: one `forward` per sequence (PR 2 behavior).
    fn tick_serial(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) -> usize {
        for _ in 0..self.running.len() {
            let mut fl = self.running.pop_front().expect("non-empty running set");
            if fl.job.cancel.is_cancelled() {
                // mid-flight abort: roll the cache back and free it
                fl.cache.reset();
                stats.on_cancel();
                self.retire_err(fl, pool, stats, "cancelled mid-flight".into());
                continue;
            }
            stats.on_step();
            let stepped =
                catch_unwind(AssertUnwindSafe(|| engine.step(&mut fl.seq, &mut fl.cache)));
            self.settle(fl, stepped, pool, stats);
        }
        self.running.len()
    }

    /// The fused pass: plan everything, one device call, apply
    /// everything.  Token-exactness vs [`StepScheduler::tick_serial`]
    /// rests on plan/forward/apply being the *same code* `step` runs
    /// (see `batch::step_via_plan`) plus `forward_batch` being
    /// row-equivalent to per-row `forward` — both are asserted by the
    /// deterministic harness in `rust/tests/scheduler.rs`.
    fn tick_fused(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) -> usize {
        // phase 1: cancellation checks + plans (finish/fallback paths
        // resolve immediately, fused plans accumulate)
        let mut fused: Vec<(Inflight, PlanInputs)> = Vec::new();
        for _ in 0..self.running.len() {
            let mut fl = self.running.pop_front().expect("non-empty running set");
            if fl.job.cancel.is_cancelled() {
                fl.cache.reset();
                stats.on_cancel();
                self.retire_err(fl, pool, stats, "cancelled mid-flight".into());
                continue;
            }
            stats.on_step();
            let planned =
                catch_unwind(AssertUnwindSafe(|| engine.plan_step(&mut fl.seq, &fl.cache)));
            match planned {
                Ok(Ok(StepPlan::Forward(plan))) => fused.push((fl, plan)),
                Ok(Ok(StepPlan::Finished(_))) => self.retire_ok(fl, pool, stats),
                Ok(Ok(StepPlan::Fallback)) => {
                    // no plan/apply split: monolithic per-sequence step
                    let stepped = catch_unwind(AssertUnwindSafe(|| {
                        engine.step(&mut fl.seq, &mut fl.cache)
                    }));
                    self.settle(fl, stepped, pool, stats);
                }
                Ok(Err(e)) => self.retire_err(fl, pool, stats, format!("{e:#}")),
                Err(panic) => self.retire_err(
                    fl,
                    pool,
                    stats,
                    format!("worker panicked: {}", panic_msg(panic)),
                ),
            }
        }
        if fused.is_empty() {
            return self.running.len();
        }

        // phase 2: one fused forward over every planned sequence
        stats.on_fused_batch(fused.len());
        let t0 = std::time::Instant::now();
        let forwarded = {
            let items: Vec<BatchItem<'_>> = fused
                .iter()
                .map(|(fl, plan)| BatchItem { plan, cache: &fl.cache })
                .collect();
            catch_unwind(AssertUnwindSafe(|| engine.forward_batch(&items)))
        };
        // attribute the shared device call evenly across its riders
        let share = t0.elapsed().as_secs_f64() / fused.len() as f64;

        // phase 3: apply each sequence's slice of the result
        match forwarded {
            Ok(Ok(outs)) if outs.len() == fused.len() => {
                for ((mut fl, plan), out) in fused.into_iter().zip(outs) {
                    fl.seq.res.decode_s += share;
                    let applied = catch_unwind(AssertUnwindSafe(|| {
                        engine.apply_step(
                            &mut fl.seq,
                            &StepResult { plan: &plan, out: &out },
                            &mut fl.cache,
                        )
                    }));
                    self.settle(fl, applied, pool, stats);
                }
            }
            Ok(Ok(outs)) => {
                let msg = format!(
                    "forward_batch returned {} outputs for {} plans",
                    outs.len(),
                    fused.len()
                );
                for (fl, _) in fused {
                    self.retire_err(fl, pool, stats, msg.clone());
                }
            }
            Ok(Err(e)) => {
                let msg = format!("{e:#}");
                for (fl, _) in fused {
                    self.retire_err(fl, pool, stats, msg.clone());
                }
            }
            Err(panic) => {
                let msg = format!("worker panicked: {}", panic_msg(panic));
                for (fl, _) in fused {
                    self.retire_err(fl, pool, stats, msg.clone());
                }
            }
        }
        self.running.len()
    }

    /// Refuse a job that never entered the in-flight set.
    fn refuse(&self, stats: &QueueStats, job: Job, queue_s: f64, msg: String) {
        let mut resp = Response::error(job.req.id, msg);
        resp.queue_s = queue_s;
        resp.worker = self.worker;
        stats.on_complete();
        // a submitter that went away just discards its response
        let _ = job.reply.send(resp);
    }

    fn retire_ok(&self, fl: Inflight, pool: &SharedCachePool, stats: &QueueStats) {
        let Inflight { job, queue_s, seq, cache } = fl;
        pool.checkin(cache);
        let r = seq.into_result();
        let resp = Response {
            id: job.req.id,
            text: workload::decode(&r.tokens),
            tau: r.tau(),
            steps: r.steps,
            decode_s: r.decode_s,
            prefill_s: r.prefill_s,
            queue_s,
            worker: self.worker,
            tokens: r.tokens,
            error: None,
        };
        stats.on_complete();
        let _ = job.reply.send(resp);
    }

    fn retire_err(&self, fl: Inflight, pool: &SharedCachePool, stats: &QueueStats, msg: String) {
        let Inflight { job, queue_s, cache, .. } = fl;
        pool.checkin(cache);
        let mut resp = Response::error(job.req.id, msg);
        resp.queue_s = queue_s;
        resp.worker = self.worker;
        stats.on_complete();
        let _ = job.reply.send(resp);
    }
}

fn panic_msg(panic: Box<dyn std::any::Any + Send>) -> String {
    panic
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic".into())
}
