//! Step-level continuous batching: one worker, many in-flight
//! sequences, one PPD tree step per sequence per tick.
//!
//! ```text
//!            WorkQueue ──try_pop──┐  (admission between steps,
//!                                 ▼   up to --max-inflight)
//!   ┌──────────────── StepScheduler ────────────────┐
//!   │ seq A ──step──▶ seq B ──step──▶ seq C ──step─▶│  round-robin
//!   │   │ cache A        │ cache B       │ cache C  │  one tick
//!   └───┼────────────────┼──────────────┼───────────┘
//!       ▼ retired on EOS/budget/cancel  ▼
//!     reply channel (out-of-order)    cache → SharedCachePool
//! ```
//!
//! This replaces the run-to-completion worker loop: a short request
//! admitted behind a long one no longer waits for the long one to
//! drain — it interleaves at the decode-step granularity (vLLM-style
//! continuous batching, the deployment metric speculative-decoding
//! papers neglect).  Correctness rests on the [`SeqState`] refactor:
//! every piece of per-sequence state (tokens, RNG, proposer pools, the
//! speculative draft cache) travels with the sequence, so admitting a
//! sequence mid-flight can never perturb another's output — asserted
//! token-exactly by `rust/tests/scheduler.rs`.
//!
//! The scheduler is deliberately synchronous and free of threads: the
//! worker loop ([`super::serve_jobs`]) drives it with `admit`/`tick`
//! calls, and the deterministic test harness scripts those same calls
//! directly to pin down admission/step/retire orderings.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use crate::decoding::{DecodeEngine, SeqState, StepOutcome};
use crate::kvcache::{HostKvCache, SharedCachePool};
use crate::metrics::QueueStats;
use crate::workload;

use super::queue::Job;
use super::request::Response;

/// Default per-worker in-flight sequence budget (`--max-inflight`).
pub const DEFAULT_MAX_INFLIGHT: usize = 4;

/// Per-worker scheduling policy.
#[derive(Debug, Clone, Copy)]
pub struct SchedPolicy {
    /// sequences a worker interleaves at once (≥ 1); 1 reproduces the
    /// run-to-completion behavior exactly
    pub max_inflight: usize,
    /// drop jobs older than this at admission (stale work never reaches
    /// a decode step); `None` disables the age check
    pub max_queue_age: Option<Duration>,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy { max_inflight: DEFAULT_MAX_INFLIGHT, max_queue_age: None }
    }
}

/// One admitted sequence: its job (id, reply channel, cancel flag), its
/// resumable decode state, and the KV cache checked out for its
/// lifetime.
struct Inflight {
    job: Job,
    queue_s: f64,
    seq: SeqState,
    cache: HostKvCache,
}

/// The per-worker step scheduler.  Drive it with [`StepScheduler::admit`]
/// (one popped job) and [`StepScheduler::tick`] (one round-robin pass);
/// it owns the in-flight set and returns every cache to the pool on
/// retirement, including error/cancel paths.
pub struct StepScheduler {
    worker: usize,
    policy: SchedPolicy,
    running: VecDeque<Inflight>,
}

impl StepScheduler {
    pub fn new(worker: usize, policy: SchedPolicy) -> Self {
        StepScheduler { worker, policy, running: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.running.len()
    }

    pub fn is_empty(&self) -> bool {
        self.running.is_empty()
    }

    pub fn has_capacity(&self) -> bool {
        self.running.len() < self.policy.max_inflight.max(1)
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Admit one job popped off the work queue: run the queue-age and
    /// cancellation checks, check a KV cache out of the pool, and
    /// prefill via [`DecodeEngine::begin_seq`].  Returns `true` when the
    /// job joined the in-flight set; on every refusal path the job's
    /// reply channel gets an error [`Response`] instead.
    pub fn admit(
        &mut self,
        engine: &mut dyn DecodeEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
        job: Job,
    ) -> bool {
        stats.on_dequeue();
        let queue_s = job.enqueued.elapsed().as_secs_f64();
        if job.cancel.is_cancelled() {
            stats.on_cancel();
            self.refuse(stats, job, queue_s, "cancelled before admission".into());
            return false;
        }
        if let Some(age) = self.policy.max_queue_age {
            if job.enqueued.elapsed() > age {
                stats.on_expire();
                self.refuse(
                    stats,
                    job,
                    queue_s,
                    format!("dropped: queued {queue_s:.3}s > max queue age {:.3}s", age.as_secs_f64()),
                );
                return false;
            }
        }
        let (l, s, d) = engine.cache_shape();
        let mut cache = match pool.checkout(l, s, d) {
            Ok(c) => c,
            Err(e) => {
                self.refuse(stats, job, queue_s, format!("{e}"));
                return false;
            }
        };
        let begun = catch_unwind(AssertUnwindSafe(|| {
            engine.begin_seq(&job.req.prompt, job.req.max_new, job.req.seed, &mut cache)
        }));
        match begun {
            Ok(Ok(seq)) => {
                stats.on_admit(self.running.len() + 1);
                self.running.push_back(Inflight { job, queue_s, seq, cache });
                true
            }
            Ok(Err(e)) => {
                pool.checkin(cache);
                self.refuse(stats, job, queue_s, format!("{e:#}"));
                false
            }
            Err(panic) => {
                pool.checkin(cache);
                self.refuse(stats, job, queue_s, format!("worker panicked: {}", panic_msg(panic)));
                false
            }
        }
    }

    /// One round-robin pass: every in-flight sequence takes exactly one
    /// decode step (cancelled sequences are aborted instead), finished
    /// sequences retire with their response, and their caches go back
    /// to the pool.  Returns the number of sequences still in flight.
    pub fn tick(
        &mut self,
        engine: &mut dyn DecodeEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) -> usize {
        for _ in 0..self.running.len() {
            let mut fl = self.running.pop_front().expect("non-empty running set");
            if fl.job.cancel.is_cancelled() {
                // mid-flight abort: roll the cache back and free it
                fl.cache.reset();
                stats.on_cancel();
                self.retire_err(fl, pool, stats, "cancelled mid-flight".into());
                continue;
            }
            stats.on_step();
            let stepped =
                catch_unwind(AssertUnwindSafe(|| engine.step(&mut fl.seq, &mut fl.cache)));
            match stepped {
                Ok(Ok(StepOutcome::Running)) => self.running.push_back(fl),
                Ok(Ok(StepOutcome::Finished(_))) => self.retire_ok(fl, pool, stats),
                Ok(Err(e)) => self.retire_err(fl, pool, stats, format!("{e:#}")),
                Err(panic) => {
                    self.retire_err(fl, pool, stats, format!("worker panicked: {}", panic_msg(panic)))
                }
            }
        }
        self.running.len()
    }

    /// Refuse a job that never entered the in-flight set.
    fn refuse(&self, stats: &QueueStats, job: Job, queue_s: f64, msg: String) {
        let mut resp = Response::error(job.req.id, msg);
        resp.queue_s = queue_s;
        resp.worker = self.worker;
        stats.on_complete();
        // a submitter that went away just discards its response
        let _ = job.reply.send(resp);
    }

    fn retire_ok(&self, fl: Inflight, pool: &SharedCachePool, stats: &QueueStats) {
        let Inflight { job, queue_s, seq, cache } = fl;
        pool.checkin(cache);
        let r = seq.into_result();
        let resp = Response {
            id: job.req.id,
            text: workload::decode(&r.tokens),
            tau: r.tau(),
            steps: r.steps,
            decode_s: r.decode_s,
            prefill_s: r.prefill_s,
            queue_s,
            worker: self.worker,
            tokens: r.tokens,
            error: None,
        };
        stats.on_complete();
        let _ = job.reply.send(resp);
    }

    fn retire_err(&self, fl: Inflight, pool: &SharedCachePool, stats: &QueueStats, msg: String) {
        let Inflight { job, queue_s, cache, .. } = fl;
        pool.checkin(cache);
        let mut resp = Response::error(job.req.id, msg);
        resp.queue_s = queue_s;
        resp.worker = self.worker;
        stats.on_complete();
        let _ = job.reply.send(resp);
    }
}

fn panic_msg(panic: Box<dyn std::any::Any + Send>) -> String {
    panic
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic".into())
}
