//! Step-level continuous batching: one worker, many in-flight
//! sequences, one PPD tree step per sequence per tick — and, under
//! `--fuse-steps`, **one device call** per tick for all of them.
//!
//! ```text
//!            WorkQueue ──try_pop──┐  (admission between steps,
//!                                 ▼   up to --max-inflight)
//!   ┌──────────────── StepScheduler ────────────────┐
//!   │ seq A ──step──▶ seq B ──step──▶ seq C ──step─▶│  round-robin
//!   │   │ cache A        │ cache B       │ cache C  │  one tick
//!   └───┼────────────────┼──────────────┼───────────┘
//!       ▼ retired on EOS/budget/cancel  ▼
//!     reply channel (out-of-order)    cache → SharedCachePool
//!
//!   fused tick (--fuse-steps):
//!     plan(A) plan(B) plan(C) ──▶ forward_batch ──▶ apply(A..C)
//!                                   (1 call)
//! ```
//!
//! This replaces the run-to-completion worker loop: a short request
//! admitted behind a long one no longer waits for the long one to
//! drain — it interleaves at the decode-step granularity (vLLM-style
//! continuous batching, the deployment metric speculative-decoding
//! papers neglect).  Correctness rests on the [`SeqState`] refactor:
//! every piece of per-sequence state (tokens, RNG, proposer pools, the
//! speculative draft cache) travels with the sequence, so admitting a
//! sequence mid-flight can never perturb another's output — asserted
//! token-exactly by `rust/tests/scheduler.rs`.
//!
//! The scheduler is deliberately synchronous and free of threads: the
//! worker loop ([`super::serve_jobs`]) drives it with `admit`/`tick`
//! calls, and the deterministic test harness scripts those same calls
//! directly to pin down admission/step/retire orderings.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

use crate::batch::dispatch::{DispatcherHandle, TickReply, TickRow};
use crate::batch::{BatchItem, BatchStepEngine, PlanInputs, StepPlan, StepResult};
use crate::decoding::{SeqState, StepOutcome};
use crate::kvcache::{HostKvCache, SharedCachePool};
use crate::metrics::{QueueStats, RequestLatency};
use crate::trace::{Phase, TraceTrack, NO_REQ};
use crate::util::panic_message;
use crate::workload;

use super::queue::Job;
use super::request::{Outcome, Response, ResponseEvent, Timing};

/// Default per-worker in-flight sequence budget (`--max-inflight`).
pub const DEFAULT_MAX_INFLIGHT: usize = 4;

/// The config-default `fwd_b{B}` batched-graph ladder, used by
/// [`admission_quota`] to size fuse-aware admission bursts.  Admission
/// only needs a *target width* — if the artifact set carries a
/// different ladder the dispatcher still picks the real bucket at
/// collation time, so a mismatch costs a little padding, never
/// correctness.
pub const FUSE_ADMIT_BUCKETS: &[usize] = &[2, 4, 8];

/// How long a dropping scheduler waits for an in-flight shared tick's
/// reply before declaring its caches lost (teardown reconciliation —
/// see [`StepScheduler`]'s `Drop`).  A live dispatcher flushes the
/// round within its coalescing window (≤ ~5ms), and a dead one
/// disconnects the channel instantly, so this bound is only reached
/// when the dispatcher is wedged mid-execution.
const PENDING_DRAIN_TIMEOUT: Duration = Duration::from_millis(250);

/// How many jobs a shared-runtime worker should admit this tick.
///
/// The default is one per tick — PR 2's pacing rule, which spreads a
/// burst across workers so no scheduler hoovers the whole queue — but
/// when a backlog is visible (`queue_depth > 1`) trickling one job per
/// tick keeps the fused batch narrow for several rounds.  Fuse-aware
/// admission instead fills the in-flight set up to the next
/// `fwd_b{B}` batch-bucket boundary in one tick, so the cross-worker
/// union reaches a compiled batched graph's width immediately.
pub fn admission_quota(
    queue_depth: usize,
    running: usize,
    max_inflight: usize,
    buckets: &[usize],
) -> usize {
    let cap = max_inflight.max(1).saturating_sub(running).min(queue_depth);
    if cap == 0 {
        return 0;
    }
    if queue_depth <= 1 {
        // no backlog: the pacing rule stays in force
        return 1;
    }
    let target = buckets.iter().copied().filter(|&b| b > running).min().unwrap_or(running + 1);
    (target - running).clamp(1, cap)
}

/// Which job the work queue hands out next (`--sched-policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QueueDiscipline {
    /// arrival order (every PR since the seed)
    #[default]
    Fifo,
    /// SLO-aware selection: strict [`super::request::Priority`] classes,
    /// a per-tenant fairness counter within a class, shortest-remaining
    /// -first within a fairness tie, arrival order last.  Queue-head
    /// jumps are counted as `ppd_sched_preemptions_total`.
    Slo,
}

impl QueueDiscipline {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "fifo" => Ok(QueueDiscipline::Fifo),
            "slo" => Ok(QueueDiscipline::Slo),
            other => Err(anyhow::anyhow!(
                "unknown scheduling policy '{other}' (expected 'fifo' or 'slo')"
            )),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            QueueDiscipline::Fifo => "fifo",
            QueueDiscipline::Slo => "slo",
        }
    }
}

/// Per-worker scheduling policy.
#[derive(Debug, Clone, Copy)]
pub struct SchedPolicy {
    /// sequences a worker interleaves at once (≥ 1); 1 reproduces the
    /// run-to-completion behavior exactly
    pub max_inflight: usize,
    /// drop jobs older than this at admission (stale work never reaches
    /// a decode step); `None` disables the age check
    pub max_queue_age: Option<Duration>,
    /// fuse every in-flight sequence's decode step into one
    /// `forward_batch` device call per tick (`--fuse-steps`); engines
    /// without a plan/apply split still step per-sequence, token-exact
    /// either way
    pub fuse_steps: bool,
    /// submit fused ticks to the coordinator's single
    /// [`crate::batch::dispatch::DeviceDispatcher`] instead of the
    /// worker's own device (`--shared-runtime`): all workers' steps
    /// coalesce into ONE device call per wall tick.  Implies fused
    /// planning; engines without a plan/apply split still step
    /// per-sequence (their device calls ride the dispatcher as solo
    /// requests when the engine holds a `SharedRuntime`).
    pub shared_runtime: bool,
    /// overlap host work with device work (`--pipelined`, implies
    /// `shared_runtime`): the worker loop admits and plans round k+1
    /// between submitting round k and applying its reply, the
    /// dispatcher double-buffers (collates round k+1's union while
    /// round k executes) and sizes its coalescing window from the
    /// observed p95 inter-submission spread, and admission is
    /// fuse-aware ([`admission_quota`]).  Token-exact vs the
    /// unpipelined shared path — only the overlap changes.
    pub pipelined: bool,
    /// page budget for the paged KV cache (`--kv-blocks`): when set,
    /// sequences draw fixed-size KV pages from a shared
    /// [`crate::kvcache::BlockPool`] bounded to this many live pages,
    /// identical prompt prefixes share pages copy-on-write, and
    /// admission refuses requests whose footprint does not fit.
    /// `None` keeps the classic one-slab-per-sequence caches.
    pub kv_blocks: Option<usize>,
    /// work-queue selection discipline (`--sched-policy fifo|slo`):
    /// `Slo` picks by priority class / per-tenant fairness / shortest-
    /// remaining-first instead of arrival order, and admission enforces
    /// per-request `deadline_ms` expiry
    pub sched_policy: QueueDiscipline,
    /// server-side default for v2 requests that do not say `"stream"`
    /// (`--stream`): reply with newline-delimited `ResponseEvent`s
    /// instead of one terminal line.  v1 requests never stream.
    pub stream: bool,
}

impl Default for SchedPolicy {
    fn default() -> Self {
        SchedPolicy {
            max_inflight: DEFAULT_MAX_INFLIGHT,
            max_queue_age: None,
            fuse_steps: false,
            shared_runtime: false,
            pipelined: false,
            kv_blocks: None,
            sched_policy: QueueDiscipline::Fifo,
            stream: false,
        }
    }
}

/// Observability attachment for one scheduler: the worker's trace
/// track plus the coordinator-wide latency histograms.  Timestamps come
/// off the track's injected clock, so the trace-event stream and the
/// histograms describe the same timeline (and the scripted-clock
/// harness controls both).
pub struct SchedObserver {
    pub track: TraceTrack,
    pub latency: Arc<RequestLatency>,
}

/// Per-request trace/latency bookkeeping, all in µs on the tracer
/// clock.  `mark_us` is the gapless-chain cursor: every span a request
/// records starts where its previous one ended.
#[derive(Debug, Clone, Copy, Default)]
struct ReqTiming {
    enqueue_us: u64,
    mark_us: u64,
    /// clock at the last token emission (TTFT vs ITL accounting)
    last_emit_us: Option<u64>,
    /// generated-token count at the last emission check
    tokens_seen: usize,
}

/// One admitted sequence: its job (id, reply channel, cancel flag), its
/// resumable decode state, and the KV cache checked out for its
/// lifetime.
struct Inflight {
    job: Job,
    queue_s: f64,
    seq: SeqState,
    cache: HostKvCache,
    t: ReqTiming,
    /// generated-token count already sent as `Tokens` stream frames —
    /// independent of the observer's `tokens_seen` so streaming works
    /// with or without a trace/latency attachment
    emitted: usize,
}

/// One sequence whose tick is in flight at the device dispatcher: its
/// cache (and plan) travel with the submission and come back with the
/// reply, so only the job/state halves stay here.
struct PendingRow {
    job: Job,
    queue_s: f64,
    seq: SeqState,
    t: ReqTiming,
    emitted: usize,
}

/// A submitted-but-not-yet-applied shared tick.
struct PendingTick {
    rows: Vec<PendingRow>,
    rx: std::sync::mpsc::Receiver<TickReply>,
}

/// The per-worker step scheduler.  Drive it with [`StepScheduler::admit`]
/// (one popped job) and [`StepScheduler::tick`] (one round-robin pass);
/// it owns the in-flight set and returns every cache to the pool on
/// retirement, including error/cancel paths.
pub struct StepScheduler {
    worker: usize,
    policy: SchedPolicy,
    running: VecDeque<Inflight>,
    /// shared-runtime mode: the handle fused ticks are submitted through
    dispatch: Option<DispatcherHandle>,
    /// whether this scheduler currently participates in the dispatcher's
    /// tick barrier (registered for the length of a busy spell)
    registered: bool,
    /// a submitted shared tick awaiting its reply/apply phase
    pending: Option<PendingTick>,
    /// teardown handles (shared-runtime mode only): `Drop` must be able
    /// to reconcile a still-pending tick's caches with the pool and
    /// count its error replies, without the worker loop's borrows
    teardown: Option<(Arc<SharedCachePool>, Arc<QueueStats>)>,
    /// trace track + latency histograms ([`StepScheduler::set_observer`])
    observer: Option<SchedObserver>,
    /// monotonically increasing tick number — the `round` key on this
    /// worker's trace events
    tick_seq: u64,
}

impl StepScheduler {
    pub fn new(worker: usize, policy: SchedPolicy) -> Self {
        StepScheduler {
            worker,
            policy,
            running: VecDeque::new(),
            dispatch: None,
            registered: false,
            pending: None,
            teardown: None,
            observer: None,
            tick_seq: 0,
        }
    }

    /// A scheduler in shared-runtime mode: fused ticks go to the
    /// coordinator's [`crate::batch::dispatch::DeviceDispatcher`]
    /// through `dispatch` and coalesce with every other worker's tick.
    /// The pool/stats handles let `Drop` reconcile a tick that is still
    /// at the dispatcher when the worker tears down.
    pub fn with_dispatcher(
        worker: usize,
        policy: SchedPolicy,
        dispatch: DispatcherHandle,
        pool: Arc<SharedCachePool>,
        stats: Arc<QueueStats>,
    ) -> Self {
        StepScheduler {
            worker,
            policy,
            running: VecDeque::new(),
            dispatch: Some(dispatch),
            registered: false,
            pending: None,
            teardown: Some((pool, stats)),
            observer: None,
            tick_seq: 0,
        }
    }

    /// Attach the worker's trace track and the coordinator-wide latency
    /// histograms.  Latency recording is always on once attached; span
    /// recording additionally obeys the tracer's sampling gate.
    pub fn set_observer(&mut self, observer: SchedObserver) {
        self.observer = Some(observer);
    }

    /// Clock read on the observer's timeline (`None` when detached).
    fn obs_now(&self) -> Option<u64> {
        self.observer.as_ref().map(|o| o.track.now_us())
    }

    /// Record `phase` as the next link of a request's gapless span
    /// chain: the span covers `[mark, now]` and the mark advances.
    fn note_span(&self, t: &mut ReqTiming, phase: Phase, req: u64) {
        if let Some(o) = &self.observer {
            let now = o.track.now_us();
            o.track.span(phase, req, self.tick_seq, 0, t.mark_us, now);
            t.mark_us = now;
        }
    }

    /// TTFT/ITL accounting + `emit` instant after a step that may have
    /// produced tokens.  One clock read serves both the histogram
    /// sample and the trace timestamp, so quantiles recomputed from the
    /// trace match the exported histograms exactly.
    fn note_emit(&self, fl: &mut Inflight) {
        let Some(o) = &self.observer else { return };
        let n = fl.seq.res.tokens.len();
        if n <= fl.t.tokens_seen {
            return;
        }
        let now = o.track.now_us();
        match fl.t.last_emit_us {
            None => o.latency.record_ttft(now.saturating_sub(fl.t.enqueue_us)),
            Some(prev) => o.latency.record_itl(now.saturating_sub(prev)),
        }
        o.track.instant(
            Phase::Emit,
            fl.job.req.id,
            self.tick_seq,
            (n - fl.t.tokens_seen) as u32,
            now,
        );
        fl.t.last_emit_us = Some(now);
        fl.t.tokens_seen = n;
    }

    /// Send any not-yet-streamed accepted tokens as one `Tokens` frame
    /// on the job's event channel (v2 streaming).  Deliberately NOT
    /// gated on the observer: production workers always attach one, but
    /// the deterministic harness does not, and streamed framing must be
    /// token-exact either way.
    fn stream_emit(&self, fl: &mut Inflight, stats: &QueueStats) {
        let Some(tx) = &fl.job.events else { return };
        let n = fl.seq.res.tokens.len();
        if n <= fl.emitted {
            return;
        }
        stats.on_stream_events(1);
        let _ = tx.send(ResponseEvent::Tokens {
            id: fl.job.req.id,
            step: fl.seq.res.steps,
            accepted: fl.seq.res.tokens[fl.emitted..].to_vec(),
        });
        fl.emitted = n;
    }

    /// Close out one scheduler tick's attribution span on the worker
    /// track (`round` = tick number, `n` = rows the tick touched).
    fn note_tick(&self, start: Option<u64>, rows: u32) {
        if let (Some(o), Some(start)) = (&self.observer, start) {
            o.track.span(Phase::Tick, NO_REQ, self.tick_seq, rows, start, o.track.now_us());
        }
    }

    /// Structured stderr record for a caught worker panic: the client
    /// gets the error response, this line is the server-side
    /// post-mortem breadcrumb.
    fn log_panic(&self, phase: &str, req: u64, msg: &str) {
        eprintln!("ppd-panic worker={} phase={phase} request={req} msg={msg:?}", self.worker);
    }

    /// Whether a submitted shared tick is awaiting its reply/apply
    /// phase — the pipelined worker loop must not exit (and the
    /// harness must not assume quiescence) while this holds.
    pub fn has_pending(&self) -> bool {
        self.pending.is_some()
    }

    /// In-flight sequence count — including rows of a submitted tick
    /// still at the dispatcher.  Pipelined admission runs *between*
    /// submit and complete, when every submitted row has been moved out
    /// of `running` into `pending`; counting only `running` there would
    /// let a worker admit past `max_inflight` (and past the cache
    /// pool's cap) every overlap window.
    pub fn len(&self) -> usize {
        self.running.len() + self.pending.as_ref().map_or(0, |p| p.rows.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn has_capacity(&self) -> bool {
        self.len() < self.policy.max_inflight.max(1)
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    /// Admit one job popped off the work queue: run the queue-age and
    /// cancellation checks, check a KV cache out of the pool, and
    /// prefill via [`crate::decoding::DecodeEngine::begin_seq`].
    /// Returns `true` when the
    /// job joined the in-flight set; on every refusal path the job's
    /// reply channel gets an error [`Response`] instead.
    pub fn admit(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
        job: Job,
    ) -> bool {
        stats.on_dequeue();
        let t_dequeue = self.obs_now();
        // one clock reading: the reported `queue_s` and the age-check
        // decision must agree (two `elapsed()` calls can straddle the
        // threshold and refuse a job while quoting a compliant age)
        let queued = job.enqueued.elapsed();
        let queue_s = queued.as_secs_f64();
        if job.cancel.is_cancelled() {
            stats.on_cancel();
            self.refuse(stats, job, queue_s, "cancelled before admission".into());
            return false;
        }
        if let Some(age) = self.policy.max_queue_age {
            if queued > age {
                stats.on_expire();
                self.refuse(
                    stats,
                    job,
                    queue_s,
                    format!("dropped: queued {queue_s:.3}s > max queue age {:.3}s", age.as_secs_f64()),
                );
                return false;
            }
        }
        // per-request deadline (v2 `deadline_ms`): stale work is
        // refused before it can occupy a cache or a decode step
        if let Some(dl) = job.req.deadline_ms {
            let deadline = Duration::from_millis(dl);
            if queued > deadline {
                stats.on_expire();
                self.refuse(
                    stats,
                    job,
                    queue_s,
                    format!("dropped: queued {queue_s:.3}s > deadline {dl}ms"),
                );
                return false;
            }
        }
        let (l, s, d) = engine.cache_shape();
        // prompt-aware checkout: block-budgeted pools seed shared
        // prefix pages and account admission in pages, not slabs
        let mut cache = match pool.checkout_for_prompt(l, s, d, &job.req.prompt) {
            Ok(c) => c,
            Err(e) => {
                self.refuse(stats, job, queue_s, format!("{e}"));
                return false;
            }
        };
        // a resumed session turn that found its conversation's pages in
        // the prefix store skipped that much re-prefill — the metric the
        // session tier is judged by
        if job.resumed && cache.prefix_len() > 0 {
            stats.on_session_prefix_turn_hit();
        }
        let begun = catch_unwind(AssertUnwindSafe(|| {
            engine.begin_seq(&job.req.prompt, job.req.max_new, job.req.seed, &mut cache)
        }));
        match begun {
            Ok(Ok(seq)) => {
                // the prompt is prefilled: record its full KV chunks in
                // the shared prefix store so identical prefixes ride
                // these pages instead of recomputing (no-op on slabs)
                pool.publish_prefix(&cache, &job.req.prompt);
                stats.on_admit(self.len() + 1);
                let mut t = ReqTiming {
                    enqueue_us: job.enqueue_us,
                    tokens_seen: seq.res.tokens.len(),
                    ..Default::default()
                };
                if let (Some(o), Some(start)) = (&self.observer, t_dequeue) {
                    // queue wait ends where admission begins; admission
                    // (cache checkout + prefill) ends at `now`
                    o.latency.record_queue_wait(start.saturating_sub(job.enqueue_us));
                    let (id, tick) = (job.req.id, self.tick_seq);
                    o.track.span(Phase::Enqueue, id, tick, 0, job.enqueue_us, start);
                    let now = o.track.now_us();
                    o.track.span(Phase::Admit, job.req.id, self.tick_seq, 0, start, now);
                    t.mark_us = now;
                }
                if let Some(tx) = &job.events {
                    stats.on_stream_events(1);
                    let _ = tx.send(ResponseEvent::Started {
                        id: job.req.id,
                        worker: self.worker,
                    });
                }
                let mut fl = Inflight { job, queue_s, seq, cache, t, emitted: 0 };
                // engines may accept tokens during prefill — frame them
                // before the first tick so the stream is gapless
                self.stream_emit(&mut fl, stats);
                self.running.push_back(fl);
                true
            }
            Ok(Err(e)) => {
                pool.checkin(cache);
                self.refuse(stats, job, queue_s, format!("{e:#}"));
                false
            }
            Err(panic) => {
                pool.checkin(cache);
                let msg = panic_message(panic);
                self.log_panic("admit", job.req.id, &msg);
                self.refuse(stats, job, queue_s, format!("worker panicked: {msg}"));
                false
            }
        }
    }

    /// One round-robin pass: every in-flight sequence takes exactly one
    /// decode step (cancelled sequences are aborted instead), finished
    /// sequences retire with their response, and their caches go back
    /// to the pool.  Returns the number of sequences still in flight.
    ///
    /// Under `fuse_steps` the pass runs in two phases — collect every
    /// sequence's [`BatchStepEngine::plan_step`], issue **one**
    /// `forward_batch` over all collected plans, then apply each
    /// sequence's slice of the result.  Sequences whose engine has no
    /// plan/apply split fall back to the monolithic `step` inside the
    /// same tick, so mixed support stays correct.
    pub fn tick(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) -> usize {
        if self.dispatch.is_some() {
            self.tick_shared_submit(engine, pool, stats);
            self.tick_shared_complete(engine, pool, stats)
        } else if self.policy.fuse_steps {
            self.tick_fused(engine, pool, stats)
        } else {
            self.tick_serial(engine, pool, stats)
        }
    }

    /// Route one sequence's step/apply result: keep it running, retire
    /// it with its response, or retire it with the error/panic message.
    /// Shared by the serial tick, the fused tick's fallback arm, and
    /// the fused apply phase, so the three paths cannot drift.
    fn settle(
        &mut self,
        fl: Inflight,
        stepped: std::thread::Result<anyhow::Result<StepOutcome>>,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) {
        match stepped {
            Ok(Ok(StepOutcome::Running)) => self.running.push_back(fl),
            Ok(Ok(StepOutcome::Finished(_))) => self.retire_ok(fl, pool, stats),
            Ok(Err(e)) => self.retire_err(fl, pool, stats, format!("{e:#}")),
            Err(panic) => {
                let msg = panic_message(panic);
                self.log_panic("step", fl.job.req.id, &msg);
                self.retire_err(fl, pool, stats, format!("worker panicked: {msg}"))
            }
        }
    }

    /// The unfused pass: one `forward` per sequence (PR 2 behavior).
    fn tick_serial(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) -> usize {
        self.tick_seq += 1;
        let tick_start = self.obs_now();
        let rows = self.running.len() as u32;
        for _ in 0..self.running.len() {
            let mut fl = self.running.pop_front().expect("non-empty running set");
            if fl.job.cancel.is_cancelled() {
                // mid-flight abort: roll the cache back and free it
                fl.cache.reset();
                stats.on_cancel();
                self.retire_err(fl, pool, stats, "cancelled mid-flight".into());
                continue;
            }
            stats.on_step();
            let stepped =
                catch_unwind(AssertUnwindSafe(|| engine.step(&mut fl.seq, &mut fl.cache)));
            // the monolithic step is device work from the request's view
            self.note_span(&mut fl.t, Phase::Device, fl.job.req.id);
            self.note_emit(&mut fl);
            self.stream_emit(&mut fl, stats);
            self.settle(fl, stepped, pool, stats);
        }
        self.note_tick(tick_start, rows);
        self.running.len()
    }

    /// Phase 1 of every fused pass (local or shared): cancellation
    /// checks + plans.  Finish/fallback/error paths resolve immediately;
    /// plans that want a forward accumulate and are returned.
    fn plan_phase(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) -> Vec<(Inflight, PlanInputs)> {
        let mut fused: Vec<(Inflight, PlanInputs)> = Vec::new();
        for _ in 0..self.running.len() {
            let mut fl = self.running.pop_front().expect("non-empty running set");
            if fl.job.cancel.is_cancelled() {
                fl.cache.reset();
                stats.on_cancel();
                self.retire_err(fl, pool, stats, "cancelled mid-flight".into());
                continue;
            }
            stats.on_step();
            let planned =
                catch_unwind(AssertUnwindSafe(|| engine.plan_step(&mut fl.seq, &fl.cache)));
            // every outcome ends the plan phase for this request
            self.note_span(&mut fl.t, Phase::Plan, fl.job.req.id);
            match planned {
                Ok(Ok(StepPlan::Forward(plan))) => fused.push((fl, plan)),
                Ok(Ok(StepPlan::Finished(_))) => self.retire_ok(fl, pool, stats),
                Ok(Ok(StepPlan::Fallback)) => {
                    // no plan/apply split: monolithic per-sequence step
                    let stepped = catch_unwind(AssertUnwindSafe(|| {
                        engine.step(&mut fl.seq, &mut fl.cache)
                    }));
                    self.note_span(&mut fl.t, Phase::Device, fl.job.req.id);
                    self.note_emit(&mut fl);
                    self.stream_emit(&mut fl, stats);
                    self.settle(fl, stepped, pool, stats);
                }
                Ok(Err(e)) => self.retire_err(fl, pool, stats, format!("{e:#}")),
                Err(panic) => {
                    let msg = panic_message(panic);
                    self.log_panic("plan", fl.job.req.id, &msg);
                    self.retire_err(fl, pool, stats, format!("worker panicked: {msg}"))
                }
            }
        }
        fused
    }

    /// The locally fused pass: plan everything, one device call, apply
    /// everything.  Token-exactness vs [`StepScheduler::tick_serial`]
    /// rests on plan/forward/apply being the *same code* `step` runs
    /// (see `batch::step_via_plan`) plus `forward_batch` being
    /// row-equivalent to per-row `forward` — both are asserted by the
    /// deterministic harness in `rust/tests/scheduler.rs`.
    fn tick_fused(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) -> usize {
        self.tick_seq += 1;
        let tick_start = self.obs_now();
        // phase 1: cancellation checks + plans (finish/fallback paths
        // resolve immediately, fused plans accumulate)
        let fused = self.plan_phase(engine, pool, stats);
        if fused.is_empty() {
            self.note_tick(tick_start, 0);
            return self.running.len();
        }

        // phase 2: one fused forward over every planned sequence
        stats.on_fused_batch(fused.len());
        let t0 = std::time::Instant::now();
        let forwarded = {
            let items: Vec<BatchItem<'_>> = fused
                .iter()
                .map(|(fl, plan)| BatchItem { plan, cache: &fl.cache })
                .collect();
            catch_unwind(AssertUnwindSafe(|| engine.forward_batch(&items)))
        };
        // attribute the shared device call evenly across its riders
        let share = t0.elapsed().as_secs_f64() / fused.len() as f64;

        // phase 3: apply each sequence's slice of the result
        let batch = fused.len() as u32;
        match forwarded {
            Ok(Ok(outs)) if outs.len() == fused.len() => {
                for ((mut fl, plan), out) in fused.into_iter().zip(outs) {
                    fl.seq.res.decode_s += share;
                    self.note_span(&mut fl.t, Phase::Device, fl.job.req.id);
                    let applied = catch_unwind(AssertUnwindSafe(|| {
                        engine.apply_step(
                            &mut fl.seq,
                            &StepResult { plan: &plan, out: &out },
                            &mut fl.cache,
                        )
                    }));
                    self.note_span(&mut fl.t, Phase::Apply, fl.job.req.id);
                    self.note_emit(&mut fl);
                    self.stream_emit(&mut fl, stats);
                    self.settle(fl, applied, pool, stats);
                }
            }
            Ok(Ok(outs)) => {
                let msg = format!(
                    "forward_batch returned {} outputs for {} plans",
                    outs.len(),
                    fused.len()
                );
                for (fl, _) in fused {
                    self.retire_err(fl, pool, stats, msg.clone());
                }
            }
            Ok(Err(e)) => {
                let msg = format!("{e:#}");
                for (fl, _) in fused {
                    self.retire_err(fl, pool, stats, msg.clone());
                }
            }
            Err(panic) => {
                let msg = panic_message(panic);
                for (fl, _) in fused {
                    self.log_panic("forward", fl.job.req.id, &msg);
                    self.retire_err(fl, pool, stats, format!("worker panicked: {msg}"));
                }
            }
        }
        self.note_tick(tick_start, batch);
        self.running.len()
    }

    /// Shared-runtime phase A: plan every in-flight sequence and submit
    /// the fused rows (plans + caches, by move) to the device
    /// dispatcher.  Registration with the dispatcher's tick barrier
    /// tracks the busy spell: a scheduler with no fused rows leaves the
    /// barrier so the window never waits on it.
    ///
    /// `pub` (with [`StepScheduler::tick_shared_complete`]) so the
    /// deterministic harness can interleave many schedulers' submissions
    /// around one scripted dispatcher flush per wall tick; the threaded
    /// worker loop calls the pair back to back via [`StepScheduler::tick`].
    pub fn tick_shared_submit(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) {
        if self.pending.is_some() {
            // a submitted tick must be applied before the next plan pass
            self.tick_shared_complete(engine, pool, stats);
        }
        let Some(dispatch) = self.dispatch.clone() else {
            // no dispatcher attached: a plain locally-fused tick is the
            // correct behavior (defensive — `tick` never routes here
            // without one, and planning before this check would have
            // dropped the plans on the floor)
            self.tick_fused(engine, pool, stats);
            return;
        };
        self.tick_seq += 1;
        let tick_start = self.obs_now();
        let fused = self.plan_phase(engine, pool, stats);
        if fused.is_empty() {
            if self.registered {
                dispatch.deregister();
                self.registered = false;
            }
            self.note_tick(tick_start, 0);
            return;
        }
        if !self.registered {
            dispatch.register();
            self.registered = true;
        }
        // per-scheduler submission width (the cross-worker union width
        // lands in the dispatcher's own histogram)
        stats.on_fused_batch(fused.len());
        let mut rows = Vec::with_capacity(fused.len());
        let mut pend = Vec::with_capacity(fused.len());
        for (fl, plan) in fused {
            let Inflight { job, queue_s, seq, cache, t, emitted } = fl;
            rows.push(TickRow { plan, cache });
            pend.push(PendingRow { job, queue_s, seq, t, emitted });
        }
        match dispatch.submit_tick(self.worker, rows) {
            Ok(rx) => {
                for p in &mut pend {
                    self.note_span(&mut p.t, Phase::Submit, p.job.req.id);
                }
                self.note_tick(tick_start, pend.len() as u32);
                self.pending = Some(PendingTick { rows: pend, rx });
            }
            Err(rows_back) => {
                // dead dispatcher: rows came straight back, retire all
                let mut back = rows_back.into_iter();
                for p in pend {
                    match back.next() {
                        Some(TickRow { cache, .. }) => {
                            let fl = Inflight {
                                job: p.job,
                                queue_s: p.queue_s,
                                seq: p.seq,
                                cache,
                                t: p.t,
                                emitted: p.emitted,
                            };
                            self.retire_err(
                                fl,
                                pool,
                                stats,
                                "device dispatcher is gone".into(),
                            );
                        }
                        None => self.retire_lost(
                            p,
                            pool,
                            stats,
                            "device dispatcher is gone".into(),
                        ),
                    }
                }
            }
        }
    }

    /// Shared-runtime phase B: receive the fused tick's reply and apply
    /// each sequence's slice (panic-isolated per row, exactly like the
    /// local fused apply phase).  Returns the number of sequences still
    /// in flight.
    pub fn tick_shared_complete(
        &mut self,
        engine: &mut dyn BatchStepEngine,
        pool: &SharedCachePool,
        stats: &QueueStats,
    ) -> usize {
        let Some(PendingTick { rows, rx }) = self.pending.take() else {
            return self.running.len();
        };
        match rx.recv() {
            Err(_) => {
                // the dispatcher died holding our rows: the caches are
                // unrecoverable — reconcile the pool and answer errors
                for p in rows {
                    self.retire_lost(p, pool, stats, "device dispatcher is gone".into());
                }
            }
            Ok(TickReply { rows: back, outs, row_share_s }) => {
                let mut back = back.into_iter();
                match outs {
                    Ok(outs) if outs.len() == rows.len() => {
                        for (p, out) in rows.into_iter().zip(outs) {
                            match back.next() {
                                Some(TickRow { plan, cache }) => {
                                    let mut fl = Inflight {
                                        job: p.job,
                                        queue_s: p.queue_s,
                                        seq: p.seq,
                                        cache,
                                        t: p.t,
                                        emitted: p.emitted,
                                    };
                                    // attribute the shared device call
                                    // evenly across its riders
                                    fl.seq.res.decode_s += row_share_s;
                                    // the wait since submit was the
                                    // dispatcher window + device round
                                    self.note_span(&mut fl.t, Phase::Device, fl.job.req.id);
                                    let applied = catch_unwind(AssertUnwindSafe(|| {
                                        engine.apply_step(
                                            &mut fl.seq,
                                            &StepResult { plan: &plan, out: &out },
                                            &mut fl.cache,
                                        )
                                    }));
                                    self.note_span(&mut fl.t, Phase::Apply, fl.job.req.id);
                                    self.note_emit(&mut fl);
                                    self.stream_emit(&mut fl, stats);
                                    self.settle(fl, applied, pool, stats);
                                }
                                None => self.retire_lost(
                                    p,
                                    pool,
                                    stats,
                                    "device dispatcher lost a row".into(),
                                ),
                            }
                        }
                    }
                    Ok(outs) => {
                        let msg = format!(
                            "device dispatcher returned {} outputs for {} rows",
                            outs.len(),
                            rows.len()
                        );
                        self.retire_all_shared(rows, back, pool, stats, msg);
                    }
                    Err(e) => {
                        let msg = format!("{e:#}");
                        self.retire_all_shared(rows, back, pool, stats, msg);
                    }
                }
            }
        }
        if self.registered && self.running.is_empty() {
            if let Some(d) = &self.dispatch {
                d.deregister();
            }
            self.registered = false;
        }
        self.running.len()
    }

    /// Retire every pending row of a failed shared tick with `msg`,
    /// checking returned caches back in (or reconciling the pool for
    /// rows the dispatcher lost).
    fn retire_all_shared(
        &self,
        rows: Vec<PendingRow>,
        mut back: std::vec::IntoIter<TickRow>,
        pool: &SharedCachePool,
        stats: &QueueStats,
        msg: String,
    ) {
        for p in rows {
            match back.next() {
                Some(TickRow { cache, .. }) => {
                    let fl = Inflight {
                        job: p.job,
                        queue_s: p.queue_s,
                        seq: p.seq,
                        cache,
                        t: p.t,
                        emitted: p.emitted,
                    };
                    self.retire_err(fl, pool, stats, msg.clone());
                }
                None => self.retire_lost(p, pool, stats, msg.clone()),
            }
        }
    }

    /// Retire a sequence whose cache is gone (moved into a dispatcher
    /// submission that will never reply): answer the error and
    /// reconcile the pool's outstanding count.
    fn retire_lost(
        &self,
        p: PendingRow,
        pool: &SharedCachePool,
        stats: &QueueStats,
        msg: String,
    ) {
        pool.forget();
        if let Some(o) = &self.observer {
            let now = o.track.now_us();
            o.track.span(Phase::Retire, p.job.req.id, self.tick_seq, 0, p.t.mark_us, now);
        }
        let mut resp = Response::error(p.job.req.id, msg);
        resp.timing.queue_s = p.queue_s;
        resp.worker = self.worker;
        stats.on_complete();
        let _ = p.job.reply.send(resp);
    }

    /// Refuse a job that never entered the in-flight set.
    fn refuse(&self, stats: &QueueStats, job: Job, queue_s: f64, msg: String) {
        let mut resp = Response::error(job.req.id, msg);
        resp.timing.queue_s = queue_s;
        resp.worker = self.worker;
        stats.on_complete();
        // a submitter that went away just discards its response
        let _ = job.reply.send(resp);
    }

    fn retire_ok(&self, fl: Inflight, pool: &SharedCachePool, stats: &QueueStats) {
        let Inflight { job, queue_s, seq, cache, t, .. } = fl;
        let r = seq.into_result();
        // A session turn leaves its full conversation (prompt + reply)
        // in the prefix store so the next turn of the same conversation
        // checks those pages out instead of re-prefilling.
        if job.req.session.is_some() {
            let mut full = job.req.prompt.clone();
            full.extend_from_slice(&r.tokens);
            pool.publish_prefix(&cache, &full);
        }
        pool.checkin(cache);
        if let Some(o) = &self.observer {
            let now = o.track.now_us();
            o.latency.record_e2e(now.saturating_sub(t.enqueue_us));
            o.track.span(Phase::Retire, job.req.id, self.tick_seq, 0, t.mark_us, now);
        }
        let resp = Response {
            id: job.req.id,
            outcome: Outcome::Ok {
                text: workload::decode(&r.tokens),
                tau: r.tau(),
                steps: r.steps,
                tokens: r.tokens,
            },
            timing: Timing { queue_s, prefill_s: r.prefill_s, decode_s: r.decode_s },
            worker: self.worker,
        };
        stats.on_complete();
        let _ = job.reply.send(resp);
    }

    fn retire_err(&self, fl: Inflight, pool: &SharedCachePool, stats: &QueueStats, msg: String) {
        let Inflight { job, queue_s, cache, t, .. } = fl;
        pool.checkin(cache);
        if let Some(o) = &self.observer {
            // no e2e sample — the histograms describe served requests —
            // but the chain still closes with a retire span
            let now = o.track.now_us();
            o.track.span(Phase::Retire, job.req.id, self.tick_seq, 0, t.mark_us, now);
        }
        let mut resp = Response::error(job.req.id, msg);
        resp.timing.queue_s = queue_s;
        resp.worker = self.worker;
        stats.on_complete();
        let _ = job.reply.send(resp);
    }
}

impl Drop for StepScheduler {
    fn drop(&mut self) {
        // a scheduler dying mid-spell (worker thread teardown) must not
        // leave the dispatcher's barrier waiting a full window per round
        if self.registered {
            if let Some(d) = &self.dispatch {
                d.deregister();
            }
            self.registered = false;
        }
        // a tick still at the dispatcher holds this scheduler's caches
        // and unanswered reply channels: wait briefly for the round to
        // flush (deregistering above stopped the barrier from waiting
        // on us), check returned caches back in, and for anything the
        // dispatcher never returns reconcile the pool's outstanding
        // count — silently dropping `pending` leaks both.
        let Some(PendingTick { rows, rx }) = self.pending.take() else {
            return;
        };
        let mut back = rx.recv_timeout(PENDING_DRAIN_TIMEOUT).ok().map(|r| r.rows.into_iter());
        let msg = "worker shut down with a tick in flight";
        for p in rows {
            let cache = back.as_mut().and_then(|b| b.next()).map(|row| row.cache);
            if let Some((pool, stats)) = &self.teardown {
                match cache {
                    Some(c) => pool.checkin(c),
                    None => pool.forget(),
                }
                stats.on_complete();
            }
            let mut resp = Response::error(p.job.req.id, msg.into());
            resp.timing.queue_s = p.queue_s;
            resp.worker = self.worker;
            let _ = p.job.reply.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quota_is_one_without_a_backlog() {
        assert_eq!(admission_quota(1, 0, 4, FUSE_ADMIT_BUCKETS), 1);
        assert_eq!(admission_quota(1, 2, 4, FUSE_ADMIT_BUCKETS), 1);
        assert_eq!(admission_quota(0, 0, 4, FUSE_ADMIT_BUCKETS), 0);
    }

    #[test]
    fn quota_fills_to_the_next_bucket_boundary_under_backlog() {
        // empty worker, deep queue: fill straight to b=2
        assert_eq!(admission_quota(8, 0, 4, FUSE_ADMIT_BUCKETS), 2);
        // 2 running: next boundary is 4
        assert_eq!(admission_quota(8, 2, 4, FUSE_ADMIT_BUCKETS), 2);
        // 3 running: one seat to the b=4 boundary
        assert_eq!(admission_quota(8, 3, 4, FUSE_ADMIT_BUCKETS), 1);
    }

    #[test]
    fn quota_respects_inflight_capacity_and_queue_depth() {
        // capacity caps the burst below the boundary
        assert_eq!(admission_quota(8, 1, 2, FUSE_ADMIT_BUCKETS), 1);
        assert_eq!(admission_quota(8, 4, 4, FUSE_ADMIT_BUCKETS), 0);
        // the queue can run out before the boundary
        assert_eq!(admission_quota(2, 0, 8, FUSE_ADMIT_BUCKETS), 2);
        // above the top bucket the quota degrades to one-per-tick
        assert_eq!(admission_quota(16, 8, 16, FUSE_ADMIT_BUCKETS), 1);
    }
}

