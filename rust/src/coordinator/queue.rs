//! Shared work queue feeding the coordinator's worker threads:
//! a mutex-protected deque + condvar (std-only — tokio is not in the
//! offline vendor set).  Submitters push jobs carrying their own reply
//! channel; workers block on `pop` until a job arrives or the queue is
//! closed, which is how coordinator shutdown drains the worker pool.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::request::{Request, Response};

/// Shared cancellation handle for one job: the submitter (e.g. the TCP
/// server noticing a client disconnect) sets it; the step scheduler
/// checks it before admission and between decode steps and aborts the
/// sequence, returning its KV cache to the pool.
#[derive(Debug, Clone, Default)]
pub struct CancelFlag(Arc<AtomicBool>);

impl CancelFlag {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// One unit of work: the request, its enqueue time (queue-latency
/// accounting and the max-queue-age drop policy), its cancel flag, and
/// the channel the worker answers on.  Routing the reply through a
/// per-job sender is what lets completions arrive out of order across
/// workers while every submitter still gets exactly the responses it
/// asked for.
pub struct Job {
    pub req: Request,
    pub enqueued: Instant,
    /// Enqueue timestamp on the coordinator's trace clock (µs) —
    /// the origin of the request's queue-wait/TTFT/e2e latency samples
    /// and its trace-span chain.  0 when the submitter records no trace.
    pub enqueue_us: u64,
    pub cancel: CancelFlag,
    pub reply: mpsc::Sender<Response>,
}

impl Job {
    pub fn new(req: Request, reply: mpsc::Sender<Response>) -> Self {
        Job { req, enqueued: Instant::now(), enqueue_us: 0, cancel: CancelFlag::new(), reply }
    }
}

/// Result of a non-blocking [`WorkQueue::try_pop`].
pub enum Polled {
    Job(Box<Job>),
    /// nothing queued right now (the queue is still open)
    Empty,
    /// the queue is closed and drained
    Closed,
}

#[derive(Default)]
struct Inner {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// MPMC queue: many submitters (TCP connections, batch drivers), many
/// worker consumers.
#[derive(Default)]
pub struct WorkQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl WorkQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Push a job; returns the queue depth after the push, or the job
    /// back as `Err` if the queue is closed (coordinator shut down).
    pub fn push(&self, job: Job) -> Result<usize, Job> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(job);
        }
        g.jobs.push_back(job);
        let depth = g.jobs.len();
        drop(g);
        self.cv.notify_one();
        Ok(depth)
    }

    /// Block until a job is available; `None` once the queue is closed
    /// and drained.
    pub fn pop(&self) -> Option<Job> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(job) = g.jobs.pop_front() {
                return Some(job);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking pop, used by the step scheduler to admit work
    /// between decode steps without stalling its running sequences.
    pub fn try_pop(&self) -> Polled {
        let mut g = self.inner.lock().unwrap();
        match g.jobs.pop_front() {
            Some(job) => Polled::Job(Box::new(job)),
            None if g.closed => Polled::Closed,
            None => Polled::Empty,
        }
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    /// Close the queue: pending jobs still drain, new pushes fail, and
    /// blocked workers wake up to exit.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn job(id: u64, reply: mpsc::Sender<Response>) -> Job {
        Job::new(Request { id, prompt: vec![1], max_new: 4, seed: 0 }, reply)
    }

    #[test]
    fn fifo_and_depth() {
        let q = WorkQueue::new();
        let (tx, _rx) = mpsc::channel();
        assert_eq!(q.push(job(1, tx.clone())).unwrap(), 1);
        assert_eq!(q.push(job(2, tx)).unwrap(), 2);
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop().unwrap().req.id, 1);
        assert_eq!(q.pop().unwrap().req.id, 2);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn try_pop_distinguishes_empty_from_closed() {
        let q = WorkQueue::new();
        let (tx, _rx) = mpsc::channel();
        assert!(matches!(q.try_pop(), Polled::Empty));
        q.push(job(1, tx)).unwrap();
        match q.try_pop() {
            Polled::Job(j) => assert_eq!(j.req.id, 1),
            _ => panic!("expected a job"),
        }
        q.close();
        assert!(matches!(q.try_pop(), Polled::Closed));
    }

    #[test]
    fn cancel_flag_is_shared() {
        let flag = CancelFlag::new();
        let clone = flag.clone();
        assert!(!clone.is_cancelled());
        flag.cancel();
        assert!(clone.is_cancelled());
    }

    #[test]
    fn close_drains_then_stops() {
        let q = WorkQueue::new();
        let (tx, _rx) = mpsc::channel();
        q.push(job(1, tx.clone())).unwrap();
        q.close();
        assert!(q.push(job(2, tx)).is_err());
        assert!(q.pop().is_some()); // pending job still drains
        assert!(q.pop().is_none());
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(WorkQueue::new());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(50));
        q.close();
        assert!(h.join().unwrap());
    }
}
