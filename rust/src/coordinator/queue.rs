//! Shared work queue feeding the coordinator's worker threads:
//! a mutex-protected deque + condvar (std-only — tokio is not in the
//! offline vendor set).  Submitters push jobs carrying their own reply
//! channel; workers block on `pop` until a job arrives or the queue is
//! closed, which is how coordinator shutdown drains the worker pool.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use super::request::{Request, Response};

/// One unit of work: the request, its enqueue time (queue-latency
/// accounting), and the channel the worker answers on.  Routing the
/// reply through a per-job sender is what lets completions arrive out
/// of order across workers while every submitter still gets exactly the
/// responses it asked for.
pub struct Job {
    pub req: Request,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Response>,
}

#[derive(Default)]
struct Inner {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// MPMC queue: many submitters (TCP connections, batch drivers), many
/// worker consumers.
#[derive(Default)]
pub struct WorkQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl WorkQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Push a job; returns the queue depth after the push, or the job
    /// back as `Err` if the queue is closed (coordinator shut down).
    pub fn push(&self, job: Job) -> Result<usize, Job> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(job);
        }
        g.jobs.push_back(job);
        let depth = g.jobs.len();
        drop(g);
        self.cv.notify_one();
        Ok(depth)
    }

    /// Block until a job is available; `None` once the queue is closed
    /// and drained.
    pub fn pop(&self) -> Option<Job> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(job) = g.jobs.pop_front() {
                return Some(job);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    /// Close the queue: pending jobs still drain, new pushes fail, and
    /// blocked workers wake up to exit.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn job(id: u64, reply: mpsc::Sender<Response>) -> Job {
        Job {
            req: Request { id, prompt: vec![1], max_new: 4, seed: 0 },
            enqueued: Instant::now(),
            reply,
        }
    }

    #[test]
    fn fifo_and_depth() {
        let q = WorkQueue::new();
        let (tx, _rx) = mpsc::channel();
        assert_eq!(q.push(job(1, tx.clone())).unwrap(), 1);
        assert_eq!(q.push(job(2, tx)).unwrap(), 2);
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop().unwrap().req.id, 1);
        assert_eq!(q.pop().unwrap().req.id, 2);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn close_drains_then_stops() {
        let q = WorkQueue::new();
        let (tx, _rx) = mpsc::channel();
        q.push(job(1, tx.clone())).unwrap();
        q.close();
        assert!(q.push(job(2, tx)).is_err());
        assert!(q.pop().is_some()); // pending job still drains
        assert!(q.pop().is_none());
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(WorkQueue::new());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(50));
        q.close();
        assert!(h.join().unwrap());
    }
}
