//! Shared work queue feeding the coordinator's worker threads:
//! a mutex-protected deque + condvar (std-only — tokio is not in the
//! offline vendor set).  Submitters push jobs carrying their own reply
//! channel; workers block on `pop` until a job arrives or the queue is
//! closed, which is how coordinator shutdown drains the worker pool.
//!
//! Job selection follows the coordinator's
//! [`QueueDiscipline`](super::scheduler::QueueDiscipline): `Fifo` pops
//! the oldest job (every PR since the seed); `Slo` (`--sched-policy
//! slo`) picks by (priority class, per-tenant fairness,
//! shortest-remaining-first, arrival order) — a pick that jumps the
//! FIFO head counts as a preemption
//! (`ppd_sched_preemptions_total`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::request::{Request, Response, ResponseEvent};
use super::scheduler::QueueDiscipline;

/// Shared cancellation handle for one job: the submitter (e.g. the TCP
/// server noticing a client disconnect) sets it; the step scheduler
/// checks it before admission and between decode steps and aborts the
/// sequence, returning its KV cache to the pool.
#[derive(Debug, Clone, Default)]
pub struct CancelFlag(Arc<AtomicBool>);

impl CancelFlag {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// One unit of work: the request, its enqueue time (queue-latency
/// accounting and the max-queue-age drop policy), its cancel flag, and
/// the channel the worker answers on.  Routing the reply through a
/// per-job sender is what lets completions arrive out of order across
/// workers while every submitter still gets exactly the responses it
/// asked for.
pub struct Job {
    pub req: Request,
    pub enqueued: Instant,
    /// Enqueue timestamp on the coordinator's trace clock (µs) —
    /// the origin of the request's queue-wait/TTFT/e2e latency samples
    /// and its trace-span chain.  0 when the submitter records no trace.
    pub enqueue_us: u64,
    pub cancel: CancelFlag,
    pub reply: mpsc::Sender<Response>,
    /// Streaming sidecar (v2 `"stream": true`): the scheduler sends
    /// `Started`/`Tokens` frames here as the request progresses; `None`
    /// keeps the classic terminal-response-only path.
    pub events: Option<mpsc::Sender<ResponseEvent>>,
    /// Whether this job resumes a session the coordinator has served a
    /// turn of before — admission uses it to attribute prefix-store
    /// hits to session resumption (`ppd_session_prefix_turn_hits_total`).
    pub resumed: bool,
}

impl Job {
    pub fn new(req: Request, reply: mpsc::Sender<Response>) -> Self {
        Job {
            req,
            enqueued: Instant::now(),
            enqueue_us: 0,
            cancel: CancelFlag::new(),
            reply,
            events: None,
            resumed: false,
        }
    }
}

/// Result of a non-blocking [`WorkQueue::try_pop`].
pub enum Polled {
    Job(Box<Job>),
    /// nothing queued right now (the queue is still open)
    Empty,
    /// the queue is closed and drained
    Closed,
}

#[derive(Default)]
struct Inner {
    jobs: VecDeque<Job>,
    closed: bool,
    /// jobs handed out per fairness bucket (the `slo` discipline's
    /// per-tenant counter; jobs without a tenant share one bucket)
    served_by_tenant: HashMap<String, u64>,
}

/// MPMC queue: many submitters (TCP connections, batch drivers), many
/// worker consumers.
#[derive(Default)]
pub struct WorkQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    discipline: QueueDiscipline,
    /// SLO picks that jumped the FIFO head (a queued job was passed
    /// over in favor of a higher-priority / shorter / fairer one)
    preemptions: AtomicU64,
}

impl WorkQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// A queue running an explicit selection discipline
    /// (`--sched-policy`).
    pub fn with_discipline(discipline: QueueDiscipline) -> Self {
        WorkQueue { discipline, ..Default::default() }
    }

    pub fn discipline(&self) -> QueueDiscipline {
        self.discipline
    }

    /// How many SLO picks jumped the FIFO queue head so far.
    pub fn preemptions(&self) -> u64 {
        self.preemptions.load(Ordering::Relaxed)
    }

    /// Push a job; returns the queue depth after the push, or the job
    /// back as `Err` if the queue is closed (coordinator shut down).
    pub fn push(&self, job: Job) -> Result<usize, Job> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(job);
        }
        g.jobs.push_back(job);
        let depth = g.jobs.len();
        drop(g);
        self.cv.notify_one();
        Ok(depth)
    }

    /// Select and remove the next job under the queue's discipline.
    fn take_next(&self, g: &mut Inner) -> Option<Job> {
        let idx = match self.discipline {
            QueueDiscipline::Fifo => 0,
            QueueDiscipline::Slo => {
                let jobs = &g.jobs;
                let served = &g.served_by_tenant;
                let (idx, _) = jobs
                    .iter()
                    .enumerate()
                    .min_by_key(|(i, j)| {
                        let bucket = j.req.tenant.as_deref().unwrap_or("");
                        let tenant_served = served.get(bucket).copied().unwrap_or(0);
                        // strict priority classes; fairness balances
                        // within a class; shortest-remaining-first
                        // breaks fairness ties; arrival order last
                        (j.req.priority, tenant_served, j.req.remaining_estimate(), *i)
                    })?;
                idx
            }
        };
        let job = g.jobs.remove(idx)?;
        if idx != 0 {
            self.preemptions.fetch_add(1, Ordering::Relaxed);
        }
        if self.discipline == QueueDiscipline::Slo {
            let bucket = job.req.tenant.clone().unwrap_or_default();
            *g.served_by_tenant.entry(bucket).or_insert(0) += 1;
        }
        Some(job)
    }

    /// Block until a job is available; `None` once the queue is closed
    /// and drained.
    pub fn pop(&self) -> Option<Job> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(job) = self.take_next(&mut g) {
                return Some(job);
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking pop, used by the step scheduler to admit work
    /// between decode steps without stalling its running sequences.
    pub fn try_pop(&self) -> Polled {
        let mut g = self.inner.lock().unwrap();
        match self.take_next(&mut g) {
            Some(job) => Polled::Job(Box::new(job)),
            None if g.closed => Polled::Closed,
            None => Polled::Empty,
        }
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    /// Close the queue: pending jobs still drain, new pushes fail, and
    /// blocked workers wake up to exit.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::super::request::Priority;
    use super::*;
    use std::sync::Arc;

    fn job(id: u64, reply: mpsc::Sender<Response>) -> Job {
        Job::new(Request::builder(vec![1]).id(id).max_new(4).seed(0).build(), reply)
    }

    fn slo_job(
        id: u64,
        priority: Priority,
        max_new: usize,
        tenant: Option<&str>,
        reply: mpsc::Sender<Response>,
    ) -> Job {
        let mut b = Request::builder(vec![1]).id(id).max_new(max_new);
        b = b.priority(priority);
        if let Some(t) = tenant {
            b = b.tenant(t);
        }
        Job::new(b.build(), reply)
    }

    #[test]
    fn fifo_and_depth() {
        let q = WorkQueue::new();
        let (tx, _rx) = mpsc::channel();
        assert_eq!(q.push(job(1, tx.clone())).unwrap(), 1);
        assert_eq!(q.push(job(2, tx)).unwrap(), 2);
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop().unwrap().req.id, 1);
        assert_eq!(q.pop().unwrap().req.id, 2);
        assert_eq!(q.depth(), 0);
        assert_eq!(q.preemptions(), 0);
    }

    #[test]
    fn try_pop_distinguishes_empty_from_closed() {
        let q = WorkQueue::new();
        let (tx, _rx) = mpsc::channel();
        assert!(matches!(q.try_pop(), Polled::Empty));
        q.push(job(1, tx)).unwrap();
        match q.try_pop() {
            Polled::Job(j) => assert_eq!(j.req.id, 1),
            _ => panic!("expected a job"),
        }
        q.close();
        assert!(matches!(q.try_pop(), Polled::Closed));
    }

    #[test]
    fn slo_prefers_high_priority_then_short_jobs() {
        let q = WorkQueue::with_discipline(QueueDiscipline::Slo);
        let (tx, _rx) = mpsc::channel();
        q.push(slo_job(1, Priority::Low, 64, None, tx.clone())).unwrap();
        q.push(slo_job(2, Priority::Normal, 64, None, tx.clone())).unwrap();
        q.push(slo_job(3, Priority::Normal, 4, None, tx.clone())).unwrap();
        q.push(slo_job(4, Priority::High, 64, None, tx)).unwrap();
        // strict class order first; SRF inside the Normal class
        assert_eq!(q.pop().unwrap().req.id, 4);
        assert_eq!(q.pop().unwrap().req.id, 3);
        assert_eq!(q.pop().unwrap().req.id, 2);
        assert_eq!(q.pop().unwrap().req.id, 1);
        // jobs 4, 3, and 2 each jumped the queue head (job 1)
        assert_eq!(q.preemptions(), 3);
    }

    #[test]
    fn slo_fairness_rotates_across_tenants() {
        let q = WorkQueue::with_discipline(QueueDiscipline::Slo);
        let (tx, _rx) = mpsc::channel();
        // tenant "a" floods the queue ahead of one "b" job of equal
        // class and length; after one "a" job is served, "b"'s zero
        // served-count must win the next pick
        q.push(slo_job(1, Priority::Normal, 8, Some("a"), tx.clone())).unwrap();
        q.push(slo_job(2, Priority::Normal, 8, Some("a"), tx.clone())).unwrap();
        q.push(slo_job(3, Priority::Normal, 8, Some("a"), tx.clone())).unwrap();
        q.push(slo_job(4, Priority::Normal, 8, Some("b"), tx)).unwrap();
        assert_eq!(q.pop().unwrap().req.id, 1);
        assert_eq!(q.pop().unwrap().req.id, 4);
        assert_eq!(q.pop().unwrap().req.id, 2);
        assert_eq!(q.pop().unwrap().req.id, 3);
    }

    #[test]
    fn cancel_flag_is_shared() {
        let flag = CancelFlag::new();
        let clone = flag.clone();
        assert!(!clone.is_cancelled());
        flag.cancel();
        assert!(clone.is_cancelled());
    }

    #[test]
    fn close_drains_then_stops() {
        let q = WorkQueue::new();
        let (tx, _rx) = mpsc::channel();
        q.push(job(1, tx.clone())).unwrap();
        q.close();
        assert!(q.push(job(2, tx)).is_err());
        assert!(q.pop().is_some()); // pending job still drains
        assert!(q.pop().is_none());
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q = Arc::new(WorkQueue::new());
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(50));
        q.close();
        assert!(h.join().unwrap());
    }
}
