//! Request/response types flowing through the coordinator, and the
//! versioned wire envelope the TCP server parses them from.
//!
//! Two protocol versions share one parser:
//! * **v1** — `{"prompt": "...", "max_new": 64, "seed": 7}` (no `"v"`
//!   key, or `"v": 1`): one request line in, one [`Response`] line out,
//!   exactly as every PR since the seed.
//! * **v2** — `{"v": 2, "prompt": "...", ...}`: adds `stream` (reply as
//!   newline-delimited [`ResponseEvent`]s instead of one terminal
//!   line), `session` (multi-turn affinity — a resumed turn checks its
//!   conversation's KV pages out of the prefix store instead of
//!   re-prefilling), and the SLO fields `priority` / `deadline_ms` /
//!   `tenant` consumed by the `--sched-policy slo` queue discipline.
//!
//! Any other `"v"` is rejected with the typed
//! [`ParseError::BadVersion`], never half-parsed.

use crate::util::json::Json;

/// SLO priority class of a request (`--sched-policy slo`).  Declaration
/// order is scheduling order: the derived `Ord` sorts `High` first, so
/// the queue can use the class directly as the leading sort key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// latency-sensitive (interactive chat): always admitted first
    High,
    #[default]
    Normal,
    /// throughput traffic (batch summarize/code jobs): yields the queue
    /// head to anything more urgent
    Low,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "low" => Some(Priority::Low),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Per-request RNG seed: the scheduler passes it to
    /// `engine.begin_seq`, which seeds the sequence's own RNG, so
    /// sampled output depends only on (prompt, max_new, seed) — never
    /// on which worker served it, what ran before it, or which other
    /// sequences it interleaved with.
    pub seed: u64,
    /// Multi-turn conversation id: turns sharing a session get prefix
    /// affinity — the coordinator publishes the finished turn's
    /// prompt+generation KV pages to the prefix store, so the next turn
    /// of the conversation prefills only its new suffix.
    pub session: Option<String>,
    /// SLO class consumed by the `slo` queue discipline; FIFO ignores it.
    pub priority: Priority,
    /// Per-request deadline: jobs still queued this many milliseconds
    /// after submission are dropped at admission (alongside the global
    /// `--max-queue-age-ms` policy).
    pub deadline_ms: Option<u64>,
    /// Fairness bucket for the `slo` discipline's per-tenant counter.
    pub tenant: Option<String>,
}

impl Request {
    /// Request with the default per-request seed (derived from the id,
    /// so concurrent sampled requests do not produce identical text).
    #[deprecated(note = "use `Request::builder(prompt).id(id).max_new(n).build()` — \
                 the positional constructor predates sessions/priorities")]
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize) -> Self {
        Request::builder(prompt).id(id).max_new(max_new).build()
    }

    /// Start building a request from its prompt; every other field has
    /// a default (`id` 0, `max_new` 64, seed = id, no session/deadline,
    /// `Priority::Normal`).
    pub fn builder(prompt: Vec<u32>) -> RequestBuilder {
        RequestBuilder {
            id: 0,
            prompt,
            max_new: 64,
            seed: None,
            session: None,
            priority: Priority::Normal,
            deadline_ms: None,
            tenant: None,
        }
    }

    /// Rough cost-to-serve estimate (prompt prefill + token budget) —
    /// the shortest-remaining-first key of the `slo` queue discipline.
    pub fn remaining_estimate(&self) -> usize {
        self.prompt.len() + self.max_new
    }
}

/// Builder for [`Request`] — the field count outgrew the positional
/// constructor when sessions, priorities, and deadlines arrived.
#[derive(Debug, Clone)]
pub struct RequestBuilder {
    id: u64,
    prompt: Vec<u32>,
    max_new: usize,
    seed: Option<u64>,
    session: Option<String>,
    priority: Priority,
    deadline_ms: Option<u64>,
    tenant: Option<String>,
}

impl RequestBuilder {
    pub fn id(mut self, id: u64) -> Self {
        self.id = id;
        self
    }

    pub fn max_new(mut self, n: usize) -> Self {
        self.max_new = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    pub fn session(mut self, sid: impl Into<String>) -> Self {
        self.session = Some(sid.into());
        self
    }

    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn tenant(mut self, t: impl Into<String>) -> Self {
        self.tenant = Some(t.into());
        self
    }

    /// Finish the request.  The seed defaults to the id (set the id
    /// before `build` or the default seed is 0).
    pub fn build(self) -> Request {
        Request {
            id: self.id,
            prompt: self.prompt,
            max_new: self.max_new,
            seed: self.seed.unwrap_or(self.id),
            session: self.session,
            priority: self.priority,
            deadline_ms: self.deadline_ms,
            tenant: self.tenant,
        }
    }
}

/// How serving a request ended: the generation result, or the error —
/// never both, never neither (the old flat struct carried eleven fields
/// plus an `Option<String>` error sidecar whose emptiness *implied*
/// success).
#[derive(Debug, Clone)]
pub enum Outcome {
    Ok { tokens: Vec<u32>, text: String, steps: usize, tau: f64 },
    Error(String),
}

/// Where a request's wall time went, in seconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct Timing {
    pub queue_s: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub outcome: Outcome,
    pub timing: Timing,
    /// index of the worker that served the request (observability:
    /// responses complete out of order across workers)
    pub worker: usize,
}

impl Response {
    pub fn error(id: u64, msg: String) -> Self {
        Response {
            id,
            outcome: Outcome::Error(msg),
            timing: Timing::default(),
            worker: 0,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self.outcome, Outcome::Ok { .. })
    }

    /// The error message, `None` for served requests.
    pub fn error_msg(&self) -> Option<&str> {
        match &self.outcome {
            Outcome::Error(e) => Some(e),
            Outcome::Ok { .. } => None,
        }
    }

    /// Generated tokens (empty for errors).
    pub fn tokens(&self) -> &[u32] {
        match &self.outcome {
            Outcome::Ok { tokens, .. } => tokens,
            Outcome::Error(_) => &[],
        }
    }

    /// Decoded text (empty for errors).
    pub fn text(&self) -> &str {
        match &self.outcome {
            Outcome::Ok { text, .. } => text,
            Outcome::Error(_) => "",
        }
    }

    pub fn steps(&self) -> usize {
        match &self.outcome {
            Outcome::Ok { steps, .. } => *steps,
            Outcome::Error(_) => 0,
        }
    }

    /// Mean accepted tokens per decode step (the paper's τ).
    pub fn tau(&self) -> f64 {
        match &self.outcome {
            Outcome::Ok { tau, .. } => *tau,
            Outcome::Error(_) => 0.0,
        }
    }

    /// The v1 wire shape — identical to the flat pre-redesign struct's
    /// (`tokens` is the COUNT, `error` present only on failures), so v1
    /// clients round-trip unchanged against the typed internals.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::Num(self.id as f64)),
            ("text", Json::str(self.text())),
            ("tokens", Json::Num(self.tokens().len() as f64)),
            ("steps", Json::Num(self.steps() as f64)),
            ("tau", Json::Num(self.tau())),
            ("decode_s", Json::Num(self.timing.decode_s)),
            ("prefill_s", Json::Num(self.timing.prefill_s)),
            ("queue_s", Json::Num(self.timing.queue_s)),
            ("worker", Json::Num(self.worker as f64)),
        ];
        if let Some(e) = self.error_msg() {
            pairs.push(("error", Json::str(e)));
        }
        Json::obj(pairs)
    }
}

/// One frame of a v2 streamed reply.  The scheduler emits `Started` and
/// `Tokens` as the request progresses; the server closes the stream
/// with exactly one terminal frame (`Done` or `Error`) synthesized from
/// the final [`Response`].
#[derive(Debug, Clone)]
pub enum ResponseEvent {
    /// the request was admitted onto a worker's step scheduler
    Started { id: u64, worker: usize },
    /// tokens accepted by one decode step, in generation order — the
    /// concatenation of every `Tokens` frame is exactly the final
    /// response's token sequence (asserted across all four topologies
    /// by the deterministic harness)
    Tokens { id: u64, step: usize, accepted: Vec<u32> },
    /// terminal: the request was served; `stats` is the v1 response
    /// object (text, counts, timing)
    Done { id: u64, stats: Json },
    /// terminal: the request failed
    Error { id: u64, message: String },
}

impl ResponseEvent {
    /// The terminal frame for `resp`: `Done` for served requests,
    /// `Error` for failures.
    pub fn terminal(resp: &Response) -> Self {
        match resp.error_msg() {
            Some(e) => ResponseEvent::Error { id: resp.id, message: e.to_string() },
            None => ResponseEvent::Done { id: resp.id, stats: resp.to_json() },
        }
    }

    pub fn id(&self) -> u64 {
        match self {
            ResponseEvent::Started { id, .. }
            | ResponseEvent::Tokens { id, .. }
            | ResponseEvent::Done { id, .. }
            | ResponseEvent::Error { id, .. } => *id,
        }
    }

    /// Terminal frames end the stream for their request.
    pub fn is_terminal(&self) -> bool {
        matches!(self, ResponseEvent::Done { .. } | ResponseEvent::Error { .. })
    }

    /// One NDJSON frame: every variant carries `"event"` and `"id"`;
    /// `Done` flattens the v1 response object into the frame.
    pub fn to_json(&self) -> Json {
        match self {
            ResponseEvent::Started { id, worker } => Json::obj(vec![
                ("event", Json::str("started")),
                ("id", Json::Num(*id as f64)),
                ("worker", Json::Num(*worker as f64)),
            ]),
            ResponseEvent::Tokens { id, step, accepted } => Json::obj(vec![
                ("event", Json::str("tokens")),
                ("id", Json::Num(*id as f64)),
                ("step", Json::Num(*step as f64)),
                (
                    "accepted",
                    Json::Arr(accepted.iter().map(|&t| Json::Num(t as f64)).collect()),
                ),
            ]),
            ResponseEvent::Done { id, stats } => {
                let mut m = match stats {
                    Json::Obj(m) => m.clone(),
                    _ => Default::default(),
                };
                m.insert("event".into(), Json::str("done"));
                m.insert("id".into(), Json::Num(*id as f64));
                Json::Obj(m)
            }
            ResponseEvent::Error { id, message } => Json::obj(vec![
                ("event", Json::str("error")),
                ("id", Json::Num(*id as f64)),
                ("error", Json::str(message)),
            ]),
        }
    }

    /// Parse one streamed frame (the client half of `to_json`).
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let id = j
            .get("id")
            .and_then(|v| v.as_usize().ok())
            .ok_or("event frame is missing 'id'")? as u64;
        match j.get("event").and_then(|v| v.as_str().ok()) {
            Some("started") => {
                let worker = j
                    .get("worker")
                    .and_then(|v| v.as_usize().ok())
                    .ok_or("started frame is missing 'worker'")?;
                Ok(ResponseEvent::Started { id, worker })
            }
            Some("tokens") => {
                let step = j
                    .get("step")
                    .and_then(|v| v.as_usize().ok())
                    .ok_or("tokens frame is missing 'step'")?;
                let accepted = j
                    .get("accepted")
                    .ok_or("tokens frame is missing 'accepted'")?
                    .as_u32_vec()
                    .map_err(|e| format!("bad 'accepted': {e}"))?;
                Ok(ResponseEvent::Tokens { id, step, accepted })
            }
            Some("done") => Ok(ResponseEvent::Done { id, stats: j.clone() }),
            Some("error") => {
                let message = j
                    .get("error")
                    .and_then(|v| v.as_str().ok())
                    .unwrap_or("unknown error")
                    .to_string();
                Ok(ResponseEvent::Error { id, message })
            }
            Some(other) => Err(format!("unknown event kind '{other}'")),
            None => Err("frame is missing 'event'".into()),
        }
    }
}

/// Typed request-parse failure.  `BadVersion` is the protocol-level
/// rejection (the server answers it distinctly); the rest mirror the
/// v1 parser's historical messages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParseError {
    BadJson(String),
    /// the `v` field names a version this server does not speak
    BadVersion(String),
    MissingPrompt,
    EmptyPrompt,
    /// a typed v2 field carried the wrong type or an unknown value
    BadField(&'static str),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::BadJson(e) => write!(f, "bad json: {e}"),
            ParseError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (this server speaks v1 and v2)")
            }
            ParseError::MissingPrompt => write!(f, "missing 'prompt'"),
            ParseError::EmptyPrompt => write!(f, "empty prompt after ascii filtering"),
            ParseError::BadField(k) => write!(f, "bad '{k}' field"),
        }
    }
}

impl std::error::Error for ParseError {}

/// A parsed request line plus the protocol framing that belongs to the
/// connection, not the scheduler.
#[derive(Debug, Clone)]
pub struct RequestEnvelope {
    pub req: Request,
    /// protocol version the client spoke (1 or 2)
    pub v: u8,
    /// v2 only: the client's explicit streaming choice (`None` defers
    /// to the server's `--stream` default; v1 never streams)
    pub stream: Option<bool>,
}

/// Parse a client request line under the versioned envelope.  Lines
/// without a `"v"` key (or with `"v": 1`) take the v1 path: `prompt`
/// required, `max_new`/`seed` optional, every v2 field ignored — byte
/// for byte the pre-envelope behavior.  `"v": 2` additionally parses
/// `stream`/`session`/`priority`/`deadline_ms`/`tenant`.
pub fn parse_envelope(line: &str, id: u64) -> Result<RequestEnvelope, ParseError> {
    let j = Json::parse(line).map_err(|e| ParseError::BadJson(e.to_string()))?;
    let v = match j.get("v") {
        None => 1,
        Some(val) => match val.as_usize() {
            Ok(1) => 1,
            Ok(2) => 2,
            _ => return Err(ParseError::BadVersion(format!("{val}"))),
        },
    };
    let prompt_text = j
        .get("prompt")
        .and_then(|p| p.as_str().ok())
        .ok_or(ParseError::MissingPrompt)?;
    let prompt = crate::workload::encode(prompt_text);
    if prompt.is_empty() {
        return Err(ParseError::EmptyPrompt);
    }
    let mut b = Request::builder(prompt).id(id);
    if let Some(m) = j.get("max_new").and_then(|m| m.as_usize().ok()) {
        b = b.max_new(m);
    }
    if let Some(s) = j.get("seed").and_then(|s| s.as_usize().ok()) {
        b = b.seed(s as u64);
    }
    let mut stream = None;
    if v >= 2 {
        if let Some(val) = j.get("stream") {
            stream = Some(val.as_bool().map_err(|_| ParseError::BadField("stream"))?);
        }
        if let Some(val) = j.get("session") {
            b = b.session(val.as_str().map_err(|_| ParseError::BadField("session"))?);
        }
        if let Some(val) = j.get("priority") {
            let p = val
                .as_str()
                .ok()
                .and_then(Priority::parse)
                .ok_or(ParseError::BadField("priority"))?;
            b = b.priority(p);
        }
        if let Some(val) = j.get("deadline_ms") {
            let d = val.as_usize().map_err(|_| ParseError::BadField("deadline_ms"))?;
            b = b.deadline_ms(d as u64);
        }
        if let Some(val) = j.get("tenant") {
            b = b.tenant(val.as_str().map_err(|_| ParseError::BadField("tenant"))?);
        }
    }
    Ok(RequestEnvelope { req: b.build(), v, stream })
}

/// Parse a v1 client request line:
/// `{"prompt": "...", "max_new": 64, "seed": 7}`
/// (`max_new` and `seed` optional; seed defaults per request id).
/// Thin compatibility wrapper over [`parse_envelope`].
pub fn parse_request_line(line: &str, id: u64) -> Result<Request, String> {
    parse_envelope(line, id)
        .map(|env| env.req)
        .map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request() {
        let r = parse_request_line(r#"{"prompt": "hi there", "max_new": 8}"#, 3).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.max_new, 8);
        assert_eq!(r.prompt.len(), 8);
        assert_eq!(r.seed, 3); // defaults to the request id
        assert_eq!(r.priority, Priority::Normal);
        assert!(r.session.is_none());
    }

    #[test]
    fn parses_explicit_seed() {
        let r = parse_request_line(r#"{"prompt": "x", "seed": 99}"#, 3).unwrap();
        assert_eq!(r.seed, 99);
    }

    #[test]
    fn default_max_new() {
        let r = parse_request_line(r#"{"prompt": "x"}"#, 0).unwrap();
        assert_eq!(r.max_new, 64);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_request_line("{", 0).is_err());
        assert!(parse_request_line(r#"{"max_new": 5}"#, 0).is_err());
        assert!(parse_request_line(r#"{"prompt": ""}"#, 0).is_err());
    }

    #[test]
    fn v1_lines_ignore_v2_fields() {
        // pre-envelope clients may carry stray keys; v1 parsing must
        // not grow new failure modes or new semantics
        let r = parse_envelope(r#"{"prompt": "x", "session": "s9", "stream": true}"#, 1).unwrap();
        assert_eq!(r.v, 1);
        assert_eq!(r.stream, None);
        assert!(r.req.session.is_none());
    }

    #[test]
    fn v2_parses_the_new_fields() {
        let line = r#"{"v": 2, "prompt": "x", "stream": true, "session": "conv-1",
                       "priority": "high", "deadline_ms": 250, "tenant": "acme"}"#;
        let env = parse_envelope(line, 7).unwrap();
        assert_eq!(env.v, 2);
        assert_eq!(env.stream, Some(true));
        assert_eq!(env.req.session.as_deref(), Some("conv-1"));
        assert_eq!(env.req.priority, Priority::High);
        assert_eq!(env.req.deadline_ms, Some(250));
        assert_eq!(env.req.tenant.as_deref(), Some("acme"));
    }

    #[test]
    fn malformed_version_is_a_typed_error() {
        let e = parse_envelope(r#"{"v": 3, "prompt": "x"}"#, 0).unwrap_err();
        assert!(matches!(e, ParseError::BadVersion(_)), "{e:?}");
        let e = parse_envelope(r#"{"v": "two", "prompt": "x"}"#, 0).unwrap_err();
        assert!(matches!(e, ParseError::BadVersion(_)), "{e:?}");
        // and bad typed fields are BadField, not silently defaulted
        let e = parse_envelope(r#"{"v": 2, "prompt": "x", "priority": "urgent"}"#, 0).unwrap_err();
        assert_eq!(e, ParseError::BadField("priority"));
    }

    #[test]
    fn builder_covers_every_field_and_defaults_seed_to_id() {
        let r = Request::builder(vec![1, 2])
            .id(9)
            .max_new(5)
            .priority(Priority::Low)
            .session("s")
            .deadline_ms(10)
            .tenant("t")
            .build();
        assert_eq!(r.seed, 9);
        assert_eq!(r.remaining_estimate(), 7);
        let explicit = Request::builder(vec![1]).id(9).seed(4).build();
        assert_eq!(explicit.seed, 4);
    }

    #[test]
    #[allow(deprecated)]
    fn positional_constructor_still_builds_the_same_request() {
        let r = Request::new(3, vec![1, 2, 3], 8);
        assert_eq!((r.id, r.max_new, r.seed), (3, 8, 3));
        assert_eq!(r.priority, Priority::Normal);
    }

    #[test]
    fn response_json_includes_error() {
        let r = Response::error(7, "boom".into());
        assert!(!r.is_ok());
        let j = r.to_json();
        assert_eq!(j.req("error").unwrap().as_str().unwrap(), "boom");
        assert_eq!(j.req("worker").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn ok_response_wire_shape_is_v1_compatible() {
        let r = Response {
            id: 4,
            outcome: Outcome::Ok {
                tokens: vec![10, 11, 12],
                text: "abc".into(),
                steps: 2,
                tau: 1.5,
            },
            timing: Timing { queue_s: 0.5, prefill_s: 0.25, decode_s: 1.0 },
            worker: 3,
        };
        let j = r.to_json();
        // `tokens` is the count (the historical v1 contract), no `error`
        assert_eq!(j.req("tokens").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.req("text").unwrap().as_str().unwrap(), "abc");
        assert_eq!(j.req("queue_s").unwrap().as_f64().unwrap(), 0.5);
        assert!(j.get("error").is_none());
        assert_eq!(r.tokens(), &[10, 11, 12]);
        assert_eq!(r.steps(), 2);
    }

    #[test]
    fn events_round_trip_through_json() {
        let evs = vec![
            ResponseEvent::Started { id: 5, worker: 2 },
            ResponseEvent::Tokens { id: 5, step: 3, accepted: vec![7, 8] },
            ResponseEvent::Error { id: 5, message: "nope".into() },
        ];
        for ev in evs {
            let parsed = ResponseEvent::from_json(&ev.to_json()).unwrap();
            assert_eq!(format!("{parsed:?}"), format!("{ev:?}"));
            assert_eq!(parsed.id(), 5);
        }
        // the terminal frame of a served response flattens its stats
        let resp = Response {
            id: 6,
            outcome: Outcome::Ok { tokens: vec![1], text: "a".into(), steps: 1, tau: 1.0 },
            timing: Timing::default(),
            worker: 0,
        };
        let term = ResponseEvent::terminal(&resp);
        assert!(term.is_terminal());
        let j = term.to_json();
        assert_eq!(j.req("event").unwrap().as_str().unwrap(), "done");
        assert_eq!(j.req("tokens").unwrap().as_usize().unwrap(), 1);
        match ResponseEvent::from_json(&j).unwrap() {
            ResponseEvent::Done { id, stats } => {
                assert_eq!(id, 6);
                assert_eq!(stats.req("text").unwrap().as_str().unwrap(), "a");
            }
            other => panic!("expected Done, got {other:?}"),
        }
        // errors map to the error frame
        assert!(matches!(
            ResponseEvent::terminal(&Response::error(9, "x".into())),
            ResponseEvent::Error { id: 9, .. }
        ));
    }
}
