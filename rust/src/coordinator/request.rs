//! Request/response types flowing through the coordinator.

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Per-request RNG seed: the scheduler passes it to
    /// `engine.begin_seq`, which seeds the sequence's own RNG, so
    /// sampled output depends only on (prompt, max_new, seed) — never
    /// on which worker served it, what ran before it, or which other
    /// sequences it interleaved with.
    pub seed: u64,
}

impl Request {
    /// Request with the default per-request seed (derived from the id,
    /// so concurrent sampled requests do not produce identical text).
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize) -> Self {
        Request { id, prompt, max_new, seed: id }
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub text: String,
    pub steps: usize,
    pub tau: f64,
    pub decode_s: f64,
    pub prefill_s: f64,
    pub queue_s: f64,
    /// index of the worker that served the request (observability:
    /// responses complete out of order across workers)
    pub worker: usize,
    pub error: Option<String>,
}

impl Response {
    pub fn error(id: u64, msg: String) -> Self {
        Response {
            id,
            tokens: vec![],
            text: String::new(),
            steps: 0,
            tau: 0.0,
            decode_s: 0.0,
            prefill_s: 0.0,
            queue_s: 0.0,
            worker: 0,
            error: Some(msg),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::Num(self.id as f64)),
            ("text", Json::str(&self.text)),
            ("tokens", Json::Num(self.tokens.len() as f64)),
            ("steps", Json::Num(self.steps as f64)),
            ("tau", Json::Num(self.tau)),
            ("decode_s", Json::Num(self.decode_s)),
            ("prefill_s", Json::Num(self.prefill_s)),
            ("queue_s", Json::Num(self.queue_s)),
            ("worker", Json::Num(self.worker as f64)),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Json::str(e)));
        }
        Json::obj(pairs)
    }
}

/// Parse a client request line:
/// `{"prompt": "...", "max_new": 64, "seed": 7}`
/// (`max_new` and `seed` optional; seed defaults per request id).
pub fn parse_request_line(line: &str, id: u64) -> Result<Request, String> {
    let j = Json::parse(line).map_err(|e| format!("bad json: {e}"))?;
    let prompt_text = j
        .get("prompt")
        .and_then(|p| p.as_str().ok())
        .ok_or("missing 'prompt'")?;
    let max_new = j
        .get("max_new")
        .and_then(|m| m.as_usize().ok())
        .unwrap_or(64);
    let seed = j
        .get("seed")
        .and_then(|s| s.as_usize().ok())
        .map(|s| s as u64)
        .unwrap_or(id);
    let prompt = crate::workload::encode(prompt_text);
    if prompt.is_empty() {
        return Err("empty prompt after ascii filtering".into());
    }
    Ok(Request { id, prompt, max_new, seed })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_request() {
        let r = parse_request_line(r#"{"prompt": "hi there", "max_new": 8}"#, 3).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.max_new, 8);
        assert_eq!(r.prompt.len(), 8);
        assert_eq!(r.seed, 3); // defaults to the request id
    }

    #[test]
    fn parses_explicit_seed() {
        let r = parse_request_line(r#"{"prompt": "x", "seed": 99}"#, 3).unwrap();
        assert_eq!(r.seed, 99);
    }

    #[test]
    fn default_max_new() {
        let r = parse_request_line(r#"{"prompt": "x"}"#, 0).unwrap();
        assert_eq!(r.max_new, 64);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_request_line("{", 0).is_err());
        assert!(parse_request_line(r#"{"max_new": 5}"#, 0).is_err());
        assert!(parse_request_line(r#"{"prompt": ""}"#, 0).is_err());
    }

    #[test]
    fn response_json_includes_error() {
        let r = Response::error(7, "boom".into());
        let j = r.to_json();
        assert_eq!(j.req("error").unwrap().as_str().unwrap(), "boom");
        assert_eq!(j.req("worker").unwrap().as_usize().unwrap(), 0);
    }
}
