//! Artifact/model configuration loaded from `artifacts/<model>/config.json`
//! (written by `python/compile/aot.py`) plus serving-side knobs.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Byte-level vocab used by every model in the zoo.
pub const VOCAB: usize = 128;
/// First prompt-token id; prompt token k (0-based) is `PROMPT_ID0 + k`
/// (inference artifacts always use 1 EPT per prompt token).
pub const PROMPT_ID0: u32 = 128;
pub const PAD_ID: u32 = 0;
pub const BOS_ID: u32 = 1;
pub const EOS_ID: u32 = 2;

/// Mirror of the python `ModelConfig` + AOT bucket metadata.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_mlp: usize,
    pub max_ctx: usize,
    pub n_prompt: usize,
    pub rope_theta: f64,
    pub buckets: Vec<usize>,
    /// batch sizes the AOT step also lowered batched graphs for
    /// (`fwd_b{B}_n{N}.hlo.txt`); empty for pre-v2 artifact sets, in
    /// which case `forward_batch` falls back to per-row forwards
    pub batch_buckets: Vec<usize>,
    /// short-KV context lengths the AOT step also lowered variants at
    /// (`fwd_n{N}_s{kv}` and `fwd_b{B}_n{N}_s{kv}`).  Older artifact
    /// sets omit the key; they only ever carried 256-slot variants, so
    /// that is the probe default — the runtime only loads variants
    /// whose files actually exist.
    pub kv_buckets: Vec<usize>,
    pub trained: bool,
    pub medusa: bool,
    pub param_count: usize,
    pub prompt_param_count: usize,
}

impl ModelConfig {
    pub fn load(dir: &Path) -> Result<Self> {
        let j = Json::from_file(&dir.join("config.json"))
            .with_context(|| format!("loading model config from {}", dir.display()))?;
        let cfg = ModelConfig {
            name: j.req("name")?.as_str()?.to_string(),
            vocab: j.req("vocab")?.as_usize()?,
            d_model: j.req("d_model")?.as_usize()?,
            n_layers: j.req("n_layers")?.as_usize()?,
            n_heads: j.req("n_heads")?.as_usize()?,
            d_head: j.req("d_head")?.as_usize()?,
            d_mlp: j.req("d_mlp")?.as_usize()?,
            max_ctx: j.req("max_ctx")?.as_usize()?,
            n_prompt: j.req("n_prompt")?.as_usize()?,
            rope_theta: j.req("rope_theta")?.as_f64()?,
            buckets: j
                .req("buckets")?
                .as_arr()?
                .iter()
                .map(|b| b.as_usize())
                .collect::<Result<_>>()?,
            batch_buckets: match j.get("batch_buckets") {
                Some(b) => {
                    // the forward_batch bucket selector walks this list
                    // in order looking for the smallest cover — keep it
                    // sorted regardless of how the exporter wrote it
                    let mut bb: Vec<usize> =
                        b.as_arr()?.iter().map(|x| x.as_usize()).collect::<Result<_>>()?;
                    bb.sort_unstable();
                    bb
                }
                None => Vec::new(),
            },
            kv_buckets: match j.get("kv_buckets") {
                Some(b) => {
                    // the covering-bucket selector walks this list in
                    // order looking for the smallest cover — keep it
                    // sorted regardless of how the exporter wrote it
                    let mut kb: Vec<usize> =
                        b.as_arr()?.iter().map(|x| x.as_usize()).collect::<Result<_>>()?;
                    kb.sort_unstable();
                    kb
                }
                // pre-kv_buckets artifact sets only ever shipped
                // 256-slot variants (probed by file existence anyway)
                None => vec![256],
            },
            trained: j.req("trained")?.as_bool()?,
            medusa: j.req("medusa")?.as_bool()?,
            param_count: j.req("param_count")?.as_usize()?,
            prompt_param_count: j.req("prompt_param_count")?.as_usize()?,
        };
        if cfg.vocab != VOCAB {
            bail!("unsupported vocab {}", cfg.vocab);
        }
        if cfg.buckets.is_empty() {
            bail!("model {} exported without buckets", cfg.name);
        }
        Ok(cfg)
    }

    /// Smallest AOT bucket that fits `n` tokens.
    pub fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .filter(|&b| b >= n)
            .min()
            .with_context(|| format!("no bucket fits {n} tokens (max {:?})", self.buckets.iter().max()))
    }

    /// Fraction of extra trainable parameters (paper's P_tr column).
    pub fn trainable_fraction(&self) -> f64 {
        self.prompt_param_count as f64 / self.param_count as f64
    }
}

/// Locations of everything the runtime needs.
#[derive(Debug, Clone)]
pub struct ArtifactPaths {
    pub root: PathBuf,
    pub model: String,
}

impl ArtifactPaths {
    pub fn new(root: impl Into<PathBuf>, model: &str) -> Self {
        ArtifactPaths { root: root.into(), model: model.to_string() }
    }

    pub fn model_dir(&self) -> PathBuf {
        self.root.join(&self.model)
    }

    pub fn fwd_hlo(&self, bucket: usize) -> PathBuf {
        self.model_dir().join(format!("fwd_n{bucket}.hlo.txt"))
    }

    /// Short-KV-context variant (perf: KV-length bucketing).
    pub fn fwd_hlo_kv(&self, bucket: usize, kv: usize) -> PathBuf {
        self.model_dir().join(format!("fwd_n{bucket}_s{kv}.hlo.txt"))
    }

    /// Batched forward graph: `batch` sequences × `bucket` tree tokens
    /// (the fused step-execution path).
    pub fn fwd_hlo_batch(&self, batch: usize, bucket: usize) -> PathBuf {
        self.model_dir().join(format!("fwd_b{batch}_n{bucket}.hlo.txt"))
    }

    /// Short-KV-context variant of the batched graph: the fused tick's
    /// stacked cache-union upload shrinks to `[batch, 2L, kv, d]`.
    pub fn fwd_hlo_batch_kv(&self, batch: usize, bucket: usize, kv: usize) -> PathBuf {
        self.model_dir().join(format!("fwd_b{batch}_n{bucket}_s{kv}.hlo.txt"))
    }

    pub fn weights_bin(&self) -> PathBuf {
        self.model_dir().join("weights.bin")
    }

    pub fn weights_manifest(&self) -> PathBuf {
        self.model_dir().join("weights.json")
    }

    pub fn medusa_hlo(&self) -> PathBuf {
        self.model_dir().join("medusa.hlo.txt")
    }

    pub fn medusa_weights(&self) -> (PathBuf, PathBuf) {
        (self.model_dir().join("medusa_weights.bin"),
         self.model_dir().join("medusa_weights.json"))
    }

    pub fn accept_stats(&self, variant: Option<&str>) -> PathBuf {
        match variant {
            Some(v) => self.model_dir().join(format!("accept_stats_{v}.json")),
            None => self.model_dir().join("accept_stats.json"),
        }
    }

    pub fn calibration(&self) -> PathBuf {
        self.model_dir().join("calibration.json")
    }

    pub fn trace(&self, task: &str) -> PathBuf {
        self.root.join("traces").join(format!("{task}.json"))
    }
}

/// Serving/decoding configuration (CLI-tunable).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// sampling temperature; 0 = greedy (exact-match verification)
    pub temperature: f32,
    /// typical-acceptance knobs (Medusa defaults)
    pub typical_epsilon: f32,
    pub typical_delta: f32,
    /// candidate + prompt token budget of the dynamic sparse tree
    pub n_candidates: usize,
    pub n_prompt_budget: usize,
    /// cap on generated tokens per request
    pub max_new_tokens: usize,
    /// candidate ranks considered per tree level
    pub top_r: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            temperature: 0.0,
            typical_epsilon: 0.3,
            typical_delta: 0.09,
            n_candidates: 12,
            n_prompt_budget: 18,
            max_new_tokens: 64,
            top_r: 10,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_cfg(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("config.json"),
            r#"{"name":"t","vocab":128,"d_model":64,"n_layers":2,"n_heads":2,
                "d_head":32,"d_mlp":176,"max_ctx":512,"n_prompt":3,"n_ept":1,
                "rope_theta":10000.0,"buckets":[1,8,64],"trained":true,
                "medusa":false,"param_count":1000000,"prompt_param_count":192}"#,
        )
        .unwrap();
    }

    #[test]
    fn loads_and_buckets() {
        let dir = std::env::temp_dir().join("ppd_cfg_test");
        write_cfg(&dir);
        let cfg = ModelConfig::load(&dir).unwrap();
        assert_eq!(cfg.bucket_for(1).unwrap(), 1);
        assert_eq!(cfg.bucket_for(2).unwrap(), 8);
        assert_eq!(cfg.bucket_for(8).unwrap(), 8);
        assert_eq!(cfg.bucket_for(9).unwrap(), 64);
        assert!(cfg.bucket_for(65).is_err());
        assert!(cfg.trainable_fraction() < 0.001);
        // pre-v2 artifact sets carry no batched graphs
        assert!(cfg.batch_buckets.is_empty());
        // …and pre-kv_buckets sets fall back to the historical 256 probe
        assert_eq!(cfg.kv_buckets, vec![256]);
    }

    #[test]
    fn batch_buckets_parse_when_present() {
        let dir = std::env::temp_dir().join("ppd_cfg_test_batch");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("config.json"),
            r#"{"name":"t","vocab":128,"d_model":64,"n_layers":2,"n_heads":2,
                "d_head":32,"d_mlp":176,"max_ctx":512,"n_prompt":3,"n_ept":1,
                "rope_theta":10000.0,"buckets":[1,8,64],"batch_buckets":[1,2,4,8],
                "trained":true,"medusa":false,"param_count":1000000,
                "prompt_param_count":192}"#,
        )
        .unwrap();
        let cfg = ModelConfig::load(&dir).unwrap();
        assert_eq!(cfg.batch_buckets, vec![1, 2, 4, 8]);
    }

    #[test]
    fn kv_buckets_parse_sorted_when_present() {
        let dir = std::env::temp_dir().join("ppd_cfg_test_kv");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("config.json"),
            r#"{"name":"t","vocab":128,"d_model":64,"n_layers":2,"n_heads":2,
                "d_head":32,"d_mlp":176,"max_ctx":512,"n_prompt":3,"n_ept":1,
                "rope_theta":10000.0,"buckets":[1,8,64],"batch_buckets":[1,2],
                "kv_buckets":[256,128],"trained":true,"medusa":false,
                "param_count":1000000,"prompt_param_count":192}"#,
        )
        .unwrap();
        let cfg = ModelConfig::load(&dir).unwrap();
        assert_eq!(cfg.kv_buckets, vec![128, 256]);
    }

    #[test]
    fn paths_layout() {
        let p = ArtifactPaths::new("/a", "ppd-m");
        assert_eq!(p.fwd_hlo(8), PathBuf::from("/a/ppd-m/fwd_n8.hlo.txt"));
        assert_eq!(p.fwd_hlo_batch(4, 8), PathBuf::from("/a/ppd-m/fwd_b4_n8.hlo.txt"));
        assert_eq!(
            p.fwd_hlo_batch_kv(4, 8, 256),
            PathBuf::from("/a/ppd-m/fwd_b4_n8_s256.hlo.txt")
        );
        assert_eq!(p.trace("chat"), PathBuf::from("/a/traces/chat.json"));
        assert!(p.accept_stats(Some("ept4")).to_str().unwrap().contains("ept4"));
    }
}
