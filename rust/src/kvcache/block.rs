//! Fixed-size KV pages, the refcounted block pool, and per-sequence
//! block tables.
//!
//! A *block* (page) is one contiguous `[planes, block_slots, d]` f32
//! buffer covering `block_slots` consecutive logical cache slots for
//! every layer's K and V plane.  Sequences never own blocks directly:
//! a [`BlockTable`] maps each logical slot range to an
//! `Arc`-refcounted [`BlockRef`], so two sequences sharing a prompt
//! prefix reference the *same* pages (computed once, counted once
//! against the budget) until one of them writes — at which point
//! [`super::HostKvCache`] copies the page out of the share
//! (copy-on-write).
//!
//! The [`BlockPool`] is the budget authority: `--kv-blocks` bounds how
//! many distinct pages may be live at once across every sequence *and*
//! the prefix store, and [`super::PoolExhausted`] carries this block
//! accounting when admission would exceed it.  Freed buffers are
//! recycled through a free list (zeroed on reuse), and prefix-store
//! pages nobody references anymore are the eviction reserve when an
//! allocation or admission is short on budget.

use std::sync::{Arc, Mutex, MutexGuard};

use super::prefix::PrefixStore;
use super::PoolExhausted;

/// Default page size, in cache slots, for production context lengths.
/// Pools built over small contexts scale it down — see
/// [`block_slots_for`].
pub const DEFAULT_BLOCK_SLOTS: usize = 64;

/// One refcounted KV page: `[planes, block_slots, d]` row-major.
/// `Arc::strong_count > 1` means the page is shared (by another
/// sequence's table or the prefix store) and must be copied before a
/// write.
pub type BlockRef = Arc<Vec<f32>>;

/// Page size for a cache of `max_ctx` slots: an eighth of the context
/// (so paging has real granularity even on tiny test shapes), clamped
/// to `[1, DEFAULT_BLOCK_SLOTS]`.
pub fn block_slots_for(max_ctx: usize) -> usize {
    (max_ctx / 8).clamp(1, DEFAULT_BLOCK_SLOTS)
}

fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Per-sequence mapping from logical cache slots to pages.  Entry `i`
/// covers slots `[i*block_slots, (i+1)*block_slots)`; `None` means the
/// range was never written (reads see zeros).
///
/// ```
/// use ppd::kvcache::BlockTable;
///
/// let table = BlockTable::new(256, 64);
/// assert_eq!(table.len(), 4);            // ceil(256 / 64) entries
/// assert_eq!(table.location(0), (0, 0)); // slot -> (block, row-in-block)
/// assert_eq!(table.location(130), (2, 2));
/// assert_eq!(table.allocated(), 0);      // nothing written yet
/// ```
#[derive(Debug, Clone)]
pub struct BlockTable {
    blocks: Vec<Option<BlockRef>>,
    block_slots: usize,
}

impl BlockTable {
    pub fn new(max_ctx: usize, block_slots: usize) -> Self {
        let bs = block_slots.max(1);
        BlockTable { blocks: vec![None; ceil_div(max_ctx.max(1), bs)], block_slots: bs }
    }

    pub fn block_slots(&self) -> usize {
        self.block_slots
    }

    /// Table entries (allocated or not).
    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// `(block index, row within the block)` for a logical slot.
    pub fn location(&self, slot: usize) -> (usize, usize) {
        (slot / self.block_slots, slot % self.block_slots)
    }

    /// Pages currently backed by real memory.
    pub fn allocated(&self) -> usize {
        self.blocks.iter().filter(|b| b.is_some()).count()
    }

    /// Whether entry `i` is a page some other table or the prefix
    /// store also references (a write to it must copy first).
    pub fn is_shared(&self, i: usize) -> bool {
        matches!(self.blocks.get(i), Some(Some(b)) if Arc::strong_count(b) > 1)
    }

    pub(crate) fn entries(&self) -> &[Option<BlockRef>] {
        &self.blocks
    }

    pub(crate) fn entries_mut(&mut self) -> &mut [Option<BlockRef>] {
        &mut self.blocks
    }
}

/// Shared, budget-bounded allocator of KV pages (plus the prefix store
/// that rides its mutex and budget).  Cloning the handle shares the
/// pool.
#[derive(Debug, Clone)]
pub struct BlockPool {
    planes: usize,
    block_slots: usize,
    d: usize,
    state: Arc<Mutex<State>>,
}

#[derive(Debug)]
struct State {
    /// recycled buffers (zeroed on reuse)
    free: Vec<Vec<f32>>,
    /// distinct live pages — shared pages count ONCE (the whole point)
    used: usize,
    peak_used: usize,
    budget: usize,
    store: PrefixStore,
    hits: u64,
    blocks_shared: u64,
    /// logical allocation clock for LRU stamps (deterministic)
    clock: u64,
}

impl BlockPool {
    /// A pool of pages shaped `[2*n_layers, block_slots, d]` with a hard
    /// budget of `budget` live pages.
    pub fn new(n_layers: usize, block_slots: usize, d: usize, budget: usize) -> Self {
        BlockPool {
            planes: 2 * n_layers,
            block_slots: block_slots.max(1),
            d,
            state: Arc::new(Mutex::new(State {
                free: Vec::new(),
                used: 0,
                peak_used: 0,
                budget: budget.max(1),
                store: PrefixStore::default(),
                hits: 0,
                blocks_shared: 0,
                clock: 0,
            })),
        }
    }

    pub fn block_slots(&self) -> usize {
        self.block_slots
    }

    /// f32 values per page.
    pub fn block_len(&self) -> usize {
        self.planes * self.block_slots * self.d
    }

    pub fn block_bytes(&self) -> usize {
        self.block_len() * std::mem::size_of::<f32>()
    }

    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Evict idle prefix-store pages until `needed` more allocations
    /// fit, or fail with block accounting.
    fn make_room(&self, st: &mut State, needed: usize) -> Result<(), PoolExhausted> {
        while st.used + needed > st.budget {
            match st.store.evict_lru() {
                Some(buf) => {
                    st.used -= 1;
                    st.free.push(buf);
                }
                None => {
                    return Err(PoolExhausted {
                        cap: 0,
                        blocks_used: st.used,
                        blocks_budget: st.budget,
                    })
                }
            }
        }
        Ok(())
    }

    /// Allocate one zeroed page against the budget.
    pub(crate) fn alloc(&self) -> Result<BlockRef, PoolExhausted> {
        let mut st = self.lock();
        self.make_room(&mut st, 1)?;
        st.used += 1;
        st.peak_used = st.peak_used.max(st.used);
        let buf = match st.free.pop() {
            Some(mut b) => {
                b.fill(0.0);
                b
            }
            None => vec![0.0; self.block_len()],
        };
        Ok(Arc::new(buf))
    }

    /// Return one reference to a page.  The buffer is only recycled —
    /// and the budget only credited — when this was the LAST reference
    /// (shared pages stay live until every holder releases).
    pub(crate) fn release(&self, block: BlockRef) {
        let mut st = self.lock();
        if let Ok(buf) = Arc::try_unwrap(block) {
            st.used -= 1;
            st.free.push(buf);
        }
    }

    /// Admission check: would `needed` more pages fit (after evicting
    /// idle prefix pages)?  Nothing is reserved — pages allocate lazily
    /// as the sequence writes.
    pub(crate) fn admit(&self, needed: usize) -> Result<(), PoolExhausted> {
        let mut st = self.lock();
        self.make_room(&mut st, needed)
    }

    /// Walk the prefix store for `prompt`, touching LRU stamps.
    pub(crate) fn lookup(&self, prompt: &[u32]) -> Vec<BlockRef> {
        let mut st = self.lock();
        st.clock += 1;
        let clock = st.clock;
        st.store.lookup(prompt, self.block_slots, clock)
    }

    /// Count one prefix hit of `blocks` shared pages.
    pub(crate) fn note_hit(&self, blocks: usize) {
        let mut st = self.lock();
        st.hits += 1;
        st.blocks_shared += blocks as u64;
    }

    /// Publish a sequence's full prompt chunks into the prefix store.
    pub(crate) fn publish(&self, prompt: &[u32], table: &BlockTable, committed: usize) -> usize {
        let mut st = self.lock();
        st.clock += 1;
        let clock = st.clock;
        st.store.publish(prompt, table.entries(), committed, self.block_slots, clock)
    }

    /// Pages needed for a prompt of `len` tokens plus one generated
    /// token, within a usable context of `capacity` slots.
    pub fn blocks_for_prompt(&self, len: usize, capacity: usize) -> usize {
        ceil_div((len + 1).min(capacity).max(1), self.block_slots)
    }

    /// Distinct live pages right now.
    pub fn blocks_used(&self) -> usize {
        self.lock().used
    }

    /// Budget headroom (`budget - used`).
    pub fn blocks_free(&self) -> usize {
        let st = self.lock();
        st.budget.saturating_sub(st.used)
    }

    pub fn blocks_budget(&self) -> usize {
        self.lock().budget
    }

    /// High-water mark of live pages (resident-memory reporting).
    pub fn peak_blocks_used(&self) -> usize {
        self.lock().peak_used
    }

    /// Pages currently pinned by the prefix store.
    pub fn store_blocks(&self) -> usize {
        self.lock().store.blocks_held()
    }

    pub fn prefix_hits(&self) -> u64 {
        self.lock().hits
    }

    pub fn prefix_blocks_shared(&self) -> u64 {
        self.lock().blocks_shared
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_locations_and_len() {
        let t = BlockTable::new(16, 2);
        assert_eq!(t.len(), 8);
        assert_eq!(t.location(0), (0, 0));
        assert_eq!(t.location(5), (2, 1));
        assert_eq!(t.allocated(), 0);
        assert!(!t.is_shared(0));
    }

    #[test]
    fn pool_recycles_and_respects_budget() {
        let p = BlockPool::new(2, 4, 4, 2); // pages of 4*4*4 floats, budget 2
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_eq!(p.blocks_used(), 2);
        assert_eq!(p.blocks_free(), 0);
        let err = p.alloc().unwrap_err();
        assert_eq!(err.blocks_used, 2);
        assert_eq!(err.blocks_budget, 2);
        p.release(a);
        assert_eq!(p.blocks_used(), 1);
        let c = p.alloc().unwrap();
        assert!(c.iter().all(|&x| x == 0.0), "recycled page must be zeroed");
        assert_eq!(p.peak_blocks_used(), 2);
        drop((b, c));
    }

    #[test]
    fn shared_pages_are_counted_once_and_freed_last() {
        let p = BlockPool::new(2, 4, 4, 4);
        let a = p.alloc().unwrap();
        let twin = Arc::clone(&a);
        assert_eq!(p.blocks_used(), 1);
        p.release(a); // twin still holds it
        assert_eq!(p.blocks_used(), 1, "a shared page must stay live");
        p.release(twin);
        assert_eq!(p.blocks_used(), 0, "last holder frees the page");
    }

    #[test]
    fn idle_store_pages_are_the_eviction_reserve() {
        let p = BlockPool::new(2, 2, 4, 2);
        let a = p.alloc().unwrap();
        let table = {
            let mut t = BlockTable::new(4, 2);
            t.entries_mut()[0] = Some(Arc::clone(&a));
            t
        };
        // publish prompt [1,2,3]: one full 2-token chunk backed by `a`
        assert_eq!(p.publish(&[1, 2, 3], &table, 3), 1);
        drop(table);
        p.release(a); // now only the store holds the page
        assert_eq!(p.blocks_used(), 1);
        // budget 2: two fresh allocations force an eviction of the
        // idle store page rather than failing
        let b = p.alloc().unwrap();
        let c = p.alloc().unwrap();
        assert_eq!(p.blocks_used(), 2);
        assert_eq!(p.store_blocks(), 0, "idle prefix page was evicted");
        drop((b, c));
    }

    #[test]
    fn lookup_never_serves_the_whole_prompt() {
        let p = BlockPool::new(2, 2, 4, 8);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let mut t = BlockTable::new(8, 2);
        t.entries_mut()[0] = Some(Arc::clone(&a));
        t.entries_mut()[1] = Some(Arc::clone(&b));
        let prompt = [1u32, 2, 3, 4];
        assert_eq!(p.publish(&prompt, &t, 4), 1, "only the strict-prefix chunk is stored");
        // the 4-token prompt hits its first chunk only: the last token
        // must be recomputed by the rider
        assert_eq!(p.lookup(&prompt).len(), 1);
        // a longer prompt sharing the prefix hits the same chunk
        assert_eq!(p.lookup(&[1, 2, 3, 9, 9, 9]).len(), 1);
        // a diverging prompt misses
        assert_eq!(p.lookup(&[1, 9, 3, 4]).len(), 0);
        drop((a, b, t));
    }
}
