//! Cross-request prompt-prefix store: a radix-style tree keyed by
//! block-sized token chunks.
//!
//! Real traffic repeats prompt prefixes constantly — system prompts,
//! few-shot preambles, multi-turn session resumption — and the KV rows
//! for a token prefix are a pure function of the tokens and their
//! positions, so recomputing them per request is waste.  The store maps
//! each *full* `block_slots`-token prompt chunk to the KV page a
//! previous sequence computed for it; a later request whose prompt
//! starts with the same chunks checks those pages out by reference
//! (copy-on-write — see [`super::HostKvCache::scatter`]) and prefills
//! only the remainder.
//!
//! Structure: the node for `prompt[..k·bs]` is keyed by the token
//! prefix itself, so a lookup walks chunk by chunk until the first
//! miss — a radix walk with the edge labels inlined into the keys.
//! The final prompt token is never served from the store: its forward
//! pass produces the logits that seed the first generated token, so at
//! least one prompt position is always recomputed by the rider.
//!
//! The store lives inside the [`super::BlockPool`] mutex, shares its
//! block budget, and is the pool's eviction reserve: when an
//! allocation would exceed the budget, least-recently-used nodes whose
//! page no sequence references anymore (`Arc` strong count of exactly
//! one) are evicted and their buffers recycled.

use std::collections::BTreeMap;
use std::sync::Arc;

use super::block::BlockRef;

/// Prefix → KV-page map. All access is serialized by the owning
/// [`super::BlockPool`]'s mutex; `clock` values are that pool's logical
/// allocation clock (monotone, deterministic — no wall time).
#[derive(Debug, Default)]
pub(crate) struct PrefixStore {
    /// key: the token prefix `prompt[..k*block_slots]`; value: the KV
    /// page covering slots `[(k-1)*block_slots, k*block_slots)`.
    nodes: BTreeMap<Vec<u32>, Node>,
}

#[derive(Debug)]
struct Node {
    block: BlockRef,
    /// last-touch stamp from the pool's logical clock (LRU eviction)
    stamp: u64,
}

impl PrefixStore {
    /// Longest stored chain of full `block_slots`-token chunks covering
    /// a *strict* prefix of `prompt`.  Returns the pages in slot order;
    /// each is an `Arc` clone, so the caller now shares them.
    pub fn lookup(&mut self, prompt: &[u32], block_slots: usize, clock: u64) -> Vec<BlockRef> {
        let mut out = Vec::new();
        let mut end = block_slots;
        // strictly `<`: the last prompt token is always recomputed
        while end < prompt.len() {
            match self.nodes.get_mut(&prompt[..end]) {
                Some(node) => {
                    node.stamp = clock;
                    out.push(Arc::clone(&node.block));
                }
                None => break,
            }
            end += block_slots;
        }
        out
    }

    /// Record the pages a sequence computed for its prompt: every full
    /// chunk that is covered by `committed` rows and backed by an
    /// allocated page is inserted (first writer wins — identical chunks
    /// produce identical KV, so there is nothing to reconcile).
    /// Returns how many new nodes were inserted.
    pub fn publish(
        &mut self,
        prompt: &[u32],
        blocks: &[Option<BlockRef>],
        committed: usize,
        block_slots: usize,
        clock: u64,
    ) -> usize {
        let mut inserted = 0;
        let mut end = block_slots;
        let mut i = 0;
        while end < prompt.len() && end <= committed {
            let Some(Some(block)) = blocks.get(i) else { break };
            if !self.nodes.contains_key(&prompt[..end]) {
                self.nodes
                    .insert(prompt[..end].to_vec(), Node { block: Arc::clone(block), stamp: clock });
                inserted += 1;
            }
            end += block_slots;
            i += 1;
        }
        inserted
    }

    /// Evict the least-recently-used node whose page nothing else
    /// references, returning its buffer for recycling.  A node whose
    /// parent was evicted first simply becomes unreachable to lookups
    /// and is collected by a later eviction pass — harmless, since its
    /// page is still budget-accounted until then.
    pub fn evict_lru(&mut self) -> Option<Vec<f32>> {
        let key = self
            .nodes
            .iter()
            .filter(|(_, n)| Arc::strong_count(&n.block) == 1)
            .min_by_key(|(k, n)| (n.stamp, k.to_vec()))
            .map(|(k, _)| k.clone())?;
        let node = self.nodes.remove(&key)?;
        // strong count was 1 and the pool mutex serializes us: unwrap
        // cannot race a new clone
        Arc::try_unwrap(node.block).ok()
    }

    /// Pages currently held by the store (shared or idle).
    pub fn blocks_held(&self) -> usize {
        self.nodes.len()
    }
}
