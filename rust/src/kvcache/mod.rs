//! Host-authoritative KV-cache manager: slab or paged storage behind
//! one `HostKvCache` API, block-budgeted pools, and cross-request
//! prefix reuse.
//!
//! The forward executables scatter the step's K/V into a *copy* of the
//! cache on device for attention, and return the new rows; rust owns the
//! real cache and applies the same scatter here, then **compacts** after
//! verification: the accepted tree path's rows are moved down onto the
//! contiguous committed region (paper §3, "candidate acceptance ... KV
//! cache is updated accordingly").  Rejected tree rows simply stay above
//! `committed` and are dead — the next step's bias never exposes them.
//!
//! Logical layout: `[2L, max_ctx, d]` row-major; layer l's keys at plane
//! `2l`, values at `2l+1`.  Slot `max_ctx-1` is reserved as the padding
//! trash row (see `runtime::Runtime::forward`); usable context is
//! `max_ctx - RESERVED` slots.
//!
//! ## Storage: slab vs paged
//!
//! [`HostKvCache::new`] allocates the classic contiguous slab.
//! [`HostKvCache::new_paged`] instead backs the same logical layout
//! with fixed-size pages drawn from a shared [`BlockPool`], mapped by a
//! per-sequence [`BlockTable`] — so a sequence only occupies memory for
//! the slots it has actually written, identical prompt prefixes can
//! share pages copy-on-write (the `prefix` module), and admission is
//! expressed in *block* budgets instead of whole-slab counts.  Every
//! mutation flows through `scatter`/`compact`/`commit_contiguous`/
//! `truncate`, so the two storages are behaviorally interchangeable;
//! the device ABI is untouched because [`HostKvCache::device_snapshot`]
//! (and the collator's [`HostKvCache::copy_plane_prefix`]) gather pages
//! back into the contiguous layout the AOT'd graphs expect.  See
//! `docs/ARCHITECTURE.md` for the full memory model.
//!
//! ## Pooling
//!
//! A cache is ~MBs and request lifetimes are short, so the serving
//! layer never allocates caches per request: each in-flight *sequence*
//! borrows a cache for its lifetime, and the coordinator's step
//! scheduler checks caches out of a [`CachePool`] (wrapped in a
//! [`SharedCachePool`] so all worker threads draw from one free list).
//! The pool enforces a hard cap — at most one cache per admitted
//! sequence, i.e. `workers × max_inflight` — and, when built with a
//! block budget (`--kv-blocks`), additionally refuses admissions whose
//! prompt footprint would exceed the budgeted page count, returning a
//! typed [`PoolExhausted`] carrying the block accounting rather than
//! allocating past it.  That is the paper's runtime-memory story
//! (≈0.0004% overhead) carried through to the serving layer.

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::{bail, Result};

pub mod block;
mod prefix;

pub use block::{block_slots_for, BlockPool, BlockRef, BlockTable, DEFAULT_BLOCK_SLOTS};

pub const RESERVED_SLOTS: usize = 2;

/// Paged backing storage: a block table plus the pool its pages came
/// from.  Dropping it returns every page reference to the pool (the
/// buffer itself is only recycled when the last referencing table or
/// prefix-store node lets go).
#[derive(Debug, Clone)]
struct Paged {
    table: BlockTable,
    pool: BlockPool,
}

impl Paged {
    /// Read one `[d]` row (zeros when the covering page was never
    /// allocated).
    fn read_row(&self, plane: usize, slot: usize, d: usize, out: &mut [f32]) {
        let (bi, off) = self.table.location(slot);
        match self.table.entries()[bi].as_ref() {
            Some(b) => {
                let base = (plane * self.table.block_slots() + off) * d;
                out.copy_from_slice(&b[base..base + d]);
            }
            None => out.fill(0.0),
        }
    }

    /// The page for table entry `bi`, allocated on first touch and
    /// copied out of any share (copy-on-write) so the caller may write.
    fn writable_block(&mut self, bi: usize) -> Result<&mut Vec<f32>> {
        if self.table.entries()[bi].is_none() {
            let fresh = self.pool.alloc()?;
            self.table.entries_mut()[bi] = Some(fresh);
        } else if self.table.is_shared(bi) {
            // copy-on-write: divergence must not touch the shared page
            let mut fresh = self.pool.alloc()?;
            {
                let cur = self.table.entries()[bi].as_ref().expect("checked above");
                Arc::get_mut(&mut fresh).expect("fresh page is unique").copy_from_slice(cur);
            }
            let old = std::mem::replace(&mut self.table.entries_mut()[bi], Some(fresh))
                .expect("checked above");
            self.pool.release(old);
        }
        let arc = self.table.entries_mut()[bi].as_mut().expect("installed above");
        Ok(Arc::get_mut(arc).expect("page is unique after COW"))
    }

    fn release_from(&mut self, first_entry: usize) {
        for i in first_entry..self.table.len() {
            if let Some(b) = self.table.entries_mut()[i].take() {
                self.pool.release(b);
            }
        }
    }
}

impl Drop for Paged {
    fn drop(&mut self) {
        self.release_from(0);
    }
}

#[derive(Debug, Clone)]
enum Storage {
    Slab(Vec<f32>),
    Paged(Paged),
}

#[derive(Debug, Clone)]
pub struct HostKvCache {
    storage: Storage,
    planes: usize,
    max_ctx: usize,
    d: usize,
    /// committed context length (number of finalized tokens)
    committed: usize,
    /// rows `[0, prefix_len)` were seeded from the shared prefix store;
    /// `reset()` rolls back to here, not to zero
    prefix_len: usize,
}

impl HostKvCache {
    /// A contiguous-slab cache (the classic layout; always available).
    pub fn new(n_layers: usize, max_ctx: usize, d: usize) -> Self {
        let planes = 2 * n_layers;
        HostKvCache {
            storage: Storage::Slab(vec![0.0; planes * max_ctx * d]),
            planes,
            max_ctx,
            d,
            committed: 0,
            prefix_len: 0,
        }
    }

    /// A paged cache drawing fixed-size pages from `pool` on demand.
    /// Same logical layout and API as a slab cache; memory is only
    /// occupied for pages actually written.
    pub fn new_paged(n_layers: usize, max_ctx: usize, d: usize, pool: &BlockPool) -> Self {
        HostKvCache {
            storage: Storage::Paged(Paged {
                table: BlockTable::new(max_ctx, pool.block_slots()),
                pool: pool.clone(),
            }),
            planes: 2 * n_layers,
            max_ctx,
            d,
            committed: 0,
            prefix_len: 0,
        }
    }

    pub fn is_paged(&self) -> bool {
        matches!(self.storage, Storage::Paged(_))
    }

    pub fn committed(&self) -> usize {
        self.committed
    }

    /// Rows seeded from the shared prefix store at checkout (0 unless
    /// the pool found a prefix hit for this sequence's prompt).
    pub fn prefix_len(&self) -> usize {
        self.prefix_len
    }

    /// `(n_layers, max_ctx, d)` — the tuple [`CachePool`] templates on.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.planes / 2, self.max_ctx, self.d)
    }

    pub fn capacity(&self) -> usize {
        self.max_ctx - RESERVED_SLOTS
    }

    pub fn remaining(&self) -> usize {
        self.capacity().saturating_sub(self.committed)
    }

    /// The raw slab (slab storage only — paged callers want
    /// [`HostKvCache::device_snapshot`]).
    ///
    /// # Panics
    /// On a paged cache, which has no contiguous backing buffer.
    pub fn as_slice(&self) -> &[f32] {
        match &self.storage {
            Storage::Slab(data) => data,
            Storage::Paged(_) => {
                panic!("as_slice on a paged cache: use device_snapshot()/copy_plane_prefix()")
            }
        }
    }

    /// The full `[planes, max_ctx, d]` contiguous view the device ABI
    /// expects: borrowed for slab storage (zero cost), gathered from
    /// the page table for paged storage (unallocated ranges read as
    /// zeros — they are masked on device anyway).
    pub fn device_snapshot(&self) -> Cow<'_, [f32]> {
        match &self.storage {
            Storage::Slab(data) => Cow::Borrowed(data.as_slice()),
            Storage::Paged(p) => {
                let mut out = vec![0.0; self.planes * self.max_ctx * self.d];
                let bs = p.table.block_slots();
                for (bi, e) in p.table.entries().iter().enumerate() {
                    let Some(b) = e else { continue };
                    let start = bi * bs;
                    let take = bs.min(self.max_ctx - start);
                    for pl in 0..self.planes {
                        let src = pl * bs * self.d;
                        let dst = (pl * self.max_ctx + start) * self.d;
                        out[dst..dst + take * self.d]
                            .copy_from_slice(&b[src..src + take * self.d]);
                    }
                }
                Cow::Owned(out)
            }
        }
    }

    /// Copy the first `kv` slots of one plane into `dst` (length
    /// `kv * d`) — the batch collator's per-row gather, paged-aware.
    pub fn copy_plane_prefix(&self, plane: usize, kv: usize, dst: &mut [f32]) {
        debug_assert_eq!(dst.len(), kv * self.d);
        match &self.storage {
            Storage::Slab(data) => {
                let src = plane * self.max_ctx * self.d;
                dst.copy_from_slice(&data[src..src + kv * self.d]);
            }
            Storage::Paged(p) => {
                dst.fill(0.0);
                let bs = p.table.block_slots();
                for (bi, e) in p.table.entries().iter().enumerate() {
                    let start = bi * bs;
                    if start >= kv {
                        break;
                    }
                    let Some(b) = e else { continue };
                    let take = bs.min(kv - start);
                    let src = plane * bs * self.d;
                    dst[start * self.d..(start + take) * self.d]
                        .copy_from_slice(&b[src..src + take * self.d]);
                }
            }
        }
    }

    /// Scatter the step's returned rows: `new_kv` is `[planes, n, d]`
    /// and token i's row lands at cache slot `slots[i]` in every plane.
    ///
    /// On a paged cache this allocates pages on first touch and copies
    /// shared (prefix) pages out of the share before writing.
    ///
    /// ```
    /// use ppd::kvcache::HostKvCache;
    ///
    /// let mut cache = HostKvCache::new(1, 8, 2); // 2 planes, 8 slots, d=2
    /// // one token's K and V rows, landing at slot 3
    /// cache.scatter(&[1.0, 1.0, 2.0, 2.0], &[3]).unwrap();
    /// assert_eq!(cache.row(0, 3), &[1.0, 1.0]);
    /// assert_eq!(cache.row(1, 3), &[2.0, 2.0]);
    /// ```
    pub fn scatter(&mut self, new_kv: &[f32], slots: &[u32]) -> Result<()> {
        let n = slots.len();
        if new_kv.len() != self.planes * n * self.d {
            bail!(
                "scatter: new_kv has {} values, want {}",
                new_kv.len(),
                self.planes * n * self.d
            );
        }
        for (i, &slot) in slots.iter().enumerate() {
            let slot = slot as usize;
            if slot >= self.max_ctx {
                bail!("scatter: slot {slot} out of range");
            }
            match &mut self.storage {
                Storage::Slab(data) => {
                    for p in 0..self.planes {
                        let src = (p * n + i) * self.d;
                        let dst = (p * self.max_ctx + slot) * self.d;
                        data[dst..dst + self.d].copy_from_slice(&new_kv[src..src + self.d]);
                    }
                }
                Storage::Paged(pg) => {
                    let (bi, off) = pg.table.location(slot);
                    let bs = pg.table.block_slots();
                    let blk = pg.writable_block(bi)?;
                    for p in 0..self.planes {
                        let src = (p * n + i) * self.d;
                        let dst = (p * bs + off) * self.d;
                        blk[dst..dst + self.d].copy_from_slice(&new_kv[src..src + self.d]);
                    }
                }
            }
        }
        Ok(())
    }

    /// Commit `count` already-contiguous rows starting at `committed`
    /// (prefill path: slots were `committed..committed+count`).  Paged
    /// caches allocate any still-missing covering pages (zeroed) so the
    /// committed region is always materialized.
    pub fn commit_contiguous(&mut self, count: usize) -> Result<()> {
        if self.committed + count > self.capacity() {
            bail!("cache overflow: {} + {count} > {}", self.committed, self.capacity());
        }
        if count > 0 {
            if let Storage::Paged(p) = &mut self.storage {
                let bs = p.table.block_slots();
                let first = self.committed / bs;
                let last = (self.committed + count - 1) / bs;
                for bi in first..=last {
                    if p.table.entries()[bi].is_none() {
                        let fresh = p.pool.alloc()?;
                        p.table.entries_mut()[bi] = Some(fresh);
                    }
                }
            }
        }
        self.committed += count;
        Ok(())
    }

    /// Compact after verification: move the rows at `accepted_slots`
    /// (tree scratch positions, in path order) down to the committed
    /// region and advance `committed`.  Slots equal to their target are
    /// skipped (the tree root is written at `committed` already).
    ///
    /// ```
    /// use ppd::kvcache::HostKvCache;
    ///
    /// let mut cache = HostKvCache::new(1, 8, 2);
    /// cache.commit_contiguous(2).unwrap(); // prompt rows at slots 0..2
    /// // tree scratch rows at slots 2..4; verification accepted slot 3
    /// cache.scatter(&[5., 5., 6., 6., 7., 7., 8., 8.], &[2, 3]).unwrap();
    /// cache.compact(&[3]).unwrap();        // slot 3 -> slot 2
    /// assert_eq!(cache.committed(), 3);
    /// assert_eq!(cache.row(0, 2), &[6.0, 6.0]);
    /// ```
    pub fn compact(&mut self, accepted_slots: &[u32]) -> Result<()> {
        if self.committed + accepted_slots.len() > self.capacity() {
            bail!(
                "cache overflow on compact: {} + {} > {}",
                self.committed,
                accepted_slots.len(),
                self.capacity()
            );
        }
        for (i, &src) in accepted_slots.iter().enumerate() {
            let src = src as usize;
            if src == self.committed + i {
                continue;
            }
            if src >= self.max_ctx {
                bail!("compact: slot {src} out of range");
            }
            if src < self.committed + i {
                bail!("compact: slot {src} would overwrite committed rows");
            }
        }
        match &mut self.storage {
            Storage::Slab(data) => {
                for (i, &src) in accepted_slots.iter().enumerate() {
                    let src = src as usize;
                    let dst = self.committed + i;
                    if src == dst {
                        continue;
                    }
                    for p in 0..self.planes {
                        let s = (p * self.max_ctx + src) * self.d;
                        let t = (p * self.max_ctx + dst) * self.d;
                        data.copy_within(s..s + self.d, t);
                    }
                }
            }
            Storage::Paged(pg) => {
                // gather the accepted rows first, then write them down:
                // block-safe even when src and dst share a page
                let k = accepted_slots.len();
                let mut tmp = vec![0.0; self.planes * k * self.d];
                for (i, &src) in accepted_slots.iter().enumerate() {
                    for p in 0..self.planes {
                        let o = (p * k + i) * self.d;
                        pg.read_row(p, src as usize, self.d, &mut tmp[o..o + self.d]);
                    }
                }
                let bs = pg.table.block_slots();
                for (i, &src) in accepted_slots.iter().enumerate() {
                    let dst = self.committed + i;
                    if src as usize == dst {
                        continue;
                    }
                    let (bi, off) = pg.table.location(dst);
                    let blk = pg.writable_block(bi)?;
                    for p in 0..self.planes {
                        let s = (p * k + i) * self.d;
                        let t = (p * bs + off) * self.d;
                        blk[t..t + self.d].copy_from_slice(&tmp[s..s + self.d]);
                    }
                }
            }
        }
        self.committed += accepted_slots.len();
        Ok(())
    }

    /// Roll back to a shorter committed length (request retry/cancel).
    /// Paged caches release any pages now entirely above `len`.
    pub fn truncate(&mut self, len: usize) -> Result<()> {
        if len > self.committed {
            bail!("truncate to {len} > committed {}", self.committed);
        }
        self.committed = len;
        self.prefix_len = self.prefix_len.min(len);
        if let Storage::Paged(p) = &mut self.storage {
            let bs = p.table.block_slots();
            let keep = if len == 0 { 0 } else { (len + bs - 1) / bs };
            p.release_from(keep);
        }
        Ok(())
    }

    /// Reset for the next sequence *of the same request lifecycle*:
    /// rolls `committed` back to the seeded prefix (or zero when none).
    /// Pages above the prefix stay allocated for reuse by this
    /// sequence; the pool wipes them on checkin.
    pub fn reset(&mut self) {
        self.committed = self.prefix_len;
    }

    /// Full clear for pool reuse: forget the prefix seed and (paged)
    /// release every page back to the pool.
    pub(crate) fn wipe(&mut self) {
        self.prefix_len = 0;
        self.committed = 0;
        if let Storage::Paged(p) = &mut self.storage {
            p.release_from(0);
        }
    }

    /// Install shared prefix pages covering the first `slots` rows and
    /// mark them committed (pool checkout path on a prefix hit).
    pub(crate) fn seed_prefix(&mut self, blocks: &[BlockRef], slots: usize) {
        if let Storage::Paged(p) = &mut self.storage {
            debug_assert_eq!(slots, blocks.len() * p.table.block_slots());
            for (i, b) in blocks.iter().enumerate() {
                p.table.entries_mut()[i] = Some(Arc::clone(b));
            }
            self.committed = slots;
            self.prefix_len = slots;
        }
    }

    /// The page table (paged storage only).
    pub fn block_table(&self) -> Option<&BlockTable> {
        match &self.storage {
            Storage::Slab(_) => None,
            Storage::Paged(p) => Some(&p.table),
        }
    }

    /// Read one row (test/debug helper).
    ///
    /// # Panics
    /// On a paged cache when the covering page was never allocated.
    pub fn row(&self, plane: usize, slot: usize) -> &[f32] {
        match &self.storage {
            Storage::Slab(data) => {
                let base = (plane * self.max_ctx + slot) * self.d;
                &data[base..base + self.d]
            }
            Storage::Paged(p) => {
                let (bi, off) = p.table.location(slot);
                let b = p.table.entries()[bi]
                    .as_ref()
                    .unwrap_or_else(|| panic!("row: slot {slot} has no allocated page"));
                let base = (plane * p.table.block_slots() + off) * self.d;
                &b[base..base + self.d]
            }
        }
    }

    /// Bytes actually resident for this cache: the whole slab, or only
    /// the allocated pages.
    pub fn memory_bytes(&self) -> usize {
        match &self.storage {
            Storage::Slab(data) => data.len() * std::mem::size_of::<f32>(),
            Storage::Paged(p) => p.table.allocated() * p.pool.block_bytes(),
        }
    }
}

/// Typed error for a checkout that would exceed the pool's cap — the
/// caller (the step scheduler) sized its admission budget wrong, a
/// cache leaked past its `checkin`, or (block-budgeted pools) the
/// request's prompt footprint does not fit the remaining `--kv-blocks`
/// budget even after evicting idle prefix pages.  Allocating anyway
/// would silently unbound runtime memory, which is exactly the paper's
/// memory story inverted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    /// the pool's outstanding-cache cap
    pub cap: usize,
    /// live pages at refusal time (0 unless block-budgeted)
    pub blocks_used: usize,
    /// the pool's page budget (0 unless block-budgeted)
    pub blocks_budget: usize,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.blocks_budget > 0 {
            write!(
                f,
                "KV cache pool exhausted: {}/{} blocks in use (cap {} sequences)",
                self.blocks_used, self.blocks_budget, self.cap
            )
        } else {
            write!(f, "KV cache pool exhausted: {} caches already checked out", self.cap)
        }
    }
}

impl std::error::Error for PoolExhausted {}

/// Pool of caches for concurrent sequences (the coordinator checks
/// caches out per in-flight sequence instead of reallocating ~MBs each
/// time).  The pool is **bounded**: at most `cap` caches may be
/// outstanding at once (the coordinator sizes it to
/// `workers × max_inflight`), so `created` converges to the live
/// concurrency and stays there no matter how many requests flow
/// through — callers that outpace `checkin` get a typed
/// [`PoolExhausted`] error instead of a silent allocation.  Built with
/// [`CachePool::new_paged`], checkouts are paged caches over a shared
/// [`BlockPool`] and memory is additionally page-budgeted.
///
/// ```
/// use ppd::kvcache::CachePool;
///
/// let mut pool = CachePool::new(2, 64, 4, 2); // cap: 2 outstanding
/// let a = pool.checkout().unwrap();
/// let b = pool.checkout().unwrap();
/// assert!(pool.checkout().is_err()); // typed PoolExhausted
/// pool.checkin(a);
/// let c = pool.checkout().unwrap();  // reuses a's buffer
/// assert_eq!(pool.created, 2);
/// # drop((b, c));
/// ```
#[derive(Debug)]
pub struct CachePool {
    template: (usize, usize, usize),
    free: Vec<HostKvCache>,
    pub created: usize,
    outstanding: usize,
    cap: usize,
    blocks: Option<BlockPool>,
}

impl CachePool {
    pub fn new(n_layers: usize, max_ctx: usize, d: usize, cap: usize) -> Self {
        CachePool {
            template: (n_layers, max_ctx, d),
            free: Vec::new(),
            created: 0,
            outstanding: 0,
            cap: cap.max(1),
            blocks: None,
        }
    }

    /// A pool whose caches are paged over a shared [`BlockPool`] of
    /// `block_budget` pages (page size from [`block_slots_for`]).
    pub fn new_paged(
        n_layers: usize,
        max_ctx: usize,
        d: usize,
        cap: usize,
        block_budget: usize,
    ) -> Self {
        let mut pool = CachePool::new(n_layers, max_ctx, d, cap);
        pool.blocks = Some(BlockPool::new(n_layers, block_slots_for(max_ctx), d, block_budget));
        pool
    }

    /// The shared page pool, when block-budgeted.
    pub fn block_pool(&self) -> Option<&BlockPool> {
        self.blocks.as_ref()
    }

    /// Caches currently checked out (≤ `cap`).
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn checkout(&mut self) -> Result<HostKvCache, PoolExhausted> {
        if self.outstanding >= self.cap {
            return Err(PoolExhausted { cap: self.cap, blocks_used: 0, blocks_budget: 0 });
        }
        self.outstanding += 1;
        Ok(match self.free.pop() {
            Some(mut c) => {
                c.reset();
                c
            }
            None => {
                self.created += 1;
                let (l, s, d) = self.template;
                match &self.blocks {
                    Some(bp) => HostKvCache::new_paged(l, s, d, bp),
                    None => HostKvCache::new(l, s, d),
                }
            }
        })
    }

    pub fn checkin(&mut self, mut cache: HostKvCache) {
        self.outstanding = self.outstanding.saturating_sub(1);
        // foreign shapes are dropped, not pooled: handing a wrong-shape
        // cache to a later checkout would make `forward` reject it
        if cache.shape() == self.template {
            // full clear: release pages and forget any prefix seed so
            // the budget is credited the moment the sequence retires
            cache.wipe();
            self.free.push(cache);
        }
    }
}

/// Thread-safe, lazily-templated [`CachePool`] shared by the
/// coordinator's workers.  The template shape is only known once the
/// first worker has loaded its model config, hence the `Option`; the
/// outstanding-cache cap — and the optional `--kv-blocks` page budget —
/// are fixed at construction.
#[derive(Debug)]
pub struct SharedCachePool {
    cap: usize,
    /// page budget for paged checkouts; `None` = classic slab caches
    kv_blocks: Option<usize>,
    inner: std::sync::Mutex<Option<CachePool>>,
}

impl SharedCachePool {
    pub fn new(cap: usize) -> Self {
        SharedCachePool { cap: cap.max(1), kv_blocks: None, inner: std::sync::Mutex::new(None) }
    }

    /// A pool whose caches are paged and jointly bounded by `kv_blocks`
    /// live pages — the serving layer's real memory ceiling.  Prefix
    /// reuse is on: [`SharedCachePool::checkout_for_prompt`] seeds
    /// shared pages and [`SharedCachePool::publish_prefix`] records
    /// them.
    pub fn with_block_budget(cap: usize, kv_blocks: usize) -> Self {
        SharedCachePool {
            cap: cap.max(1),
            kv_blocks: Some(kv_blocks.max(1)),
            inner: std::sync::Mutex::new(None),
        }
    }

    /// Check a cache out, initializing the pool template on first use.
    pub fn checkout(
        &self,
        n_layers: usize,
        max_ctx: usize,
        d: usize,
    ) -> Result<HostKvCache, PoolExhausted> {
        self.checkout_for_prompt(n_layers, max_ctx, d, &[])
    }

    /// Check a cache out for a specific prompt: on block-budgeted pools
    /// this walks the shared prefix store, seeds any hit pages
    /// copy-on-write (the sequence starts with `committed() ==
    /// prefix_len()` rows it never has to prefill), and refuses
    /// admission — with block accounting in [`PoolExhausted`] — when
    /// the *new* pages the prompt needs do not fit the budget.
    pub fn checkout_for_prompt(
        &self,
        n_layers: usize,
        max_ctx: usize,
        d: usize,
        prompt: &[u32],
    ) -> Result<HostKvCache, PoolExhausted> {
        let mut g = self.inner.lock().unwrap();
        let cap = self.cap;
        let kv_blocks = self.kv_blocks;
        let pool = g.get_or_insert_with(|| match kv_blocks {
            Some(budget) => CachePool::new_paged(n_layers, max_ctx, d, cap, budget),
            None => CachePool::new(n_layers, max_ctx, d, cap),
        });
        if pool.template != (n_layers, max_ctx, d) {
            // heterogeneous shapes (mixed models / per-worker configs):
            // serve a correctly-shaped unpooled cache instead of
            // silently substituting the template shape — checkin()
            // drops it rather than polluting the free list.  It still
            // counts against the cap: the cap bounds live cache memory,
            // not just the template shape.
            if pool.outstanding >= pool.cap {
                return Err(PoolExhausted {
                    cap: pool.cap,
                    blocks_used: 0,
                    blocks_budget: 0,
                });
            }
            pool.created += 1;
            pool.outstanding += 1;
            return Ok(HostKvCache::new(n_layers, max_ctx, d));
        }
        let mut cache = pool.checkout()?;
        let Some(bp) = pool.blocks.clone() else { return Ok(cache) };
        let mut shared = bp.lookup(prompt);
        // never seed past the usable context
        shared.truncate(cache.capacity() / bp.block_slots());
        let needed =
            bp.blocks_for_prompt(prompt.len(), cache.capacity()).saturating_sub(shared.len());
        if let Err(mut e) = bp.admit(needed) {
            e.cap = pool.cap;
            pool.checkin(cache);
            return Err(e);
        }
        if !shared.is_empty() {
            let hit = shared.len() * bp.block_slots();
            cache.seed_prefix(&shared, hit);
            bp.note_hit(shared.len());
        }
        Ok(cache)
    }

    /// Record a sequence's prompt pages in the shared prefix store so
    /// later requests with the same prompt prefix ride them.  Call
    /// after the engine has prefilled (every full prompt chunk within
    /// `committed()` is published).  No-op on slab pools.
    pub fn publish_prefix(&self, cache: &HostKvCache, prompt: &[u32]) {
        let g = self.inner.lock().unwrap();
        let Some(pool) = g.as_ref() else { return };
        let Some(bp) = &pool.blocks else { return };
        if let Some(table) = cache.block_table() {
            bp.publish(prompt, table, cache.committed());
        }
    }

    pub fn checkin(&self, cache: HostKvCache) {
        let mut g = self.inner.lock().unwrap();
        if let Some(pool) = g.as_mut() {
            pool.checkin(cache);
        }
    }

    /// Reconcile a cache that is *gone* — moved into a device-dispatcher
    /// submission whose reply channel died with the dispatcher, so there
    /// is no `HostKvCache` to hand back.  Decrements `outstanding` (the
    /// cap must not stay consumed by a dead device thread); the lost
    /// allocation itself is not re-pooled, so a later checkout may
    /// allocate a replacement within the cap.  Paged caches release
    /// their pages in `Drop` wherever the dispatcher dropped them, so
    /// the block budget self-heals.
    pub fn forget(&self) {
        let mut g = self.inner.lock().unwrap();
        if let Some(pool) = g.as_mut() {
            pool.outstanding = pool.outstanding.saturating_sub(1);
        }
    }

    /// Total caches ever allocated (the pool-efficiency metric: stays
    /// at `workers × max_inflight` under steady load).
    pub fn created(&self) -> usize {
        self.inner.lock().unwrap().as_ref().map_or(0, |p| p.created)
    }

    /// Caches currently checked out across all workers.
    pub fn outstanding(&self) -> usize {
        self.inner.lock().unwrap().as_ref().map_or(0, |p| p.outstanding)
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    fn with_blocks<T: Default>(&self, f: impl FnOnce(&BlockPool) -> T) -> T {
        self.inner
            .lock()
            .unwrap()
            .as_ref()
            .and_then(|p| p.blocks.as_ref().map(f))
            .unwrap_or_default()
    }

    /// Distinct live pages (0 on slab pools).
    pub fn blocks_used(&self) -> usize {
        self.with_blocks(|b| b.blocks_used())
    }

    /// Page-budget headroom (0 on slab pools).
    pub fn blocks_free(&self) -> usize {
        self.with_blocks(|b| b.blocks_free())
    }

    /// High-water mark of live pages (0 on slab pools).
    pub fn peak_blocks_used(&self) -> usize {
        self.with_blocks(|b| b.peak_blocks_used())
    }

    /// Prompt-prefix store hits served so far (0 on slab pools).
    pub fn prefix_hits(&self) -> u64 {
        self.with_blocks(|b| b.prefix_hits())
    }

    /// Total pages handed out by reference from the prefix store.
    pub fn prefix_blocks_shared(&self) -> u64 {
        self.with_blocks(|b| b.prefix_blocks_shared())
    }

    /// Page size in slots (0 on slab pools).
    pub fn kv_block_slots(&self) -> usize {
        self.with_blocks(|b| b.block_slots())
    }

    /// Peak resident KV bytes: live pages at high water for paged
    /// pools, every slab ever created for slab pools.
    pub fn resident_kv_bytes(&self) -> usize {
        let g = self.inner.lock().unwrap();
        match g.as_ref() {
            None => 0,
            Some(p) => match &p.blocks {
                Some(bp) => bp.peak_blocks_used() * bp.block_bytes(),
                None => {
                    let (l, s, d) = p.template;
                    p.created * 2 * l * s * d * std::mem::size_of::<f32>()
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> HostKvCache {
        HostKvCache::new(2, 16, 4) // planes=4, S=16, d=4
    }

    fn mk_paged(pool: &BlockPool) -> HostKvCache {
        HostKvCache::new_paged(2, 16, 4, pool)
    }

    fn small_block_pool(budget: usize) -> BlockPool {
        BlockPool::new(2, 2, 4, budget) // pages of 2 slots
    }

    fn kv_rows(planes: usize, n: usize, d: usize, base: f32) -> Vec<f32> {
        // row (p, i) filled with base + p*100 + i
        let mut v = Vec::with_capacity(planes * n * d);
        for p in 0..planes {
            for i in 0..n {
                for _ in 0..d {
                    v.push(base + (p * 100 + i) as f32);
                }
            }
        }
        v
    }

    #[test]
    fn scatter_places_rows() {
        let mut c = mk();
        let kv = kv_rows(4, 2, 4, 0.0);
        c.scatter(&kv, &[3, 7]).unwrap();
        assert_eq!(c.row(0, 3)[0], 0.0);
        assert_eq!(c.row(0, 7)[0], 1.0);
        assert_eq!(c.row(3, 7)[0], 301.0);
    }

    #[test]
    fn scatter_validates_sizes() {
        let mut c = mk();
        assert!(c.scatter(&[0.0; 7], &[0]).is_err());
        let kv = kv_rows(4, 1, 4, 0.0);
        assert!(c.scatter(&kv, &[16]).is_err());
    }

    #[test]
    fn compact_moves_accepted_path() {
        let mut c = mk();
        c.commit_contiguous(5).unwrap();
        // tree scratch rows at slots 5..9; accepted path = slots 5, 7, 8
        let kv = kv_rows(4, 4, 4, 0.5);
        c.scatter(&kv, &[5, 6, 7, 8]).unwrap();
        let want_7 = c.row(0, 7).to_vec();
        let want_8 = c.row(1, 8).to_vec();
        c.compact(&[5, 7, 8]).unwrap();
        assert_eq!(c.committed(), 8);
        assert_eq!(c.row(0, 6), &want_7[..]); // slot 7 -> 6
        assert_eq!(c.row(1, 7), &want_8[..]); // slot 8 -> 7
    }

    #[test]
    fn compact_rejects_overlap_and_overflow() {
        let mut c = mk();
        c.commit_contiguous(5).unwrap();
        assert!(c.compact(&[3]).is_err()); // would clobber committed
        let mut c2 = mk();
        c2.commit_contiguous(13).unwrap();
        assert!(c2.compact(&[13, 13]).is_err()); // 15 > capacity 14
    }

    #[test]
    fn prefill_then_truncate() {
        let mut c = mk();
        c.commit_contiguous(10).unwrap();
        c.truncate(4).unwrap();
        assert_eq!(c.committed(), 4);
        assert!(c.truncate(5).is_err());
    }

    #[test]
    fn capacity_reserves_trash_slot() {
        let c = mk();
        assert_eq!(c.capacity(), 14);
        assert_eq!(c.memory_bytes(), 4 * 16 * 4 * 4);
    }

    #[test]
    fn paged_cache_mirrors_slab_semantics() {
        // the same op sequence on both storages must agree on committed
        // length and every committed logical byte (rows above committed
        // are dead in both designs — slab keeps stale garbage there,
        // paged reads zeros from released pages; the device masks both)
        let pool = small_block_pool(64);
        let mut slab = mk();
        let mut paged = mk_paged(&pool);
        let kv = kv_rows(4, 3, 4, 1.0);
        for c in [&mut slab, &mut paged] {
            c.commit_contiguous(4).unwrap();
            c.scatter(&kv, &[4, 6, 7]).unwrap();
            c.compact(&[4, 7]).unwrap();
            c.truncate(5).unwrap();
            c.scatter(&kv_rows(4, 1, 4, 9.0), &[5]).unwrap();
            c.commit_contiguous(1).unwrap();
        }
        assert_eq!(slab.committed(), paged.committed());
        let kv_len = slab.committed();
        // per-plane collator gathers over the committed region agree
        for p in 0..4 {
            let mut a = vec![0.0; kv_len * 4];
            let mut b = vec![0.0; kv_len * 4];
            slab.copy_plane_prefix(p, kv_len, &mut a);
            paged.copy_plane_prefix(p, kv_len, &mut b);
            assert_eq!(a, b, "plane {p}");
        }
        // device snapshots agree row-for-row within the committed region
        let (sa, sb) = (slab.device_snapshot().into_owned(), paged.device_snapshot().into_owned());
        for p in 0..4 {
            let at = |s: &[f32]| s[p * 16 * 4..(p * 16 + kv_len) * 4].to_vec();
            assert_eq!(at(&sa), at(&sb), "plane {p}");
        }
    }

    #[test]
    fn paged_cache_releases_pages_on_truncate_and_drop() {
        let pool = small_block_pool(64);
        let mut c = mk_paged(&pool);
        c.commit_contiguous(8).unwrap(); // pages 0..4 (2 slots each)
        assert_eq!(pool.blocks_used(), 4);
        assert_eq!(c.memory_bytes(), 4 * pool.block_bytes());
        c.truncate(3).unwrap(); // pages 2,3 now fully above len
        assert_eq!(pool.blocks_used(), 2);
        drop(c);
        assert_eq!(pool.blocks_used(), 0, "drop must return every page");
    }

    #[test]
    fn cow_divergence_never_touches_the_shared_page() {
        let p = SharedCachePool::with_block_budget(8, 64);
        let prompt = [9u32, 8, 7, 6, 5];
        // first sequence computes the prompt KV and publishes it
        let mut c0 = p.checkout_for_prompt(2, 16, 4, &prompt).unwrap();
        c0.scatter(&kv_rows(4, 5, 4, 0.0), &[0, 1, 2, 3, 4]).unwrap();
        c0.commit_contiguous(5).unwrap();
        p.publish_prefix(&c0, &prompt);
        p.checkin(c0);
        // two riders share the prefix pages (bs=2 -> 4 slots seeded)
        let mut a = p.checkout_for_prompt(2, 16, 4, &prompt).unwrap();
        let b = p.checkout_for_prompt(2, 16, 4, &prompt).unwrap();
        assert_eq!(a.committed(), 4);
        assert_eq!(a.prefix_len(), 4);
        assert_eq!(p.prefix_hits(), 2);
        assert_eq!(p.prefix_blocks_shared(), 4);
        let before = b.row(0, 1).to_vec();
        assert!(a.block_table().unwrap().is_shared(0));
        // rider A diverges: overwrite a row inside a shared page
        a.scatter(&kv_rows(4, 1, 4, 500.0), &[1]).unwrap();
        assert!(!a.block_table().unwrap().is_shared(0), "write must have copied the page");
        assert_eq!(a.row(0, 1)[0], 500.0);
        assert_eq!(b.row(0, 1), &before[..], "rider B sees the original page");
        // a third rider still gets the unmodified store copy
        let c = p.checkout_for_prompt(2, 16, 4, &prompt).unwrap();
        assert_eq!(c.row(0, 1), &before[..]);
        p.checkin(a);
        p.checkin(b);
        p.checkin(c);
        // on retire every non-store reference is refcount-freed: only
        // the prefix store still pins pages
        assert_eq!(p.outstanding(), 0);
        assert_eq!(p.blocks_used(), 2, "only the 2 published pages stay live");
    }

    #[test]
    fn shared_prefix_admits_strictly_more_sequences_per_block_budget() {
        // acceptance: with the SAME 7-page budget, distinct prompts fit
        // 2 concurrent sequences; a shared prefix fits 3+ because the
        // prefix pages are counted once
        let prompt = [1u32, 2, 3, 4, 5]; // needs 3 pages (bs=2, 6 slots)
        let solo = SharedCachePool::with_block_budget(16, 7);
        let mut held = Vec::new();
        for i in 0..2u32 {
            let distinct: Vec<u32> = prompt.iter().map(|&t| t + 10 * i).collect();
            let mut c = solo.checkout_for_prompt(2, 16, 4, &distinct).unwrap();
            c.commit_contiguous(6).unwrap(); // materialize prompt+1 rows
            held.push(c);
        }
        assert_eq!(solo.blocks_used(), 6);
        let err = solo.checkout_for_prompt(2, 16, 4, &[7u32, 7, 7, 7, 7]).unwrap_err();
        assert_eq!(err.blocks_used, 6);
        assert_eq!(err.blocks_budget, 7);
        assert!(format!("{err}").contains("blocks"));

        let sharing = SharedCachePool::with_block_budget(16, 7);
        let mut c0 = sharing.checkout_for_prompt(2, 16, 4, &prompt).unwrap();
        c0.commit_contiguous(6).unwrap();
        sharing.publish_prefix(&c0, &prompt);
        sharing.checkin(c0);
        let mut riders = Vec::new();
        for _ in 0..3 {
            let mut c = sharing.checkout_for_prompt(2, 16, 4, &prompt).unwrap();
            assert_eq!(c.committed(), 4, "prefix pages seeded");
            c.commit_contiguous(2).unwrap(); // only the tail is new
            riders.push(c);
        }
        assert!(
            riders.len() > held.len(),
            "sharing must fit strictly more concurrent sequences"
        );
        // 2 shared pages + 3 private tail pages
        assert_eq!(sharing.blocks_used(), 5);
        assert!(sharing.prefix_hits() >= 3);
        drop((held, riders));
    }

    #[test]
    fn pool_reuses() {
        let mut p = CachePool::new(2, 16, 4, 8);
        let mut a = p.checkout().unwrap();
        a.commit_contiguous(3).unwrap();
        p.checkin(a);
        let b = p.checkout().unwrap();
        assert_eq!(b.committed(), 0);
        assert_eq!(p.created, 1);
        let _c = p.checkout().unwrap();
        assert_eq!(p.created, 2);
    }

    #[test]
    fn pool_rejects_foreign_shapes() {
        let mut p = CachePool::new(2, 16, 4, 8);
        p.checkin(HostKvCache::new(3, 16, 4)); // wrong layer count
        let c = p.checkout().unwrap();
        assert_eq!(c.shape(), (2, 16, 4));
        assert_eq!(p.created, 1);
    }

    #[test]
    fn pool_cap_is_enforced_with_typed_error() {
        // regression: checkout used to silently allocate without bound
        // when callers outpaced checkin
        let mut p = CachePool::new(2, 16, 4, 2);
        let a = p.checkout().unwrap();
        let b = p.checkout().unwrap();
        assert_eq!(p.outstanding(), 2);
        let err = p.checkout().unwrap_err();
        assert_eq!(err, PoolExhausted { cap: 2, blocks_used: 0, blocks_budget: 0 });
        assert!(format!("{err}").contains("exhausted"));
        // created never grew past the cap
        assert_eq!(p.created, 2);
        // a checkin frees a slot again
        p.checkin(a);
        let c = p.checkout().unwrap();
        assert_eq!(c.shape(), (2, 16, 4));
        drop(b);
    }

    #[test]
    fn paged_pool_checkouts_are_paged_and_wiped_on_checkin() {
        let mut p = CachePool::new_paged(2, 16, 4, 4, 32);
        let mut a = p.checkout().unwrap();
        assert!(a.is_paged());
        a.commit_contiguous(6).unwrap();
        let bp = p.block_pool().unwrap().clone();
        assert!(bp.blocks_used() > 0);
        p.checkin(a);
        assert_eq!(bp.blocks_used(), 0, "checkin must release every page");
        let b = p.checkout().unwrap();
        assert!(b.is_paged());
        assert_eq!(b.committed(), 0);
        assert_eq!(p.created, 1, "wiped cache was reused");
    }

    #[test]
    fn shared_pool_is_lazy_and_bounded() {
        let p = SharedCachePool::new(8);
        assert_eq!(p.created(), 0);
        let a = p.checkout(2, 16, 4).unwrap();
        let b = p.checkout(2, 16, 4).unwrap();
        assert_eq!(p.created(), 2);
        assert_eq!(p.outstanding(), 2);
        p.checkin(a);
        p.checkin(b);
        assert_eq!(p.outstanding(), 0);
        // steady state: repeated checkout/checkin allocates nothing new
        for _ in 0..8 {
            let c = p.checkout(2, 16, 4).unwrap();
            p.checkin(c);
        }
        assert_eq!(p.created(), 2);
    }

    #[test]
    fn shared_pool_enforces_cap() {
        let p = SharedCachePool::new(1);
        let a = p.checkout(2, 16, 4).unwrap();
        assert!(p.checkout(2, 16, 4).is_err());
        // foreign shapes count against the cap too (they are live memory)
        assert!(p.checkout(3, 32, 4).is_err());
        p.checkin(a);
        assert!(p.checkout(2, 16, 4).is_ok());
    }

    #[test]
    fn shared_pool_serves_foreign_shapes_unpooled() {
        let p = SharedCachePool::new(8);
        let a = p.checkout(2, 16, 4).unwrap(); // sets the template
        let b = p.checkout(3, 32, 4).unwrap(); // foreign shape: must not be coerced
        assert_eq!(b.shape(), (3, 32, 4));
        assert_eq!(p.outstanding(), 2);
        p.checkin(a);
        p.checkin(b); // foreign cache is dropped, not pooled
        assert_eq!(p.outstanding(), 0);
        let c = p.checkout(2, 16, 4).unwrap();
        assert_eq!(c.shape(), (2, 16, 4));
    }

    #[test]
    fn slab_pool_metrics_read_zero_and_paged_pool_reports() {
        let slab = SharedCachePool::new(2);
        let _a = slab.checkout(2, 16, 4).unwrap();
        assert_eq!(slab.blocks_used(), 0);
        assert_eq!(slab.prefix_hits(), 0);
        assert!(slab.resident_kv_bytes() > 0);

        let paged = SharedCachePool::with_block_budget(2, 16);
        let mut c = paged.checkout(2, 16, 4).unwrap();
        c.commit_contiguous(4).unwrap();
        assert_eq!(paged.kv_block_slots(), 2);
        assert_eq!(paged.blocks_used(), 2);
        assert_eq!(paged.blocks_free(), 14);
        assert_eq!(paged.resident_kv_bytes(), 2 * 2 * 2 * 2 * 4 * 4);
        paged.checkin(c);
        assert_eq!(paged.blocks_used(), 0);
        assert_eq!(paged.peak_blocks_used(), 2);
    }
}
