//! Host-authoritative KV-cache manager.
//!
//! The forward executables scatter the step's K/V into a *copy* of the
//! cache on device for attention, and return the new rows; rust owns the
//! real cache and applies the same scatter here, then **compacts** after
//! verification: the accepted tree path's rows are moved down onto the
//! contiguous committed region (paper §3, "candidate acceptance ... KV
//! cache is updated accordingly").  Rejected tree rows simply stay above
//! `committed` and are dead — the next step's bias never exposes them.
//!
//! Layout: `[2L, max_ctx, d]` row-major; layer l's keys at plane `2l`,
//! values at `2l+1`.  Slot `max_ctx-1` is reserved as the padding trash
//! row (see `runtime::Runtime::forward`); usable context is
//! `max_ctx - RESERVED` slots.
//!
//! ## Pooling
//!
//! A cache is ~MBs and request lifetimes are short, so the serving
//! layer never allocates caches per request: each in-flight *sequence*
//! borrows a cache for its lifetime, and the coordinator's step
//! scheduler checks caches out of a [`CachePool`] (wrapped in a
//! [`SharedCachePool`] so all worker threads draw from one free list).
//! The pool enforces a hard cap — at most one cache per admitted
//! sequence, i.e. `workers × max_inflight` — returning a typed
//! [`PoolExhausted`] error rather than allocating past it, which is the
//! paper's runtime-memory story (≈0.0004% overhead) carried through to
//! the serving layer.

use anyhow::{bail, Result};

pub const RESERVED_SLOTS: usize = 2;

#[derive(Debug, Clone)]
pub struct HostKvCache {
    data: Vec<f32>,
    planes: usize,
    max_ctx: usize,
    d: usize,
    /// committed context length (number of finalized tokens)
    committed: usize,
}

impl HostKvCache {
    pub fn new(n_layers: usize, max_ctx: usize, d: usize) -> Self {
        let planes = 2 * n_layers;
        HostKvCache {
            data: vec![0.0; planes * max_ctx * d],
            planes,
            max_ctx,
            d,
            committed: 0,
        }
    }

    pub fn committed(&self) -> usize {
        self.committed
    }

    /// `(n_layers, max_ctx, d)` — the tuple [`CachePool`] templates on.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.planes / 2, self.max_ctx, self.d)
    }

    pub fn capacity(&self) -> usize {
        self.max_ctx - RESERVED_SLOTS
    }

    pub fn remaining(&self) -> usize {
        self.capacity().saturating_sub(self.committed)
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Scatter the step's returned rows: `new_kv` is `[planes, n, d]`
    /// and token i's row lands at cache slot `slots[i]` in every plane.
    pub fn scatter(&mut self, new_kv: &[f32], slots: &[u32]) -> Result<()> {
        let n = slots.len();
        if new_kv.len() != self.planes * n * self.d {
            bail!(
                "scatter: new_kv has {} values, want {}",
                new_kv.len(),
                self.planes * n * self.d
            );
        }
        for (i, &slot) in slots.iter().enumerate() {
            let slot = slot as usize;
            if slot >= self.max_ctx {
                bail!("scatter: slot {slot} out of range");
            }
            for p in 0..self.planes {
                let src = (p * n + i) * self.d;
                let dst = (p * self.max_ctx + slot) * self.d;
                self.data[dst..dst + self.d].copy_from_slice(&new_kv[src..src + self.d]);
            }
        }
        Ok(())
    }

    /// Commit `count` already-contiguous rows starting at `committed`
    /// (prefill path: slots were `committed..committed+count`).
    pub fn commit_contiguous(&mut self, count: usize) -> Result<()> {
        if self.committed + count > self.capacity() {
            bail!("cache overflow: {} + {count} > {}", self.committed, self.capacity());
        }
        self.committed += count;
        Ok(())
    }

    /// Compact after verification: move the rows at `accepted_slots`
    /// (tree scratch positions, in path order) down to the committed
    /// region and advance `committed`.  Slots equal to their target are
    /// skipped (the tree root is written at `committed` already).
    pub fn compact(&mut self, accepted_slots: &[u32]) -> Result<()> {
        if self.committed + accepted_slots.len() > self.capacity() {
            bail!(
                "cache overflow on compact: {} + {} > {}",
                self.committed,
                accepted_slots.len(),
                self.capacity()
            );
        }
        for (i, &src) in accepted_slots.iter().enumerate() {
            let src = src as usize;
            let dst = self.committed + i;
            if src == dst {
                continue;
            }
            if src >= self.max_ctx {
                bail!("compact: slot {src} out of range");
            }
            if src < self.committed + i {
                bail!("compact: slot {src} would overwrite committed rows");
            }
            for p in 0..self.planes {
                let s = (p * self.max_ctx + src) * self.d;
                let t = (p * self.max_ctx + dst) * self.d;
                self.data.copy_within(s..s + self.d, t);
            }
        }
        self.committed += accepted_slots.len();
        Ok(())
    }

    /// Roll back to a shorter committed length (request retry/cancel).
    pub fn truncate(&mut self, len: usize) -> Result<()> {
        if len > self.committed {
            bail!("truncate to {len} > committed {}", self.committed);
        }
        self.committed = len;
        Ok(())
    }

    /// Reset for reuse by another sequence.
    pub fn reset(&mut self) {
        self.committed = 0;
        // rows above committed are always masked; no need to zero
    }

    /// Read one row (test/debug helper).
    pub fn row(&self, plane: usize, slot: usize) -> &[f32] {
        let base = (plane * self.max_ctx + slot) * self.d;
        &self.data[base..base + self.d]
    }

    pub fn memory_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// Typed error for a checkout that would exceed the pool's cap — the
/// caller (the step scheduler) sized its admission budget wrong, or a
/// cache leaked past its `checkin`.  Allocating anyway would silently
/// unbound runtime memory, which is exactly the paper's memory story
/// inverted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    /// the pool's outstanding-cache cap
    pub cap: usize,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "KV cache pool exhausted: {} caches already checked out", self.cap)
    }
}

impl std::error::Error for PoolExhausted {}

/// Pool of caches for concurrent sequences (the coordinator checks
/// caches out per in-flight sequence instead of reallocating ~MBs each
/// time).  The pool is **bounded**: at most `cap` caches may be
/// outstanding at once (the coordinator sizes it to
/// `workers × max_inflight`), so `created` converges to the live
/// concurrency and stays there no matter how many requests flow
/// through — callers that outpace `checkin` get a typed
/// [`PoolExhausted`] error instead of a silent allocation.
#[derive(Debug)]
pub struct CachePool {
    template: (usize, usize, usize),
    free: Vec<HostKvCache>,
    pub created: usize,
    outstanding: usize,
    cap: usize,
}

impl CachePool {
    pub fn new(n_layers: usize, max_ctx: usize, d: usize, cap: usize) -> Self {
        CachePool {
            template: (n_layers, max_ctx, d),
            free: Vec::new(),
            created: 0,
            outstanding: 0,
            cap: cap.max(1),
        }
    }

    /// Caches currently checked out (≤ `cap`).
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn checkout(&mut self) -> Result<HostKvCache, PoolExhausted> {
        if self.outstanding >= self.cap {
            return Err(PoolExhausted { cap: self.cap });
        }
        self.outstanding += 1;
        Ok(match self.free.pop() {
            Some(mut c) => {
                c.reset();
                c
            }
            None => {
                self.created += 1;
                let (l, s, d) = self.template;
                HostKvCache::new(l, s, d)
            }
        })
    }

    pub fn checkin(&mut self, cache: HostKvCache) {
        self.outstanding = self.outstanding.saturating_sub(1);
        // foreign shapes are dropped, not pooled: handing a wrong-shape
        // cache to a later checkout would make `forward` reject it
        if cache.shape() == self.template {
            self.free.push(cache);
        }
    }
}

/// Thread-safe, lazily-templated [`CachePool`] shared by the
/// coordinator's workers.  The template shape is only known once the
/// first worker has loaded its model config, hence the `Option`; the
/// outstanding-cache cap is fixed at construction.
#[derive(Debug)]
pub struct SharedCachePool {
    cap: usize,
    inner: std::sync::Mutex<Option<CachePool>>,
}

impl SharedCachePool {
    pub fn new(cap: usize) -> Self {
        SharedCachePool { cap: cap.max(1), inner: std::sync::Mutex::new(None) }
    }

    /// Check a cache out, initializing the pool template on first use.
    pub fn checkout(
        &self,
        n_layers: usize,
        max_ctx: usize,
        d: usize,
    ) -> Result<HostKvCache, PoolExhausted> {
        let mut g = self.inner.lock().unwrap();
        let cap = self.cap;
        let pool = g.get_or_insert_with(|| CachePool::new(n_layers, max_ctx, d, cap));
        if pool.template != (n_layers, max_ctx, d) {
            // heterogeneous shapes (mixed models / per-worker configs):
            // serve a correctly-shaped unpooled cache instead of
            // silently substituting the template shape — checkin()
            // drops it rather than polluting the free list.  It still
            // counts against the cap: the cap bounds live cache memory,
            // not just the template shape.
            if pool.outstanding >= pool.cap {
                return Err(PoolExhausted { cap: pool.cap });
            }
            pool.created += 1;
            pool.outstanding += 1;
            return Ok(HostKvCache::new(n_layers, max_ctx, d));
        }
        pool.checkout()
    }

    pub fn checkin(&self, cache: HostKvCache) {
        let mut g = self.inner.lock().unwrap();
        if let Some(pool) = g.as_mut() {
            pool.checkin(cache);
        }
    }

    /// Reconcile a cache that is *gone* — moved into a device-dispatcher
    /// submission whose reply channel died with the dispatcher, so there
    /// is no `HostKvCache` to hand back.  Decrements `outstanding` (the
    /// cap must not stay consumed by a dead device thread); the lost
    /// allocation itself is not re-pooled, so a later checkout may
    /// allocate a replacement within the cap.
    pub fn forget(&self) {
        let mut g = self.inner.lock().unwrap();
        if let Some(pool) = g.as_mut() {
            pool.outstanding = pool.outstanding.saturating_sub(1);
        }
    }

    /// Total caches ever allocated (the pool-efficiency metric: stays
    /// at `workers × max_inflight` under steady load).
    pub fn created(&self) -> usize {
        self.inner.lock().unwrap().as_ref().map_or(0, |p| p.created)
    }

    /// Caches currently checked out across all workers.
    pub fn outstanding(&self) -> usize {
        self.inner.lock().unwrap().as_ref().map_or(0, |p| p.outstanding)
    }

    pub fn cap(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> HostKvCache {
        HostKvCache::new(2, 16, 4) // planes=4, S=16, d=4
    }

    fn kv_rows(planes: usize, n: usize, d: usize, base: f32) -> Vec<f32> {
        // row (p, i) filled with base + p*100 + i
        let mut v = Vec::with_capacity(planes * n * d);
        for p in 0..planes {
            for i in 0..n {
                for _ in 0..d {
                    v.push(base + (p * 100 + i) as f32);
                }
            }
        }
        v
    }

    #[test]
    fn scatter_places_rows() {
        let mut c = mk();
        let kv = kv_rows(4, 2, 4, 0.0);
        c.scatter(&kv, &[3, 7]).unwrap();
        assert_eq!(c.row(0, 3)[0], 0.0);
        assert_eq!(c.row(0, 7)[0], 1.0);
        assert_eq!(c.row(3, 7)[0], 301.0);
    }

    #[test]
    fn scatter_validates_sizes() {
        let mut c = mk();
        assert!(c.scatter(&[0.0; 7], &[0]).is_err());
        let kv = kv_rows(4, 1, 4, 0.0);
        assert!(c.scatter(&kv, &[16]).is_err());
    }

    #[test]
    fn compact_moves_accepted_path() {
        let mut c = mk();
        c.commit_contiguous(5).unwrap();
        // tree scratch rows at slots 5..9; accepted path = slots 5, 7, 8
        let kv = kv_rows(4, 4, 4, 0.5);
        c.scatter(&kv, &[5, 6, 7, 8]).unwrap();
        let want_7 = c.row(0, 7).to_vec();
        let want_8 = c.row(1, 8).to_vec();
        c.compact(&[5, 7, 8]).unwrap();
        assert_eq!(c.committed(), 8);
        assert_eq!(c.row(0, 6), &want_7[..]); // slot 7 -> 6
        assert_eq!(c.row(1, 7), &want_8[..]); // slot 8 -> 7
    }

    #[test]
    fn compact_rejects_overlap_and_overflow() {
        let mut c = mk();
        c.commit_contiguous(5).unwrap();
        assert!(c.compact(&[3]).is_err()); // would clobber committed
        let mut c2 = mk();
        c2.commit_contiguous(13).unwrap();
        assert!(c2.compact(&[13, 13]).is_err()); // 15 > capacity 14
    }

    #[test]
    fn prefill_then_truncate() {
        let mut c = mk();
        c.commit_contiguous(10).unwrap();
        c.truncate(4).unwrap();
        assert_eq!(c.committed(), 4);
        assert!(c.truncate(5).is_err());
    }

    #[test]
    fn capacity_reserves_trash_slot() {
        let c = mk();
        assert_eq!(c.capacity(), 14);
        assert_eq!(c.memory_bytes(), 4 * 16 * 4 * 4);
    }

    #[test]
    fn pool_reuses() {
        let mut p = CachePool::new(2, 16, 4, 8);
        let mut a = p.checkout().unwrap();
        a.commit_contiguous(3).unwrap();
        p.checkin(a);
        let b = p.checkout().unwrap();
        assert_eq!(b.committed(), 0);
        assert_eq!(p.created, 1);
        let _c = p.checkout().unwrap();
        assert_eq!(p.created, 2);
    }

    #[test]
    fn pool_rejects_foreign_shapes() {
        let mut p = CachePool::new(2, 16, 4, 8);
        p.checkin(HostKvCache::new(3, 16, 4)); // wrong layer count
        let c = p.checkout().unwrap();
        assert_eq!(c.shape(), (2, 16, 4));
        assert_eq!(p.created, 1);
    }

    #[test]
    fn pool_cap_is_enforced_with_typed_error() {
        // regression: checkout used to silently allocate without bound
        // when callers outpaced checkin
        let mut p = CachePool::new(2, 16, 4, 2);
        let a = p.checkout().unwrap();
        let b = p.checkout().unwrap();
        assert_eq!(p.outstanding(), 2);
        let err = p.checkout().unwrap_err();
        assert_eq!(err, PoolExhausted { cap: 2 });
        assert!(format!("{err}").contains("exhausted"));
        // created never grew past the cap
        assert_eq!(p.created, 2);
        // a checkin frees a slot again
        p.checkin(a);
        let c = p.checkout().unwrap();
        assert_eq!(c.shape(), (2, 16, 4));
        drop(b);
    }

    #[test]
    fn shared_pool_is_lazy_and_bounded() {
        let p = SharedCachePool::new(8);
        assert_eq!(p.created(), 0);
        let a = p.checkout(2, 16, 4).unwrap();
        let b = p.checkout(2, 16, 4).unwrap();
        assert_eq!(p.created(), 2);
        assert_eq!(p.outstanding(), 2);
        p.checkin(a);
        p.checkin(b);
        assert_eq!(p.outstanding(), 0);
        // steady state: repeated checkout/checkin allocates nothing new
        for _ in 0..8 {
            let c = p.checkout(2, 16, 4).unwrap();
            p.checkin(c);
        }
        assert_eq!(p.created(), 2);
    }

    #[test]
    fn shared_pool_enforces_cap() {
        let p = SharedCachePool::new(1);
        let a = p.checkout(2, 16, 4).unwrap();
        assert!(p.checkout(2, 16, 4).is_err());
        // foreign shapes count against the cap too (they are live memory)
        assert!(p.checkout(3, 32, 4).is_err());
        p.checkin(a);
        assert!(p.checkout(2, 16, 4).is_ok());
    }

    #[test]
    fn shared_pool_serves_foreign_shapes_unpooled() {
        let p = SharedCachePool::new(8);
        let a = p.checkout(2, 16, 4).unwrap(); // sets the template
        let b = p.checkout(3, 32, 4).unwrap(); // foreign shape: must not be coerced
        assert_eq!(b.shape(), (3, 32, 4));
        assert_eq!(p.outstanding(), 2);
        p.checkin(a);
        p.checkin(b); // foreign cache is dropped, not pooled
        assert_eq!(p.outstanding(), 0);
        let c = p.checkout(2, 16, 4).unwrap();
        assert_eq!(c.shape(), (2, 16, 4));
    }
}
