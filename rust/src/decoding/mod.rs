//! Decode engines: the guess-and-verify loop in all its variants.
//!
//! * [`vanilla`]     — plain autoregressive decoding (the baseline all
//!                     speedups are measured against)
//! * [`ppd`]         — the paper's Parallel Prompt Decoding with the
//!                     dynamic sparse tree
//! * [`medusa`]      — Medusa-1 baseline (decoding heads, static tree)
//! * [`lookup`]      — retrieval-style baselines: PLD (prompt lookup),
//!                     REST (datastore n-grams), lookahead-lite
//! * [`speculative`] — draft-model speculative decoding, with optional
//!                     PPD-accelerated drafting (paper §5.3)
//! * [`verify`]      — exact-match + typical-acceptance verification

pub mod lookup;
pub mod medusa;
pub mod ppd;
pub mod speculative;
pub mod vanilla;
pub mod verify;

use anyhow::{bail, Result};

use crate::config::EOS_ID;
use crate::kvcache::HostKvCache;
use crate::runtime::{Runtime, StepOutput, NEG_INF};

/// Outcome of one generation, with the accounting every bench needs.
#[derive(Debug, Clone, Default)]
pub struct GenerationResult {
    /// generated tokens (prompt excluded)
    pub tokens: Vec<u32>,
    /// forward passes of the *target* model during decode
    pub steps: usize,
    /// tokens emitted by each decode step (the τ samples)
    pub accepted_per_step: Vec<usize>,
    /// input length of each decode step (S_input samples)
    pub input_lens: Vec<usize>,
    /// wallclock of the decode phase (prefill excluded)
    pub decode_s: f64,
    /// wallclock of the prefill phase
    pub prefill_s: f64,
    /// draft-model forward passes (speculative engines)
    pub draft_steps: usize,
}

impl GenerationResult {
    /// Mean accepted length τ (tokens per decode step).
    pub fn tau(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.steps as f64
        }
    }

    /// Decode-phase throughput in tokens/s.
    pub fn throughput(&self) -> f64 {
        if self.decode_s == 0.0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.decode_s
        }
    }

    /// Mean forward-pass latency during decode.
    pub fn mean_fp_latency(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.decode_s / self.steps as f64
        }
    }

    pub fn mean_input_len(&self) -> f64 {
        if self.input_lens.is_empty() {
            0.0
        } else {
            self.input_lens.iter().sum::<usize>() as f64 / self.input_lens.len() as f64
        }
    }
}

/// A decoding engine; one instance serves one request at a time (each
/// coordinator worker owns one engine).
///
/// Engines do **not** own their KV cache: the hot entry point is
/// [`DecodeEngine::generate_with_cache`], which borrows a
/// [`HostKvCache`] the caller provides — the coordinator checks caches
/// out of a [`crate::kvcache::CachePool`] per request, so the ~MB cache
/// allocation is amortized across requests instead of being repaid on
/// every engine construction.  [`DecodeEngine::generate`] is a
/// convenience wrapper for single-shot use (examples, benches).
pub trait DecodeEngine {
    fn name(&self) -> &'static str;

    /// Cache shape this engine generates against:
    /// `(n_layers, max_ctx, d_model)` of the *target* model.
    /// (Speculative engines keep their draft-model cache internal — its
    /// shape differs and it never leaves the engine.)
    fn cache_shape(&self) -> (usize, usize, usize);

    /// Reset all per-request state (sampling RNG, online proposer
    /// pools) so the output depends only on `(prompt, max_new, seed)` —
    /// this is what makes serving results independent of which worker
    /// a request lands on.
    fn begin_request(&mut self, seed: u64);

    /// Generate up to `max_new` tokens greedily/with the engine's
    /// configured sampling into the caller-provided cache, returning
    /// the result accounting.  Implementations reset `cache` first.
    fn generate_with_cache(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        cache: &mut HostKvCache,
    ) -> Result<GenerationResult>;

    /// Single-shot wrapper that allocates a throwaway cache.
    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenerationResult> {
        let (l, s, d) = self.cache_shape();
        let mut cache = HostKvCache::new(l, s, d);
        self.generate_with_cache(prompt, max_new, &mut cache)
    }
}

/// Prefill the prompt into `cache` in bucket-sized causal chunks and
/// return the model outputs of the **last** chunk (its final row are the
/// logits/hidden of the last prompt token).
pub fn prefill(rt: &Runtime, cache: &mut HostKvCache, prompt: &[u32]) -> Result<StepOutput> {
    if prompt.is_empty() {
        bail!("empty prompt");
    }
    let s = rt.cfg.max_ctx;
    if prompt.len() > cache.remaining() {
        bail!("prompt of {} tokens exceeds context {}", prompt.len(), cache.capacity());
    }
    let max_bucket = *rt.cfg.buckets.iter().max().unwrap();
    let mut out: Option<StepOutput> = None;
    let mut done = 0;
    while done < prompt.len() {
        let chunk = (prompt.len() - done).min(max_bucket);
        let base = cache.committed();
        let tokens = &prompt[done..done + chunk];
        let pos: Vec<u32> = (0..chunk as u32).map(|i| (base as u32) + i).collect();
        let slots = pos.clone();
        let mut bias = vec![NEG_INF; chunk * s];
        for i in 0..chunk {
            for j in 0..=(base + i) {
                bias[i * s + j] = 0.0;
            }
        }
        let step = rt.forward(tokens, &pos, &slots, &bias, cache.as_slice())?;
        cache.scatter(&step.new_kv, &slots)?;
        cache.commit_contiguous(chunk)?;
        out = Some(step);
        done += chunk;
    }
    Ok(out.expect("non-empty prompt"))
}

/// Record one decode step's accounting, keeping at most `remaining`
/// of the step's emitted tokens: the final step of a capped generation
/// would otherwise push past `max_new` and let tokens that are about to
/// be discarded inflate `accepted_per_step` (and so τ/throughput).
/// Returns `true` if EOS landed in the *kept* region.
pub fn record_step(
    res: &mut GenerationResult,
    emitted: &[u32],
    remaining: usize,
    input_len: usize,
) -> bool {
    let kept = emitted.len().min(remaining);
    res.steps += 1;
    res.accepted_per_step.push(kept);
    res.input_lens.push(input_len);
    res.tokens.extend_from_slice(&emitted[..kept]);
    emitted[..kept].contains(&EOS_ID)
}

/// Truncate a generated sequence at (and including) the first EOS.
pub fn truncate_at_eos(tokens: &mut Vec<u32>) -> bool {
    if let Some(i) = tokens.iter().position(|&t| t == EOS_ID) {
        tokens.truncate(i + 1);
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn result_accounting() {
        let r = GenerationResult {
            tokens: vec![1; 12],
            steps: 4,
            accepted_per_step: vec![3; 4],
            input_lens: vec![10, 20, 20, 30],
            decode_s: 2.0,
            prefill_s: 0.5,
            draft_steps: 0,
        };
        assert_eq!(r.tau(), 3.0);
        assert_eq!(r.throughput(), 6.0);
        assert_eq!(r.mean_fp_latency(), 0.5);
        assert_eq!(r.mean_input_len(), 20.0);
    }

    #[test]
    fn record_step_caps_to_remaining() {
        let mut r = GenerationResult::default();
        r.tokens = vec![1, 1, 1];
        // 4 emitted but only 2 wanted: τ accounting must see 2
        let eos = record_step(&mut r, &[5, 6, 7, 8], 2, 9);
        assert!(!eos);
        assert_eq!(r.tokens, vec![1, 1, 1, 5, 6]);
        assert_eq!(r.accepted_per_step, vec![2]);
        assert_eq!(r.input_lens, vec![9]);
        assert_eq!(r.steps, 1);
    }

    #[test]
    fn record_step_eos_only_counts_in_kept_region() {
        let mut r = GenerationResult::default();
        assert!(!record_step(&mut r, &[5, EOS_ID], 1, 3));
        let mut r2 = GenerationResult::default();
        assert!(record_step(&mut r2, &[5, EOS_ID], 2, 3));
    }

    #[test]
    fn eos_truncation() {
        let mut t = vec![5, 6, EOS_ID, 9];
        assert!(truncate_at_eos(&mut t));
        assert_eq!(t, vec![5, 6, EOS_ID]);
        let mut t2 = vec![5, 6];
        assert!(!truncate_at_eos(&mut t2));
    }
}
