//! Decode engines: the guess-and-verify loop in all its variants.
//!
//! * [`vanilla`]     — plain autoregressive decoding (the baseline all
//!                     speedups are measured against)
//! * [`ppd`]         — the paper's Parallel Prompt Decoding with the
//!                     dynamic sparse tree
//! * [`medusa`]      — Medusa-1 baseline (decoding heads, static tree)
//! * [`lookup`]      — retrieval-style baselines: PLD (prompt lookup),
//!                     REST (datastore n-grams), lookahead-lite
//! * [`speculative`] — draft-model speculative decoding, with optional
//!                     PPD-accelerated drafting (paper §5.3)
//! * [`verify`]      — exact-match + typical-acceptance verification

pub mod lookup;
pub mod medusa;
pub mod ppd;
pub mod speculative;
pub mod vanilla;
pub mod verify;

use anyhow::{bail, Result};

use crate::config::EOS_ID;
use crate::kvcache::HostKvCache;
use crate::runtime::{Runtime, StepOutput, NEG_INF};

/// Outcome of one generation, with the accounting every bench needs.
#[derive(Debug, Clone, Default)]
pub struct GenerationResult {
    /// generated tokens (prompt excluded)
    pub tokens: Vec<u32>,
    /// forward passes of the *target* model during decode
    pub steps: usize,
    /// tokens emitted by each decode step (the τ samples)
    pub accepted_per_step: Vec<usize>,
    /// input length of each decode step (S_input samples)
    pub input_lens: Vec<usize>,
    /// wallclock of the decode phase (prefill excluded)
    pub decode_s: f64,
    /// wallclock of the prefill phase
    pub prefill_s: f64,
    /// draft-model forward passes (speculative engines)
    pub draft_steps: usize,
}

impl GenerationResult {
    /// Mean accepted length τ (tokens per decode step).
    pub fn tau(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.steps as f64
        }
    }

    /// Decode-phase throughput in tokens/s.
    pub fn throughput(&self) -> f64 {
        if self.decode_s == 0.0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.decode_s
        }
    }

    /// Mean forward-pass latency during decode.
    pub fn mean_fp_latency(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.decode_s / self.steps as f64
        }
    }

    pub fn mean_input_len(&self) -> f64 {
        if self.input_lens.is_empty() {
            0.0
        } else {
            self.input_lens.iter().sum::<usize>() as f64 / self.input_lens.len() as f64
        }
    }
}

/// A decoding engine; one instance serves one request at a time (the
/// coordinator owns a pool of engines).
pub trait DecodeEngine {
    fn name(&self) -> &'static str;

    /// Generate up to `max_new` tokens greedily/with the engine's
    /// configured sampling, returning the result accounting.
    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenerationResult>;
}

/// Prefill the prompt into `cache` in bucket-sized causal chunks and
/// return the model outputs of the **last** chunk (its final row are the
/// logits/hidden of the last prompt token).
pub fn prefill(rt: &Runtime, cache: &mut HostKvCache, prompt: &[u32]) -> Result<StepOutput> {
    if prompt.is_empty() {
        bail!("empty prompt");
    }
    let s = rt.cfg.max_ctx;
    if prompt.len() > cache.remaining() {
        bail!("prompt of {} tokens exceeds context {}", prompt.len(), cache.capacity());
    }
    let max_bucket = *rt.cfg.buckets.iter().max().unwrap();
    let mut out: Option<StepOutput> = None;
    let mut done = 0;
    while done < prompt.len() {
        let chunk = (prompt.len() - done).min(max_bucket);
        let base = cache.committed();
        let tokens = &prompt[done..done + chunk];
        let pos: Vec<u32> = (0..chunk as u32).map(|i| (base as u32) + i).collect();
        let slots = pos.clone();
        let mut bias = vec![NEG_INF; chunk * s];
        for i in 0..chunk {
            for j in 0..=(base + i) {
                bias[i * s + j] = 0.0;
            }
        }
        let step = rt.forward(tokens, &pos, &slots, &bias, cache.as_slice())?;
        cache.scatter(&step.new_kv, &slots)?;
        cache.commit_contiguous(chunk)?;
        out = Some(step);
        done += chunk;
    }
    Ok(out.expect("non-empty prompt"))
}

/// Truncate a generated sequence at (and including) the first EOS.
pub fn truncate_at_eos(tokens: &mut Vec<u32>) -> bool {
    if let Some(i) = tokens.iter().position(|&t| t == EOS_ID) {
        tokens.truncate(i + 1);
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn result_accounting() {
        let r = GenerationResult {
            tokens: vec![1; 12],
            steps: 4,
            accepted_per_step: vec![3; 4],
            input_lens: vec![10, 20, 20, 30],
            decode_s: 2.0,
            prefill_s: 0.5,
            draft_steps: 0,
        };
        assert_eq!(r.tau(), 3.0);
        assert_eq!(r.throughput(), 6.0);
        assert_eq!(r.mean_fp_latency(), 0.5);
        assert_eq!(r.mean_input_len(), 20.0);
    }

    #[test]
    fn eos_truncation() {
        let mut t = vec![5, 6, EOS_ID, 9];
        assert!(truncate_at_eos(&mut t));
        assert_eq!(t, vec![5, 6, EOS_ID]);
        let mut t2 = vec![5, 6];
        assert!(!truncate_at_eos(&mut t2));
    }
}
