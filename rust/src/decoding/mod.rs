//! Decode engines: the guess-and-verify loop in all its variants.
//!
//! * [`vanilla`]     — plain autoregressive decoding (the baseline all
//!                     speedups are measured against)
//! * [`ppd`]         — the paper's Parallel Prompt Decoding with the
//!                     dynamic sparse tree
//! * [`medusa`]      — Medusa-1 baseline (decoding heads, static tree)
//! * [`lookup`]      — retrieval-style baselines: PLD (prompt lookup),
//!                     REST (datastore n-grams), lookahead-lite
//! * [`speculative`] — draft-model speculative decoding, with optional
//!                     PPD-accelerated drafting (paper §5.3)
//! * [`verify`]      — exact-match + typical-acceptance verification

pub mod lookup;
pub mod medusa;
pub mod ppd;
pub mod speculative;
pub mod vanilla;
pub mod verify;

use anyhow::{bail, Result};

use crate::config::EOS_ID;
use crate::kvcache::HostKvCache;
use crate::runtime::{Device, StepOutput, NEG_INF};
use crate::util::rng::Rng;

/// Outcome of one generation, with the accounting every bench needs.
#[derive(Debug, Clone, Default)]
pub struct GenerationResult {
    /// generated tokens (prompt excluded)
    pub tokens: Vec<u32>,
    /// forward passes of the *target* model during decode
    pub steps: usize,
    /// tokens emitted by each decode step (the τ samples)
    pub accepted_per_step: Vec<usize>,
    /// input length of each decode step (S_input samples)
    pub input_lens: Vec<usize>,
    /// wallclock of the decode phase (prefill excluded)
    pub decode_s: f64,
    /// wallclock of the prefill phase
    pub prefill_s: f64,
    /// draft-model forward passes (speculative engines)
    pub draft_steps: usize,
}

impl GenerationResult {
    /// Mean accepted length τ (tokens per decode step).
    pub fn tau(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.steps as f64
        }
    }

    /// Decode-phase throughput in tokens/s.
    pub fn throughput(&self) -> f64 {
        if self.decode_s == 0.0 {
            0.0
        } else {
            self.tokens.len() as f64 / self.decode_s
        }
    }

    /// Mean forward-pass latency during decode.
    pub fn mean_fp_latency(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.decode_s / self.steps as f64
        }
    }

    pub fn mean_input_len(&self) -> f64 {
        if self.input_lens.is_empty() {
            0.0
        } else {
            self.input_lens.iter().sum::<usize>() as f64 / self.input_lens.len() as f64
        }
    }
}

/// Why a sequence stopped decoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// EOS landed in the kept region of a step
    Eos,
    /// the `max_new` token budget filled
    Budget,
    /// the KV cache / context window was exhausted
    Context,
}

/// Outcome of one [`DecodeEngine::step`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// the sequence wants more steps
    Running,
    /// the sequence retired on this step (final truncation applied)
    Finished(FinishReason),
}

/// Resumable per-sequence decode state — everything one in-flight
/// request carries between steps, so an engine can interleave many
/// sequences (continuous batching) without any of them observing the
/// others.  The companion [`HostKvCache`] is owned by the scheduler and
/// handed back on every [`DecodeEngine::step`] call.
///
/// The per-sequence [`Rng`] lives here (not on the engine): sampled
/// output stays a pure function of `(prompt, max_new, seed)` no matter
/// how sequences are interleaved.  `inner` holds the engine-specific
/// loop state (PPD's tree-state machine, the speculative draft cache,
/// …); engines downcast it in `step`.
pub struct SeqState {
    /// accumulated accounting; becomes the final [`GenerationResult`]
    pub res: GenerationResult,
    /// the request's token budget
    pub max_new: usize,
    /// EOS observed in a kept region (retire on the next check)
    pub eos_seen: bool,
    /// set once by [`SeqState::finish`]; `step` is a no-op afterwards
    pub finished: Option<FinishReason>,
    /// per-sequence sampling RNG, seeded from the request seed
    pub rng: Rng,
    /// engine-specific resumable state (downcast by the owning engine)
    pub inner: Box<dyn std::any::Any + Send>,
}

impl SeqState {
    pub fn new(max_new: usize, rng: Rng, inner: Box<dyn std::any::Any + Send>) -> Self {
        SeqState {
            res: GenerationResult::default(),
            max_new,
            eos_seen: false,
            finished: None,
            rng,
            inner,
        }
    }

    pub fn is_finished(&self) -> bool {
        self.finished.is_some()
    }

    /// Retire the sequence: apply the final truncation (at EOS, then to
    /// the token budget) exactly like the run-to-completion loops did.
    pub fn finish(&mut self, reason: FinishReason) -> StepOutcome {
        truncate_at_eos(&mut self.res.tokens);
        self.res.tokens.truncate(self.max_new);
        self.finished = Some(reason);
        StepOutcome::Finished(reason)
    }

    pub fn into_result(self) -> GenerationResult {
        self.res
    }
}

/// A decoding engine; one instance may hold many in-flight sequences'
/// worth of work, but all per-sequence state lives in [`SeqState`] —
/// the engine itself only carries read-only configuration between
/// steps, which is what makes step-level scheduling safe.
///
/// Engines do **not** own their KV cache: each sequence borrows a
/// [`HostKvCache`] the caller provides — the coordinator checks caches
/// out of a [`crate::kvcache::CachePool`] per sequence, so the ~MB
/// cache allocation is amortized across requests instead of being
/// repaid on every engine construction.
///
/// The resumable API is [`DecodeEngine::begin_seq`] (prefill + first
/// token) followed by repeated [`DecodeEngine::step`] calls, one PPD
/// tree step each; [`DecodeEngine::generate_with_cache`] is the
/// run-to-completion wrapper built on exactly that pair, and
/// [`DecodeEngine::generate`] additionally allocates a throwaway cache
/// (examples, benches).
pub trait DecodeEngine {
    fn name(&self) -> &'static str;

    /// Cache shape this engine generates against:
    /// `(n_layers, max_ctx, d_model)` of the *target* model.
    /// (Speculative engines keep their draft-model cache inside
    /// [`SeqState::inner`] — its shape differs and it never leaves the
    /// sequence.)
    fn cache_shape(&self) -> (usize, usize, usize);

    /// Set the seed the next [`DecodeEngine::generate_with_cache`] call
    /// runs under, so single-shot output depends only on
    /// `(prompt, max_new, seed)` — never on which worker a request
    /// lands on or what ran before it.
    fn begin_request(&mut self, seed: u64);

    /// The seed installed by [`DecodeEngine::begin_request`] (or the
    /// constructor).
    fn request_seed(&self) -> u64;

    /// Start a resumable sequence: reset + prefill `cache` with
    /// `prompt`, emit the first token, and return the state that
    /// subsequent [`DecodeEngine::step`] calls advance.
    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        cache: &mut HostKvCache,
    ) -> Result<SeqState>;

    /// Advance `seq` by one decode step (one target-model forward pass
    /// for the tree engines; one draft-round + verification for the
    /// speculative ones).  Calling `step` on a finished sequence is a
    /// no-op returning the original [`FinishReason`].
    fn step(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome>;

    /// Run-to-completion wrapper over `begin_seq` + `step`: generate up
    /// to `max_new` tokens into the caller-provided cache under the
    /// seed from [`DecodeEngine::begin_request`].
    ///
    /// Each call advances the stored seed, so repeated single-shot
    /// calls without an intervening `begin_request` (benches replaying
    /// a trace at temperature > 0) draw fresh sampling streams per
    /// call, as the pre-refactor engine-owned RNG did — while any
    /// explicit `begin_request(seed)` still pins the next call exactly.
    fn generate_with_cache(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        cache: &mut HostKvCache,
    ) -> Result<GenerationResult> {
        let seed = self.request_seed();
        self.begin_request(seed.wrapping_add(1));
        let mut seq = self.begin_seq(prompt, max_new, seed, cache)?;
        while !seq.is_finished() {
            self.step(&mut seq, cache)?;
        }
        Ok(seq.into_result())
    }

    /// Single-shot wrapper that allocates a throwaway cache.
    fn generate(&mut self, prompt: &[u32], max_new: usize) -> Result<GenerationResult> {
        let (l, s, d) = self.cache_shape();
        let mut cache = HostKvCache::new(l, s, d);
        self.generate_with_cache(prompt, max_new, &mut cache)
    }
}

/// Prefill the prompt into `cache` in bucket-sized causal chunks and
/// return the model outputs of the **last** chunk (its final row are the
/// logits/hidden of the last prompt token).
pub fn prefill(rt: &dyn Device, cache: &mut HostKvCache, prompt: &[u32]) -> Result<StepOutput> {
    if prompt.is_empty() {
        bail!("empty prompt");
    }
    let s = rt.cfg().max_ctx;
    // a prefix-seeded cache (shared prompt pages from the pool's radix
    // store) already holds KV for its first committed() rows: prefill
    // only the remainder.  The seed is always a *strict* prefix, so the
    // last prompt token — whose logits start generation — is always
    // recomputed here.
    let mut done = cache.committed();
    if done >= prompt.len() {
        bail!("cache already holds {done} committed rows, prompt has only {} tokens", prompt.len());
    }
    if prompt.len() - done > cache.remaining() {
        bail!("prompt of {} tokens exceeds context {}", prompt.len(), cache.capacity());
    }
    let max_bucket = *rt.cfg().buckets.iter().max().unwrap();
    let mut out: Option<StepOutput> = None;
    while done < prompt.len() {
        let chunk = (prompt.len() - done).min(max_bucket);
        let base = cache.committed();
        let tokens = &prompt[done..done + chunk];
        let pos: Vec<u32> = (0..chunk as u32).map(|i| (base as u32) + i).collect();
        let slots = pos.clone();
        let mut bias = vec![NEG_INF; chunk * s];
        for i in 0..chunk {
            for j in 0..=(base + i) {
                bias[i * s + j] = 0.0;
            }
        }
        let step = rt.forward(tokens, &pos, &slots, &bias, &cache.device_snapshot())?;
        cache.scatter(&step.new_kv, &slots)?;
        cache.commit_contiguous(chunk)?;
        out = Some(step);
        done += chunk;
    }
    Ok(out.expect("non-empty prompt"))
}

/// Record one decode step's accounting, keeping at most `remaining`
/// of the step's emitted tokens: the final step of a capped generation
/// would otherwise push past `max_new` and let tokens that are about to
/// be discarded inflate `accepted_per_step` (and so τ/throughput).
/// Returns `true` if EOS landed in the *kept* region.
pub fn record_step(
    res: &mut GenerationResult,
    emitted: &[u32],
    remaining: usize,
    input_len: usize,
) -> bool {
    let kept = emitted.len().min(remaining);
    res.steps += 1;
    res.accepted_per_step.push(kept);
    res.input_lens.push(input_len);
    res.tokens.extend_from_slice(&emitted[..kept]);
    emitted[..kept].contains(&EOS_ID)
}

/// Truncate a generated sequence at (and including) the first EOS.
pub fn truncate_at_eos(tokens: &mut Vec<u32>) -> bool {
    if let Some(i) = tokens.iter().position(|&t| t == EOS_ID) {
        tokens.truncate(i + 1);
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn result_accounting() {
        let r = GenerationResult {
            tokens: vec![1; 12],
            steps: 4,
            accepted_per_step: vec![3; 4],
            input_lens: vec![10, 20, 20, 30],
            decode_s: 2.0,
            prefill_s: 0.5,
            draft_steps: 0,
        };
        assert_eq!(r.tau(), 3.0);
        assert_eq!(r.throughput(), 6.0);
        assert_eq!(r.mean_fp_latency(), 0.5);
        assert_eq!(r.mean_input_len(), 20.0);
    }

    #[test]
    fn record_step_caps_to_remaining() {
        let mut r = GenerationResult::default();
        r.tokens = vec![1, 1, 1];
        // 4 emitted but only 2 wanted: τ accounting must see 2
        let eos = record_step(&mut r, &[5, 6, 7, 8], 2, 9);
        assert!(!eos);
        assert_eq!(r.tokens, vec![1, 1, 1, 5, 6]);
        assert_eq!(r.accepted_per_step, vec![2]);
        assert_eq!(r.input_lens, vec![9]);
        assert_eq!(r.steps, 1);
    }

    #[test]
    fn record_step_eos_only_counts_in_kept_region() {
        let mut r = GenerationResult::default();
        assert!(!record_step(&mut r, &[5, EOS_ID], 1, 3));
        let mut r2 = GenerationResult::default();
        assert!(record_step(&mut r2, &[5, EOS_ID], 2, 3));
    }

    #[test]
    fn seq_finish_applies_final_truncation() {
        // finish must replicate the run-to-completion epilogue exactly:
        // truncate at EOS first, then to the token budget
        let mut seq = SeqState::new(3, Rng::new(0), Box::new(()));
        seq.res.tokens = vec![5, EOS_ID, 9, 10, 11];
        let out = seq.finish(FinishReason::Budget);
        assert_eq!(out, StepOutcome::Finished(FinishReason::Budget));
        assert_eq!(seq.res.tokens, vec![5, EOS_ID]);
        assert!(seq.is_finished());

        let mut seq2 = SeqState::new(2, Rng::new(0), Box::new(()));
        seq2.res.tokens = vec![7, 8, 9];
        seq2.finish(FinishReason::Budget);
        assert_eq!(seq2.res.tokens, vec![7, 8]);
    }

    #[test]
    fn eos_truncation() {
        let mut t = vec![5, 6, EOS_ID, 9];
        assert!(truncate_at_eos(&mut t));
        assert_eq!(t, vec![5, 6, EOS_ID]);
        let mut t2 = vec![5, 6];
        assert!(!truncate_at_eos(&mut t2));
    }
}
