//! Draft-model speculative decoding (Leviathan et al.) and its PPD
//! integration (paper §5.3): PPD is orthogonal to speculative decoding —
//! applying prompt tokens to the *draft* model reduces the number of
//! draft forward passes per speculation round, which shortens the
//! drafting phase and speeds up the whole pipeline.
//!
//! Greedy variant: the target accepts the longest prefix of the draft
//! chain matching its own argmax (plus one bonus token), so outputs are
//! byte-identical to vanilla target decoding.

use std::time::Instant;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::kvcache::HostKvCache;
use crate::runtime::{Runtime, NEG_INF};
use crate::tree::builder::AcceptStats;
use crate::tree::dynamic::DynamicTreeSet;
use crate::tree::{assemble_step, GuessSet};
use crate::util::argmax;
use crate::util::rng::Rng;
use crate::util::{softmax, topk};

use super::verify::{verify, VerifyMode};
use super::{prefill, record_step, truncate_at_eos, DecodeEngine, GenerationResult};

/// How the draft model produces its chain.
pub enum DraftMode {
    /// plain autoregressive drafting: γ draft forwards per round
    Vanilla,
    /// PPD-accelerated drafting: the draft runs its own guess-and-verify
    /// loop, needing ~γ/τ_draft forwards per round
    Ppd { set: DynamicTreeSet, top_r: usize },
}

pub struct SpeculativeEngine<'a> {
    target: &'a Runtime,
    draft: &'a Runtime,
    /// the draft model's cache shape differs from the target's, so it
    /// stays engine-owned; the target cache is borrowed per call (and
    /// pooled by the coordinator) like every other engine
    draft_cache: HostKvCache,
    mode: DraftMode,
    /// speculation length per round
    pub gamma: usize,
    rng: Rng,
}

impl<'a> SpeculativeEngine<'a> {
    pub fn new_vanilla(target: &'a Runtime, draft: &'a Runtime, gamma: usize, seed: u64) -> Self {
        Self::new(target, draft, DraftMode::Vanilla, gamma, seed)
    }

    pub fn new_ppd(
        target: &'a Runtime,
        draft: &'a Runtime,
        stats: &AcceptStats,
        cfg: &ServeConfig,
        gamma: usize,
        seed: u64,
    ) -> Result<Self> {
        let set = DynamicTreeSet::build(
            stats,
            draft.cfg.n_prompt,
            cfg.n_candidates,
            cfg.n_prompt_budget,
            cfg.top_r,
        )?;
        Ok(Self::new(target, draft, DraftMode::Ppd { set, top_r: cfg.top_r }, gamma, seed))
    }

    fn new(target: &'a Runtime, draft: &'a Runtime, mode: DraftMode, gamma: usize, seed: u64) -> Self {
        SpeculativeEngine {
            draft_cache: HostKvCache::new(draft.cfg.n_layers, draft.cfg.max_ctx, draft.cfg.d_model),
            target,
            draft,
            mode,
            gamma,
            rng: Rng::new(seed),
        }
    }

    /// Draft up to `limit` tokens continuing `root`; returns (chain,
    /// #draft forwards).  The draft cache must already hold the
    /// committed context *excluding* root.  `limit` is
    /// `gamma.min(remaining - 1)` so the final round never drafts
    /// tokens the budget cap would discard.
    fn draft_chain(&mut self, root: u32, limit: usize) -> Result<(Vec<u32>, usize)> {
        let vocab = self.draft.cfg.vocab;
        let s = self.draft.cfg.max_ctx;
        match &self.mode {
            DraftMode::Vanilla => {
                let mut chain = Vec::with_capacity(limit);
                let mut steps = 0;
                let mut cur = root;
                let mut bias = vec![NEG_INF; s];
                while chain.len() < limit && self.draft_cache.remaining() > 1 {
                    let c = self.draft_cache.committed();
                    for (j, b) in bias.iter_mut().enumerate() {
                        *b = if j <= c { 0.0 } else { NEG_INF };
                    }
                    let out = self.draft.forward(&[cur], &[c as u32], &[c as u32], &bias, self.draft_cache.as_slice())?;
                    self.draft_cache.scatter(&out.new_kv, &[c as u32])?;
                    self.draft_cache.commit_contiguous(1)?;
                    steps += 1;
                    cur = argmax(out.logits_row(0, vocab)) as u32;
                    chain.push(cur);
                }
                Ok((chain, steps))
            }
            DraftMode::Ppd { set, top_r } => {
                // guess-and-verify loop on the draft model
                let set = set.clone();
                let top_r = *top_r;
                let mut chain: Vec<u32> = Vec::with_capacity(limit + 4);
                let mut steps = 0;
                let mut guesses = GuessSet::default();
                let mut state = 0usize;
                let mut cur = root;
                while chain.len() < limit && self.draft_cache.remaining() > set.max_input_len() + 2 {
                    let k = state.min(guesses.depth()).min(set.trees.len() - 1);
                    let tree = &set.trees[k];
                    let layout = &set.layouts[k];
                    let committed = self.draft_cache.committed();
                    let inputs = assemble_step(tree, layout, &guesses, cur, committed as u32, committed, s)?;
                    let out = self.draft.forward(&inputs.tokens, &inputs.pos, &inputs.slots, &inputs.bias, self.draft_cache.as_slice())?;
                    self.draft_cache.scatter(&out.new_kv, &inputs.slots)?;
                    let v = verify(tree, layout, &out, &inputs.tokens, VerifyMode::Greedy, vocab, &mut self.rng);
                    let mut accepted_slots = vec![inputs.slots[0]];
                    accepted_slots.extend(v.accepted_nodes.iter().map(|&n| inputs.slots[layout.node_input[n]]));
                    self.draft_cache.compact(&accepted_slots)?;
                    steps += 1;
                    chain.extend_from_slice(&v.emitted);
                    // guesses for next draft round
                    let mut per_distance = Vec::new();
                    for &row in &layout.prompt_input[v.final_node] {
                        let probs = softmax(out.logits_row(row, vocab));
                        let ranked = topk(&probs, top_r);
                        per_distance.push(ranked.iter().map(|&t| (t as u32, probs[t])).collect::<Vec<_>>());
                    }
                    guesses = GuessSet { per_distance };
                    state = tree.nodes[v.final_node].prompt_len;
                    cur = *chain.last().unwrap();
                }
                chain.truncate(limit);
                Ok((chain, steps))
            }
        }
    }

    /// Resync the draft cache after the target rejected a suffix: drop
    /// the speculated rows and re-ingest the accepted tokens.
    fn draft_catch_up(&mut self, accepted: &[u32], target_committed: usize) -> Result<()> {
        // the draft cache may have advanced past / diverged from the
        // accepted prefix: rewind to the last agreed length then feed
        // the accepted tokens (minus the one reserved as next root)
        let agreed = target_committed.saturating_sub(accepted.len());
        if self.draft_cache.committed() > agreed {
            self.draft_cache.truncate(agreed)?;
        }
        if accepted.is_empty() {
            return Ok(());
        }
        let s = self.draft.cfg.max_ctx;
        let base = self.draft_cache.committed();
        let n = accepted.len();
        let pos: Vec<u32> = (0..n as u32).map(|i| base as u32 + i).collect();
        let mut bias = vec![NEG_INF; n * s];
        for i in 0..n {
            for j in 0..=(base + i) {
                bias[i * s + j] = 0.0;
            }
        }
        let out = self.draft.forward(accepted, &pos, &pos, &bias, self.draft_cache.as_slice())?;
        self.draft_cache.scatter(&out.new_kv, &pos)?;
        self.draft_cache.commit_contiguous(n)?;
        Ok(())
    }
}

impl DecodeEngine for SpeculativeEngine<'_> {
    fn name(&self) -> &'static str {
        match self.mode {
            DraftMode::Vanilla => "spec",
            DraftMode::Ppd { .. } => "spec+ppd",
        }
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (self.target.cfg.n_layers, self.target.cfg.max_ctx, self.target.cfg.d_model)
    }

    fn begin_request(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
    }

    fn generate_with_cache(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        target_cache: &mut HostKvCache,
    ) -> Result<GenerationResult> {
        let mut res = GenerationResult::default();
        target_cache.reset();
        self.draft_cache.reset();
        let vocab = self.target.cfg.vocab;
        let s = self.target.cfg.max_ctx;

        let t0 = Instant::now();
        let pre_t = prefill(self.target, target_cache, prompt)?;
        prefill(self.draft, &mut self.draft_cache, prompt)?;
        res.prefill_s = t0.elapsed().as_secs_f64();

        let mut root = argmax(pre_t.logits_row(pre_t.n - 1, vocab)) as u32;
        res.tokens.push(root);
        let mut eos_seen = root == crate::config::EOS_ID;

        let t1 = Instant::now();
        'outer: while res.tokens.len() < max_new && !eos_seen {
            let remaining = max_new - res.tokens.len();
            let (chain, draft_steps) = self.draft_chain(root, self.gamma.min(remaining - 1))?;
            res.draft_steps += draft_steps;
            if chain.is_empty() && remaining > 1 {
                break; // draft context exhausted mid-generation
            }
            // verify [root, chain...] against the target in one forward
            // (with remaining == 1 the chain is empty and this is a
            // plain one-token step producing the final bonus token)
            let committed = target_cache.committed();
            let n = 1 + chain.len();
            if committed + n + 2 >= s || target_cache.remaining() < n + 2 {
                break 'outer;
            }
            let mut tokens = Vec::with_capacity(n);
            tokens.push(root);
            tokens.extend_from_slice(&chain);
            let pos: Vec<u32> = (0..n as u32).map(|i| committed as u32 + i).collect();
            let mut bias = vec![NEG_INF; n * s];
            for i in 0..n {
                for j in 0..=(committed + i) {
                    bias[i * s + j] = 0.0;
                }
            }
            let out = self.target.forward(&tokens, &pos, &pos, &bias, target_cache.as_slice())?;
            target_cache.scatter(&out.new_kv, &pos)?;

            // longest matching prefix + bonus
            let mut accepted = 0;
            while accepted < chain.len() {
                let want = argmax(out.logits_row(accepted, vocab)) as u32;
                if chain[accepted] == want {
                    accepted += 1;
                } else {
                    break;
                }
            }
            let bonus = argmax(out.logits_row(accepted, vocab)) as u32;
            // commit root + accepted chain rows (they are contiguous)
            target_cache.commit_contiguous(1 + accepted)?;

            let mut emitted: Vec<u32> = chain[..accepted].to_vec();
            emitted.push(bonus);
            eos_seen |= record_step(&mut res, &emitted, remaining, n);

            // draft resync: accepted prefix (without bonus — that is the
            // next root and will be fed on the next draft round)
            let catch: Vec<u32> = std::iter::once(root).chain(chain[..accepted].iter().copied()).collect();
            self.draft_catch_up(&catch, target_cache.committed())?;
            root = bonus;
        }
        res.decode_s = t1.elapsed().as_secs_f64();
        truncate_at_eos(&mut res.tokens);
        res.tokens.truncate(max_new);
        Ok(res)
    }
}
