//! Draft-model speculative decoding (Leviathan et al.) and its PPD
//! integration (paper §5.3): PPD is orthogonal to speculative decoding —
//! applying prompt tokens to the *draft* model reduces the number of
//! draft forward passes per speculation round, which shortens the
//! drafting phase and speeds up the whole pipeline.
//!
//! Greedy variant: the target accepts the longest prefix of the draft
//! chain matching its own argmax (plus one bonus token), so outputs are
//! byte-identical to vanilla target decoding.
//!
//! One [`DecodeEngine::step`] = one speculation round (a draft chain +
//! one target verification forward).  The draft-model KV cache is
//! per-sequence state carried in [`SeqState`], so interleaved sequences
//! each keep their own draft context.

use std::time::Instant;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::kvcache::HostKvCache;
use crate::runtime::{Device, NEG_INF};
use crate::tree::builder::AcceptStats;
use crate::tree::dynamic::DynamicTreeSet;
use crate::tree::{assemble_step, GuessSet};
use crate::util::argmax;
use crate::util::rng::Rng;
use crate::util::{softmax, topk};

use super::verify::{verify, VerifyMode};
use super::{prefill, record_step, DecodeEngine, FinishReason, SeqState, StepOutcome};

/// How the draft model produces its chain.
pub enum DraftMode {
    /// plain autoregressive drafting: γ draft forwards per round
    Vanilla,
    /// PPD-accelerated drafting: the draft runs its own guess-and-verify
    /// loop, needing ~γ/τ_draft forwards per round
    Ppd { set: DynamicTreeSet, top_r: usize },
}

pub struct SpeculativeEngine<'a> {
    target: &'a dyn Device,
    draft: &'a dyn Device,
    mode: DraftMode,
    /// speculation length per round
    pub gamma: usize,
    seed: u64,
    /// retired sequences' draft caches, reused by later `begin_seq`s so
    /// steady-state serving allocates no draft cache per request (the
    /// target cache is pooled by the coordinator; this is the engine-
    /// local equivalent for the draft shape).  Bounded by the in-flight
    /// budget: at most one entry per concurrently admitted sequence.
    draft_free: Vec<HostKvCache>,
}

/// Per-sequence state: the cursor token plus the sequence's own
/// draft-model KV cache (its shape differs from the target's and it
/// never enters the shared pool).
struct SpecSeq {
    root: u32,
    draft_cache: HostKvCache,
}

impl<'a> SpeculativeEngine<'a> {
    pub fn new_vanilla(target: &'a dyn Device, draft: &'a dyn Device, gamma: usize, seed: u64) -> Self {
        Self::new(target, draft, DraftMode::Vanilla, gamma, seed)
    }

    pub fn new_ppd(
        target: &'a dyn Device,
        draft: &'a dyn Device,
        stats: &AcceptStats,
        cfg: &ServeConfig,
        gamma: usize,
        seed: u64,
    ) -> Result<Self> {
        let set = DynamicTreeSet::build(
            stats,
            draft.cfg().n_prompt,
            cfg.n_candidates,
            cfg.n_prompt_budget,
            cfg.top_r,
        )?;
        Ok(Self::new(target, draft, DraftMode::Ppd { set, top_r: cfg.top_r }, gamma, seed))
    }

    fn new(target: &'a dyn Device, draft: &'a dyn Device, mode: DraftMode, gamma: usize, seed: u64) -> Self {
        SpeculativeEngine { target, draft, mode, gamma, seed, draft_free: Vec::new() }
    }

    fn draft_shape(&self) -> (usize, usize, usize) {
        (self.draft.cfg().n_layers, self.draft.cfg().max_ctx, self.draft.cfg().d_model)
    }

    /// Retire a sequence: move its draft cache back to the engine's
    /// free list (idempotent — a reclaimed slot holds a zero-layer
    /// placeholder that fails the shape check; `RESERVED_SLOTS` rows
    /// keep every accessor on it well-defined) and finish.
    fn finish_and_reclaim(&mut self, seq: &mut SeqState, reason: FinishReason) -> StepOutcome {
        if let Some(st) = seq.inner.downcast_mut::<SpecSeq>() {
            let placeholder = HostKvCache::new(0, crate::kvcache::RESERVED_SLOTS, 0);
            let dc = std::mem::replace(&mut st.draft_cache, placeholder);
            if dc.shape() == self.draft_shape() {
                self.draft_free.push(dc);
            }
        }
        seq.finish(reason)
    }

    /// Draft up to `limit` tokens continuing `root`; returns (chain,
    /// #draft forwards).  `draft_cache` must already hold the committed
    /// context *excluding* root.  `limit` is `gamma.min(remaining - 1)`
    /// so the final round never drafts tokens the budget cap would
    /// discard.
    fn draft_chain(
        &self,
        draft_cache: &mut HostKvCache,
        rng: &mut Rng,
        root: u32,
        limit: usize,
    ) -> Result<(Vec<u32>, usize)> {
        let vocab = self.draft.cfg().vocab;
        let s = self.draft.cfg().max_ctx;
        match &self.mode {
            DraftMode::Vanilla => {
                let mut chain = Vec::with_capacity(limit);
                let mut steps = 0;
                let mut cur = root;
                let mut bias = vec![NEG_INF; s];
                while chain.len() < limit && draft_cache.remaining() > 1 {
                    let c = draft_cache.committed();
                    for (j, b) in bias.iter_mut().enumerate() {
                        *b = if j <= c { 0.0 } else { NEG_INF };
                    }
                    let out = self.draft.forward(&[cur], &[c as u32], &[c as u32], &bias, &draft_cache.device_snapshot())?;
                    draft_cache.scatter(&out.new_kv, &[c as u32])?;
                    draft_cache.commit_contiguous(1)?;
                    steps += 1;
                    cur = argmax(out.logits_row(0, vocab)) as u32;
                    chain.push(cur);
                }
                Ok((chain, steps))
            }
            DraftMode::Ppd { set, top_r } => {
                // guess-and-verify loop on the draft model
                let top_r = *top_r;
                let mut chain: Vec<u32> = Vec::with_capacity(limit + 4);
                let mut steps = 0;
                let mut guesses = GuessSet::default();
                let mut state = 0usize;
                let mut cur = root;
                while chain.len() < limit && draft_cache.remaining() > set.max_input_len() + 2 {
                    let k = state.min(guesses.depth()).min(set.trees.len() - 1);
                    let tree = &set.trees[k];
                    let layout = &set.layouts[k];
                    let committed = draft_cache.committed();
                    let inputs = assemble_step(tree, layout, &guesses, cur, committed as u32, committed, s)?;
                    let out = self.draft.forward(&inputs.tokens, &inputs.pos, &inputs.slots, &inputs.bias, &draft_cache.device_snapshot())?;
                    draft_cache.scatter(&out.new_kv, &inputs.slots)?;
                    let v = verify(tree, layout, &out, &inputs.tokens, VerifyMode::Greedy, vocab, rng);
                    let mut accepted_slots = vec![inputs.slots[0]];
                    accepted_slots.extend(v.accepted_nodes.iter().map(|&n| inputs.slots[layout.node_input[n]]));
                    draft_cache.compact(&accepted_slots)?;
                    steps += 1;
                    chain.extend_from_slice(&v.emitted);
                    // guesses for next draft round
                    let mut per_distance = Vec::new();
                    for &row in &layout.prompt_input[v.final_node] {
                        let probs = softmax(out.logits_row(row, vocab));
                        let ranked = topk(&probs, top_r);
                        per_distance.push(ranked.iter().map(|&t| (t as u32, probs[t])).collect::<Vec<_>>());
                    }
                    guesses = GuessSet { per_distance };
                    state = tree.nodes[v.final_node].prompt_len;
                    cur = *chain.last().unwrap();
                }
                chain.truncate(limit);
                Ok((chain, steps))
            }
        }
    }

    /// Resync the draft cache after the target rejected a suffix: drop
    /// the speculated rows and re-ingest the accepted tokens.
    fn draft_catch_up(
        &self,
        draft_cache: &mut HostKvCache,
        accepted: &[u32],
        target_committed: usize,
    ) -> Result<()> {
        // the draft cache may have advanced past / diverged from the
        // accepted prefix: rewind to the last agreed length then feed
        // the accepted tokens (minus the one reserved as next root)
        let agreed = target_committed.saturating_sub(accepted.len());
        if draft_cache.committed() > agreed {
            draft_cache.truncate(agreed)?;
        }
        if accepted.is_empty() {
            return Ok(());
        }
        let s = self.draft.cfg().max_ctx;
        let base = draft_cache.committed();
        let n = accepted.len();
        let pos: Vec<u32> = (0..n as u32).map(|i| base as u32 + i).collect();
        let mut bias = vec![NEG_INF; n * s];
        for i in 0..n {
            for j in 0..=(base + i) {
                bias[i * s + j] = 0.0;
            }
        }
        let out = self.draft.forward(accepted, &pos, &pos, &bias, &draft_cache.device_snapshot())?;
        draft_cache.scatter(&out.new_kv, &pos)?;
        draft_cache.commit_contiguous(n)?;
        Ok(())
    }
}

impl DecodeEngine for SpeculativeEngine<'_> {
    fn name(&self) -> &'static str {
        match self.mode {
            DraftMode::Vanilla => "spec",
            DraftMode::Ppd { .. } => "spec+ppd",
        }
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (self.target.cfg().n_layers, self.target.cfg().max_ctx, self.target.cfg().d_model)
    }

    fn begin_request(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn request_seed(&self) -> u64 {
        self.seed
    }

    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        target_cache: &mut HostKvCache,
    ) -> Result<SeqState> {
        target_cache.reset();
        let mut draft_cache = self.draft_free.pop().unwrap_or_else(|| {
            let (l, s, d) = self.draft_shape();
            HostKvCache::new(l, s, d)
        });
        draft_cache.reset();
        let vocab = self.target.cfg().vocab;

        let t0 = Instant::now();
        let pre_t = prefill(self.target, target_cache, prompt)?;
        prefill(self.draft, &mut draft_cache, prompt)?;
        let prefill_s = t0.elapsed().as_secs_f64();

        let root = argmax(pre_t.logits_row(pre_t.n - 1, vocab)) as u32;
        let mut seq = SeqState::new(max_new, Rng::new(seed), Box::new(SpecSeq { root, draft_cache }));
        seq.res.prefill_s = prefill_s;
        seq.res.tokens.push(root);
        seq.eos_seen = root == crate::config::EOS_ID;
        Ok(seq)
    }

    fn step(&mut self, seq: &mut SeqState, target_cache: &mut HostKvCache) -> Result<StepOutcome> {
        if let Some(r) = seq.finished {
            return Ok(StepOutcome::Finished(r));
        }
        if seq.eos_seen {
            return Ok(self.finish_and_reclaim(seq, FinishReason::Eos));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(self.finish_and_reclaim(seq, FinishReason::Budget));
        }
        let t = Instant::now();
        let vocab = self.target.cfg().vocab;
        let s = self.target.cfg().max_ctx;
        let remaining = seq.max_new - seq.res.tokens.len();

        let root = seq.inner.downcast_ref::<SpecSeq>().expect("spec seq state").root;
        let limit = self.gamma.min(remaining - 1);
        let (chain, draft_steps) = {
            let st = seq.inner.downcast_mut::<SpecSeq>().expect("spec seq state");
            self.draft_chain(&mut st.draft_cache, &mut seq.rng, root, limit)?
        };
        seq.res.draft_steps += draft_steps;
        if chain.is_empty() && remaining > 1 {
            // draft context exhausted mid-generation
            seq.res.decode_s += t.elapsed().as_secs_f64();
            return Ok(self.finish_and_reclaim(seq, FinishReason::Context));
        }
        // verify [root, chain...] against the target in one forward
        // (with remaining == 1 the chain is empty and this is a
        // plain one-token step producing the final bonus token)
        let committed = target_cache.committed();
        let n = 1 + chain.len();
        if committed + n + 2 >= s || target_cache.remaining() < n + 2 {
            seq.res.decode_s += t.elapsed().as_secs_f64();
            return Ok(self.finish_and_reclaim(seq, FinishReason::Context));
        }
        let mut tokens = Vec::with_capacity(n);
        tokens.push(root);
        tokens.extend_from_slice(&chain);
        let pos: Vec<u32> = (0..n as u32).map(|i| committed as u32 + i).collect();
        let mut bias = vec![NEG_INF; n * s];
        for i in 0..n {
            for j in 0..=(committed + i) {
                bias[i * s + j] = 0.0;
            }
        }
        let out = self.target.forward(&tokens, &pos, &pos, &bias, &target_cache.device_snapshot())?;
        target_cache.scatter(&out.new_kv, &pos)?;

        // longest matching prefix + bonus
        let mut accepted = 0;
        while accepted < chain.len() {
            let want = argmax(out.logits_row(accepted, vocab)) as u32;
            if chain[accepted] == want {
                accepted += 1;
            } else {
                break;
            }
        }
        let bonus = argmax(out.logits_row(accepted, vocab)) as u32;
        // commit root + accepted chain rows (they are contiguous)
        target_cache.commit_contiguous(1 + accepted)?;

        let mut emitted: Vec<u32> = chain[..accepted].to_vec();
        emitted.push(bonus);
        seq.eos_seen |= record_step(&mut seq.res, &emitted, remaining, n);

        // draft resync: accepted prefix (without bonus — that is the
        // next root and will be fed on the next draft round)
        let catch: Vec<u32> = std::iter::once(root).chain(chain[..accepted].iter().copied()).collect();
        {
            let st = seq.inner.downcast_mut::<SpecSeq>().expect("spec seq state");
            self.draft_catch_up(&mut st.draft_cache, &catch, target_cache.committed())?;
            st.root = bonus;
        }
        seq.res.decode_s += t.elapsed().as_secs_f64();
        if seq.eos_seen {
            return Ok(self.finish_and_reclaim(seq, FinishReason::Eos));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(self.finish_and_reclaim(seq, FinishReason::Budget));
        }
        Ok(StepOutcome::Running)
    }
}

// A speculative step is a draft *loop* plus one target forward; fusing
// it needs draft-side batching first.  The default `StepPlan::Fallback`
// keeps it correct (per-sequence `step`) under `--fuse-steps`.
impl crate::batch::BatchStepEngine for SpeculativeEngine<'_> {}
