//! Retrieval-style parallel-decoding baselines for Fig 4, all built on
//! one generic chain-proposal engine:
//!
//! * **PLD** (prompt lookup decoding, Saxena 2023) — match the current
//!   n-gram suffix against the *request's own context* and propose the
//!   continuation that followed it.
//! * **REST** (He et al. 2023) — same matching against an external
//!   datastore (here: the synthetic training-corpus validation stream,
//!   standing in for REST's corpus index).
//! * **Lookahead-lite** (Fu et al. 2023) — n-gram pool harvested online
//!   from the request's *generated* tokens (the n-gram-cache half of
//!   lookahead decoding; the Jacobi branch is not reproduced).
//!
//! Proposals are linear chains merged into a (possibly branching) tree
//! and verified with the same exact-match walk as PPD — guess sources
//! differ, verification is shared, which is exactly the paper's framing
//! of these methods.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::kvcache::HostKvCache;
use crate::runtime::Device;
use crate::tree::{assemble_step, GuessSet, SparseTree, TreeNode};
use crate::util::rng::Rng;

use super::verify::{verify, VerifyMode};
use super::{prefill, record_step, DecodeEngine, FinishReason, SeqState, StepOutcome};

/// A source of speculative continuation chains.
///
/// Proposers are **per-sequence** state: the engine keeps a template
/// and clones a fresh (reset) copy into every [`SeqState`], so one
/// request's harvested n-grams can never leak into another even when
/// sequences interleave at the step level.
pub trait ChainProposer {
    fn name(&self) -> &'static str;

    /// Propose up to a few continuations of `ctx` (most recent last).
    fn propose(&mut self, ctx: &[u32]) -> Vec<Vec<u32>>;

    /// Observe newly accepted tokens (lookahead harvests from these).
    fn observe(&mut self, _ctx: &[u32]) {}

    /// Drop state harvested from previous requests (lookahead's n-gram
    /// pool): without this, one request's generation leaks into the
    /// next request's proposals and serving output depends on request
    /// order / worker placement.
    fn reset(&mut self) {}
}

/// Find continuations of the longest matching suffix n-gram of `ctx`
/// inside `corpus`.  Shared by PLD/REST/lookahead.
pub fn ngram_continuations(
    corpus: &[u32],
    ctx: &[u32],
    max_ngram: usize,
    span: usize,
    max_hits: usize,
) -> Vec<Vec<u32>> {
    for n in (1..=max_ngram.min(ctx.len())).rev() {
        let pat = &ctx[ctx.len() - n..];
        let mut hits = Vec::new();
        if corpus.len() < n + 1 {
            continue;
        }
        // scan backwards so recent matches rank first
        for start in (0..corpus.len() - n).rev() {
            if &corpus[start..start + n] == pat {
                let cont_start = start + n;
                let cont_end = (cont_start + span).min(corpus.len());
                if cont_end > cont_start {
                    hits.push(corpus[cont_start..cont_end].to_vec());
                }
                if hits.len() >= max_hits {
                    break;
                }
            }
        }
        if !hits.is_empty() {
            return hits;
        }
    }
    Vec::new()
}

/// PLD: the corpus is the request's own context.
#[derive(Clone)]
pub struct PldProposer {
    pub span: usize,
}

impl ChainProposer for PldProposer {
    fn name(&self) -> &'static str {
        "pld"
    }

    fn propose(&mut self, ctx: &[u32]) -> Vec<Vec<u32>> {
        if ctx.len() < 2 {
            return vec![];
        }
        // exclude the suffix itself from the search corpus
        let body = &ctx[..ctx.len() - 1];
        ngram_continuations(body, ctx, 3, self.span, 1)
    }
}

/// REST: external datastore of corpus tokens.  The datastore is behind
/// an `Arc`: proposers are cloned per admitted sequence, and the corpus
/// is read-only — a deep copy per request would be O(corpus) on the
/// admission path.
#[derive(Clone)]
pub struct RestProposer {
    pub datastore: std::sync::Arc<Vec<u32>>,
    pub span: usize,
    pub max_hits: usize,
}

impl ChainProposer for RestProposer {
    fn name(&self) -> &'static str {
        "rest"
    }

    fn propose(&mut self, ctx: &[u32]) -> Vec<Vec<u32>> {
        ngram_continuations(&self.datastore, ctx, 3, self.span, self.max_hits)
    }
}

/// Lookahead-lite: n-gram pool keyed by the last token, harvested from
/// the generation itself.
#[derive(Clone)]
pub struct LookaheadProposer {
    pub span: usize,
    pool: HashMap<u32, Vec<Vec<u32>>>,
    window: usize,
}

impl LookaheadProposer {
    pub fn new(span: usize) -> Self {
        LookaheadProposer { span, pool: HashMap::new(), window: 0 }
    }
}

impl ChainProposer for LookaheadProposer {
    fn name(&self) -> &'static str {
        "lookahead"
    }

    fn propose(&mut self, ctx: &[u32]) -> Vec<Vec<u32>> {
        let Some(&last) = ctx.last() else { return vec![] };
        self.pool.get(&last).cloned().unwrap_or_default()
    }

    fn reset(&mut self) {
        self.pool.clear();
        self.window = 0;
    }

    fn observe(&mut self, ctx: &[u32]) {
        // harvest (key, continuation-span) n-grams from fresh tokens
        let start = self.window;
        for i in start.max(1)..ctx.len() {
            let key = ctx[i - 1];
            let end = (i + self.span).min(ctx.len());
            if end > i {
                let entry = self.pool.entry(key).or_default();
                let gram = ctx[i..end].to_vec();
                if !entry.contains(&gram) {
                    if entry.len() >= 3 {
                        entry.remove(0);
                    }
                    entry.push(gram);
                }
            }
        }
        self.window = ctx.len();
    }
}

/// Merge proposal chains into a sparse tree + the guess table feeding
/// `assemble_step` (depth d rank r = r-th distinct token at depth d).
pub fn chains_to_tree(chains: &[Vec<u32>], max_depth: usize, max_nodes: usize) -> (SparseTree, GuessSet) {
    let mut nodes = vec![TreeNode { parent: usize::MAX, depth: 0, rank: 0, prompt_len: 0 }];
    let mut per_distance: Vec<Vec<(u32, f32)>> = vec![Vec::new(); max_depth];
    // parent node idx + token -> node idx (prefix merging)
    let mut index: HashMap<(usize, u32), usize> = HashMap::new();
    for chain in chains {
        let mut parent = 0usize;
        for (d, &tok) in chain.iter().take(max_depth).enumerate() {
            let depth = d + 1;
            if nodes.len() >= max_nodes {
                break;
            }
            let key = (parent, tok);
            parent = *index.entry(key).or_insert_with(|| {
                // rank = position of tok in this depth's guess list
                let lvl = &mut per_distance[depth - 1];
                let rank = match lvl.iter().position(|&(t, _)| t == tok) {
                    Some(r) => r,
                    None => {
                        lvl.push((tok, 0.0));
                        lvl.len() - 1
                    }
                };
                nodes.push(TreeNode { parent, depth, rank, prompt_len: 0 });
                nodes.len() - 1
            });
        }
    }
    let state = nodes.iter().map(|n| n.depth).max().unwrap_or(0);
    (SparseTree { nodes, state }, GuessSet { per_distance })
}

/// The generic chain-speculation engine (verification shared with PPD).
pub struct ChainEngine<'rt, P: ChainProposer> {
    rt: &'rt dyn Device,
    /// template proposer; each sequence gets a reset clone
    proposer: P,
    max_depth: usize,
    max_nodes: usize,
    seed: u64,
}

/// Per-sequence state: the cursor token, the full context the proposer
/// matches against, and the sequence's own proposer instance.
struct ChainSeq<P> {
    root: u32,
    full_ctx: Vec<u32>,
    proposer: P,
}

impl<'rt, P: ChainProposer> ChainEngine<'rt, P> {
    pub fn new(rt: &'rt dyn Device, proposer: P, max_depth: usize, max_nodes: usize, seed: u64) -> Self {
        ChainEngine { rt, proposer, max_depth, max_nodes, seed }
    }
}

impl<P: ChainProposer + Clone + Send + 'static> DecodeEngine for ChainEngine<'_, P> {
    fn name(&self) -> &'static str {
        self.proposer.name()
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (self.rt.cfg().n_layers, self.rt.cfg().max_ctx, self.rt.cfg().d_model)
    }

    fn begin_request(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn request_seed(&self) -> u64 {
        self.seed
    }

    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        cache: &mut HostKvCache,
    ) -> Result<SeqState> {
        cache.reset();
        let vocab = self.rt.cfg().vocab;
        // drop state harvested from previous requests (lookahead's
        // n-gram pool): without this, one request's generation would
        // leak into the next request's proposals
        let mut proposer = self.proposer.clone();
        proposer.reset();

        let t0 = Instant::now();
        let pre = prefill(self.rt, cache, prompt)?;
        let prefill_s = t0.elapsed().as_secs_f64();

        let root = crate::util::argmax(pre.logits_row(pre.n - 1, vocab)) as u32;
        let mut full_ctx: Vec<u32> = prompt.to_vec();
        full_ctx.push(root);
        proposer.observe(&full_ctx);

        let inner = ChainSeq { root, full_ctx, proposer };
        let mut seq = SeqState::new(max_new, Rng::new(seed), Box::new(inner));
        seq.res.prefill_s = prefill_s;
        seq.res.tokens.push(root);
        seq.eos_seen = root == crate::config::EOS_ID;
        Ok(seq)
    }

    fn step(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome> {
        if let Some(r) = seq.finished {
            return Ok(StepOutcome::Finished(r));
        }
        if seq.eos_seen {
            return Ok(seq.finish(FinishReason::Eos));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(seq.finish(FinishReason::Budget));
        }
        let t = Instant::now();
        let vocab = self.rt.cfg().vocab;
        let max_ctx = self.rt.cfg().max_ctx;
        let remaining = seq.max_new - seq.res.tokens.len();

        let (root, chains) = {
            let st = seq.inner.downcast_mut::<ChainSeq<P>>().expect("chain seq state");
            let chains = st.proposer.propose(&st.full_ctx);
            (st.root, chains)
        };
        // depth-capped near the budget: a depth-d tree emits at most
        // d+1 tokens, anything deeper is discarded work
        let depth = self.max_depth.min(remaining - 1);
        let (tree, guesses) = chains_to_tree(&chains, depth, self.max_nodes);
        let layout = tree.layout();
        let committed = cache.committed();
        if committed + tree.input_len() + 2 >= max_ctx {
            seq.res.decode_s += t.elapsed().as_secs_f64();
            return Ok(seq.finish(FinishReason::Context));
        }
        let inputs = assemble_step(&tree, &layout, &guesses, root, committed as u32, committed, max_ctx)?;
        let out = self.rt.forward(&inputs.tokens, &inputs.pos, &inputs.slots, &inputs.bias, &cache.device_snapshot())?;
        cache.scatter(&out.new_kv, &inputs.slots)?;

        let v = verify(&tree, &layout, &out, &inputs.tokens, VerifyMode::Greedy, vocab, &mut seq.rng);
        let mut accepted_slots = vec![inputs.slots[0]];
        accepted_slots.extend(v.accepted_nodes.iter().map(|&n| inputs.slots[layout.node_input[n]]));
        cache.compact(&accepted_slots)?;

        seq.eos_seen |= record_step(&mut seq.res, &v.emitted, remaining, tree.input_len());
        {
            let st = seq.inner.downcast_mut::<ChainSeq<P>>().expect("chain seq state");
            st.full_ctx.extend_from_slice(&v.emitted);
            st.proposer.observe(&st.full_ctx);
            st.root = *v.emitted.last().unwrap();
        }
        seq.res.decode_s += t.elapsed().as_secs_f64();
        if seq.eos_seen {
            return Ok(seq.finish(FinishReason::Eos));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(seq.finish(FinishReason::Budget));
        }
        Ok(StepOutcome::Running)
    }
}

// Chain engines build their tree from retrieval state that changes
// *during* the step (proposer clones, datastore hits), so they have no
// native plan/apply split yet: the default `StepPlan::Fallback` makes
// the fused scheduler run their monolithic `step` per sequence.
impl<P: ChainProposer + Clone + Send + 'static> crate::batch::BatchStepEngine
    for ChainEngine<'_, P>
{
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ngram_matching_prefers_long_grams() {
        let corpus = vec![1, 2, 3, 9, 9, 1, 2, 3, 4, 5, 6];
        let ctx = vec![7, 1, 2, 3];
        let hits = ngram_continuations(&corpus, &ctx, 3, 3, 2);
        assert_eq!(hits[0], vec![4, 5, 6]);
    }

    #[test]
    fn ngram_falls_back_to_short() {
        let corpus = vec![5, 8, 9];
        let ctx = vec![1, 2, 5];
        let hits = ngram_continuations(&corpus, &ctx, 3, 2, 2);
        assert_eq!(hits[0], vec![8, 9]);
    }

    #[test]
    fn ngram_empty_when_no_match() {
        assert!(ngram_continuations(&[1, 2], &[9], 3, 2, 2).is_empty());
    }

    #[test]
    fn chains_merge_common_prefixes() {
        let chains = vec![vec![5, 6, 7], vec![5, 6, 8], vec![9]];
        let (tree, guesses) = chains_to_tree(&chains, 3, 16);
        tree.validate().unwrap();
        // depth1: {5, 9}; depth2: {6} (shared); depth3: {7, 8} -> 5 nodes
        assert_eq!(tree.n_candidates(), 5);
        assert_eq!(guesses.per_distance[0].len(), 2);
        assert_eq!(guesses.token_at(1, 0), Some(5));
        assert_eq!(guesses.token_at(2, 0), Some(6));
        assert_eq!(guesses.token_at(3, 1), Some(8));
    }

    #[test]
    fn chains_respect_node_cap() {
        let chains = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let (tree, _) = chains_to_tree(&chains, 8, 4);
        assert!(tree.nodes.len() <= 4);
        tree.validate().unwrap();
    }

    #[test]
    fn pld_finds_repeated_pattern() {
        let mut p = PldProposer { span: 3 };
        // "calc: 12" ... "calc: " -> proposes "12"-ish continuation
        let ctx = vec![10, 20, 30, 40, 50, 10, 20, 30];
        let hits = p.propose(&ctx);
        assert_eq!(hits[0], vec![40, 50, 10]);
    }

    #[test]
    fn lookahead_harvests_and_proposes() {
        let mut p = LookaheadProposer::new(2);
        p.observe(&[1, 2, 3, 4]);
        let hits = p.propose(&[9, 2]);
        assert!(hits.contains(&vec![3, 4]));
        // pool caps at 3 entries per key
        p.observe(&[1, 2, 5, 1, 2, 6, 1, 2, 7, 1, 2, 8]);
        assert!(p.propose(&[0, 2]).len() <= 3);
    }

    #[test]
    fn empty_chains_give_root_only_tree() {
        let (tree, g) = chains_to_tree(&[], 3, 8);
        assert_eq!(tree.n_candidates(), 0);
        assert_eq!(g.depth(), 3);
        tree.validate().unwrap();
    }
}
