//! Vanilla autoregressive decoding — the Table 1 baseline.

use std::time::Instant;

use anyhow::Result;

use crate::batch::{BatchItem, BatchStepEngine, PlanInputs, StepPlan, StepResult};
use crate::kvcache::HostKvCache;
use crate::runtime::{Device, StepOutput, NEG_INF};
use crate::util::argmax;
use crate::util::rng::Rng;

use super::verify::softmax_temp;
use super::{prefill, DecodeEngine, FinishReason, SeqState, StepOutcome};

pub struct VanillaEngine<'rt> {
    rt: &'rt dyn Device,
    temperature: f32,
    seed: u64,
}

/// Per-sequence state: just the next token to feed.
struct VanillaSeq {
    next: u32,
}

impl<'rt> VanillaEngine<'rt> {
    pub fn new(rt: &'rt dyn Device, temperature: f32, seed: u64) -> Self {
        VanillaEngine { rt, temperature, seed }
    }

    fn pick(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        if self.temperature <= 0.0 {
            argmax(logits) as u32
        } else {
            let p = softmax_temp(logits, self.temperature);
            rng.sample_dist(&p) as u32
        }
    }
}

impl DecodeEngine for VanillaEngine<'_> {
    fn name(&self) -> &'static str {
        "vanilla"
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (self.rt.cfg().n_layers, self.rt.cfg().max_ctx, self.rt.cfg().d_model)
    }

    fn begin_request(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn request_seed(&self) -> u64 {
        self.seed
    }

    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        cache: &mut HostKvCache,
    ) -> Result<SeqState> {
        cache.reset();
        let vocab = self.rt.cfg().vocab;
        let mut rng = Rng::new(seed);

        let t0 = Instant::now();
        let pre = prefill(self.rt, cache, prompt)?;
        let next = self.pick(pre.logits_row(pre.n - 1, vocab), &mut rng);
        let mut seq = SeqState::new(max_new, rng, Box::new(VanillaSeq { next }));
        seq.res.prefill_s = t0.elapsed().as_secs_f64();
        Ok(seq)
    }

    fn step(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome> {
        // plan → forward → apply: the identical code the fused
        // scheduler runs, minus the batching
        let rt = self.rt;
        crate::batch::step_via_plan(rt, self, seq, cache)
    }
}

impl BatchStepEngine for VanillaEngine<'_> {
    fn plan_step(&mut self, seq: &mut SeqState, cache: &HostKvCache) -> Result<StepPlan> {
        if let Some(r) = seq.finished {
            return Ok(StepPlan::Finished(StepOutcome::Finished(r)));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Budget)));
        }
        if cache.remaining() <= 1 {
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Context)));
        }
        let t = Instant::now();
        let s = self.rt.cfg().max_ctx;
        let next = seq.inner.downcast_ref::<VanillaSeq>().expect("vanilla seq state").next;

        let c = cache.committed();
        seq.res.tokens.push(next);
        // stop *before* the forward once the budget is filled or EOS was
        // emitted — a successor token would never be kept
        if next == crate::config::EOS_ID {
            seq.res.decode_s += t.elapsed().as_secs_f64();
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Eos)));
        }
        if seq.res.tokens.len() >= seq.max_new {
            seq.res.decode_s += t.elapsed().as_secs_f64();
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Budget)));
        }
        let mut bias = vec![NEG_INF; s];
        for b in bias.iter_mut().take(c + 1) {
            *b = 0.0;
        }
        seq.res.decode_s += t.elapsed().as_secs_f64();
        Ok(StepPlan::Forward(PlanInputs {
            tokens: vec![next],
            pos: vec![c as u32],
            slots: vec![c as u32],
            bias,
            max_ctx: s,
        }))
    }

    fn apply_step(
        &mut self,
        seq: &mut SeqState,
        res: &StepResult<'_>,
        cache: &mut HostKvCache,
    ) -> Result<StepOutcome> {
        let t = Instant::now();
        let vocab = self.rt.cfg().vocab;
        let out: &StepOutput = res.out;
        cache.scatter(&out.new_kv, &res.plan.slots)?;
        cache.commit_contiguous(1)?;
        seq.res.steps += 1;
        seq.res.accepted_per_step.push(1);
        seq.res.input_lens.push(1);
        let picked = self.pick(out.logits_row(0, vocab), &mut seq.rng);
        seq.inner.downcast_mut::<VanillaSeq>().expect("vanilla seq state").next = picked;
        seq.res.decode_s += t.elapsed().as_secs_f64();
        Ok(StepOutcome::Running)
    }

    fn forward_batch(&mut self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.rt.forward_batch(items)
    }
}
