//! Vanilla autoregressive decoding — the Table 1 baseline.

use std::time::Instant;

use anyhow::Result;

use crate::kvcache::HostKvCache;
use crate::runtime::{Runtime, NEG_INF};
use crate::util::argmax;
use crate::util::rng::Rng;

use super::verify::softmax_temp;
use super::{prefill, truncate_at_eos, DecodeEngine, GenerationResult};

pub struct VanillaEngine<'rt> {
    rt: &'rt Runtime,
    temperature: f32,
    rng: Rng,
}

impl<'rt> VanillaEngine<'rt> {
    pub fn new(rt: &'rt Runtime, temperature: f32, seed: u64) -> Self {
        VanillaEngine { rt, temperature, rng: Rng::new(seed) }
    }

    fn pick(&mut self, logits: &[f32]) -> u32 {
        if self.temperature <= 0.0 {
            argmax(logits) as u32
        } else {
            let p = softmax_temp(logits, self.temperature);
            self.rng.sample_dist(&p) as u32
        }
    }
}

impl DecodeEngine for VanillaEngine<'_> {
    fn name(&self) -> &'static str {
        "vanilla"
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (self.rt.cfg.n_layers, self.rt.cfg.max_ctx, self.rt.cfg.d_model)
    }

    fn begin_request(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
    }

    fn generate_with_cache(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        cache: &mut HostKvCache,
    ) -> Result<GenerationResult> {
        let mut res = GenerationResult::default();
        cache.reset();
        let s = self.rt.cfg.max_ctx;
        let vocab = self.rt.cfg.vocab;

        let t0 = Instant::now();
        let pre = prefill(self.rt, cache, prompt)?;
        res.prefill_s = t0.elapsed().as_secs_f64();

        let mut next = self.pick(pre.logits_row(pre.n - 1, vocab));
        let t1 = Instant::now();
        let mut bias = vec![NEG_INF; s];
        while res.tokens.len() < max_new && cache.remaining() > 1 {
            let c = cache.committed();
            res.tokens.push(next);
            // stop *before* the forward once the budget is filled — the
            // old loop shape burned one extra forward pass computing a
            // successor token that was never kept
            if next == crate::config::EOS_ID || res.tokens.len() >= max_new {
                break;
            }
            for (j, b) in bias.iter_mut().enumerate() {
                *b = if j <= c { 0.0 } else { NEG_INF };
            }
            let out = self.rt.forward(&[next], &[c as u32], &[c as u32], &bias, cache.as_slice())?;
            cache.scatter(&out.new_kv, &[c as u32])?;
            cache.commit_contiguous(1)?;
            res.steps += 1;
            res.accepted_per_step.push(1);
            res.input_lens.push(1);
            next = self.pick(out.logits_row(0, vocab));
        }
        res.decode_s = t1.elapsed().as_secs_f64();
        truncate_at_eos(&mut res.tokens);
        Ok(res)
    }
}
