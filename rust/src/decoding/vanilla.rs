//! Vanilla autoregressive decoding — the Table 1 baseline.

use std::time::Instant;

use anyhow::Result;

use crate::kvcache::HostKvCache;
use crate::runtime::{Runtime, NEG_INF};
use crate::util::argmax;
use crate::util::rng::Rng;

use super::verify::softmax_temp;
use super::{prefill, DecodeEngine, FinishReason, SeqState, StepOutcome};

pub struct VanillaEngine<'rt> {
    rt: &'rt Runtime,
    temperature: f32,
    seed: u64,
}

/// Per-sequence state: just the next token to feed.
struct VanillaSeq {
    next: u32,
}

impl<'rt> VanillaEngine<'rt> {
    pub fn new(rt: &'rt Runtime, temperature: f32, seed: u64) -> Self {
        VanillaEngine { rt, temperature, seed }
    }

    fn pick(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        if self.temperature <= 0.0 {
            argmax(logits) as u32
        } else {
            let p = softmax_temp(logits, self.temperature);
            rng.sample_dist(&p) as u32
        }
    }
}

impl DecodeEngine for VanillaEngine<'_> {
    fn name(&self) -> &'static str {
        "vanilla"
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (self.rt.cfg.n_layers, self.rt.cfg.max_ctx, self.rt.cfg.d_model)
    }

    fn begin_request(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn request_seed(&self) -> u64 {
        self.seed
    }

    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        cache: &mut HostKvCache,
    ) -> Result<SeqState> {
        cache.reset();
        let vocab = self.rt.cfg.vocab;
        let mut rng = Rng::new(seed);

        let t0 = Instant::now();
        let pre = prefill(self.rt, cache, prompt)?;
        let next = self.pick(pre.logits_row(pre.n - 1, vocab), &mut rng);
        let mut seq = SeqState::new(max_new, rng, Box::new(VanillaSeq { next }));
        seq.res.prefill_s = t0.elapsed().as_secs_f64();
        Ok(seq)
    }

    fn step(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome> {
        if let Some(r) = seq.finished {
            return Ok(StepOutcome::Finished(r));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(seq.finish(FinishReason::Budget));
        }
        if cache.remaining() <= 1 {
            return Ok(seq.finish(FinishReason::Context));
        }
        let t = Instant::now();
        let s = self.rt.cfg.max_ctx;
        let vocab = self.rt.cfg.vocab;
        let next = seq.inner.downcast_ref::<VanillaSeq>().expect("vanilla seq state").next;

        let c = cache.committed();
        seq.res.tokens.push(next);
        // stop *before* the forward once the budget is filled or EOS was
        // emitted — a successor token would never be kept
        if next == crate::config::EOS_ID {
            seq.res.decode_s += t.elapsed().as_secs_f64();
            return Ok(seq.finish(FinishReason::Eos));
        }
        if seq.res.tokens.len() >= seq.max_new {
            seq.res.decode_s += t.elapsed().as_secs_f64();
            return Ok(seq.finish(FinishReason::Budget));
        }
        let mut bias = vec![NEG_INF; s];
        for b in bias.iter_mut().take(c + 1) {
            *b = 0.0;
        }
        let out = self.rt.forward(&[next], &[c as u32], &[c as u32], &bias, cache.as_slice())?;
        cache.scatter(&out.new_kv, &[c as u32])?;
        cache.commit_contiguous(1)?;
        seq.res.steps += 1;
        seq.res.accepted_per_step.push(1);
        seq.res.input_lens.push(1);
        let picked = self.pick(out.logits_row(0, vocab), &mut seq.rng);
        seq.inner.downcast_mut::<VanillaSeq>().expect("vanilla seq state").next = picked;
        seq.res.decode_s += t.elapsed().as_secs_f64();
        Ok(StepOutcome::Running)
    }
}
