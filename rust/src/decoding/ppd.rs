//! Parallel Prompt Decoding — the paper's engine.
//!
//! Per decode step (Fig 2):
//! 1. pick the dynamic-tree state `T_k` (k = prompt-chain length of the
//!    node where the previous verification stopped);
//! 2. assemble the step input: root (previous bonus token) + candidate
//!    tokens filled from the previous step's prompt-token guesses +
//!    prompt chains; one forward pass with the tree bias;
//! 3. verify (exact match / typical acceptance), emit the accepted path
//!    + bonus token;
//! 4. compact the accepted rows in the KV cache;
//! 5. extract the next guesses from the stopped node's prompt-chain
//!    logits.
//!
//! The loop is expressed as one [`DecodeEngine::step`] per tree step,
//! with the state machine's cursor (`root`, `guesses`, state index)
//! carried in [`SeqState`] so the coordinator can interleave many
//! sequences on one engine.

use std::time::Instant;

use anyhow::Result;

use crate::batch::{BatchItem, BatchStepEngine, PlanInputs, StepPlan, StepResult};
use crate::config::ServeConfig;
use crate::kvcache::HostKvCache;
use crate::runtime::{Device, StepOutput};
use crate::tree::builder::AcceptStats;
use crate::tree::dynamic::DynamicTreeSet;
use crate::tree::{assemble_step, GuessSet, TreeLayout};
use crate::util::rng::Rng;
use crate::util::{softmax, topk};

use super::verify::{softmax_temp, verify, VerifyMode};
use super::{prefill, record_step, DecodeEngine, FinishReason, SeqState, StepOutcome};

pub struct PpdEngine<'rt> {
    rt: &'rt dyn Device,
    pub set: DynamicTreeSet,
    mode: VerifyMode,
    top_r: usize,
    seed: u64,
}

/// Per-sequence cursor of the dynamic-tree state machine.
struct PpdSeq {
    /// previous step's bonus token (next step's tree root)
    root: u32,
    /// prompt-token guesses extracted from the stopped node
    guesses: GuessSet,
    /// prompt-chain length of the stopped node (selects `T_k`)
    state: usize,
}

impl<'rt> PpdEngine<'rt> {
    pub fn new(rt: &'rt dyn Device, stats: &AcceptStats, cfg: &ServeConfig, seed: u64) -> Result<Self> {
        let m = rt.cfg().n_prompt;
        let set = DynamicTreeSet::build(stats, m, cfg.n_candidates, cfg.n_prompt_budget, cfg.top_r)?;
        Ok(Self::with_tree_set(rt, set, cfg, seed))
    }

    /// Use a pre-built tree set (benches build static/random/sized sets).
    pub fn with_tree_set(rt: &'rt dyn Device, set: DynamicTreeSet, cfg: &ServeConfig, seed: u64) -> Self {
        let mode = if cfg.temperature <= 0.0 {
            VerifyMode::Greedy
        } else {
            VerifyMode::Typical {
                temperature: cfg.temperature,
                epsilon: cfg.typical_epsilon,
                delta: cfg.typical_delta,
            }
        };
        PpdEngine { rt, set, mode, top_r: cfg.top_r, seed }
    }

    /// Extract next-step guesses from the stopped node's prompt chain.
    fn extract_guesses(
        &self,
        layout: &TreeLayout,
        node: usize,
        out: &StepOutput,
    ) -> GuessSet {
        let vocab = self.rt.cfg().vocab;
        let mut per_distance = Vec::new();
        for &row in &layout.prompt_input[node] {
            let probs = softmax(out.logits_row(row, vocab));
            let ranked = topk(&probs, self.top_r);
            per_distance.push(
                ranked.iter().map(|&t| (t as u32, probs[t])).collect::<Vec<_>>(),
            );
        }
        GuessSet { per_distance }
    }

    fn pick_root(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match self.mode {
            VerifyMode::Greedy => crate::util::argmax(logits) as u32,
            VerifyMode::Typical { temperature, .. } => {
                let p = softmax_temp(logits, temperature);
                rng.sample_dist(&p) as u32
            }
        }
    }

    /// The tree-state index this step runs under — a pure function of
    /// the sequence cursor, so `plan_step` and `apply_step` recompute
    /// the same `T_k` independently.
    ///
    /// A state-k tree emits at most k+1 tokens, so near the cap a
    /// shallower tree produces the same kept output with a much smaller
    /// forward pass.
    fn state_for(&self, seq: &SeqState) -> usize {
        let remaining = seq.max_new - seq.res.tokens.len();
        let st = seq.inner.downcast_ref::<PpdSeq>().expect("ppd seq state");
        st.state
            .min(st.guesses.depth())
            .min(self.set.trees.len() - 1)
            .min(remaining - 1)
    }
}

impl DecodeEngine for PpdEngine<'_> {
    fn name(&self) -> &'static str {
        "ppd"
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (self.rt.cfg().n_layers, self.rt.cfg().max_ctx, self.rt.cfg().d_model)
    }

    fn begin_request(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn request_seed(&self) -> u64 {
        self.seed
    }

    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        cache: &mut HostKvCache,
    ) -> Result<SeqState> {
        cache.reset();
        let vocab = self.rt.cfg().vocab;
        let mut rng = Rng::new(seed);

        let t0 = Instant::now();
        let pre = prefill(self.rt, cache, prompt)?;
        let prefill_s = t0.elapsed().as_secs_f64();

        // the first root token comes from the prefill logits
        let root = self.pick_root(pre.logits_row(pre.n - 1, vocab), &mut rng);
        let inner = PpdSeq { root, guesses: GuessSet::default(), state: 0 };
        let mut seq = SeqState::new(max_new, rng, Box::new(inner));
        seq.res.prefill_s = prefill_s;
        seq.res.tokens.push(root);
        seq.eos_seen = root == crate::config::EOS_ID;
        Ok(seq)
    }

    fn step(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome> {
        // plan → forward → apply: the identical code the fused
        // scheduler runs, minus the batching
        let rt = self.rt;
        crate::batch::step_via_plan(rt, self, seq, cache)
    }
}

impl BatchStepEngine for PpdEngine<'_> {
    fn plan_step(&mut self, seq: &mut SeqState, cache: &HostKvCache) -> Result<StepPlan> {
        if let Some(r) = seq.finished {
            return Ok(StepPlan::Finished(StepOutcome::Finished(r)));
        }
        if seq.eos_seen {
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Eos)));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Budget)));
        }
        let t = Instant::now();
        let max_ctx = self.rt.cfg().max_ctx;
        let state_k = self.state_for(seq);
        let tree = &self.set.trees[state_k];
        let layout = &self.set.layouts[state_k];
        let committed = cache.committed();
        if committed + tree.input_len() + 2 >= max_ctx {
            seq.res.decode_s += t.elapsed().as_secs_f64();
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Context)));
        }
        let st = seq.inner.downcast_ref::<PpdSeq>().expect("ppd seq state");
        let inputs = assemble_step(
            tree,
            layout,
            &st.guesses,
            st.root,
            committed as u32,
            committed,
            max_ctx,
        )?;
        seq.res.decode_s += t.elapsed().as_secs_f64();
        Ok(StepPlan::Forward(PlanInputs {
            tokens: inputs.tokens,
            pos: inputs.pos,
            slots: inputs.slots,
            bias: inputs.bias,
            max_ctx,
        }))
    }

    fn apply_step(
        &mut self,
        seq: &mut SeqState,
        res: &StepResult<'_>,
        cache: &mut HostKvCache,
    ) -> Result<StepOutcome> {
        let t = Instant::now();
        let vocab = self.rt.cfg().vocab;
        let remaining = seq.max_new - seq.res.tokens.len();
        // the cursor is untouched between plan and apply, so this
        // recovers exactly the tree the plan was assembled from
        let state_k = self.state_for(seq);
        let tree = &self.set.trees[state_k];
        let layout = &self.set.layouts[state_k];
        let out: &StepOutput = res.out;
        cache.scatter(&out.new_kv, &res.plan.slots)?;

        let v = verify(tree, layout, out, &res.plan.tokens, self.mode, vocab, &mut seq.rng);
        // compact: root + accepted candidate rows become committed
        let mut accepted_slots = vec![res.plan.slots[0]];
        accepted_slots.extend(
            v.accepted_nodes.iter().map(|&n| res.plan.slots[layout.node_input[n]]),
        );
        cache.compact(&accepted_slots)?;

        seq.eos_seen |= record_step(&mut seq.res, &v.emitted, remaining, tree.input_len());

        let next_guesses = self.extract_guesses(layout, v.final_node, out);
        let next_state = tree.nodes[v.final_node].prompt_len;
        let next_root = *v.emitted.last().unwrap();
        {
            let st = seq.inner.downcast_mut::<PpdSeq>().expect("ppd seq state");
            st.guesses = next_guesses;
            st.state = next_state;
            st.root = next_root;
        }
        seq.res.decode_s += t.elapsed().as_secs_f64();
        if seq.eos_seen {
            return Ok(seq.finish(FinishReason::Eos));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(seq.finish(FinishReason::Budget));
        }
        Ok(StepOutcome::Running)
    }

    fn forward_batch(&mut self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.rt.forward_batch(items)
    }
}
