//! Parallel Prompt Decoding — the paper's engine.
//!
//! Per decode step (Fig 2):
//! 1. pick the dynamic-tree state `T_k` (k = prompt-chain length of the
//!    node where the previous verification stopped);
//! 2. assemble the step input: root (previous bonus token) + candidate
//!    tokens filled from the previous step's prompt-token guesses +
//!    prompt chains; one forward pass with the tree bias;
//! 3. verify (exact match / typical acceptance), emit the accepted path
//!    + bonus token;
//! 4. compact the accepted rows in the KV cache;
//! 5. extract the next guesses from the stopped node's prompt-chain
//!    logits.

use std::time::Instant;

use anyhow::Result;

use crate::config::ServeConfig;
use crate::kvcache::HostKvCache;
use crate::runtime::{Runtime, StepOutput};
use crate::tree::builder::AcceptStats;
use crate::tree::dynamic::DynamicTreeSet;
use crate::tree::{assemble_step, GuessSet, TreeLayout};
use crate::util::rng::Rng;
use crate::util::{softmax, topk};

use super::verify::{softmax_temp, verify, VerifyMode};
use super::{prefill, record_step, truncate_at_eos, DecodeEngine, GenerationResult};

pub struct PpdEngine<'rt> {
    rt: &'rt Runtime,
    pub set: DynamicTreeSet,
    mode: VerifyMode,
    top_r: usize,
    rng: Rng,
}

impl<'rt> PpdEngine<'rt> {
    pub fn new(rt: &'rt Runtime, stats: &AcceptStats, cfg: &ServeConfig, seed: u64) -> Result<Self> {
        let m = rt.cfg.n_prompt;
        let set = DynamicTreeSet::build(stats, m, cfg.n_candidates, cfg.n_prompt_budget, cfg.top_r)?;
        Ok(Self::with_tree_set(rt, set, cfg, seed))
    }

    /// Use a pre-built tree set (benches build static/random/sized sets).
    pub fn with_tree_set(rt: &'rt Runtime, set: DynamicTreeSet, cfg: &ServeConfig, seed: u64) -> Self {
        let mode = if cfg.temperature <= 0.0 {
            VerifyMode::Greedy
        } else {
            VerifyMode::Typical {
                temperature: cfg.temperature,
                epsilon: cfg.typical_epsilon,
                delta: cfg.typical_delta,
            }
        };
        PpdEngine { rt, set, mode, top_r: cfg.top_r, rng: Rng::new(seed) }
    }

    /// Extract next-step guesses from the stopped node's prompt chain.
    fn extract_guesses(
        &self,
        layout: &TreeLayout,
        node: usize,
        out: &StepOutput,
    ) -> GuessSet {
        let vocab = self.rt.cfg.vocab;
        let mut per_distance = Vec::new();
        for &row in &layout.prompt_input[node] {
            let probs = softmax(out.logits_row(row, vocab));
            let ranked = topk(&probs, self.top_r);
            per_distance.push(
                ranked.iter().map(|&t| (t as u32, probs[t])).collect::<Vec<_>>(),
            );
        }
        GuessSet { per_distance }
    }

    fn pick_root(&mut self, logits: &[f32]) -> u32 {
        match self.mode {
            VerifyMode::Greedy => crate::util::argmax(logits) as u32,
            VerifyMode::Typical { temperature, .. } => {
                let p = softmax_temp(logits, temperature);
                self.rng.sample_dist(&p) as u32
            }
        }
    }
}

impl DecodeEngine for PpdEngine<'_> {
    fn name(&self) -> &'static str {
        "ppd"
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (self.rt.cfg.n_layers, self.rt.cfg.max_ctx, self.rt.cfg.d_model)
    }

    fn begin_request(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
    }

    fn generate_with_cache(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        cache: &mut HostKvCache,
    ) -> Result<GenerationResult> {
        let mut res = GenerationResult::default();
        cache.reset();
        let vocab = self.rt.cfg.vocab;
        let max_ctx = self.rt.cfg.max_ctx;

        let t0 = Instant::now();
        let pre = prefill(self.rt, cache, prompt)?;
        res.prefill_s = t0.elapsed().as_secs_f64();

        // the first root token comes from the prefill logits
        let mut root = self.pick_root(pre.logits_row(pre.n - 1, vocab));
        res.tokens.push(root);
        // EOS tracked as a flag fed from each step's emitted tokens; the
        // old `res.tokens.contains(EOS)` loop guard rescanned the whole
        // output every step — O(n²) over the generation length
        let mut eos_seen = root == crate::config::EOS_ID;
        let mut guesses = GuessSet::default();
        let mut state = 0usize; // no guesses yet -> root-only tree

        let t1 = Instant::now();
        while res.tokens.len() < max_new && !eos_seen {
            let remaining = max_new - res.tokens.len();
            // a state-k tree emits at most k+1 tokens, so near the cap a
            // shallower tree produces the same kept output with a much
            // smaller forward pass
            let state_k = state
                .min(guesses.depth())
                .min(self.set.trees.len() - 1)
                .min(remaining - 1);
            let tree = &self.set.trees[state_k];
            let layout = &self.set.layouts[state_k];
            let committed = cache.committed();
            if committed + tree.input_len() + 2 >= max_ctx {
                break; // context exhausted
            }
            let inputs = assemble_step(
                tree,
                layout,
                &guesses,
                root,
                committed as u32,
                committed,
                max_ctx,
            )?;
            let out = self.rt.forward(
                &inputs.tokens,
                &inputs.pos,
                &inputs.slots,
                &inputs.bias,
                cache.as_slice(),
            )?;
            cache.scatter(&out.new_kv, &inputs.slots)?;

            let v = verify(tree, layout, &out, &inputs.tokens, self.mode, vocab, &mut self.rng);
            // compact: root + accepted candidate rows become committed
            let mut accepted_slots = vec![inputs.slots[0]];
            accepted_slots.extend(
                v.accepted_nodes.iter().map(|&n| inputs.slots[layout.node_input[n]]),
            );
            cache.compact(&accepted_slots)?;

            eos_seen |= record_step(&mut res, &v.emitted, remaining, tree.input_len());

            guesses = self.extract_guesses(layout, v.final_node, &out);
            state = tree.nodes[v.final_node].prompt_len;
            root = *v.emitted.last().unwrap();
        }
        res.decode_s = t1.elapsed().as_secs_f64();
        truncate_at_eos(&mut res.tokens);
        res.tokens.truncate(max_new);
        Ok(res)
    }
}
