//! Candidate verification (paper §3, step 2):
//!
//! * **exact matching** (greedy, temperature 0) — a candidate child is
//!   accepted iff its token equals the argmax of its parent's logits.
//!   Guarantees the output is byte-identical to vanilla greedy decoding
//!   (the Table 1 "Same" quality row).
//! * **typical acceptance** (temperature > 0, Medusa §3.3) — a child is
//!   accepted if its probability under the (temperature-scaled) parent
//!   distribution exceeds `min(ε, δ·exp(−H))`; the deepest accepted path
//!   wins and the bonus token is sampled from the final node's
//!   distribution.

use crate::runtime::StepOutput;
use crate::tree::{SparseTree, TreeLayout};
use crate::util::rng::Rng;
use crate::util::{argmax, entropy, softmax};

#[derive(Debug, Clone, Copy)]
pub enum VerifyMode {
    Greedy,
    Typical { temperature: f32, epsilon: f32, delta: f32 },
}

#[derive(Debug, Clone)]
pub struct Verification {
    /// accepted candidate node indices, in path order (root excluded)
    pub accepted_nodes: Vec<usize>,
    /// tokens emitted this step: accepted candidates then the bonus
    pub emitted: Vec<u32>,
    /// node index where verification stopped (0 = root)
    pub final_node: usize,
}

/// Walk the tree from the root, accepting children per `mode`.
///
/// `tokens` is the step's input-token vector (candidate values live
/// there); logits come from `out` at each node's input row.
pub fn verify(
    _tree: &SparseTree,
    layout: &TreeLayout,
    out: &StepOutput,
    tokens: &[u32],
    mode: VerifyMode,
    vocab: usize,
    rng: &mut Rng,
) -> Verification {
    let mut accepted_nodes = Vec::new();
    let mut emitted = Vec::new();
    let mut node = 0usize;
    loop {
        let row = out.logits_row(layout.node_input[node], vocab);
        let next = match mode {
            VerifyMode::Greedy => {
                let want = argmax(row) as u32;
                layout.children[node]
                    .iter()
                    .copied()
                    .find(|&c| tokens[layout.node_input[c]] == want)
            }
            VerifyMode::Typical { temperature, epsilon, delta } => {
                let probs = softmax_temp(row, temperature);
                let h = entropy(&probs);
                let threshold = epsilon.min(delta * (-h).exp());
                layout.children[node]
                    .iter()
                    .copied()
                    .filter(|&c| probs[tokens[layout.node_input[c]] as usize] >= threshold)
                    // total_cmp: extreme logits can softmax to NaN
                    // (e.g. +inf - +inf); partial_cmp().unwrap() here
                    // panicked the serving worker mid-request
                    .max_by(|&a, &b| {
                        let pa = probs[tokens[layout.node_input[a]] as usize];
                        let pb = probs[tokens[layout.node_input[b]] as usize];
                        pa.total_cmp(&pb)
                    })
            }
        };
        match next {
            Some(c) => {
                accepted_nodes.push(c);
                emitted.push(tokens[layout.node_input[c]]);
                node = c;
            }
            None => break,
        }
    }
    // bonus token from the final node's distribution
    let row = out.logits_row(layout.node_input[node], vocab);
    let bonus = match mode {
        VerifyMode::Greedy => argmax(row) as u32,
        VerifyMode::Typical { temperature, .. } => {
            let probs = softmax_temp(row, temperature);
            rng.sample_dist(&probs) as u32
        }
    };
    emitted.push(bonus);
    Verification { accepted_nodes, emitted, final_node: node }
}

/// Temperature softmax; temperature 0 degenerates to a one-hot argmax.
pub fn softmax_temp(logits: &[f32], temperature: f32) -> Vec<f32> {
    if temperature <= 0.0 {
        let mut p = vec![0.0; logits.len()];
        p[argmax(logits)] = 1.0;
        return p;
    }
    let scaled: Vec<f32> = logits.iter().map(|&x| x / temperature).collect();
    softmax(&scaled)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tree::{SparseTree, TreeNode};

    fn tree() -> (SparseTree, TreeLayout) {
        // root -> a(rank0), b(rank1); a -> c
        let t = SparseTree {
            nodes: vec![
                TreeNode { parent: usize::MAX, depth: 0, rank: 0, prompt_len: 0 },
                TreeNode { parent: 0, depth: 1, rank: 0, prompt_len: 0 },
                TreeNode { parent: 0, depth: 1, rank: 1, prompt_len: 0 },
                TreeNode { parent: 1, depth: 2, rank: 0, prompt_len: 0 },
            ],
            state: 2,
        };
        let l = t.layout();
        (t, l)
    }

    fn out_with_argmax(rows: &[(usize, u32)], vocab: usize, n: usize) -> StepOutput {
        let mut logits = vec![0.0f32; n * vocab];
        for &(row, tok) in rows {
            logits[row * vocab + tok as usize] = 10.0;
        }
        StepOutput { n, logits, hidden: vec![0.0; n], new_kv: vec![] }
    }

    #[test]
    fn greedy_accepts_matching_path() {
        let (t, l) = tree();
        let tokens = vec![7, 65, 66, 67]; // root, a, b, c
        // root argmax = 65 (accept a), a argmax = 67 (accept c),
        // c argmax = 99 (bonus)
        let out = out_with_argmax(&[(0, 65), (1, 67), (3, 99)], 128, 4);
        let mut rng = Rng::new(0);
        let v = verify(&t, &l, &out, &tokens, VerifyMode::Greedy, 128, &mut rng);
        assert_eq!(v.accepted_nodes, vec![1, 3]);
        assert_eq!(v.emitted, vec![65, 67, 99]);
        assert_eq!(v.final_node, 3);
    }

    #[test]
    fn greedy_stops_at_mismatch() {
        let (t, l) = tree();
        let tokens = vec![7, 65, 66, 67];
        let out = out_with_argmax(&[(0, 50)], 128, 4); // no child matches
        let mut rng = Rng::new(0);
        let v = verify(&t, &l, &out, &tokens, VerifyMode::Greedy, 128, &mut rng);
        assert!(v.accepted_nodes.is_empty());
        assert_eq!(v.emitted, vec![50]);
        assert_eq!(v.final_node, 0);
    }

    #[test]
    fn greedy_second_rank_child_can_win() {
        let (t, l) = tree();
        let tokens = vec![7, 65, 66, 67];
        let out = out_with_argmax(&[(0, 66), (2, 42)], 128, 4);
        let mut rng = Rng::new(0);
        let v = verify(&t, &l, &out, &tokens, VerifyMode::Greedy, 128, &mut rng);
        assert_eq!(v.accepted_nodes, vec![2]);
        assert_eq!(v.emitted, vec![66, 42]);
    }

    #[test]
    fn typical_accepts_probable_children() {
        let (t, l) = tree();
        let tokens = vec![7, 65, 66, 67];
        // flat-ish logits; child 65 clearly most probable at root
        let mut logits = vec![0.0f32; 4 * 128];
        logits[65] = 5.0;
        logits[67 + 128] = 5.0;
        let out = StepOutput { n: 4, logits, hidden: vec![0.0; 4], new_kv: vec![] };
        let mut rng = Rng::new(0);
        let mode = VerifyMode::Typical { temperature: 1.0, epsilon: 0.3, delta: 0.09 };
        let v = verify(&t, &l, &out, &tokens, mode, 128, &mut rng);
        assert_eq!(v.accepted_nodes, vec![1, 3]);
        assert_eq!(v.emitted.len(), 3);
    }

    #[test]
    fn typical_rejects_improbable() {
        let (t, l) = tree();
        let tokens = vec![7, 65, 66, 67];
        // uniform distribution: every child has p = 1/128, entropy high
        let out = StepOutput { n: 4, logits: vec![0.0; 4 * 128], hidden: vec![0.0; 4], new_kv: vec![] };
        let mut rng = Rng::new(0);
        let mode = VerifyMode::Typical { temperature: 1.0, epsilon: 0.3, delta: 0.09 };
        let v = verify(&t, &l, &out, &tokens, mode, 128, &mut rng);
        // threshold = min(0.3, 0.09*exp(-ln 128)) .. wait exp(-H) tiny,
        // so threshold tiny; uniform p = 0.0078 >= 0.09/128=0.0007 ->
        // children CAN be accepted under high entropy (typical sampling
        // tolerates uncertainty). Just check it terminates and emits.
        assert!(!v.emitted.is_empty());
    }

    #[test]
    fn softmax_temp_zero_is_argmax() {
        let p = softmax_temp(&[0.1, 3.0, 1.0], 0.0);
        assert_eq!(p, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn typical_survives_non_finite_logits() {
        // regression: +inf logits softmax to NaN probabilities; the
        // typical-acceptance max_by used partial_cmp().unwrap() and
        // panicked instead of degrading to a root-only step
        let (t, l) = tree();
        let tokens = vec![7, 65, 66, 67];
        let mut logits = vec![0.0f32; 4 * 128];
        for row in 0..4 {
            logits[row * 128 + 65] = f32::INFINITY;
            logits[row * 128 + 66] = f32::INFINITY;
            logits[row * 128 + 70] = f32::NEG_INFINITY;
        }
        let out = StepOutput { n: 4, logits, hidden: vec![0.0; 4], new_kv: vec![] };
        let mut rng = Rng::new(0);
        let mode = VerifyMode::Typical { temperature: 1.0, epsilon: 0.3, delta: 0.09 };
        let v = verify(&t, &l, &out, &tokens, mode, 128, &mut rng);
        // no panic, and the step still emits at least a bonus token
        assert!(!v.emitted.is_empty());
        assert!(v.emitted.iter().all(|&tok| tok < 128));
    }
}
