//! Medusa-1 baseline: per-distance decoding heads + a static sparse
//! tree.  Identical guess-and-verify machinery to PPD, but the guesses
//! come from the trained heads applied to the stopped node's *hidden
//! state*, the tree carries no prompt tokens, and its shape is fixed
//! across steps (Medusa has no dynamic state machine).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::batch::{BatchItem, BatchStepEngine, PlanInputs, StepPlan, StepResult};
use crate::config::ServeConfig;
use crate::kvcache::HostKvCache;
use crate::runtime::{Device, StepOutput};
use crate::tree::builder::{build_candidate_tree, AcceptStats};
use crate::tree::{assemble_step, GuessSet, SparseTree, TreeLayout};
use crate::util::rng::Rng;
use crate::util::{softmax, topk};

use super::verify::{softmax_temp, verify, VerifyMode};
use super::{prefill, record_step, DecodeEngine, FinishReason, SeqState, StepOutcome};

pub struct MedusaEngine<'rt> {
    rt: &'rt dyn Device,
    pub tree: SparseTree,
    layout: TreeLayout,
    mode: VerifyMode,
    top_r: usize,
    seed: u64,
}

/// Per-sequence cursor: previous bonus token + head guesses.
struct MedusaSeq {
    root: u32,
    guesses: GuessSet,
}

impl<'rt> MedusaEngine<'rt> {
    /// `n_candidates` sizes the static tree (Medusa's published config
    /// uses 63 nodes; at our scale Table 1 uses the same ratio).
    pub fn new(rt: &'rt dyn Device, stats: &AcceptStats, cfg: &ServeConfig, n_candidates: usize, seed: u64) -> Result<Self> {
        if !rt.has_medusa() {
            bail!("model {} has no medusa heads artifact", rt.cfg().name);
        }
        let depth = rt.medusa_n_heads();
        let tree = build_candidate_tree(stats, depth, n_candidates, cfg.top_r);
        let layout = tree.layout();
        let mode = if cfg.temperature <= 0.0 {
            VerifyMode::Greedy
        } else {
            VerifyMode::Typical {
                temperature: cfg.temperature,
                epsilon: cfg.typical_epsilon,
                delta: cfg.typical_delta,
            }
        };
        Ok(MedusaEngine { rt, tree, layout, mode, top_r: cfg.top_r, seed })
    }

    fn guesses_from_hidden(&self, hidden: &[f32]) -> Result<GuessSet> {
        let heads = self.rt.medusa_heads(hidden)?;
        let mut per_distance = Vec::new();
        for logits in &heads {
            let probs = softmax(logits);
            let ranked = topk(&probs, self.top_r);
            per_distance.push(ranked.iter().map(|&t| (t as u32, probs[t])).collect());
        }
        Ok(GuessSet { per_distance })
    }

    fn pick_root(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match self.mode {
            VerifyMode::Greedy => crate::util::argmax(logits) as u32,
            VerifyMode::Typical { temperature, .. } => {
                let p = softmax_temp(logits, temperature);
                rng.sample_dist(&p) as u32
            }
        }
    }
}

impl DecodeEngine for MedusaEngine<'_> {
    fn name(&self) -> &'static str {
        "medusa"
    }

    fn cache_shape(&self) -> (usize, usize, usize) {
        (self.rt.cfg().n_layers, self.rt.cfg().max_ctx, self.rt.cfg().d_model)
    }

    fn begin_request(&mut self, seed: u64) {
        self.seed = seed;
    }

    fn request_seed(&self) -> u64 {
        self.seed
    }

    fn begin_seq(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        seed: u64,
        cache: &mut HostKvCache,
    ) -> Result<SeqState> {
        cache.reset();
        let vocab = self.rt.cfg().vocab;
        let d = self.rt.cfg().d_model;
        let mut rng = Rng::new(seed);

        let t0 = Instant::now();
        let pre = prefill(self.rt, cache, prompt)?;
        let prefill_s = t0.elapsed().as_secs_f64();

        let root = self.pick_root(pre.logits_row(pre.n - 1, vocab), &mut rng);
        let guesses = self.guesses_from_hidden(pre.hidden_row(pre.n - 1, d))?;
        let mut seq = SeqState::new(max_new, rng, Box::new(MedusaSeq { root, guesses }));
        seq.res.prefill_s = prefill_s;
        seq.res.tokens.push(root);
        seq.eos_seen = root == crate::config::EOS_ID;
        Ok(seq)
    }

    fn step(&mut self, seq: &mut SeqState, cache: &mut HostKvCache) -> Result<StepOutcome> {
        // plan → forward → apply: the identical code the fused
        // scheduler runs, minus the batching
        let rt = self.rt;
        crate::batch::step_via_plan(rt, self, seq, cache)
    }
}

impl BatchStepEngine for MedusaEngine<'_> {
    fn plan_step(&mut self, seq: &mut SeqState, cache: &HostKvCache) -> Result<StepPlan> {
        if let Some(r) = seq.finished {
            return Ok(StepPlan::Finished(StepOutcome::Finished(r)));
        }
        if seq.eos_seen {
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Eos)));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Budget)));
        }
        let t = Instant::now();
        let max_ctx = self.rt.cfg().max_ctx;
        let committed = cache.committed();
        if committed + self.tree.input_len() + 2 >= max_ctx {
            seq.res.decode_s += t.elapsed().as_secs_f64();
            return Ok(StepPlan::Finished(seq.finish(FinishReason::Context)));
        }
        let st = seq.inner.downcast_ref::<MedusaSeq>().expect("medusa seq state");
        let inputs = assemble_step(
            &self.tree,
            &self.layout,
            &st.guesses,
            st.root,
            committed as u32,
            committed,
            max_ctx,
        )?;
        seq.res.decode_s += t.elapsed().as_secs_f64();
        Ok(StepPlan::Forward(PlanInputs {
            tokens: inputs.tokens,
            pos: inputs.pos,
            slots: inputs.slots,
            bias: inputs.bias,
            max_ctx,
        }))
    }

    fn apply_step(
        &mut self,
        seq: &mut SeqState,
        res: &StepResult<'_>,
        cache: &mut HostKvCache,
    ) -> Result<StepOutcome> {
        let t = Instant::now();
        let vocab = self.rt.cfg().vocab;
        let d = self.rt.cfg().d_model;
        let remaining = seq.max_new - seq.res.tokens.len();
        let out: &StepOutput = res.out;
        cache.scatter(&out.new_kv, &res.plan.slots)?;

        let v = verify(&self.tree, &self.layout, out, &res.plan.tokens, self.mode, vocab, &mut seq.rng);
        let mut accepted_slots = vec![res.plan.slots[0]];
        accepted_slots.extend(
            v.accepted_nodes.iter().map(|&n| res.plan.slots[self.layout.node_input[n]]),
        );
        cache.compact(&accepted_slots)?;

        // Medusa's tree is static, so the final step cannot shrink
        // its forward pass like PPD's dynamic set does — but its
        // accounting is still capped to the kept tokens
        seq.eos_seen |= record_step(&mut seq.res, &v.emitted, remaining, self.tree.input_len());

        // the head pass stays per-sequence even under fused stepping
        // (a follow-on could batch it too)
        let hid = out.hidden_row(self.layout.node_input[v.final_node], d).to_vec();
        let next_guesses = self.guesses_from_hidden(&hid)?;
        let next_root = *v.emitted.last().unwrap();
        {
            let st = seq.inner.downcast_mut::<MedusaSeq>().expect("medusa seq state");
            st.guesses = next_guesses;
            st.root = next_root;
        }
        seq.res.decode_s += t.elapsed().as_secs_f64();
        if seq.eos_seen {
            return Ok(seq.finish(FinishReason::Eos));
        }
        if seq.res.tokens.len() >= seq.max_new {
            return Ok(seq.finish(FinishReason::Budget));
        }
        Ok(StepOutcome::Running)
    }

    fn forward_batch(&mut self, items: &[BatchItem<'_>]) -> Result<Vec<StepOutput>> {
        self.rt.forward_batch(items)
    }
}
