//! Tiny benchmark harness (criterion is not vendored).
//!
//! Provides warmup + timed iterations with median / mean / p95 stats and
//! the row-printing used by the `rust/benches/*` binaries to regenerate
//! the paper's tables and figures.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub total_s: f64,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let total: f64 = samples.iter().sum();
        BenchStats {
            iters: n,
            mean_s: total / n as f64,
            median_s: samples[n / 2],
            p95_s: samples[(n as f64 * 0.95) as usize % n],
            min_s: samples[0],
            total_s: total,
        }
    }
}

/// Run `f` for `warmup` unrecorded and `iters` recorded iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(samples)
}

/// Time a single invocation.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Fixed-width table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:width$}  ", c, width = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = BenchStats::from_samples(vec![3.0, 1.0, 2.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert_eq!(s.median_s, 3.0);
        assert_eq!(s.min_s, 1.0);
        assert!((s.mean_s - 3.0).abs() < 1e-12);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }
}
