//! Tiny benchmark harness (criterion is not vendored).
//!
//! Provides warmup + timed iterations with median / mean / p95 stats and
//! the row-printing used by the `rust/benches/*` binaries to regenerate
//! the paper's tables and figures.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    pub total_s: f64,
}

impl BenchStats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let total: f64 = samples.iter().sum();
        BenchStats {
            iters: n,
            mean_s: total / n as f64,
            median_s: quantile(&samples, 0.5),
            p95_s: quantile(&samples, 0.95),
            min_s: samples[0],
            total_s: total,
        }
    }
}

/// Linearly interpolated quantile of a pre-sorted sample set (the
/// "R-7" estimator: rank `q * (n - 1)`, interpolating between the two
/// neighboring order statistics).  The median of an even-sized set is
/// the mean of the middle pair, and p95 of a small set no longer
/// collapses to the max (`(n * 0.95) as usize` truncated to `n - 1`
/// for every n ≤ 20, which inflated every p95 the bench gate reads).
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q), "quantile {q} outside [0, 1]");
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Run `f` for `warmup` unrecorded and `iters` recorded iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchStats::from_samples(samples)
}

/// Time a single invocation.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Fixed-width table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:width$}  ", c, width = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = BenchStats::from_samples(vec![3.0, 1.0, 2.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert_eq!(s.median_s, 3.0);
        assert_eq!(s.min_s, 1.0);
        assert!((s.mean_s - 3.0).abs() < 1e-12);
        // p95 interpolates between the 4th and 5th order statistics
        // (rank 0.95 * 4 = 3.8) instead of pinning to the max
        assert!((s.p95_s - 4.8).abs() < 1e-12);
    }

    #[test]
    fn even_sample_median_averages_the_middle_pair() {
        let s = BenchStats::from_samples(vec![4.0, 1.0, 3.0, 2.0]);
        assert_eq!(s.median_s, 2.5);
        assert!((s.p95_s - 3.85).abs() < 1e-12);
    }

    #[test]
    fn quantiles_interpolate_known_ranks() {
        let sorted: Vec<f64> = (1..=20).map(f64::from).collect();
        // rank 0.95 * 19 = 18.05 → 19 + 0.05 (the old truncating index
        // returned 20.0, the max, for every n ≤ 20)
        assert!((quantile(&sorted, 0.95) - 19.05).abs() < 1e-12);
        assert_eq!(quantile(&sorted, 0.0), 1.0);
        assert_eq!(quantile(&sorted, 1.0), 20.0);
        assert_eq!(quantile(&sorted, 0.5), 10.5);
        assert_eq!(quantile(&[7.0], 0.95), 7.0);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn table_accepts_matching_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic(expected = "assertion")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only one cell".into()]);
    }
}
