//! Dependency-free utilities: JSON, PRNG, bench harness, math helpers.

pub mod bench;
pub mod json;
pub mod rng;

/// Numerically-stable softmax over a logits row.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut out: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = out.iter().sum();
    for x in &mut out {
        *x /= s;
    }
    out
}

/// Index of the maximum element.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Indices of the top-k elements, descending.  NaN-tolerant
/// (`total_cmp`, NaN ranks below every real value): the inputs are
/// softmaxed logits, which go NaN under extreme inputs, and a panicking
/// comparator here would take a serving worker down with the request.
pub fn topk(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        let (xa, xb) = (nan_low(xs[a]), nan_low(xs[b]));
        xb.total_cmp(&xa)
    });
    idx.truncate(k);
    idx
}

/// Map NaN to -inf so ordering treats it as the worst value.
fn nan_low(x: f32) -> f32 {
    if x.is_nan() {
        f32::NEG_INFINITY
    } else {
        x
    }
}

/// Human-readable message out of a caught panic payload (the
/// `catch_unwind` sites in the scheduler and the device dispatcher
/// share this so their error responses cannot drift).
pub fn panic_message(panic: Box<dyn std::any::Any + Send>) -> String {
    panic
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| panic.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic".into())
}

/// Shannon entropy of a probability distribution (nats).
pub fn entropy(probs: &[f32]) -> f32 {
    probs
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.ln())
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let p = softmax(&[1000.0, 999.0]);
        assert!(p[0].is_finite() && p[1].is_finite());
        assert!(p[0] > p[1]);
    }

    #[test]
    fn argmax_topk() {
        let xs = [0.1, 0.9, 0.5, 0.7];
        assert_eq!(argmax(&xs), 1);
        assert_eq!(topk(&xs, 2), vec![1, 3]);
    }

    #[test]
    fn entropy_uniform_is_log_n() {
        let h = entropy(&[0.25; 4]);
        assert!((h - (4f32).ln()).abs() < 1e-6);
        assert_eq!(entropy(&[1.0, 0.0]), 0.0);
    }
}
