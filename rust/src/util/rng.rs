//! Seedable xorshift64* PRNG (the `rand` crate is not vendored).
//!
//! Used for workload generation, sampling under typical acceptance, and
//! the random-tree ablation.  Deterministic across platforms.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zeros fixed point
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Pick an index proportionally to `weights`.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from a probability distribution (sums to ~1).
    pub fn sample_dist(&mut self, probs: &[f32]) -> usize {
        let mut t = self.next_f64() as f32;
        for (i, p) in probs.iter().enumerate() {
            t -= p;
            if t <= 0.0 {
                return i;
            }
        }
        probs.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(3);
        for _ in 0..200 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.below(4)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }
}
