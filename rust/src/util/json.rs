//! Minimal JSON parser/serializer.
//!
//! serde is not available in the offline vendor set, and the artifact
//! interchange (configs, weight manifests, acceptance stats, traces,
//! calibration files) is all JSON, so we carry a small, strict,
//! dependency-free implementation.  Supports the full JSON grammar with
//! f64 numbers; no comments, no trailing commas.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 {
            bail!("expected integer, got {f}");
        }
        Ok(f as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Array of numbers -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of integers -> Vec<u32> (token ids).
    pub fn as_u32_vec(&self) -> Result<Vec<u32>> {
        self.as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? as u32))
            .collect()
    }

    /// 2-D array of numbers.
    pub fn as_f64_mat(&self) -> Result<Vec<Vec<f64>>> {
        self.as_arr()?.iter().map(|v| v.as_f64_vec()).collect()
    }

    // -- constructors ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                f.write_char('[')?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        f.write_char(',')?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_char(']')
            }
            Json::Obj(m) => {
                f.write_char('{')?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_char(',')?;
                    }
                    write_escaped(f, k)?;
                    f.write_char(':')?;
                    write!(f, "{v}")?;
                }
                f.write_char('}')
            }
        }
    }
}

fn write_escaped(f: &mut std::fmt::Formatter<'_>, s: &str) -> std::fmt::Result {
    f.write_char('"')?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => f.write_char(c)?,
        }
    }
    f.write_char('"')
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let n: f64 = s
            .parse()
            .map_err(|_| anyhow!("invalid number '{s}' at byte {start}"))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| anyhow!("bad \\u escape '{hex}'"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through (c already consumed)
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize().unwrap(), 2);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"m":[[0.5,0.25],[0.1,0.2]],"name":"ppd-m","n":512}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let m = j.get("m").unwrap().as_f64_mat().unwrap();
        assert_eq!(m[0][1], 0.25);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let j = Json::parse(r#"{"x": 1.5}"#).unwrap();
        assert!(j.get("x").unwrap().as_usize().is_err());
        assert!(j.req("missing").is_err());
        assert!(j.get("x").unwrap().as_str().is_err());
    }

    #[test]
    fn serializes_escapes() {
        let j = Json::Str("a\"b\\c\n".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\n""#);
    }

    #[test]
    fn long_string_fast_path() {
        let s = "x".repeat(10_000);
        let j = Json::parse(&format!("\"{s}\"")).unwrap();
        assert_eq!(j.as_str().unwrap().len(), 10_000);
    }
}
