//! `ppd` — CLI for the PPD serving stack.
//!
//! Subcommands:
//!   info                               artifact inventory
//!   generate --model M --engine E      one generation, timed
//!   serve --model M --port P           TCP line-protocol server
//!   calibrate --model M [--force]      measure L_fp(n) per bucket
//!   sweep --model M                    hardware-aware tree-size curve
//!   trees --model M                    print the dynamic tree set
//!
//! (clap is not in the offline vendor set; flags are parsed by hand.)

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use ppd::config::{ArtifactPaths, ModelConfig, ServeConfig};
use ppd::coordinator::{build_engine, Coordinator, EngineKind, SchedPolicy};
use ppd::decoding::DecodeEngine;
use ppd::runtime::Device;
use ppd::runtime::calibrate::Calibration;
use ppd::runtime::Runtime;
use ppd::tree::builder::AcceptStats;
use ppd::tree::dynamic::DynamicTreeSet;
use ppd::tree::hardware::{default_budgets, sweep};
use ppd::util::bench::Table;
use ppd::workload;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = HashMap::new();
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                bail!("unexpected argument '{a}'");
            };
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if matches!(
                name,
                "force"
                    | "greedy"
                    | "fuse-steps"
                    | "shared-runtime"
                    | "pipelined"
                    | "trace-sample"
                    | "stream"
            ) {
                flags.insert(name.to_string(), "true".to_string());
            } else {
                let v = it.next().ok_or_else(|| anyhow!("--{name} needs a value"))?;
                flags.insert(name.to_string(), v);
            }
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn model(&self) -> String {
        self.get("model").unwrap_or("ppd-m").to_string()
    }

    fn artifacts(&self) -> PathBuf {
        PathBuf::from(self.get("artifacts").unwrap_or("artifacts"))
    }

    fn serve_cfg(&self) -> Result<ServeConfig> {
        let mut cfg = ServeConfig::default();
        if let Some(t) = self.get("temp") {
            cfg.temperature = t.parse().context("--temp")?;
        }
        if let Some(n) = self.get("candidates") {
            cfg.n_candidates = n.parse().context("--candidates")?;
        }
        if let Some(n) = self.get("prompt-budget") {
            cfg.n_prompt_budget = n.parse().context("--prompt-budget")?;
        }
        if let Some(n) = self.get("max-new") {
            cfg.max_new_tokens = n.parse().context("--max-new")?;
        }
        Ok(cfg)
    }
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "info" => cmd_info(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "calibrate" => cmd_calibrate(&args),
        "sweep" => cmd_sweep(&args),
        "trees" => cmd_trees(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "ppd — Hardware-Aware Parallel Prompt Decoding (EMNLP 2025 reproduction)\n\n\
         USAGE: ppd <command> [--flag value ...]\n\n\
         COMMANDS\n\
           info        list artifact models and configs\n\
           generate    --model M --engine {{{}}} --prompt TEXT [--max-new N] [--temp T]\n\
           serve       --model M [--port 7878] [--engine ppd] [--workers N]\n\
                       [--max-inflight 4] [--max-queue-age-ms MS] [--fuse-steps]\n\
                       [--shared-runtime] [--pipelined] [--trace-sample]\n\
                       [--kv-blocks N] [--sched-policy fifo|slo] [--stream]\n\
                       continuous batching: each worker interleaves up to\n\
                       --max-inflight sequences one decode step at a time;\n\
                       --fuse-steps batches every in-flight tree step into\n\
                       one forward_batch device call per tick;\n\
                       --shared-runtime routes ALL workers' ticks through\n\
                       one device dispatcher: 1 device call per wall tick;\n\
                       --pipelined overlaps host planning/admission with\n\
                       device execution (double-buffered dispatcher);\n\
                       --trace-sample records request-lifecycle spans into\n\
                       the bounded flight recorder (snapshot via the TCP\n\
                       `trace` request; load the JSON in Perfetto);\n\
                       --kv-blocks switches the KV cache to fixed-size\n\
                       pages with a hard budget of N live pages: shared\n\
                       prompt prefixes are prefilled once and referenced\n\
                       copy-on-write, raising concurrency per byte;\n\
                       --sched-policy slo replaces FIFO pickup with\n\
                       priority classes, per-tenant fairness, and\n\
                       shortest-remaining-first (plus per-request\n\
                       deadline_ms expiry at admission);\n\
                       --stream makes v2 requests default to streamed\n\
                       newline-delimited response events\n\
           calibrate   --model M [--force]  measure per-bucket forward latency\n\
           sweep       --model M            theoretical-speedup curve vs tree size\n\
           trees       --model M            print the dynamic sparse tree set\n\n\
         COMMON FLAGS\n\
           --artifacts DIR   artifact root (default: artifacts)\n\
           --candidates N / --prompt-budget N   tree budgets",
        EngineKind::all().join("|")
    );
}

fn cmd_info(args: &Args) -> Result<()> {
    let root = args.artifacts();
    let manifest = ppd::runtime::load_manifest(&root)?;
    let mut table = Table::new(&["model", "params", "P_tr %", "layers", "d", "ctx", "buckets", "medusa"]);
    for m in manifest.req("models")?.as_arr()? {
        let name = m.as_str()?;
        let cfg = ModelConfig::load(&root.join(name))?;
        table.row(&[
            cfg.name.clone(),
            format!("{}", cfg.param_count),
            format!("{:.5}", 100.0 * cfg.trainable_fraction()),
            format!("{}", cfg.n_layers),
            format!("{}", cfg.d_model),
            format!("{}", cfg.max_ctx),
            format!("{:?}", cfg.buckets),
            format!("{}", cfg.medusa),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let root = args.artifacts();
    let model = args.model();
    let kind = EngineKind::parse(args.get("engine").unwrap_or("ppd"))?;
    let cfg = args.serve_cfg()?;
    let prompt_text = args
        .get("prompt")
        .unwrap_or("user: what is your favorite color?\nassistant:");
    let max_new: usize = args.get("max-new").unwrap_or("64").parse()?;

    let paths = ArtifactPaths::new(root.clone(), &model);
    let rt = Runtime::load(&paths)?;
    let draft = match kind {
        EngineKind::Spec | EngineKind::SpecPpd => {
            let dm = args.get("draft").unwrap_or("ppd-d");
            Some(Runtime::load(&ArtifactPaths::new(root.clone(), dm))?)
        }
        _ => None,
    };
    let mut engine =
        build_engine(kind, &rt, draft.as_ref().map(|d| d as &dyn Device), &paths, &cfg, 0)?;
    let prompt = workload::encode(prompt_text);
    let r = engine.generate(&prompt, max_new)?;
    println!("── {} | {} ──", rt.cfg.name, engine.name());
    println!("{}", workload::decode(&r.tokens));
    println!("──");
    println!(
        "tokens={} steps={} tau={:.2} prefill={:.3}s decode={:.3}s throughput={:.1} tok/s",
        r.tokens.len(),
        r.steps,
        r.tau(),
        r.prefill_s,
        r.decode_s,
        r.throughput()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let port: u16 = args.get("port").unwrap_or("7878").parse()?;
    let kind = EngineKind::parse(args.get("engine").unwrap_or("ppd"))?;
    let workers: usize = args.get("workers").unwrap_or("1").parse().context("--workers")?;
    let mut policy = SchedPolicy::default();
    if let Some(m) = args.get("max-inflight") {
        policy.max_inflight = m.parse().context("--max-inflight")?;
    }
    if let Some(ms) = args.get("max-queue-age-ms") {
        let ms: u64 = ms.parse().context("--max-queue-age-ms")?;
        policy.max_queue_age = Some(std::time::Duration::from_millis(ms));
    }
    if let Some(b) = args.get("kv-blocks") {
        policy.kv_blocks = Some(b.parse().context("--kv-blocks")?);
    }
    policy.fuse_steps = args.get("fuse-steps").is_some();
    policy.shared_runtime = args.get("shared-runtime").is_some();
    policy.pipelined = args.get("pipelined").is_some();
    if let Some(p) = args.get("sched-policy") {
        policy.sched_policy = ppd::coordinator::QueueDiscipline::parse(p).context("--sched-policy")?;
    }
    policy.stream = args.get("stream").is_some();
    if policy.pipelined && !policy.shared_runtime {
        return Err(anyhow::anyhow!("--pipelined requires --shared-runtime"));
    }
    let draft = match kind {
        EngineKind::Spec | EngineKind::SpecPpd => Some(args.get("draft").unwrap_or("ppd-d").to_string()),
        _ => None,
    };
    let coord = Coordinator::spawn_with_policy(
        args.artifacts(),
        args.model(),
        draft,
        kind,
        args.serve_cfg()?,
        workers,
        policy,
    )?;
    if args.get("trace-sample").is_some() {
        // flip the flight recorder's sampling gate: lifecycle spans land
        // in the bounded per-track rings and the TCP `trace` request
        // returns a Chrome trace snapshot.  Off (the default) the
        // instrumentation costs one relaxed atomic load per site.
        coord.tracer().set_enabled(true);
    }
    let max = args.get("max-requests").map(|m| m.parse()).transpose()?;
    ppd::coordinator::server::serve(coord, &format!("127.0.0.1:{port}"), max)
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let paths = ArtifactPaths::new(args.artifacts(), &args.model());
    let rt = Runtime::load(&paths)?;
    let cal_path = paths.calibration();
    if args.get("force").is_some() && cal_path.exists() {
        std::fs::remove_file(&cal_path)?;
    }
    let cal = Calibration::load_or_measure(&rt, &cal_path, 12)?;
    let mut t = Table::new(&["bucket", "L_fp (ms)"]);
    for (b, l) in &cal.latency_s {
        t.row(&[format!("{b}"), format!("{:.2}", l * 1e3)]);
    }
    t.print();
    println!("saved to {}", cal_path.display());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let paths = ArtifactPaths::new(args.artifacts(), &args.model());
    let rt = Runtime::load(&paths)?;
    let cal = Calibration::load_or_measure(&rt, &paths.calibration(), 12)?;
    let stats = AcceptStats::load(&paths.accept_stats(None), "ppd")?;
    let model = sweep(&stats, rt.cfg.n_prompt, &default_budgets(), &cal, 10)?;
    let mut t = Table::new(&["budget", "n_c", "n_p", "input", "tau", "L_fp ms", "speedup"]);
    for p in &model.points {
        t.row(&[
            format!("{}", p.total_budget),
            format!("{}", p.n_candidates),
            format!("{}", p.n_prompt),
            format!("{}", p.input_len),
            format!("{:.3}", p.tau),
            format!("{:.2}", p.latency_s * 1e3),
            format!("{:.3}", p.speedup),
        ]);
    }
    t.print();
    let best = model.best().unwrap();
    println!("optimal: budget={} (theoretical speedup {:.2}x)", best.total_budget, best.speedup);
    Ok(())
}

fn cmd_trees(args: &Args) -> Result<()> {
    let paths = ArtifactPaths::new(args.artifacts(), &args.model());
    let cfg = ModelConfig::load(&paths.model_dir())?;
    let stats = AcceptStats::load(&paths.accept_stats(None), "ppd")?;
    let sc = args.serve_cfg()?;
    let set = DynamicTreeSet::build(&stats, cfg.n_prompt, sc.n_candidates, sc.n_prompt_budget, sc.top_r)?;
    println!(
        "dynamic tree set: n_c={} n_p<={} tau={:.3} S_tr={:?} steady={:?}",
        set.n_candidates,
        set.n_prompt_budget,
        set.tau(),
        set.size_tuple(),
        set.steady.iter().map(|p| (p * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );
    for (k, tree) in set.trees.iter().enumerate() {
        println!(
            "  T_{k}: candidates={} prompts={} input_len={} f={:.3}",
            tree.n_candidates(),
            tree.n_prompt(),
            tree.input_len(),
            set.f[k]
        );
    }
    Ok(())
}
