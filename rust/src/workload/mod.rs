//! Workloads: serving traces written by the build-time python
//! (`artifacts/traces/*.json`) plus a rust-native synthetic generator
//! for load tests where the trace pool is too small.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// One request: a prompt and (for quality checks) the reference
/// continuation the corpus generator produced.
#[derive(Debug, Clone)]
pub struct TraceItem {
    pub prompt: Vec<u32>,
    pub reference: Vec<u32>,
}

/// Load a task trace (chat/math/code).
pub fn load_trace(path: &Path) -> Result<Vec<TraceItem>> {
    let j = Json::from_file(path).with_context(|| format!("loading trace {}", path.display()))?;
    let mut out = Vec::new();
    for item in j.as_arr()? {
        out.push(TraceItem {
            prompt: item.req("prompt")?.as_u32_vec()?,
            reference: item.req("reference")?.as_u32_vec()?,
        });
    }
    if out.is_empty() {
        bail!("empty trace {}", path.display());
    }
    Ok(out)
}

/// Load the validation token stream (REST datastore, accuracy evals).
pub fn load_val_stream(root: &Path) -> Result<Vec<u32>> {
    Json::from_file(&root.join("traces").join("val_ids.json"))?.as_u32_vec()
}

/// Rust-native synthetic prompt generator mirroring the corpus grammar
/// (byte-level).  Used by the server example for open-ended load.
pub struct WorkloadGen {
    rng: Rng,
}

const SUBJECTS: &[&str] = &["the sky", "a river", "the moon", "a forest", "the ocean"];
const ADJECTIVES: &[&str] = &["blue", "calm", "bright", "green", "vast"];
const TOPICS: &[&str] = &["color", "place", "season", "animal"];

impl WorkloadGen {
    pub fn new(seed: u64) -> Self {
        WorkloadGen { rng: Rng::new(seed) }
    }

    fn zipf<'a>(&mut self, items: &[&'a str]) -> &'a str {
        let weights: Vec<f64> = (0..items.len()).map(|i| 1.0 / (i + 1) as f64).collect();
        items[self.rng.weighted(&weights)]
    }

    pub fn chat_prompt(&mut self) -> Vec<u32> {
        let t = self.zipf(TOPICS);
        let a = self.zipf(ADJECTIVES);
        let s = self.zipf(SUBJECTS);
        let text = format!(
            "user: what is your favorite {t}?\nassistant: my favorite {t} is {a} because it reminds me of {s}.\nuser: which {t} do you like the most?\nassistant:"
        );
        encode(&text)
    }

    pub fn math_prompt(&mut self) -> Vec<u32> {
        let a = self.rng.range(2, 99);
        let b = self.rng.range(2, 99);
        let text = format!("calc: {a} + {b} = {} ; calc: {} + {} = ", a + b, a + 1, b);
        encode(&text)
    }

    pub fn code_prompt(&mut self) -> Vec<u32> {
        let text = "def add_a_b(a, b):\n    result = a + b\n    return result\n\ndef add_x_y(x, y):\n";
        encode(text)
    }

    /// A long-document summarization prompt: several generated
    /// sentences followed by a summarize instruction — the long-prompt
    /// / short-output end of the serving mix.
    pub fn summarize_prompt(&mut self) -> Vec<u32> {
        let n_sentences = self.rng.range(3, 7);
        let mut doc = String::new();
        for _ in 0..n_sentences {
            let s = self.zipf(SUBJECTS);
            let a = self.zipf(ADJECTIVES);
            doc.push_str(&format!("{s} looked {a} that day. "));
        }
        encode(&format!("document: {doc}\nsummarize the document in one line:\n"))
    }

    pub fn mixed_prompt(&mut self) -> Vec<u32> {
        match self.rng.below(3) {
            0 => self.chat_prompt(),
            1 => self.math_prompt(),
            _ => self.code_prompt(),
        }
    }

    /// A trace-driven serving mix: `n` requests with bursty arrivals
    /// (geometric gaps punctuated by zero-gap bursts), long-tail output
    /// lengths (an occasional request asks for 4× the budget), and a
    /// chat-heavy chat/summarize/code blend.  Deterministic in the
    /// generator's seed, so bench sweeps and the SLO scheduler see the
    /// same offered load run over run.
    pub fn mix_trace(&mut self, n: usize) -> Vec<MixItem> {
        let mut t_ms = 0u64;
        (0..n)
            .map(|_| {
                // ~1 in 4 requests arrives in a burst with no gap
                let gap = if self.rng.below(4) == 0 {
                    0
                } else {
                    4 + self.rng.below(40) as u64
                };
                t_ms += gap;
                let kind = match self.rng.weighted(&[0.6, 0.25, 0.15]) {
                    0 => MixKind::Chat,
                    1 => MixKind::Summarize,
                    _ => MixKind::Code,
                };
                let prompt = match kind {
                    MixKind::Chat => self.chat_prompt(),
                    MixKind::Summarize => self.summarize_prompt(),
                    MixKind::Code => self.code_prompt(),
                };
                let base = match kind {
                    // interactive turns are short; summaries shorter
                    // still; code completions run longer
                    MixKind::Chat => 6,
                    MixKind::Summarize => 4,
                    MixKind::Code => 8,
                };
                // long-tail output lengths: 1 in 8 requests wants 4×
                let max_new = if self.rng.below(8) == 0 { base * 4 } else { base };
                MixItem { kind, prompt, max_new, arrival_ms: t_ms }
            })
            .collect()
    }
}

/// Task class of one [`MixItem`].  The bench layer maps classes to SLO
/// priorities/tenants; the workload layer stays independent of the
/// coordinator's types.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MixKind {
    Chat,
    Summarize,
    Code,
}

impl MixKind {
    pub fn as_str(self) -> &'static str {
        match self {
            MixKind::Chat => "chat",
            MixKind::Summarize => "summarize",
            MixKind::Code => "code",
        }
    }
}

/// One request of a trace-driven serving mix ([`WorkloadGen::mix_trace`]):
/// what to ask, how much to generate, and when it arrives relative to
/// the trace start.
#[derive(Debug, Clone)]
pub struct MixItem {
    pub kind: MixKind,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// arrival offset from the trace start, in milliseconds
    pub arrival_ms: u64,
}

/// Byte-level encode (identity over ASCII).
pub fn encode(text: &str) -> Vec<u32> {
    text.bytes().filter(|&b| b < 128).map(|b| b as u32).collect()
}

/// Byte-level decode for display.
pub fn decode(tokens: &[u32]) -> String {
    tokens
        .iter()
        .filter_map(|&t| {
            if (32..128).contains(&t) || t == 9 || t == 10 {
                Some(t as u8 as char)
            } else {
                None
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let s = "calc: 1 + 2 = 3 ;\n";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn encode_drops_non_ascii() {
        assert_eq!(encode("a\u{00e9}b").len(), 2);
    }

    #[test]
    fn generator_is_deterministic() {
        let mut a = WorkloadGen::new(5);
        let mut b = WorkloadGen::new(5);
        assert_eq!(a.chat_prompt(), b.chat_prompt());
        assert_eq!(a.math_prompt(), b.math_prompt());
    }

    #[test]
    fn prompts_are_ascii_tokens() {
        let mut g = WorkloadGen::new(1);
        for _ in 0..10 {
            assert!(g.mixed_prompt().iter().all(|&t| t < 128));
        }
    }

    #[test]
    fn mix_trace_is_deterministic_and_bursty() {
        let a = WorkloadGen::new(11).mix_trace(64);
        let b = WorkloadGen::new(11).mix_trace(64);
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.kind, y.kind);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
            assert_eq!(x.arrival_ms, y.arrival_ms);
        }
        // arrivals are monotone, and bursts (zero gaps) happen
        assert!(a.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        assert!(a.windows(2).any(|w| w[0].arrival_ms == w[1].arrival_ms));
        // the blend covers every class and the length tail fires
        for kind in [MixKind::Chat, MixKind::Summarize, MixKind::Code] {
            assert!(a.iter().any(|i| i.kind == kind), "missing {kind:?}");
        }
        assert!(a.iter().any(|i| i.max_new >= 16), "no long-tail request");
        assert!(a.iter().all(|i| !i.prompt.is_empty()));
    }

    #[test]
    fn trace_loader_parses() {
        let dir = std::env::temp_dir().join("ppd_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        std::fs::write(&p, r#"[{"prompt":[1,2,3],"reference":[4,5]}]"#).unwrap();
        let t = load_trace(&p).unwrap();
        assert_eq!(t[0].prompt, vec![1, 2, 3]);
        assert_eq!(t[0].reference, vec![4, 5]);
        std::fs::write(&p, "[]").unwrap();
        assert!(load_trace(&p).is_err());
    }
}
